"""The SA-backed training data plane: streaming dedup byte-identity +
one-build-per-shard, the contamination gate's guarantees (100% planted
recall, 0 false positives on a disjoint control set), probe metrics, and
a subprocess train-smoke that sees gate/probe numbers in the step report."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.api import SegmentedIndex, SuffixArrayIndex, builder_cache_stats
from repro.data.pipeline import (ContaminationGate, PipelineConfig,
                                 TrainingDataPlane, synthetic_corpus,
                                 synthetic_doc_shards)
from repro.text.dedup import dedup_docs

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                   "src"))
VOCAB = 64
MIN_LEN = 24


def _builds() -> int:
    s = builder_cache_stats()
    return s["hits"] + s["misses"]


def make_shards(n_chars=50_000, shard_docs=5, doc_len=1200, dup=0.4, seed=3):
    return synthetic_doc_shards(n_chars, VOCAB, shard_docs=shard_docs,
                                doc_len=doc_len, dup_fraction=dup, seed=seed)


# ---------------------------------------------------------- streaming dedup
@pytest.mark.parametrize("shard_docs", [1, 4, 16])
def test_streaming_dedup_byte_identical_to_monolithic(shard_docs):
    """The acceptance bar: any sharding of the same corpus streams to the
    exact bytes the whole-corpus `dedup_docs` pass produces."""
    shards = make_shards(shard_docs=shard_docs)
    docs = [d for s in shards for d in s]
    plane = TrainingDataPlane(
        PipelineConfig(dedup=True, dedup_min_len=MIN_LEN, vocab=VOCAB),
        shards=shards)
    mono, rep = dedup_docs(docs, min_len=MIN_LEN, sigma=VOCAB)
    assert rep.dropped_chars > 0            # the corpus has real duplicates
    assert len(plane._kept) == len(mono)
    for a, b in zip(plane._kept, mono):
        assert np.array_equal(a, b)
    assert plane.report.dropped_chars == rep.dropped_chars
    assert plane.report.kept_chars == sum(len(d) for d in mono)


def test_streaming_dedup_one_segment_build_per_shard():
    """Ingest cost contract, measured via builder-cache deltas: each shard
    is exactly ONE new-segment build — prior-shard matching is pure
    queries, never a rebuild."""
    shards = make_shards(shard_docs=4)
    plane = TrainingDataPlane(
        PipelineConfig(dedup=True, dedup_min_len=MIN_LEN, vocab=VOCAB))
    for shard in shards:
        before = _builds()
        st = plane.ingest_shard(shard)
        assert _builds() - before == 1
        assert st.builds == 1
    assert plane.report.builds == len(shards)
    assert len(plane.index.segments) == len(shards)


def test_streaming_dedup_cross_shard_only_duplicates():
    """A shard that repeats ONLY prior-shard content dedups to nothing but
    its unique tail — via containment queries, not adjacency."""
    rng = np.random.default_rng(7)
    a = rng.integers(0, VOCAB, 2000)
    fresh = rng.integers(0, VOCAB, 100)
    plane = TrainingDataPlane(
        PipelineConfig(dedup=True, dedup_min_len=MIN_LEN, vocab=VOCAB))
    plane.ingest_shard([a])
    st = plane.ingest_shard([np.concatenate([a[500:800], fresh])])
    assert st.prior_hits > 0 and st.dropped_chars >= 300
    assert np.array_equal(plane._kept[1], fresh)


def test_plane_without_dedup_keeps_raw_bytes():
    shards = make_shards(shard_docs=4, dup=0.0)
    plane = TrainingDataPlane(PipelineConfig(vocab=VOCAB), shards=shards)
    assert plane.index is None
    assert plane.report.dropped_chars == 0
    assert plane.n == sum(len(d) for s in shards for d in s)


# ------------------------------------------------------- contamination gate
def eval_and_control():
    """Eval docs over symbols [0, 32); control windows over [32, 64) —
    provably zero overlap, so any control hit is a false positive."""
    rng = np.random.default_rng(11)
    eval_docs = [rng.integers(0, 32, 2000) for _ in range(3)]
    control = rng.integers(32, 64, size=(16, 3 * MIN_LEN))
    return eval_docs, control


def test_gate_flags_all_planted_none_disjoint():
    eval_docs, control = eval_and_control()
    gate = ContaminationGate(eval_docs, min_len=MIN_LEN, sigma=VOCAB)
    planted = control.copy()
    for i in range(len(planted)):       # plant an eval stretch ≥ min_len
        src = int(i * 37 % (len(eval_docs[0]) - MIN_LEN))
        planted[i, 5:5 + MIN_LEN] = eval_docs[0][src:src + MIN_LEN]
    hits_p, mask_p = gate.check(planted)
    hits_c, mask_c = gate.check(control)
    assert (hits_p > 0).all()           # 100% of planted overlaps flagged
    assert (hits_c == 0).all()          # 0 false positives, disjoint set
    assert not mask_c.any()
    # the mask covers the planted chars and nothing left of them
    assert mask_p[:, 5:5 + MIN_LEN].all()
    assert not mask_p[:, :5].any()


def test_gate_reject_policy_resamples_deterministically():
    eval_docs, _ = eval_and_control()
    # training corpus heavily contaminated → rejections guaranteed
    rng = np.random.default_rng(12)
    doc = rng.integers(32, 64, 6000)
    doc[1000:3000] = np.concatenate([eval_docs[0], eval_docs[0]])[:2000]
    cfg = PipelineConfig(seq_len=48, global_batch=8, gate_min_len=MIN_LEN,
                         gate_policy="reject", vocab=VOCAB, seed=5)
    p1 = TrainingDataPlane(cfg, eval_docs=eval_docs, shards=[[doc]])
    p2 = TrainingDataPlane(cfg, eval_docs=eval_docs, shards=[[doc]])
    for step in range(4):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        assert np.array_equal(b1["tokens"], b2["tokens"])
        assert np.array_equal(b1["loss_mask"], b2["loss_mask"])
    assert p1.gate.stats["rejected_windows"] > 0
    assert p1.gate.stats == p2.gate.stats


def test_gate_mask_policy_zeroes_contaminated_targets():
    eval_docs, _ = eval_and_control()
    rng = np.random.default_rng(13)
    doc = rng.integers(32, 64, 4000)
    doc[:2000] = eval_docs[0]           # first half is pure eval text
    cfg = PipelineConfig(seq_len=48, global_batch=16, gate_min_len=MIN_LEN,
                         gate_policy="mask", vocab=VOCAB)
    plane = TrainingDataPlane(cfg, eval_docs=eval_docs, shards=[[doc]])
    b = plane.batch_at(0)
    assert b["loss_mask"].shape == (16, 48)
    assert b["loss_mask"].dtype == np.float32
    assert plane.gate.stats["masked_windows"] > 0
    # a fully-contaminated window trains on zero targets
    full = plane.gate.check(doc[None, :49])[0]
    assert full[0] > 0
    masked = plane.batch_at(0)["loss_mask"]
    assert masked.min() == 0.0 or plane.gate.stats["masked_windows"] > 0


def test_gate_mask_feeds_loss_and_masked_frac_metric():
    """loss_mask flows batch → lm_loss → chunked xent; masked targets
    change the loss and surface as the masked_frac metric."""
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models.lm import lm_init, lm_loss
    from repro.train.optim import OptConfig
    from repro.train.train_step import (TrainConfig, make_train_state,
                                        make_train_step)
    cfg = get_config("minicpm_2b").smoke()
    params, _ = lm_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (2, 17)).astype(np.int32)
    full = {"tokens": toks, "loss_mask": np.ones((2, 16), np.float32)}
    half_mask = np.ones((2, 16), np.float32)
    half_mask[:, 8:] = 0.0
    half = {"tokens": toks, "loss_mask": half_mask}
    l_full, m_full = lm_loss(params, cfg, full)
    l_half, m_half = lm_loss(params, cfg, half)
    assert float(m_full["tokens"]) == 32 and float(m_half["tokens"]) == 16
    assert not np.isclose(float(l_full), float(l_half))
    step = jax.jit(make_train_step(cfg, TrainConfig(opt=OptConfig())))
    state = make_train_state(params, TrainConfig(opt=OptConfig()))
    _, metrics = step(state, half)
    assert np.isclose(float(metrics["masked_frac"]), 0.5)
    assert np.isfinite(float(metrics["loss"]))


# ----------------------------------------------------------- probe metrics
def test_longest_match_monolithic_and_segmented():
    rng = np.random.default_rng(21)
    docs = [rng.integers(0, VOCAB, 1500) for _ in range(4)]
    mono = SuffixArrayIndex.from_docs(docs, sigma=VOCAB)
    seg = SegmentedIndex.from_docs(docs, segment_docs=2, sigma=VOCAB)
    verbatim = docs[1][200:500]
    fresh = rng.integers(0, VOCAB, 300)
    for idx in (mono, seg):
        assert idx.longest_match(verbatim) == 300
        assert idx.longest_match(fresh) < MIN_LEN
        assert idx.longest_match(np.zeros(0, np.int64)) == 0
        # out-of-alphabet symbols never match (generated tokens may
        # exceed the corpus alphabet)
        weird = np.concatenate([verbatim[:50], [VOCAB + 7], verbatim[:50]])
        assert idx.longest_match(weird) == 50


def test_plane_probe_reports_copy_metrics():
    shards = make_shards(shard_docs=4)
    plane = TrainingDataPlane(
        PipelineConfig(dedup=True, dedup_min_len=MIN_LEN, vocab=VOCAB),
        shards=shards)
    excerpt = shards[0][0][100:340]     # raw doc slice — what the index holds
    fresh = np.random.default_rng(22).integers(0, VOCAB, 240)
    m = plane.probe([excerpt, fresh], min_len=100)
    assert m["samples"] == 2
    assert m["longest_copy_max"] >= 240
    assert m["frac_memorized"] == 0.5
    with pytest.raises(RuntimeError):
        TrainingDataPlane(PipelineConfig(vocab=VOCAB)).probe([excerpt])


# ------------------------------------------------ legacy facade + launcher
def test_token_pipeline_facade_matches_legacy_batching():
    """dedup=False batches are the historical pure-(seed, step) windows
    over the raw corpus — resume determinism unchanged."""
    corpus = synthetic_corpus(16_000, vocab=VOCAB, seed=1)
    from repro.data.pipeline import TokenPipeline
    pipe = TokenPipeline(corpus, PipelineConfig(seq_len=32, global_batch=4,
                                                seed=9))
    assert np.array_equal(pipe.corpus, corpus)
    rng = np.random.default_rng(np.random.SeedSequence([9, 3]))
    starts = rng.integers(0, max(1, len(corpus) - 33), size=4)
    want = np.stack([corpus[s:s + 33] for s in starts])
    got = pipe.batch_at(3)
    assert set(got) == {"tokens"}
    assert np.array_equal(got["tokens"], want)


def test_train_smoke_subprocess_gate_and_probe_in_report():
    """The CI train-smoke path: planted contamination must surface as
    rejected windows, the probe must log copy metrics, loss stays finite."""
    code = textwrap.dedent("""
    import json, math
    from repro.launch.train import main
    m = main(["--arch", "minicpm-2b", "--smoke", "--steps", "4",
              "--seq-len", "48", "--batch", "4", "--corpus-chars", "30000",
              "--doc-len", "1500", "--shard-docs", "5", "--dedup",
              "--dedup-min-len", "24", "--eval-gate", "--gate-min-len", "24",
              "--plant-contamination", "40", "--probe-every", "2",
              "--probe-len", "8", "--log-every", "2"])
    assert m["gate"]["rejected_windows"] > 0, m
    assert m["probe"]["samples"] > 0, m
    assert math.isfinite(m["loss"]), m
    assert m["dedup"]["builds"] == m["dedup"]["shards"] > 1, m
    print("TRAIN_SMOKE_OK", json.dumps(m))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=420)
    assert "TRAIN_SMOKE_OK" in r.stdout, (r.stdout[-2000:], r.stderr[-3000:])
    # gate + probe numbers appear in the human step report too
    assert "gate[rej" in r.stdout and "copy[max" in r.stdout
