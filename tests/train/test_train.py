"""Training substrate tests: convergence, schedules, checkpoint/restore,
elastic reshard, gradient compression error feedback."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.ckpt.checkpoint import (latest_step, restore_checkpoint,
                                   save_checkpoint)
from repro.data.pipeline import (PipelineConfig, TokenPipeline,
                                 synthetic_corpus)
from repro.models.lm import lm_init
from repro.train.optim import (OptConfig, clip_by_global_norm,
                               compressed_grads_with_feedback, global_norm)
from repro.train.schedule import cosine_schedule, wsd_schedule
from repro.train.train_step import (TrainConfig, make_train_state,
                                    make_train_step)


def _setup(vocab=64, opt="adamw", lr=3e-3, **tkw):
    cfg = get_config("minicpm_2b").smoke().replace(vocab_size=vocab)
    params, _ = lm_init(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(opt=OptConfig(name=opt, lr=lr), warmup=5,
                       total_steps=60, **tkw)
    state = make_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    pipe = TokenPipeline(synthetic_corpus(16000, vocab=vocab, seed=1),
                         PipelineConfig(seq_len=32, global_batch=8))
    return cfg, state, step, pipe


def test_loss_decreases():
    _, state, step, pipe = _setup()
    losses = []
    for i in range(40):
        state, m = step(state, pipe.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.8 * losses[0]
    assert all(np.isfinite(losses))


def test_wsd_schedule_shape():
    base, warm, total = 1.0, 10, 100
    s = lambda t: float(wsd_schedule(jnp.asarray(t, jnp.float32),
                                     base_lr=base, warmup=warm, total=total))
    assert s(0) == 0.0
    assert abs(s(10) - 1.0) < 1e-6
    assert abs(s(50) - 1.0) < 1e-6           # stable plateau
    assert s(95) < 0.6                        # decay phase
    assert s(100) <= 0.011
    c = lambda t: float(cosine_schedule(jnp.asarray(t, jnp.float32),
                                        base_lr=base, warmup=warm,
                                        total=total))
    assert c(55) > c(90)


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4
    assert float(norm) > 1.0


def test_compression_error_feedback_preserves_mean():
    """Error feedback: accumulated quantised grads ≈ accumulated true grads."""
    rng = np.random.default_rng(0)
    true = [{"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
            for _ in range(50)]
    err = {"w": jnp.zeros(32)}
    acc_q = jnp.zeros(32)
    for g in true:
        q, err = compressed_grads_with_feedback(g, err)
        acc_q = acc_q + q["w"]
    acc_t = sum(g["w"] for g in true)
    rel = float(jnp.linalg.norm(acc_q - acc_t) / jnp.linalg.norm(acc_t))
    assert rel < 0.05


def test_checkpoint_resume_bitexact():
    """Fault tolerance: train 10, crash, restore, continue 10 == train 20."""
    _, state, step, pipe = _setup()
    s = state
    for i in range(10):
        s, _ = step(s, pipe.batch_at(i))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 10, s)
        assert latest_step(d) == 10
        restored, _ = restore_checkpoint(d, 10, s)
    a = s
    b = restored
    for i in range(10, 20):
        a, _ = step(a, pipe.batch_at(i))
        b, _ = step(b, pipe.batch_at(i))
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert np.array_equal(np.asarray(la), np.asarray(lb))


def test_elastic_reshard_roundtrip():
    """Restore a checkpoint onto a different device layout (subprocess with
    8 fake devices shards it; values must be identical)."""
    import subprocess
    import sys
    import textwrap
    cfg, state, step, pipe = _setup()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 5, {"params": state["params"]})
        code = textwrap.dedent(f"""
        import jax, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models.lm import lm_init
        from repro.ckpt.checkpoint import restore_checkpoint
        cfg = get_config("minicpm_2b").smoke().replace(vocab_size=64)
        params, _ = lm_init(jax.random.PRNGKey(0), cfg)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        sh = jax.tree_util.tree_map(
            lambda l: NamedSharding(mesh, P()), params)
        restored, _ = restore_checkpoint({d!r}, 5, {{"params": params}},
                                         shardings={{"params": sh}})
        leaves = jax.tree_util.tree_leaves(restored)
        assert all(len(l.sharding.device_set) >= 1 for l in leaves)
        print("RESHARD_OK")
        """)
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "..", "src"))
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=300)
        assert "RESHARD_OK" in r.stdout, r.stderr[-2000:]


def test_pipeline_deterministic_resume():
    pipe = TokenPipeline(synthetic_corpus(5000, seed=7),
                         PipelineConfig(seq_len=16, global_batch=4, seed=3))
    a = pipe.batch_at(12)["tokens"]
    b = pipe.batch_at(12)["tokens"]
    assert np.array_equal(a, b)
    assert not np.array_equal(a, pipe.batch_at(13)["tokens"])


def test_dedup_pipeline_stage():
    corpus = synthetic_corpus(4000, dup_fraction=0.3, seed=2)
    pipe = TokenPipeline(corpus, PipelineConfig(
        seq_len=16, global_batch=2, dedup=True, dedup_min_len=48))
    assert pipe.dedup_report is not None
    assert pipe.dedup_report.dup_chars > 0
    assert pipe.n < len(corpus)
    b = pipe.batch_at(0)
    assert b["tokens"].shape == (2, 17)
