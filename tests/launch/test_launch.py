"""Launch-layer tests: spec construction for every cell, HLO collective
parser, and a true (tiny-mesh) lowering in a subprocess."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs import get_config, model_archs
from repro.models.config import SHAPES

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                   "src"))
RESULTS = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                       "results", "dryrun"))


def test_cell_applicability_table():
    from repro.launch.specs import cell_runs
    runs = sum(cell_runs(get_config(a), s)
               for a in model_archs() for s in SHAPES)
    assert runs == 35          # 40 − 5 documented long_500k skips


def test_parse_collective_bytes():
    from repro.launch.dryrun import parse_collective_bytes
    hlo = """
  %p0 = bf16[1024,512]{1,0} parameter(0)
  %ag = bf16[16384,512]{1,0} all-gather(%p0), dimensions={0}
  %ar = bf16[1024,512]{1,0} all-reduce(%p0), to_apply=%add
  %cp = bf16[1024,512]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
"""
    out = parse_collective_bytes(hlo)
    assert out["all-gather"] == 1024 * 512 * 2
    assert out["all-reduce"] == 1024 * 512 * 2
    assert out["collective-permute"] == 1024 * 512 * 2
    assert out["_counts"]["all-gather"] == 1


@pytest.mark.parametrize("arch", model_archs())
def test_model_flops_estimate_positive(arch):
    from repro.launch.dryrun import model_flops_estimate, \
        model_params_breakdown
    cfg = get_config(arch)
    total, active, emb = model_params_breakdown(cfg)
    assert total > active > 0 and emb > 0
    if cfg.is_moe:
        assert active < 0.6 * total
    for s in SHAPES.values():
        assert model_flops_estimate(cfg, s) > 0


def test_tiny_mesh_lowering_subprocess():
    """True .lower().compile() on an 8-device (2×4) mesh for a reduced arch
    — the fast CI version of the 512-device dry-run."""
    code = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.configs import get_config
    from repro.models.lm import lm_init
    from repro.train.train_step import TrainConfig, make_train_state, \\
        make_train_step
    from repro.train.optim import OptConfig
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("gemma3_1b").smoke().replace(n_layers=6)
    params, _ = lm_init(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(opt=OptConfig())
    state = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype,
            sharding=NamedSharding(mesh, P())),
        jax.eval_shape(lambda: make_train_state(params, tcfg)))
    batch = {"tokens": jax.ShapeDtypeStruct((8, 33), jax.numpy.int32,
             sharding=NamedSharding(mesh, P("data", None)))}
    step = make_train_step(cfg, tcfg, mesh=mesh)
    with mesh:
        compiled = jax.jit(step).lower(state, batch).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older jaxlib: one dict per device
        cost = cost[0] if cost else {}
    assert cost.get("flops", 0) > 0
    print("LOWER_OK", int(cost["flops"]))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=420)
    assert "LOWER_OK" in r.stdout, r.stderr[-3000:]


@pytest.mark.skipif(not os.path.isdir(RESULTS),
                    reason="full dry-run results not present")
def test_full_dryrun_results_all_ok():
    """Once the 512-device sweep has run, every recorded cell must be ok."""
    recs = []
    for f in os.listdir(RESULTS):
        if f.endswith(".json"):
            recs.append(json.load(open(os.path.join(RESULTS, f))))
    assert recs, "no dry-run records"
    bad = [(r["arch"], r["shape"], r["mesh"], r.get("error", ""))
           for r in recs if r["status"] != "ok"]
    assert not bad, bad
