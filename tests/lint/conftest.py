import os
import sys

# `tools.saca_lint` lives at the repo root (not under src/), mirroring how
# CI invokes it: `python -m tools.saca_lint` from the checkout root.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", ".."))
