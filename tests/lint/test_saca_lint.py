"""saca-lint tests: planted violations per rule, pragma semantics, the
empty-baseline invariant on the real tree, and the CLI contract.

Every planted line in tests/lint/fixtures/*.py carries a ``PLANT:<tag>``
(or ``PLANTED-DIVERGENT``) marker comment; tests locate lines by marker so
editing a fixture cannot silently rot the expected line numbers.
"""
import ast
import subprocess
import sys
import textwrap
from pathlib import Path

from tools import saca_lint
from tools.saca_lint import collectives
from tools.saca_lint.__main__ import main as lint_main
from tools.saca_lint.astutil import Module

REPO = Path(__file__).resolve().parents[2]
FIXTURES = Path(__file__).resolve().parent / "fixtures"
BSP = REPO / "src" / "repro" / "bsp"


def plant_lines(path: Path, needle: str = "PLANT") -> dict[str, int]:
    """marker tag -> 1-based line number."""
    out = {}
    for i, text in enumerate(path.read_text().splitlines(), start=1):
        if needle in text:
            tag = text.split(needle + ":", 1)[1].split()[0] \
                if needle + ":" in text else needle
            out[tag] = i
    return out


def found(report, fixture: Path) -> set[tuple[str, int]]:
    rel = fixture.resolve().relative_to(REPO).as_posix()
    return {(f.rule_id, f.line) for f in report.active if f.path == rel}


# ---------------------------------------------------------------------------
# the real tree: empty baseline, no active findings, justified suppressions
# ---------------------------------------------------------------------------

def test_real_tree_is_clean():
    report = saca_lint.run()
    assert report.active == [], \
        "unexpected findings:\n" + "\n".join(f.render() for f in report.active)
    assert report.stale_pragmas == []
    assert report.baselined == []
    for f in report.suppressed:
        assert f.justification, f.render()


def test_baseline_file_is_empty():
    keys = [ln for ln in saca_lint.DEFAULT_BASELINE.read_text().splitlines()
            if ln.strip() and not ln.startswith("#")]
    assert keys == []


# ---------------------------------------------------------------------------
# planted regression: divergent collective in a copy of psort_shard_body
# ---------------------------------------------------------------------------

def test_planted_psort_divergence_caught_at_line():
    fixture = FIXTURES / "psort_divergent.py"
    line = plant_lines(fixture, "PLANTED-DIVERGENT")["PLANTED-DIVERGENT"]
    report = saca_lint.run([fixture, BSP])
    assert found(report, fixture) == {("SCHED001", line)}
    # the un-planted bsp package stays clean in the same run
    assert all("psort_divergent" in f.path for f in report.active)


# ---------------------------------------------------------------------------
# one planted violation per rule
# ---------------------------------------------------------------------------

def test_sched_rules():
    fixture = FIXTURES / "sched_violations.py"
    at = plant_lines(fixture)
    report = saca_lint.run([fixture])
    assert found(report, fixture) == {
        ("SCHED001", at["SCHED001"]),
        ("SCHED001", at["SCHED001-early"]),
        ("SCHED003", at["SCHED003"]),
        ("SCHED004", at["SCHED004-host"]),
        ("SCHED004", at["SCHED004-lax"]),
    }


def test_trace_rules():
    fixture = FIXTURES / "trace_violations.py"
    at = plant_lines(fixture)
    report = saca_lint.run([fixture])
    assert found(report, fixture) == {
        ("TRACE001", at["TRACE001-counter"]),
        ("TRACE001", at["TRACE001-cache"]),
        ("TRACE002", at["TRACE002-float"]),
        ("TRACE002", at["TRACE002-asarray"]),
        ("TRACE002", at["TRACE002-item"]),
        ("TRACE003", at["TRACE003-range"]),
        ("TRACE003", at["TRACE003-if"]),
        ("TRACE003", at["TRACE003-bitlength"]),
    }


def test_sparse_kernel_rules():
    """The sparse query kernel's failure modes, planted in a mock: an
    unjustified retrace counter, a host sync on a traced reduction, and
    a data-steered loop bound — while the clean variant (the real
    kernel's shape-derived static bound + pragma'd counter) stays quiet,
    and the REAL `repro.sparse` package is clean in the same run."""
    fixture = FIXTURES / "sparse_query_violations.py"
    at = plant_lines(fixture)
    sparse_pkg = REPO / "src" / "repro" / "sparse"
    report = saca_lint.run([fixture, sparse_pkg])
    assert found(report, fixture) == {
        ("TRACE001", at["TRACE001-retrace"]),
        ("TRACE002", at["TRACE002-sync"]),
        ("TRACE003", at["TRACE003-depth"]),
    }
    assert all("sparse_query_violations" in f.path for f in report.active)
    # the real package's one suppression is justified and live
    sup = [f for f in report.suppressed if "src/repro/sparse" in f.path]
    assert [f.rule_id for f in sup] == ["TRACE001"]
    assert report.stale_pragmas == []


def test_thread_rules():
    fixture = FIXTURES / "thread_violations.py"
    at = plant_lines(fixture)
    report = saca_lint.run([fixture])
    assert found(report, fixture) == {
        ("THREAD001", at["THREAD001-flag"]),
        ("THREAD001", at["THREAD001-counter"]),
        ("THREAD001", at["THREAD001-ema"]),
        ("THREAD002", at["THREAD002-wait"]),
        ("THREAD002", at["THREAD002-notify"]),
        ("THREAD003", at["THREAD003-deque"]),
    }


# ---------------------------------------------------------------------------
# pragma semantics: justified suppresses, unjustified doesn't, stale flagged
# ---------------------------------------------------------------------------

def test_pragma_semantics():
    fixture = FIXTURES / "pragma_cases.py"
    report = saca_lint.run([fixture])

    sup = {f.justification for f in report.suppressed}
    assert len(report.suppressed) == 2
    assert any("deliberate trace counter" in j for j in sup)
    assert any("pragma on the line above" in j for j in sup)

    assert len(report.active) == 1
    assert report.active[0].rule_id == "TRACE001"
    assert "missing justification" in report.active[0].message

    assert len(report.stale_pragmas) == 1
    assert report.stale_pragmas[0].rules == ("THREAD001",)


# ---------------------------------------------------------------------------
# SCHED002: drift between source and the pinned counter contract
# ---------------------------------------------------------------------------

def test_sched002_drift_detected(tmp_path):
    src = textwrap.dedent("""\
        import jax

        def _sm1_body(x, axis):
            return jax.lax.ppermute(x, axis, [(0, 1)])

        def _sm2_body(x, axis):
            return jax.lax.all_gather(x, axis)
    """)
    mod = Module(path=tmp_path / "suffix_array.py",
                 name="repro.bsp.suffix_array",
                 tree=ast.parse(src), source=src)
    findings, _ex = collectives.analyze({mod.name: mod})
    drift = [f for f in findings if f.rule_id == "SCHED002"]
    assert drift, "schedule drift must be reported"
    msgs = " | ".join(f.message for f in drift)
    assert "counter contract" in msgs
    assert "pinned 11/9" in msgs


def test_static_schedule_matches_contract():
    report = saca_lint.run([BSP])
    assert report.active == [], \
        "\n".join(f.render() for f in report.active)
    ex = report.extractor
    expected = {
        "exchange": ["all_to_all"] * 2,
        "psort": ["all_gather", "all_to_all", "all_to_all",
                  "all_gather", "all_to_all", "all_to_all"],
        "SM1": [collectives.LABEL_KINDS[s] for s in collectives.SM1_LABELS],
        "SM2": [collectives.LABEL_KINDS[s] for s in collectives.SM2_LABELS],
    }
    for stage, want in expected.items():
        seq = ex.stage_schedule(stage)
        assert seq is not None, stage
        assert [e.kind for e in seq] == want, stage
    assert len(ex.stage_schedule("SM1")) == 11
    assert len(ex.stage_schedule("SM2")) == 9


# ---------------------------------------------------------------------------
# CLI contract
# ---------------------------------------------------------------------------

def test_cli_check_exits_zero_on_real_tree():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.saca_lint", "--check"],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 failure(s)" in proc.stdout


def test_cli_strict_exits_zero_on_real_tree(capsys):
    assert lint_main(["--strict"]) == 0
    out = capsys.readouterr().out
    assert "suppressed" in out


def test_cli_exits_one_on_fixture(capsys):
    rc = lint_main([str(FIXTURES / "trace_violations.py")])
    assert rc == 1
    out = capsys.readouterr().out
    assert "TRACE002" in out


def test_cli_list_rules(capsys):
    assert lint_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule_id in saca_lint.RULES:
        assert rule_id in out


def test_cli_schedule_dump(capsys):
    assert lint_main(["--schedule"]) == 0
    out = capsys.readouterr().out
    assert "[11]" in out and "[ 9]" in out
