"""Planted SCHED001/SCHED003/SCHED004 violations (parsed by saca-lint only).

Each planted line carries a ``PLANT:<RULE>`` marker comment so the tests can
locate it without hard-coding line numbers. The clean functions at the
bottom must produce NO findings — they pin the structural/teardown
exemptions.
"""
import jax
import jax.numpy as jnp
import numpy as np


def gather_stage(x, axis):
    return jax.lax.all_gather(x, axis)


def host_divergence(x, axis):
    if bool(np.asarray(x).any()):  # PLANT:SCHED001
        y = gather_stage(x, axis)
    else:
        y = x
    return y


def early_return_divergence(x, axis):
    if bool(np.asarray(x).any()):  # PLANT:SCHED001-early
        return x
    return jax.lax.all_gather(x, axis)


def divergent_cond(x, axis):
    return jax.lax.cond(  # PLANT:SCHED003
        x.sum() > 0,
        lambda v: jax.lax.all_gather(v, axis),
        lambda v: v,
        x)


def host_loop_collective(x, axis, steps):
    for _ in range(steps):  # PLANT:SCHED004-host
        x = jax.lax.ppermute(x, axis, [(0, 1)])
    return x


def lax_loop_collective(x, axis):
    def body(i, acc):
        return acc + jax.lax.all_gather(acc, axis).sum()
    return jax.lax.fori_loop(0, 4, body, x)  # PLANT:SCHED004-lax


# ---- clean: must produce no findings -----------------------------------

def structural_divergence_ok(x, axis, p):
    # predicate is a host config scalar -> replica-uniform by construction
    if p > 2:
        x = jax.lax.all_gather(x, axis)
    return x


def teardown_ok(x, axis, over):
    if bool(np.asarray(over).any()):
        raise RuntimeError("overflow")  # raise-terminated branch is exempt
    return jax.lax.all_gather(x, axis)


def uniform_branches_ok(x, axis, flag_arr):
    # divergent predicate but identical collective sequence on both arms
    if bool(np.asarray(flag_arr).any()):
        x = jax.lax.all_gather(x, axis)
    else:
        x = jax.lax.all_gather(x * 2, axis)
    return x
