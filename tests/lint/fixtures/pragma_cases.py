"""Pragma semantics fixture: justified / unjustified / stale / standalone."""
import collections

import jax

COUNTS = collections.Counter()
OTHER = collections.Counter()
THIRD = collections.Counter()


@jax.jit
def justified(x):
    COUNTS["a"] += 1  # saca-lint: allow[TRACE001] fixture: deliberate trace counter
    return x


@jax.jit
def unjustified(x):
    OTHER["b"] += 1  # saca-lint: allow[TRACE001]
    return x


@jax.jit
def standalone_pragma(x):
    # saca-lint: allow[TRACE001] fixture: pragma on the line above
    # (second comment line, pragma must skip past it too)
    THIRD["c"] += 1
    return x


def stale_pragma(x):
    return x + 1  # saca-lint: allow[THREAD001] fixture: nothing to suppress here
