"""Planted-regression fixture: `repro.bsp.psort.psort_shard_body` with a
data-dependent branch around the rebalance count-gather.

The plant (marked ``PLANTED-DIVERGENT`` below) is the classic BSP deadlock
shape: a shard that received no rows after the bucket exchange "skips" the
``all_gather`` that every other shard still executes, so the mesh hangs at
the next collective. `tests/lint/test_saca_lint.py` asserts the schedule
extractor reports SCHED001 at exactly that line.

Not imported at runtime — parsed by saca-lint only.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.bsp.exchange import exchange
from repro.bsp.primitives import searchsorted_rows

INT32_MAX = np.iinfo(np.int32).max


def make_pad_rows(k, W):
    return jnp.full((k, W), INT32_MAX, dtype=jnp.int32)


def local_sort_lex(rows):
    return rows[jnp.argsort(rows[:, 0], stable=True)]


def lex_lt_full(a, b):
    return a[..., 0] < b[..., 0]


def psort_shard_body(rows, *, p, axis, lt_fn=None, local_sort=None):
    if lt_fn is None:
        lt_fn = lex_lt_full
    if local_sort is None:
        local_sort = local_sort_lex
    m, W = rows.shape

    # --- 1. local sort ---
    rows = local_sort(rows)
    nvalid = jnp.sum((rows[:, 0] == 0).astype(jnp.int32))

    # --- 2. p+1 equally spaced primary samples ---
    t = jnp.arange(p + 1, dtype=jnp.int32)
    samp_idx = jnp.where(
        nvalid > 0,
        (t.astype(jnp.int64) * jnp.maximum(nvalid - 1, 0) // p).astype(jnp.int32),
        0)
    primary = rows[samp_idx]
    primary = jnp.where((nvalid > 0), primary, make_pad_rows(p + 1, W))

    # --- 3. gather all p(p+1) samples everywhere ---
    all_samples = jax.lax.all_gather(primary, axis).reshape(p * (p + 1), W)
    all_samples = local_sort(all_samples)
    ns = jnp.sum((all_samples[:, 0] == 0).astype(jnp.int32))

    # --- 4. p-1 secondary splitters -> p buckets ---
    tt = jnp.arange(1, p, dtype=jnp.int32)
    sec_idx = jnp.where(
        ns > 0,
        (tt.astype(jnp.int64) * jnp.maximum(ns - 1, 0) // p).astype(jnp.int32),
        0)
    splitters = all_samples[sec_idx]

    valid = rows[:, 0] == 0
    dest = searchsorted_rows(splitters, rows, lt_fn=lt_fn)
    dest = jnp.clip(dest, 0, p - 1)

    # --- 5. bucket exchange + local sort ---
    cap_out = 2 * m + 2 * p + 4
    got, got_valid, over1 = exchange(rows, dest, valid, p=p, cap_out=cap_out,
                                     axis=axis)
    got = jnp.where(got_valid[:, None], got, make_pad_rows(cap_out, W))
    got = local_sort(got)

    # --- 6. rebalance to exactly m rows per shard ---
    cnt = jnp.sum(got_valid.astype(jnp.int32))
    if int(np.asarray(cnt)) == 0:  # PLANTED-DIVERGENT
        # "optimization": empty shard skips the count gather — deadlocks
        # the mesh, since the other shards still enter the all_gather.
        counts = jnp.zeros((p,), jnp.int32)
    else:
        counts = jax.lax.all_gather(cnt[None], axis).reshape(p)
    offset = jnp.cumsum(counts) - counts
    my_off = offset[jax.lax.axis_index(axis)]
    gpos = my_off + jnp.arange(cap_out, dtype=jnp.int32)
    v2 = got[:, 0] == 0
    dest2 = jnp.clip(gpos // m, 0, p - 1)
    carried = jnp.concatenate([gpos[:, None].astype(jnp.int32), got], axis=1)
    out, out_valid, over2 = exchange(carried, dest2, v2, p=p, cap_out=m,
                                     axis=axis)
    perm = jnp.argsort(jnp.where(out_valid, out[:, 0], INT32_MAX), stable=True)
    out = out[perm][:, 1:]
    out_valid = out_valid[perm]
    out = jnp.where(out_valid[:, None], out, make_pad_rows(m, W))
    return out, (over1 | over2)
