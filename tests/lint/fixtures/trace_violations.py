"""Planted TRACE001/TRACE002/TRACE003 violations (parsed by saca-lint only)."""
import collections
import functools

import jax
import jax.numpy as jnp
import numpy as np

COUNTS = collections.Counter()
CACHE = {}


@jax.jit
def closes_over_mutable(x):
    COUNTS["hits"] += 1  # PLANT:TRACE001-counter
    CACHE["last"] = 1  # PLANT:TRACE001-cache
    return x + 1


@functools.partial(jax.jit, static_argnames=("n",))
def host_sync(x, n):
    y = jnp.cumsum(x)
    s = float(y[-1])  # PLANT:TRACE002-float
    z = np.asarray(y)  # PLANT:TRACE002-asarray
    t = y.sum().item()  # PLANT:TRACE002-item
    return x * n + s + t + z.shape[0]


@jax.jit
def scalar_steers(x, steps):
    acc = x
    for _ in range(steps):  # PLANT:TRACE003-range
        acc = acc + 1
    if steps > 3:  # PLANT:TRACE003-if
        acc = acc * 2
    b = steps.bit_length()  # PLANT:TRACE003-bitlength
    return acc + b


# ---- clean: must produce no findings -----------------------------------

@jax.jit
def shape_control_ok(x):
    n = x.shape[0]  # .shape is static metadata, not a traced value
    w = np.zeros(n)
    s = float(w.sum())  # sync on a host numpy value is fine
    for _ in range(n):
        x = x + s
    return x


@functools.partial(jax.jit, static_argnames=("steps",))
def static_arg_ok(x, steps):
    for _ in range(steps):  # steps is a declared static arg
        x = x * 2
    return x
