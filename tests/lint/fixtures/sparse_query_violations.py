"""Planted trace-hygiene violations in a mock of the sparse two-level
query kernel (parsed by saca-lint only, never imported by product code).

The bad variant makes the three mistakes the real
`repro.sparse.query._sparse_ranges_kernel` must avoid: an unjustified
retrace counter, a host sync on a traced reduction, and a data-steered
Python loop bound. The clean variant mirrors the real kernel's skeleton
— shape-derived static loop bound, pragma'd counter, fori_loop — and
must produce no findings."""
import collections
import functools

import jax
import jax.numpy as jnp

RETRACES = collections.Counter()


@functools.partial(jax.jit, static_argnames=("sample_rate",))
def sparse_ranges_kernel_bad(text, ssa, pats, lens, sample_rate, depth):
    RETRACES["sparse"] += 1  # PLANT:TRACE001-retrace
    budget = float(lens.sum())  # PLANT:TRACE002-sync
    lo = jnp.zeros((pats.shape[0], sample_rate, 2), jnp.int32)
    hi = jnp.full((pats.shape[0], sample_rate, 2), ssa.shape[0], jnp.int32)
    for _ in range(depth):  # PLANT:TRACE003-depth
        mid = lo + (hi - lo) // 2
        lo = jnp.where(mid < hi, mid + 1, lo)
    return lo + budget


# ---- clean: the real kernel's shape — must produce no findings ----------

@functools.partial(jax.jit, static_argnames=("sample_rate",))
def sparse_ranges_kernel_ok(text, ssa, pats, lens, sample_rate):
    # saca-lint: allow[TRACE001] deliberate: trace-time retrace counter for tests
    RETRACES["sparse_ok"] += 1
    ns = ssa.shape[0]                    # static metadata, not traced
    steps = max(int(ns).bit_length(), 1) + 1

    def body(_, state):
        lo, hi = state
        mid = lo + (hi - lo) // 2
        return jnp.where(mid < hi, mid + 1, lo), hi

    B = pats.shape[0]
    lo0 = jnp.zeros((B, sample_rate, 2), jnp.int32)
    hi0 = jnp.full((B, sample_rate, 2), ns, jnp.int32)
    lo, hi = jax.lax.fori_loop(0, steps, body, (lo0, hi0))
    return lo[..., 0], lo[..., 1]
