"""Planted THREAD001/THREAD002/THREAD003 violations (parsed by saca-lint only)."""
import collections
import threading


class BadServer:
    def __init__(self):
        self._cond = threading.Condition()
        self._jobs = collections.deque()
        self._running = False
        self._total = 0
        self._ema = None

    def start(self):
        self._running = True  # PLANT:THREAD001-flag
        t = threading.Thread(target=self._worker, daemon=True)
        t.start()

    def submit(self, item):
        with self._cond:
            self._jobs.append(item)
            self._cond.notify_all()
        self._total += 1  # PLANT:THREAD001-counter
        self._jobs.append(item)  # PLANT:THREAD003-deque

    def _bad_wait(self):
        with self._cond:
            if not self._jobs:
                self._cond.wait()  # PLANT:THREAD002-wait

    def _notify_unlocked(self):
        self._cond.notify_all()  # PLANT:THREAD002-notify

    def _worker(self):
        while self._running:
            with self._cond:
                while not self._jobs:
                    self._cond.wait()  # clean: wait under retest loop
                item = self._jobs.popleft()  # clean: mutation under lock
                self._total -= 1  # clean: write under lock
            self._ema = item  # PLANT:THREAD001-ema

    def stats(self):
        return self._total, self._ema


class NoLockNoFindings:
    """Classes that own no lock are out of scope for the THREAD rules."""

    def __init__(self):
        self.x = 0

    def bump(self):
        self.x += 1
