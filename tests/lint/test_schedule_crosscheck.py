"""Static ⇔ dynamic schedule cross-check (8 fake CPU devices, subprocess).

The AST extractor's per-stage collective schedules must equal — kind for
kind, superstep for superstep — the label stream `BSPCounters` records in
a LIVE `suffix_array_bsp` run, under both the accelerated and the fixed
sampling schedule. This is the end-to-end closure of SCHED002: source,
counters and execution cannot drift apart in any pairing.
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
SRC = os.path.join(REPO, "src")


def test_static_schedule_matches_live_counters():
    body = """
    import numpy as np
    import jax
    from jax.sharding import Mesh

    from repro.bsp.counters import BSPCounters
    from repro.bsp.suffix_array import suffix_array_bsp
    from repro.core.seq_ref import accelerated_next_v, fixed_next_v
    from tools.saca_lint import collectives
    from tools.saca_lint.astutil import REPO, load_modules

    # --- static side: extract the per-stage schedules from the AST
    mods = load_modules([REPO / "src" / "repro" / "bsp"])
    _findings, ex = collectives.analyze(mods)
    static = {s: [e.kind for e in ex.stage_schedule(s)] for s in ("SM1", "SM2")}
    assert len(static["SM1"]) == 11 and len(static["SM2"]) == 9

    def live_kinds_per_round(ct):
        '''Group the counter label stream into per-stage runs and map each
        label to its collective kind; returns list of (stage, kinds).'''
        labels = [e["label"] for e in ct.log]
        runs, i = [], 0
        while i < len(labels):
            lab = labels[i]
            if lab.startswith(("SM1/", "SM2/")):
                stage = lab[:3]
                width = 11 if stage == "SM1" else 9
                chunk = labels[i:i + width]
                assert all(c.startswith(stage + "/") for c in chunk), chunk
                suffixes = [c.split("/", 1)[1] for c in chunk]
                runs.append((stage,
                             [collectives.LABEL_KINDS[s] for s in suffixes]))
                i += width
            else:
                assert lab == "base/gather", lab
                i += 1
        return runs

    # --- dynamic side: live runs on an 8-device mesh, both schedules
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("bsp",))
    x = np.zeros(3000, np.int64)     # all-equal: never short-circuits
    rounds = {}
    for name, sched in (("accelerated", accelerated_next_v),
                        ("fixed", fixed_next_v)):
        ct = BSPCounters()
        suffix_array_bsp(x, mesh, base_threshold=64, counters=ct,
                         schedule=sched)
        runs = live_kinds_per_round(ct)
        assert runs, name
        for stage, kinds in runs:
            assert kinds == static[stage], (name, stage, kinds)
        n_sm1 = sum(1 for s, _ in runs if s == "SM1")
        n_sm2 = sum(1 for s, _ in runs if s == "SM2")
        assert n_sm1 == ct.rounds and n_sm2 == ct.rounds, name
        # S = 20*rounds + 1 when the recursion bottoms out in the base
        # gather; the all-distinct short-circuit skips that superstep
        # (fixed-v reaches distinct ranks before the size threshold).
        n_base = sum(1 for e in ct.log if e["label"] == "base/gather")
        assert n_base in (0, 1), name
        assert ct.supersteps == 20 * ct.rounds + n_base, name
        rounds[name] = ct.rounds

    # paper C4: accelerated sampling needs no more rounds than fixed-v
    assert rounds["accelerated"] <= rounds["fixed"], rounds
    print("OK", rounds)
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + REPO
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "OK" in r.stdout
