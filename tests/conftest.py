import os
import sys

# Tests must see the normal 1-device CPU environment (the dry-run sets its
# own flags in a separate process). Keep threads tame on the 1-core box.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The image does not ship `hypothesis`; fall back to the deterministic stub
# in tests/_stubs (real hypothesis wins whenever it is importable, e.g. CI).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_stubs"))
