"""Admission control decisions — pure logic, plain numbers."""
import pytest

from repro.serve import AdmissionController, POLICIES


def test_none_policy_accepts_everything():
    a = AdmissionController(queue_depth=1, policy="none")
    d = a.admit(queued=10**6, oldest_age_us=10**9)
    assert d.action == "accept" and d.accepted
    assert d.retry_after_us is None


def test_reject_at_queue_depth_with_priced_retry_hint():
    a = AdmissionController(queue_depth=4, policy="reject")
    assert a.admit(3, 0.0).action == "accept"
    d = a.admit(4, 0.0, est_us_per_req=250.0)
    assert d.action == "reject" and not d.accepted
    # hint = backlog x measured per-request cost
    assert d.retry_after_us == pytest.approx(4 * 250.0)


def test_reject_hint_floors_without_service_estimate():
    a = AdmissionController(queue_depth=1, policy="reject")
    assert a.admit(1, 0.0).retry_after_us == pytest.approx(1.0)
    assert a.admit(0, 10.0, None).action == "accept"


def test_age_bound_trips_even_below_depth():
    a = AdmissionController(queue_depth=1024, policy="reject",
                            max_age_us=1000.0)
    assert a.admit(1, 999.0).action == "accept"
    assert a.admit(1, 1001.0).action == "reject"


def test_shed_policy_admits_by_evicting():
    a = AdmissionController(queue_depth=2, policy="shed")
    d = a.admit(2, 0.0)
    assert d.action == "shed" and d.accepted


def test_validation():
    assert set(POLICIES) == {"none", "reject", "shed"}
    with pytest.raises(ValueError, match="policy"):
        AdmissionController(policy="drop")
    with pytest.raises(ValueError, match="queue_depth"):
        AdmissionController(queue_depth=0)
