"""Schema gate + findings derivation of the serving SLO benchmark,
exercised on synthetic records (no load is generated here — the real
sweep is the CI serve-slo-smoke job)."""
import copy

from benchmarks.serve_slo import (MODES, derive_findings, validate_artifact)

GRID = [1000.0, 2000.0, 4000.0, 8000.0]
#: p99 curves per mode over GRID: admission stays bounded, the
#: no-admission baseline diverges, batch-of-one saturates early
P99 = {
    "coalesce+admit": [4.0, 6.0, 10.0, 60.0],
    "coalesce+none": [4.0, 5.0, 400.0, 2000.0],
    "batch1+admit": [8.0, 40.0, 50.0, 55.0],
}
GOODPUT = {
    "coalesce+admit": [990.0, 1980.0, 2050.0, 800.0],
    "coalesce+none": [990.0, 1980.0, 3900.0, 7800.0],
    "batch1+admit": [990.0, 1100.0, 1050.0, 600.0],
}


def _record(mode, arrival, qps, p99_ms, goodput):
    ok = int(goodput)
    return {"mode": mode, "arrival": arrival, "offered_qps": qps,
            "duration_s": 1.0, "offered": ok + 5, "ok": ok, "rejected": 5,
            "shed": 0, "goodput_qps": goodput, "p50_ms": p99_ms / 4,
            "p95_ms": p99_ms / 2, "p99_ms": p99_ms,
            "queue_p99_ms": p99_ms / 2, "max_ms": p99_ms * 2,
            "batch_size_mean": 8.0, "bucket_occupancy_mean": 0.5,
            "counters": {"submitted": ok + 5}}


def _artifact():
    records = [_record(m, "poisson", q, p, g)
               for m in MODES
               for q, p, g in zip(GRID, P99[m], GOODPUT[m])]
    records.append(_record("coalesce+admit", "onoff", GRID[-2], 12.0, 1900.0))
    return {"bench": "serve_slo", "smoke": False, "n": 1000,
            "pattern_len": 512, "max_batch": 32, "queue_depth": 64,
            "seed": 0, "duration_s": 1.0, "capacity_qps": 2000.0,
            "grid_qps": GRID, "records": records,
            "findings": derive_findings(records, slo_ms=25.0)}


def test_synthetic_artifact_passes_schema():
    assert validate_artifact(_artifact()) == []


def test_findings_read_the_curves_correctly():
    f = _artifact()["findings"]
    assert f["slo_ms"] == 25.0
    # best goodput among points with p99 <= SLO
    assert f["sustained_qps_at_slo"] == {"coalesce+admit": 2050.0,
                                         "batch1+admit": 990.0}
    assert f["coalescing_sustains_higher_qps"] is True
    # the 2x point (grid[-2]): 10ms bounded vs 400ms diverging
    assert f["overload_qps"] == GRID[-2]
    assert f["p99_past_saturation_ms"] == {"coalesce+admit": 10.0,
                                           "coalesce+none": 400.0}
    assert f["admission_bounds_p99"] is True


def test_findings_catch_an_unbounded_admit_curve():
    art = _artifact()
    bad = copy.deepcopy(art["records"])
    for r in bad:
        if r["mode"] == "coalesce+admit" and r["offered_qps"] == GRID[-2]:
            r["p99_ms"] = 390.0              # admission no longer helping
    assert derive_findings(bad, slo_ms=25.0)["admission_bounds_p99"] is False


def test_schema_catches_broken_artifacts():
    art = _artifact()

    missing = copy.deepcopy(art)
    del missing["grid_qps"]
    assert any("grid_qps" in p for p in validate_artifact(missing))

    short = copy.deepcopy(art)
    short["grid_qps"] = short["grid_qps"][:2]
    assert any(">= 3 offered points" in p for p in validate_artifact(short))

    no_mode = copy.deepcopy(art)
    no_mode["records"] = [r for r in no_mode["records"]
                          if r["mode"] != "batch1+admit"]
    assert any("batch1+admit" in p for p in validate_artifact(no_mode))

    no_burst = copy.deepcopy(art)
    no_burst["records"] = [r for r in no_burst["records"]
                           if r["arrival"] != "onoff"]
    assert any("onoff" in p for p in validate_artifact(no_burst))

    fake_zero = copy.deepcopy(art)
    fake_zero["records"][0]["p99_ms"] = None     # served but stats absent
    assert any("p99 is absent" in p for p in validate_artifact(fake_zero))

    dropped = copy.deepcopy(art)
    del dropped["records"][0]["queue_p99_ms"]
    assert any("missing keys" in p for p in validate_artifact(dropped))
