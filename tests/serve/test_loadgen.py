"""Arrival processes (deterministic, seeded) + open-loop summary rules."""
import numpy as np
import pytest

from repro.api import SuffixArrayIndex
from repro.serve import (ARRIVALS, Response, SAServer, make_arrivals,
                         run_open_loop, summarize)


@pytest.mark.parametrize("process", ARRIVALS)
def test_arrivals_are_deterministic_sorted_and_in_range(process):
    a = make_arrivals(process, 500.0, 0.5, seed=7)
    b = make_arrivals(process, 500.0, 0.5, seed=7)
    assert np.array_equal(a, b)              # same seed, same schedule
    assert np.all(np.diff(a) >= 0)
    assert a.size > 0 and a[0] >= 0 and a[-1] < 0.5


def test_poisson_seed_changes_schedule_and_rate_is_right():
    a = make_arrivals("poisson", 2000.0, 1.0, seed=0)
    b = make_arrivals("poisson", 2000.0, 1.0, seed=1)
    assert not np.array_equal(a, b)
    assert 1600 < a.size < 2400              # ~qps*duration +/- noise


def test_onoff_arrivals_only_inside_on_windows():
    on_ms, off_ms = 20.0, 80.0
    a = make_arrivals("onoff", 1000.0, 1.0, seed=0,
                      on_ms=on_ms, off_ms=off_ms)
    period = (on_ms + off_ms) * 1e-3
    assert np.all((a % period) < on_ms * 1e-3)
    assert 700 < a.size < 1300               # mean rate is still ~qps


def test_uniform_is_evenly_spaced():
    a = make_arrivals("uniform", 100.0, 0.1, seed=0)
    assert a.size == 10
    assert np.allclose(np.diff(a), 0.01)


def test_arrival_validation():
    with pytest.raises(ValueError, match="arrival process"):
        make_arrivals("lognormal", 100.0, 1.0)
    with pytest.raises(ValueError):
        make_arrivals("poisson", 0.0, 1.0)
    with pytest.raises(ValueError):
        make_arrivals("poisson", 100.0, -1.0)


def test_run_open_loop_serves_every_arrival_in_schedule_order():
    rng = np.random.default_rng(5)
    idx = SuffixArrayIndex.build(rng.integers(0, 4, 200), sigma=4)
    pats = [rng.integers(0, 4, 8) for _ in range(5)]
    with SAServer(idx, max_batch=8, coalesce_max_wait_us=500.0) as srv:
        srv.warmup(pattern_lens=(8,))
        arrivals = make_arrivals("uniform", 400.0, 0.1, seed=0)
        responses = run_open_loop(srv, pats, arrivals, tick_s=0.001)
    assert len(responses) == arrivals.size
    assert [r.req_id for r in responses] == sorted(r.req_id
                                                   for r in responses)
    for i, r in enumerate(responses):
        assert r.ok and r.count == idx.count(pats[i % len(pats)])
    s = summarize(responses, 0.1)
    assert s["ok"] == len(responses) and s["rejected"] == 0
    assert s["p50_ms"] <= s["p95_ms"] <= s["p99_ms"] <= s["max_ms"]
    with pytest.raises(ValueError, match="pattern"):
        run_open_loop(srv, [], arrivals)


def test_summarize_absent_when_nothing_served():
    rejected = [Response(req_id=i, status="rejected", retry_after_us=5.0)
                for i in range(4)]
    s = summarize(rejected, 1.0)
    assert s["offered"] == 4 and s["rejected"] == 4 and s["ok"] == 0
    assert s["goodput_qps"] == 0.0
    assert s["p50_ms"] is None and s["p99_ms"] is None
    assert s["queue_p99_ms"] is None and s["max_ms"] is None
