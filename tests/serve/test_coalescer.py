"""Coalescer window logic under adversarial arrivals — driven with a
purely virtual clock (the class takes `now` everywhere, so no sleeps)."""
import numpy as np
import pytest

from repro.serve import Coalescer, PendingQuery

WAIT_US = 500.0
WAIT_S = WAIT_US * 1e-6


def _req(rid, length, t):
    return PendingQuery(req_id=rid, pattern=np.zeros(length, np.int64),
                        t_arrival=t)


def test_straggler_flushes_at_max_wait_never_stranded():
    c = Coalescer(max_batch=64, max_wait_us=WAIT_US)
    c.add(_req(0, 8, t=0.0))
    # before the deadline the window stays open ...
    assert c.pop_ready(WAIT_S * 0.99) == []
    assert c.pending_count() == 1
    # ... at the deadline the lone straggler goes out alone
    [batch] = c.pop_ready(WAIT_S)
    assert [r.req_id for r in batch] == [0]
    assert c.pending_count() == 0
    assert c.next_deadline() is None


def test_younger_requests_ride_the_oldest_deadline():
    c = Coalescer(max_batch=64, max_wait_us=WAIT_US)
    c.add(_req(0, 8, t=0.0))
    c.add(_req(1, 8, t=WAIT_S * 0.9))       # 10% of its wait budget spent
    [batch] = c.pop_ready(WAIT_S)
    assert [r.req_id for r in batch] == [0, 1]   # arrival order preserved


def test_burst_larger_than_biggest_bucket_splits_into_full_chunks():
    c = Coalescer(max_batch=16, max_wait_us=WAIT_US)
    for i in range(41):                      # 2 full chunks + 9 remainder
        c.add(_req(i, 8, t=0.0))
    batches = c.pop_ready(0.0)               # full windows close instantly
    assert [len(b) for b in batches] == [16, 16]
    assert [r.req_id for r in batches[0]] == list(range(16))
    assert c.pending_count() == 9            # remainder keeps pending ...
    [rest] = c.pop_ready(WAIT_S)             # ... until ITS deadline
    assert [r.req_id for r in rest] == list(range(32, 41))


def test_mixed_lengths_coalesce_into_distinct_buckets_same_window():
    c = Coalescer(max_batch=64, max_wait_us=WAIT_US)
    c.add(_req(0, 4, t=0.0))        # -> 8-bucket (floor)
    c.add(_req(1, 100, t=0.0))      # -> 128-bucket
    c.add(_req(2, 8, t=0.0))        # -> 8-bucket again
    batches = c.pop_ready(WAIT_S)
    assert sorted(len(b) for b in batches) == [1, 2]
    for b in batches:
        assert len({r.len_bucket for r in b}) == 1   # homogeneous shapes
    assert {r.req_id for b in batches for r in b} == {0, 1, 2}


def test_full_bucket_closes_without_waiting():
    c = Coalescer(max_batch=8, max_wait_us=1e9)      # deadline effectively off
    for i in range(8):
        c.add(_req(i, 8, t=0.0))
    [batch] = c.pop_ready(0.0)
    assert len(batch) == 8


def test_flush_closes_every_window_regardless_of_age():
    c = Coalescer(max_batch=64, max_wait_us=1e9)
    c.add(_req(0, 8, t=0.0))
    c.add(_req(1, 100, t=0.0))
    assert len(c.pop_ready(0.0, flush=True)) == 2
    assert c.pending_count() == 0


def test_shed_oldest_is_global_across_buckets():
    c = Coalescer(max_batch=64, max_wait_us=WAIT_US)
    c.add(_req(0, 8, t=2.0))
    c.add(_req(1, 100, t=1.0))      # older, different bucket
    victim = c.shed_oldest()
    assert victim.req_id == 1
    assert c.pending_count() == 1
    assert c.shed_oldest().req_id == 0
    assert c.shed_oldest() is None


def test_bookkeeping_age_deadline_and_pow2_coercion():
    assert Coalescer(max_batch=5).max_batch == 8     # pow2 kernel bucket
    c = Coalescer(max_batch=64, max_wait_us=WAIT_US)
    assert c.oldest_age_us(123.0) == 0.0
    assert c.next_deadline() is None
    c.add(_req(0, 8, t=1.0))
    assert c.oldest_age_us(1.0 + 200e-6) == pytest.approx(200.0)
    assert c.next_deadline() == pytest.approx(1.0 + WAIT_S)


def test_constructor_validation():
    with pytest.raises(ValueError):
        Coalescer(max_batch=0)
    with pytest.raises(ValueError):
        Coalescer(max_wait_us=-1.0)
