"""SAServer end-to-end: correctness vs the closed-loop engine, admission
behaviour under a deliberately stalled window, lifecycle + accounting."""
import time

import numpy as np
import pytest

from repro.api import SuffixArrayIndex
from repro.serve import SAServer

SIGMA = 4


@pytest.fixture(scope="module")
def index():
    rng = np.random.default_rng(3)
    return SuffixArrayIndex.build(rng.integers(0, SIGMA, 400), sigma=SIGMA)


def test_served_counts_match_closed_loop_engine(index):
    rng = np.random.default_rng(4)
    pats = [rng.integers(0, SIGMA, m) for m in (1, 3, 8, 20, 100)] * 3
    with SAServer(index, max_batch=4, coalesce_max_wait_us=200.0) as srv:
        futs = [srv.submit(p) for p in pats]
        got = [f.result(timeout=60.0) for f in futs]
    for p, r in zip(pats, got):
        assert r.ok
        assert r.count == index.count(p)
        assert r.hi - r.lo == r.count
        assert r.queue_us >= 0 and r.service_us > 0
        assert r.total_us >= r.queue_us
    # one response per request, ids unique
    assert len({r.req_id for r in got}) == len(pats)


def test_queue_full_rejects_with_retry_hint(index):
    # a 10s window + queue_depth=2 makes the 3rd submit deterministic:
    # nothing can drain before it arrives
    srv = SAServer(index, max_batch=64, coalesce_max_wait_us=10e6,
                   queue_depth=2, overload_policy="reject").start()
    f1, f2 = srv.submit([0, 1]), srv.submit([1, 0])
    f3 = srv.submit([0, 0])
    r3 = f3.result(timeout=5.0)              # resolved immediately
    assert r3.status == "rejected" and not r3.ok
    assert r3.retry_after_us >= 1.0
    assert r3.count is None
    srv.stop()                               # drains the accepted two
    assert f1.result(timeout=5.0).ok and f2.result(timeout=5.0).ok
    c = srv.metrics.counters()
    assert c["submitted"] == 3 and c["accepted"] == 2
    assert c["rejected"] == 1 and c["completed"] == 2


def test_shed_policy_evicts_the_oldest(index):
    srv = SAServer(index, max_batch=64, coalesce_max_wait_us=10e6,
                   queue_depth=1, overload_policy="shed").start()
    f1 = srv.submit([0, 1])
    f2 = srv.submit([1, 0])                  # admitted by evicting f1
    r1 = f1.result(timeout=5.0)
    assert r1.status == "shed" and r1.total_us >= 0
    srv.stop()
    assert f2.result(timeout=5.0).ok
    assert srv.metrics.counter("shed") == 1


def test_scheduled_arrival_charges_loadgen_lateness(index):
    with SAServer(index, max_batch=4, coalesce_max_wait_us=100.0) as srv:
        fut = srv.submit([0, 1], t_arrival=time.perf_counter() - 1.0)
        r = fut.result(timeout=30.0)
    assert r.ok and r.total_us >= 1e6        # the fictitious second counts


def test_submit_validates_synchronously(index):
    srv = SAServer(index)
    with pytest.raises(RuntimeError, match="not running"):
        srv.submit([0])
    srv.start()
    try:
        with pytest.raises(ValueError):
            srv.submit([SIGMA])              # out of alphabet
        # empty pattern is legal and matches everywhere, same as the
        # closed-loop engine's count([])
        assert srv.submit([]).result(timeout=30.0).count == index.n
    finally:
        srv.stop()


def test_warmup_counts_every_shape(index):
    srv = SAServer(index, max_batch=4)
    # pow2 batch buckets {1,2,4} x length buckets {8,16} = 6 shapes
    assert srv.warmup(pattern_lens=(5, 16)) == 6
    assert srv.warmed_shapes == 6
    assert srv.warmup(pattern_lens=(8,), batch_buckets=(2,)) == 1


def test_metrics_snapshot_absent_not_zero(index):
    srv = SAServer(index)
    snap = srv.metrics.snapshot()
    assert snap["counters"]["submitted"] == 0
    assert snap["total_us"]["count"] == 0
    assert snap["total_us"]["p99"] is None   # absent, never 0.0
    with SAServer(index, coalesce_max_wait_us=100.0) as srv2:
        srv2.submit([0, 1]).result(timeout=30.0)
    snap = srv2.metrics.snapshot()
    assert snap["total_us"]["p99"] is not None
    assert snap["batch_size"]["count"] == 1
    assert 0 < snap["bucket_occupancy"]["max"] <= 1.0


# ------------------------------------------------------------- GC hygiene
def test_gc_hygiene_pins_thresholds_and_freezes(index):
    import gc

    base = gc.get_threshold()
    srv = SAServer(index, max_batch=4)
    with srv:
        assert gc.get_threshold() != base          # gen-2 pinned out
        assert gc.get_threshold()[:2] == base[:2]  # young gens untouched
        srv.warmup(pattern_lens=(8,))
        assert srv._gc_frozen and gc.get_freeze_count() > 0
        # the deliberate warmup collection is off the clock
        assert srv.metrics.counter("gc_pauses") == 0
        assert srv.submit([0, 1]).result(timeout=30.0).ok
        gc.collect()                               # in-loop full collection
        assert srv.metrics.counter("gc_pauses") == 1
    # stop() hands the process-global state back
    assert gc.get_threshold() == base
    assert gc.get_freeze_count() == 0
    assert srv._on_gc not in gc.callbacks


def test_gc_hygiene_opt_out(index):
    import gc

    base = gc.get_threshold()
    with SAServer(index, gc_hygiene=False) as srv:
        assert gc.get_threshold() == base
        srv.warmup(pattern_lens=(8,))
        assert not srv._gc_frozen
        gc.collect()
        assert srv.metrics.counter("gc_pauses") == 0
