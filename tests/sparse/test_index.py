"""`SparseSuffixArrayIndex` API contract: facade dispatch, the typed
short-pattern error, dense-identical query answers, retrace accounting,
the serving-tier protocol, persistence hooks, and the segmented variant.
(The randomized sparse-vs-dense differential matrix lives in
`tests/api/test_fuzz_differential.py` under `-m fuzz`.)"""
import numpy as np
import pytest

from repro.api import (SAOptions, SegmentedIndex, SuffixArrayIndex,
                      build_suffix_array)
from repro.sparse import PatternTooShortError, SparseSuffixArrayIndex
from repro.sparse.query import trace_events

RATE = 4
OPTS = SAOptions(sample_rate=RATE)


def _docs(seed=0, n_docs=4, lo=20, hi=120, sigma=6):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, sigma, int(rng.integers(lo, hi)))
            for _ in range(n_docs)]


def _pair(seed=0, **kw):
    docs = _docs(seed, **kw)
    return (SuffixArrayIndex.from_docs(docs, SAOptions()),
            SuffixArrayIndex.from_docs(docs, OPTS), docs)


# -------------------------------------------------------- facade dispatch
def test_facade_dispatches_on_sample_rate():
    text = np.arange(40) % 7
    idx = SuffixArrayIndex.build(text, OPTS)
    assert type(idx) is SparseSuffixArrayIndex
    assert idx.sample_rate == RATE and idx.min_pattern_len == RATE
    assert idx.ns == -(-idx.n // RATE)
    # rate 1 stays dense, and the dense class attribute is the no-floor 0
    dense = SuffixArrayIndex.build(text, SAOptions())
    assert type(dense) is SuffixArrayIndex
    assert dense.min_pattern_len == 0


def test_build_suffix_array_rejects_sparse_plan():
    """The raw-SA entry point returns FULL suffix arrays by contract —
    a sparse plan must be an error there, not a silently sampled array."""
    with pytest.raises(ValueError, match="sample_rate"):
        build_suffix_array(np.arange(10), SAOptions(sample_rate=4))


def test_options_validate_sample_rate():
    with pytest.raises(ValueError, match="sample_rate"):
        SAOptions(sample_rate=0)
    with pytest.raises(ValueError, match="sample_rate"):
        SparseSuffixArrayIndex.build(np.arange(8), SAOptions())


def test_fingerprint_carries_rate():
    assert "rate=4" in OPTS.fingerprint()
    assert OPTS.fingerprint() != SAOptions().fingerprint()


# ------------------------------------------------------ short-pattern error
def test_pattern_too_short_is_typed_and_described():
    idx = SuffixArrayIndex.build(np.arange(64) % 5, OPTS)
    with pytest.raises(PatternTooShortError) as ei:
        idx.count_batch([[1, 2, 3]])
    assert isinstance(ei.value, ValueError)          # catchable as ValueError
    assert ei.value.pattern_len == 3
    assert ei.value.sample_rate == RATE
    for meth in (idx.count, idx.contains_batch, idx.locate_batch,
                 idx.locate_docs_batch):
        with pytest.raises(PatternTooShortError):
            meth([[0] * (RATE - 1)])
    # empty pattern is also below the floor (dense would answer n)
    with pytest.raises(PatternTooShortError):
        idx.count([])


# ------------------------------------------------------------ dense parity
def test_queries_identical_to_dense():
    dense, sparse, docs = _pair(seed=1)
    pats = [docs[0][:RATE], docs[1][: 2 * RATE + 1], docs[2],
            np.full(RATE, 5), np.asarray([0, 1, 2, 3] * 3)]
    np.testing.assert_array_equal(sparse.count_batch(pats),
                                  dense.count_batch(pats))
    np.testing.assert_array_equal(sparse.contains_batch(pats),
                                  dense.contains_batch(pats))
    for got, want in zip(sparse.locate_batch(pats), dense.locate_batch(pats)):
        np.testing.assert_array_equal(got, want)
    for got, want in zip(sparse.locate_docs_batch(pats),
                         dense.locate_docs_batch(pats)):
        np.testing.assert_array_equal(got, want)


def test_longest_match_floors_at_rate():
    dense, sparse, docs = _pair(seed=2)
    probe = np.asarray(docs[0][: 3 * RATE], np.int64)
    want = dense.longest_match(probe)
    assert want >= RATE                      # a planted substring matches
    assert sparse.longest_match(probe) == want
    # nothing ≥ rate in common → 0, not a short-pattern error
    alien = np.full(2 * RATE, 97, np.int64)
    assert sparse.longest_match(alien) == 0
    assert sparse.longest_match(probe[:RATE - 1]) == 0


def test_empty_and_tiny_corpora():
    empty = SuffixArrayIndex.from_docs([], OPTS)
    assert isinstance(empty, SparseSuffixArrayIndex) and empty.ns == 0
    assert empty.count_batch([[1] * RATE]).tolist() == [0]
    assert empty.locate_batch([[1] * RATE])[0].tolist() == []
    tiny = SuffixArrayIndex.build(np.asarray([2, 2]), OPTS)   # n < rate
    assert tiny.ns == 1
    assert tiny.count([2, 2, 2, 2]) == 0


def test_sparse_lcp_lazy_property():
    idx = SuffixArrayIndex.build(np.tile([0, 1], 30), OPTS)
    assert idx._lcp is None
    lcp = idx.lcp
    assert idx._lcp is not None and len(lcp) == idx.ns
    assert lcp[0] == 0 and (lcp[1:] > 0).any()


def test_dense_only_statistics_raise():
    idx = SuffixArrayIndex.build(np.arange(32) % 3, OPTS)
    for call in (lambda: idx.ngram_stats(4),
                 lambda: idx.duplicate_spans(4),
                 lambda: idx.cross_doc_duplicates(4),
                 lambda: idx.sa_ranges_batch([[0] * RATE])):
        with pytest.raises(NotImplementedError):
            call()


# ------------------------------------------------------- retrace accounting
def test_reused_bucket_does_not_retrace():
    rng = np.random.default_rng(8)
    idx = SuffixArrayIndex.build(rng.integers(0, 4, 256), OPTS)
    idx.count_batch([[0, 1, 2, 3], [1, 2, 3, 0], [2, 3, 0, 1]])
    before = trace_events()
    # same (B, L) bucket: different patterns, different batch size
    idx.count_batch([[1, 1, 2, 2], [3, 3, 3, 3], [0, 1, 0, 1], [2] * 4])
    idx.locate_batch([[0, 1, 2, 3], [1, 2, 3, 0], [3, 2, 1, 0]])
    assert trace_events() == before
    # a genuinely new shape traces once (longer patterns → new L bucket)
    idx.count_batch([rng.integers(0, 4, 20).tolist()])
    assert trace_events() == before + 1


# --------------------------------------------------- serving-tier protocol
def test_stage_encoded_ranges_staged_widths_match_dense():
    dense, sparse, docs = _pair(seed=3)
    pats = [docs[0][:RATE], np.full(RATE + 2, 3), docs[1][: 2 * RATE]]
    enc = [sparse._encode_pattern(p) for p in pats]
    lo, hi = sparse.ranges_staged(sparse.stage_encoded(enc))
    dl, dh = dense.ranges_staged(dense.stage_encoded(
        [dense._encode_pattern(p) for p in pats]))
    # sparse ranges are virtual (lo pinned to 0) but widths are exact
    np.testing.assert_array_equal(hi - lo, dh - dl)
    np.testing.assert_array_equal(lo, np.zeros(len(pats), np.int64))
    np.testing.assert_array_equal(sparse._counts_encoded(enc), dh - dl)
    for got, want in zip(sparse._positions_encoded(enc),
                         dense._positions_encoded(
                             [dense._encode_pattern(p) for p in pats])):
        np.testing.assert_array_equal(got, want)


def test_query_session_warmup_respects_floor():
    from repro.api.query import QuerySession
    idx = SuffixArrayIndex.build(np.arange(128) % 5, OPTS)
    sess = QuerySession(idx)
    sess.warmup()                              # must not trip the floor
    counts = sess.count([[0, 1, 2, 3]])
    assert counts.tolist() == [int(idx.count([0, 1, 2, 3]))]


# ----------------------------------------------------------- segmented mode
def test_segmented_index_goes_sparse_per_segment():
    docs = _docs(seed=4, n_docs=6)
    seg = SegmentedIndex.from_docs(docs, OPTS, segment_docs=2)
    assert seg.min_pattern_len == RATE
    assert all(isinstance(s.index, SparseSuffixArrayIndex)
               for s in seg.segments)
    mono = SegmentedIndex.from_docs(docs, SAOptions(), segment_docs=2)
    pats = [docs[0][:RATE], docs[3][: 2 * RATE], np.full(RATE, 1)]
    np.testing.assert_array_equal(seg.count_batch(pats),
                                  mono.count_batch(pats))
    for got, want in zip(seg.locate_batch(pats), mono.locate_batch(pats)):
        np.testing.assert_array_equal(got, want)
    with pytest.raises(PatternTooShortError):
        seg.count_batch([[0] * (RATE - 1)])
    # serving protocol fans out per segment with exact widths
    enc = [seg._encode_pattern(p) for p in pats]
    lo, hi = seg.ranges_staged(seg.stage_encoded(enc))
    np.testing.assert_array_equal(hi - lo, mono.count_batch(pats))


def test_segmented_compact_preserves_sparse_answers():
    docs = _docs(seed=5, n_docs=8)
    seg = SegmentedIndex.from_docs(docs, OPTS.replace(compact_fanin=2),
                                   segment_docs=1)
    seg.compact()
    assert all(isinstance(s.index, SparseSuffixArrayIndex)
               for s in seg.segments)
    mono = SuffixArrayIndex.from_docs(docs, OPTS)
    pats = [docs[2][:RATE], docs[7][: 2 * RATE]]
    np.testing.assert_array_equal(seg.count_batch(pats),
                                  mono.count_batch(pats))
