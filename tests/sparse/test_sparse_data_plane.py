"""Sparse-backed training data plane: the sample_rate ≤ gram-length
guards reject incompatible configs at construction, and a plane whose
index is sparse streams the exact bytes (and batches) the dense-indexed
plane produces — the sparse index only ever answers grams ≥ its rate,
so dedup/gate/probe results cannot drift."""
import numpy as np
import pytest

from repro.api import SAOptions, SegmentedIndex
from repro.configs import get_config
from repro.data.pipeline import (ContaminationGate, PipelineConfig,
                                 StreamingDedup, TrainingDataPlane,
                                 synthetic_doc_shards)

VOCAB = 64
MIN_LEN = 24
RATE = 8


def make_shards(n_chars=30_000, shard_docs=4, seed=3):
    return synthetic_doc_shards(n_chars, VOCAB, shard_docs=shard_docs,
                                doc_len=900, dup_fraction=0.4, seed=seed)


# ------------------------------------------------------------------ guards
def test_pipeline_config_rejects_rate_above_dedup_gram():
    with pytest.raises(ValueError, match="dedup_min_len"):
        PipelineConfig(dedup=True, dedup_min_len=8,
                       options=SAOptions(sample_rate=16))
    with pytest.raises(ValueError, match="gate_min_len"):
        PipelineConfig(dedup_min_len=32, gate_min_len=8,
                       options=SAOptions(sample_rate=16))
    # equal is fine: an exactly-rate-length gram is still answerable
    PipelineConfig(dedup=True, dedup_min_len=16, gate_min_len=16,
                   options=SAOptions(sample_rate=16))


def test_sa_config_to_pipeline_carries_the_guard():
    cfg = get_config("suffix-array")
    bad = type(cfg)(**{**cfg.__dict__, "sample_rate": 64,
                       "dedup_min_len": 48})
    with pytest.raises(ValueError, match="dedup_min_len"):
        bad.to_pipeline()
    ok = type(cfg)(**{**cfg.__dict__, "sample_rate": 16})
    assert ok.to_pipeline().options.sample_rate == 16


def test_streaming_dedup_and_gate_validate_directly():
    seg = SegmentedIndex(options=SAOptions(sample_rate=16), sigma=VOCAB)
    with pytest.raises(ValueError, match="sample_rate"):
        StreamingDedup(seg, min_len=8)
    with pytest.raises(ValueError, match="minimum answerable"):
        ContaminationGate([np.arange(64) % 7], min_len=8,
                          options=SAOptions(sample_rate=16), sigma=VOCAB)


# ----------------------------------------------------- sparse/dense parity
def test_sparse_plane_byte_identical_to_dense():
    """Acceptance: same shards, same config except the index flavour —
    kept bytes, drop accounting, and the deterministic gated batches all
    match the dense-indexed plane exactly."""
    shards = make_shards()
    rng = np.random.default_rng(11)
    eval_docs = [rng.integers(0, 32, 1500) for _ in range(2)]

    def build(rate):
        cfg = PipelineConfig(
            seq_len=96, global_batch=4, dedup=True, dedup_min_len=MIN_LEN,
            gate_min_len=MIN_LEN, vocab=VOCAB, seed=5,
            options=SAOptions(sample_rate=rate))
        return TrainingDataPlane(cfg, eval_docs=eval_docs, shards=shards)

    dense, sparse = build(1), build(RATE)
    assert sparse.index.options.sample_rate == RATE
    assert sparse.index.min_pattern_len == RATE
    assert dense.report.dropped_chars > 0          # real duplicates removed
    assert sparse.report.dropped_chars == dense.report.dropped_chars
    assert len(sparse._kept) == len(dense._kept)
    for a, b in zip(sparse._kept, dense._kept):
        np.testing.assert_array_equal(a, b)
    for step in range(4):                          # gated batches included
        ba, bb = sparse.batch_at(step), dense.batch_at(step)
        assert sorted(ba) == sorted(bb)
        for k in ba:
            np.testing.assert_array_equal(ba[k], bb[k])
    # probe rides the sparse training index: floored, never an exception
    m = sparse.probe([sparse._kept[0][:MIN_LEN * 2],
                      np.full(MIN_LEN, VOCAB - 1)])
    assert m["samples"] == 2 and m["longest_copy_max"] >= MIN_LEN
