"""Sparse suffix-array construction: the sampled SA must equal the dense
SA restricted to sampled positions (the brute-force oracle), across the
corpus families that stress the stride-doubling tie-break; `sparse_lcp`
must equal its naive per-pair definition."""
import numpy as np
import pytest

from repro.api import SAOptions, build_suffix_array
from repro.sparse import build_sparse_suffix_array, sparse_lcp


def _oracle_sparse(text, rate):
    sa = build_suffix_array(np.asarray(text, np.int64), backend="oracle")
    sa = np.asarray(sa, np.int64)
    return sa[sa % rate == 0]


def _naive_lcp(text, ssa):
    text = np.asarray(text, np.int64)
    out = np.zeros(len(ssa), np.int64)
    for i in range(1, len(ssa)):
        a, b = int(ssa[i - 1]), int(ssa[i])
        k = 0
        while a + k < len(text) and b + k < len(text) \
                and text[a + k] == text[b + k]:
            k += 1
        out[i] = k
    return out


CORPORA = {
    "uniform": lambda rng, n: rng.integers(0, 5, n),
    "binary": lambda rng, n: rng.integers(0, 2, n),
    "all_equal": lambda rng, n: np.zeros(n, np.int64),
    "periodic": lambda rng, n: np.tile([1, 0, 2], n // 3 + 1)[:n],
    "large_alpha": lambda rng, n: rng.integers(0, 1 << 20, n),
}


@pytest.mark.parametrize("family", sorted(CORPORA))
@pytest.mark.parametrize("rate", [2, 3, 4, 7, 16])
def test_matches_dense_filtered_oracle(family, rate):
    rng = np.random.default_rng([family == f for f in CORPORA] + [rate])
    for n in (1, 2, rate - 1, rate, rate + 1, 5 * rate, 257):
        text = np.asarray(CORPORA[family](rng, n), np.int64)
        got = build_sparse_suffix_array(text, rate)
        want = _oracle_sparse(text, rate)
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"{family} n={n} rate={rate}")
        assert got.dtype == np.int32


def test_empty_text():
    assert len(build_sparse_suffix_array(np.zeros(0, np.int64), 4)) == 0


def test_rejects_bad_inputs():
    with pytest.raises(ValueError, match="sample_rate"):
        build_sparse_suffix_array(np.asarray([1, 2, 3]), 1)
    with pytest.raises(ValueError, match="≥ 0"):
        build_sparse_suffix_array(np.asarray([1, -2, 3]), 4)


@pytest.mark.parametrize("family", ["uniform", "all_equal", "periodic"])
def test_sparse_lcp_matches_naive(family):
    rng = np.random.default_rng(5)
    for n in (0, 1, 7, 64, 300):
        text = np.asarray(CORPORA[family](rng, n), np.int64)
        ssa = build_sparse_suffix_array(text, 4)
        np.testing.assert_array_equal(
            sparse_lcp(text, ssa), _naive_lcp(text, ssa),
            err_msg=f"{family} n={n}")
        # chunk smaller than the longest LCP exercises the refill loop
        np.testing.assert_array_equal(
            sparse_lcp(text, ssa, chunk=3), _naive_lcp(text, ssa))
