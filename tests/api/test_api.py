"""Equivalence + contract tests for the `repro.api` facade.

Every registered backend must agree with the oracle on random strings,
repetitive strings, tiny/empty inputs, and multi-document corpora; the
plan object round-trips; the legacy `repro.text.corpus_sa` /
`repro.text.dedup` shims keep working (with DeprecationWarnings).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.api import (SAOptions, SuffixArrayIndex, build_suffix_array,
                       encode_docs, get_backend, register_backend,
                       registered_backends)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                   "src"))
BACKENDS = registered_backends()


def _naive_sa(x):
    x = np.asarray(x, np.int64)
    return np.asarray(sorted(range(len(x)), key=lambda i: tuple(x[i:])),
                      np.int64)


def _cases():
    rng = np.random.default_rng(42)
    cases = {
        "empty": np.zeros(0, np.int64),
        "single": np.asarray([5]),
        "pair": np.asarray([1, 0]),
        "all-equal": np.zeros(97, np.int64),
        "period-2": np.tile([0, 1], 60),
        "descending": np.arange(50)[::-1].copy(),
        "fibonacci-word": None,   # filled below — maximally repetitive
    }
    fib = [0]
    a, b = [0], [0, 1]
    while len(b) < 120:
        a, b = b, b + a
    cases["fibonacci-word"] = np.asarray(b[:120])
    for sigma in (2, 4, 26):
        cases[f"random-s{sigma}"] = rng.integers(0, sigma, size=150)
    return cases


CASES = _cases()


# ------------------------------------------------------------- equivalence
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("case", sorted(CASES))
def test_backend_matches_oracle(backend, case):
    x = CASES[case]
    got = build_suffix_array(x, backend=backend)
    assert got.dtype == np.int32
    assert np.array_equal(got, _naive_sa(x)), (backend, case)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_multidoc_matches_oracle(backend):
    rng = np.random.default_rng(7)
    docs = [rng.integers(0, 3, size=int(rng.integers(1, 40)))
            for _ in range(4)] + [np.zeros(0, np.int64)]
    text, starts, n_docs = encode_docs(docs)
    got = build_suffix_array(text, backend=backend)
    assert np.array_equal(got, _naive_sa(text)), backend
    idx = SuffixArrayIndex.from_docs(docs, backend=backend)
    assert np.array_equal(idx.sa, got)
    assert idx.n_docs == n_docs == 5 and idx.sep_count == 5


def test_all_backends_identical_results():
    """The acceptance criterion verbatim: identical SAs across backends."""
    rng = np.random.default_rng(3)
    for _ in range(3):
        x = rng.integers(0, 5, size=int(rng.integers(2, 200)))
        sas = {b: build_suffix_array(x, SAOptions(backend=b)).tolist()
               for b in BACKENDS}
        assert len({tuple(v) for v in sas.values()}) == 1, sas


# ------------------------------------------------------------------- plan
def test_options_defaults_and_auto_rule():
    opts = SAOptions()
    assert opts.backend == "auto" and opts.resolve_backend() == "jax"
    assert opts.v0 == 3 and opts.schedule == "accelerated"
    assert opts.base_threshold is None and opts.mesh is None
    assert SAOptions(mesh=object()).resolve_backend() == "bsp"
    assert SAOptions(backend="seq", mesh=object()).resolve_backend() == "seq"


def test_options_roundtrip_and_validation():
    opts = SAOptions(backend="seq", v0=7, schedule="fixed", base_threshold=64)
    opts2 = opts.replace(backend="jax")
    assert opts2.backend == "jax" and opts2.v0 == 7   # others preserved
    assert opts.backend == "seq"                      # frozen original
    with pytest.raises(ValueError):
        SAOptions(schedule="warp")
    with pytest.raises(ValueError):
        SAOptions(v0=2)
    assert callable(opts.schedule_fn)
    custom = SAOptions(schedule=lambda v, d, m: 3)
    assert custom.schedule_fn(9, 3, 10) == 3


def test_saconfig_produces_options():
    from repro.configs.suffix_array import SAConfig
    cfg = SAConfig(backend="seq", v0=5, schedule="fixed", base_threshold=99)
    opts = cfg.to_options()
    assert isinstance(opts, SAOptions)
    assert (opts.backend, opts.v0, opts.schedule, opts.base_threshold) == \
        ("seq", 5, "fixed", 99)
    mesh = object()
    assert cfg.to_options(mesh=mesh).mesh is mesh
    from repro.configs import get_config
    assert get_config("suffix_array").to_options().resolve_backend() == "jax"


def test_build_rejects_bad_input():
    with pytest.raises(ValueError):
        build_suffix_array(np.asarray([[0, 1], [1, 0]]))
    with pytest.raises(ValueError):
        build_suffix_array(np.asarray([1, -2, 3]))
    with pytest.raises(TypeError):
        build_suffix_array(np.asarray([0.5, 1.5]))
    with pytest.raises(KeyError):
        build_suffix_array(np.asarray([1, 0]), backend="nope")


def test_register_backend():
    def fake(x, options):
        return np.arange(len(x))[::-1]
    register_backend("reversed-fake", fake)
    try:
        assert "reversed-fake" in registered_backends()
        assert get_backend("reversed-fake") is fake
        with pytest.raises(ValueError):
            register_backend("reversed-fake", fake)
        got = build_suffix_array(np.asarray([3, 2, 1]),
                                 backend="reversed-fake")
        assert got.tolist() == [2, 1, 0]
    finally:
        from repro.api import registry
        registry._REGISTRY.pop("reversed-fake", None)


# ------------------------------------------------------------------ index
def test_count_locate_match_naive():
    rng = np.random.default_rng(11)
    x = rng.integers(0, 4, size=400)
    idx = SuffixArrayIndex.build(x)
    for m in (1, 2, 3, 5, 9):
        for _ in range(10):
            pat = rng.integers(0, 4, size=m)
            want = [i for i in range(len(x) - m + 1)
                    if x[i:i + m].tolist() == pat.tolist()]
            assert idx.count(pat) == len(want)
            assert idx.locate(pat).tolist() == want
    assert idx.count([]) == len(x)       # empty prefix of every suffix
    with pytest.raises(ValueError):      # "every position" is not a locate
        idx.locate([])
    assert idx.count(np.zeros(401, np.int64)) == 0   # longer than the text


def test_multidoc_queries_respect_boundaries():
    # "ab" + "ab": pattern "ba" must NOT match across the boundary
    idx = SuffixArrayIndex.from_docs([[0, 1], [0, 1]])
    assert idx.count([0, 1]) == 2
    assert idx.count([1, 0]) == 0
    assert idx.locate_docs([0, 1]).tolist() == [[0, 0], [1, 0]]
    doc, off = idx.doc_offset(idx.locate([0, 1]))
    assert np.asarray(doc).tolist() == [0, 1]
    assert np.asarray(off).tolist() == [0, 0]


def test_ngram_stats_excludes_separators():
    idx = SuffixArrayIndex.from_docs([[0, 1, 0], [0, 1]])
    st = idx.ngram_stats(2)
    # windows: doc0 {01, 10}, doc1 {01} → total 3, distinct 2
    assert (st.total, st.distinct) == (3, 2)
    single = SuffixArrayIndex.build(np.asarray([0, 1, 0, 1, 0]))
    st2 = single.ngram_stats(2)
    assert (st2.total, st2.distinct) == (4, 2)
    assert single.ngram_stats(0).total == 0


def test_cross_doc_duplicates_vectorised():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 50, 300)
    b = rng.integers(0, 50, 300)
    b[100:180] = a[50:130]                  # contaminate doc 1 with doc 0
    idx = SuffixArrayIndex.from_docs([a, b])
    hits = idx.cross_doc_duplicates(min_len=60)
    assert any(l >= 80 for _, _, l in hits)
    assert all(i == 0 and j == 1 for i, j, _ in hits)
    assert idx.cross_doc_duplicates(min_len=10_000) == []


def test_lcp_lazy_and_duplicate_spans():
    x = np.asarray([0, 1, 2, 0, 1, 2, 0, 1, 2])
    idx = SuffixArrayIndex.build(x)
    assert idx._lcp is None                 # not built yet
    spans = idx.duplicate_spans(min_len=3)
    assert idx._lcp is not None             # built exactly when needed
    covered = set()
    for s, e in spans:
        covered.update(range(s, e))
    assert set(range(6)) <= covered         # positions 0..5 repeat


# ------------------------------------------------------------------ shims
def test_corpus_sa_shim_matches_facade():
    from repro.text.corpus_sa import (build_corpus_sa, count_occurrences,
                                      cross_doc_duplicates)
    docs = [np.asarray([0, 1, 0, 2]), np.asarray([2, 0, 1])]
    with pytest.deprecated_call():
        csa = build_corpus_sa(docs)
    idx = SuffixArrayIndex.from_docs(docs)
    assert np.array_equal(csa.sa, idx.sa)
    assert np.array_equal(csa.text, idx.text)
    with pytest.deprecated_call():
        assert count_occurrences(csa, [0, 1]) == idx.count([0, 1]) == 2
    with pytest.deprecated_call():
        assert cross_doc_duplicates(csa, 2) == idx.cross_doc_duplicates(2)
    # doc_of now accepts arrays (and still scalars)
    assert csa.doc_of(0) == 0
    assert csa.doc_of(np.asarray([0, 5, 6])).tolist() == [0, 1, 1]
    # legacy sa_builder= passthrough
    with pytest.deprecated_call():
        csa2 = build_corpus_sa(docs, sa_builder=_naive_sa)
    assert np.array_equal(csa2.sa, idx.sa)


def test_dedup_through_facade():
    from repro.text.dedup import dedup_corpus, find_duplicates
    rng = np.random.default_rng(1)
    x = rng.integers(0, 64, 800)
    x[500:620] = x[100:220]
    rep = find_duplicates(x, min_len=64, options=SAOptions(backend="jax"))
    assert rep.dup_chars >= 120
    out, rep2 = dedup_corpus(x, min_len=64)
    assert len(out) < len(x)
    with pytest.deprecated_call():          # legacy sa_builder kwarg
        rep3 = find_duplicates(x, min_len=64, sa_builder=_naive_sa)
    assert rep3.spans == rep.spans


# ------------------------------------------------- distributed auto-select
def test_mesh_auto_selects_bsp_subprocess():
    """With a real 8-device mesh in the plan, `backend="auto"` must run the
    BSP builder and agree with the oracle (the facade acceptance check)."""
    code = textwrap.dedent("""
    import jax, numpy as np
    from repro.api import SAOptions, build_suffix_array
    from repro.bsp.counters import BSPCounters
    from repro.launch.mesh import make_sa_mesh
    mesh = make_sa_mesh(8)
    ct = BSPCounters()
    opts = SAOptions(mesh=mesh, base_threshold=64, counters=ct)
    assert opts.resolve_backend() == "bsp"
    rng = np.random.default_rng(5)
    x = rng.integers(0, 3, size=1200)
    got = build_suffix_array(x, opts)
    want = build_suffix_array(x, backend="oracle")
    assert np.array_equal(got, want)
    assert ct.supersteps > 0      # proof the BSP path actually ran
    print("AUTO_BSP_OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=420, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "AUTO_BSP_OK" in r.stdout
