"""Regression tests for `SuffixArrayIndex` edge cases: empty corpora,
empty documents, n == 0 queries, and the doc_of/doc_offset range contract
(empty results, never a crash or a silent wrap-around)."""
import numpy as np
import pytest

from repro.api import SuffixArrayIndex
from repro.api.index import encode_docs


# ---------------------------------------------------------------- empties
def test_from_docs_empty_corpus_queries_are_empty():
    idx = SuffixArrayIndex.from_docs([])
    assert idx.n == 0 and idx.n_docs == 0 and idx.sep_count == 0
    assert idx.count([1, 2]) == 0
    assert idx.count([]) == 0
    assert idx.locate([1, 2]).tolist() == []
    assert idx.locate_docs([1]).shape == (0, 2)
    st = idx.ngram_stats(3)
    assert (st.total, st.distinct) == (0, 0)
    assert idx.lcp.tolist() == []
    assert idx.duplicate_spans(2) == []
    assert idx.cross_doc_duplicates(2) == []


def test_build_empty_text_queries_are_empty():
    idx = SuffixArrayIndex.build(np.zeros(0, np.int64))
    assert idx.n == 0
    assert idx.count([0]) == 0
    assert idx.locate([0]).tolist() == []
    assert idx.ngram_stats(1).total == 0
    assert idx.lcp.tolist() == []


def test_encode_docs_empty():
    text, starts, n_docs = encode_docs([])
    assert len(text) == 0 and len(starts) == 0 and n_docs == 0


def test_from_docs_all_empty_docs():
    idx = SuffixArrayIndex.from_docs([[], []])
    # two separators only, no payload → the data alphabet is empty and
    # every data query is out-of-alphabet (rejected, not silently 0)
    assert idx.n == 2 and idx.n_docs == 2
    assert idx.sigma == 0
    with pytest.raises(ValueError):
        idx.count([0])
    assert idx.count([]) == 2           # empty prefix of both separators
    assert idx.ngram_stats(1).total == 0
    assert idx.duplicate_spans(1) == []
    assert idx.cross_doc_duplicates(1) == []


def test_from_docs_empty_doc_mixed_with_real():
    idx = SuffixArrayIndex.from_docs([[], [1, 2, 1, 2]])
    pos = idx.locate([1, 2])
    assert len(pos) == 2
    docs = idx.locate_docs([1, 2])
    assert docs[:, 0].tolist() == [1, 1]
    assert docs[:, 1].tolist() == [0, 2]
    assert idx.count([2, 1]) == 1


# ------------------------------------------------- doc_of / doc_offset
def test_doc_of_empty_index_rejects_positions():
    idx = SuffixArrayIndex.from_docs([])
    with pytest.raises(IndexError):
        idx.doc_of(0)
    with pytest.raises(IndexError):
        idx.doc_offset(0)


def test_doc_of_empty_position_array_is_empty():
    idx = SuffixArrayIndex.from_docs([])
    assert idx.doc_of(np.zeros(0, np.int64)).tolist() == []
    doc, off = idx.doc_offset(np.zeros(0, np.int64))
    assert doc.tolist() == [] and off.tolist() == []


def test_doc_of_out_of_range_raises_not_wraps():
    idx = SuffixArrayIndex.from_docs([[5, 6], [7]])
    with pytest.raises(IndexError):
        idx.doc_of(-1)                  # used to wrap to the last document
    with pytest.raises(IndexError):
        idx.doc_of(idx.n)
    with pytest.raises(IndexError):
        idx.doc_of(np.array([0, idx.n + 3]))
    # in-range still exact
    assert idx.doc_of(0) == 0
    assert idx.doc_of(idx.n - 1) == 1


def test_doc_offset_roundtrip():
    docs = [[3, 4, 5], [6], [7, 8]]
    idx = SuffixArrayIndex.from_docs(docs)
    for d, doc in enumerate(docs):
        for off in range(len(doc)):
            pos = int(idx.doc_starts[d]) + off
            dd, oo = idx.doc_offset(pos)
            assert (dd, int(oo)) == (d, off)


# ------------------------------------------------------------ n==0 probes
def test_suffix_cmp_no_wraparound_on_empty_index():
    idx = SuffixArrayIndex.from_docs([])
    # direct probe of the vectorised comparator: on n==0 every suffix is
    # past-the-end, strictly below any pattern — and never wraps text[-1].
    out = idx._suffix_cmp(np.array([0]), np.array([3]))
    assert out.tolist() == [-1]
    out = idx._suffix_cmp(np.array([0, 1]), np.zeros(0, np.int64))
    assert out.tolist() == [0, 0]       # empty pattern prefixes everything


def test_pattern_longer_than_text():
    idx = SuffixArrayIndex.build(np.array([1, 2]))
    assert idx.count([1, 2, 2]) == 0        # longer than the text: 0
    assert idx.locate([1, 2, 2]).tolist() == []
    assert idx.count([1, 2]) == 1
    with pytest.raises(ValueError):         # 3 ≥ sigma: rejected, not 0
        idx.count([1, 2, 3])
