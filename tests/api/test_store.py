"""`IndexStore` / persistence tests: save→load→query round-trips across
backends, staleness detection (plan fingerprint, corpus hash, format
version), the hardened `restore_checkpoint` validation it relies on, and
the serving acceptance check — a warm store restart skips the build
entirely (builder-cache stats stay at zero in a fresh process).
"""
import json
import os
import subprocess
import sys
import textwrap
import zipfile

import numpy as np
import pytest

from repro.api import (IndexStore, SAOptions, StaleIndexError,
                       SuffixArrayIndex, corpus_fingerprint, encode_docs,
                       load_index, save_index)
from repro.ckpt.checkpoint import restore_checkpoint, save_checkpoint

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                   "src"))


def _docs(seed=3, n_docs=3, max_len=60):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 5, int(rng.integers(5, max_len)))
            for _ in range(n_docs)]


# ------------------------------------------------------------ round-trips
@pytest.mark.parametrize("backend", ["jax", "bsp"])   # bsp: p=1 degenerate
def test_save_load_query_roundtrip(backend, tmp_path):
    docs = _docs()
    opts = SAOptions(backend=backend, base_threshold=64)
    idx = SuffixArrayIndex.from_docs(docs, opts)
    path = str(tmp_path / "idx")
    idx.save(path)
    got = SuffixArrayIndex.load(path, options=opts)
    assert np.array_equal(got.text, idx.text)
    assert np.array_equal(got.sa, idx.sa)
    assert np.array_equal(got.doc_starts, idx.doc_starts)
    assert (got.shift, got.sigma, got.n_docs) == \
        (idx.shift, idx.sigma, idx.n_docs)
    # restored index answers queries identically (batched + scalar)
    pats = [docs[0][:4].tolist(), docs[1].tolist(), [4, 4, 4, 4]]
    assert got.count_batch(pats).tolist() == idx.count_batch(pats).tolist()
    assert got.locate(pats[0]).tolist() == idx.locate(pats[0]).tolist()
    assert got.cross_doc_duplicates(2) == idx.cross_doc_duplicates(2)


def test_restored_index_resaves_with_same_plan_fingerprint(tmp_path):
    """load → save must not relabel the artifact with a default plan."""
    opts = SAOptions(backend="jax", v0=7, schedule="fixed")
    idx = SuffixArrayIndex.build(np.asarray([0, 1, 2, 0, 1]), opts)
    p1, p2, p3 = (str(tmp_path / n) for n in ("a", "b", "c"))
    idx.save(p1)
    # restored WITHOUT passing options: the persisted plan is re-attached
    restored = SuffixArrayIndex.load(p1)
    assert restored.options.fingerprint() == opts.fingerprint()
    restored.save(p2)
    assert SuffixArrayIndex.load(p2, options=opts).n == idx.n
    # restored WITH options: those take over (already fingerprint-checked)
    SuffixArrayIndex.load(p1, options=opts).save(p3)
    assert SuffixArrayIndex.load(p3, options=opts).n == idx.n


def test_callable_schedule_keeps_other_plan_fields(tmp_path):
    """A callable schedule can't round-trip, but every other plan field
    must survive a load (not collapse to a default SAOptions)."""
    opts = SAOptions(backend="jax", v0=7, schedule=lambda v, d, m: m,
                     sort_impl="lax")
    idx = SuffixArrayIndex.build(np.asarray([0, 1, 2, 0, 1]), opts)
    path = str(tmp_path / "idx")
    idx.save(path)
    restored = SuffixArrayIndex.load(path)
    ro = restored.options
    assert (ro.backend, ro.v0, ro.sort_impl) == ("jax", 7, "lax")
    assert ro.schedule == "accelerated"       # the one lossy field


def test_lcp_persisted_only_when_computed(tmp_path):
    idx = SuffixArrayIndex.build(np.tile([0, 1, 2], 40))
    p1 = str(tmp_path / "nolcp")
    idx.save(p1)
    assert SuffixArrayIndex.load(p1)._lcp is None     # stayed lazy
    _ = idx.lcp                                       # force Kasai
    p2 = str(tmp_path / "lcp")
    idx.save(p2)
    restored = SuffixArrayIndex.load(p2)
    assert restored._lcp is not None                  # no recompute needed
    assert np.array_equal(restored.lcp, idx.lcp)


def test_empty_index_roundtrip(tmp_path):
    idx = SuffixArrayIndex.from_docs([])
    path = str(tmp_path / "empty")
    idx.save(path)
    got = SuffixArrayIndex.load(path)
    assert got.n == 0 and got.n_docs == 0 and got.count([]) == 0


# -------------------------------------------------------------- staleness
def test_load_rejects_wrong_plan_and_corpus(tmp_path):
    docs = _docs()
    opts = SAOptions(backend="jax")
    idx = SuffixArrayIndex.from_docs(docs, opts)
    path = str(tmp_path / "idx")
    save_index(path, idx)
    with pytest.raises(StaleIndexError, match="plan"):
        load_index(path, options=SAOptions(backend="jax", v0=7))
    with pytest.raises(StaleIndexError, match="corpus"):
        load_index(path, expect_corpus_sha="0" * 64)
    # without expectations the artifact loads fine
    assert load_index(path).n == idx.n


def test_load_rejects_format_version_and_kind(tmp_path):
    idx = SuffixArrayIndex.build(np.asarray([0, 1, 0, 1]))
    path = str(tmp_path / "idx")
    save_index(path, idx)
    mpath = os.path.join(path, "step_00000000", "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["extras"]["format"] = 999
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(StaleIndexError, match="format"):
        load_index(path)
    manifest["extras"]["kind"] = "lm-checkpoint"
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(StaleIndexError, match="not a suffix-array"):
        load_index(path)


def test_get_or_build_traffic(tmp_path):
    docs = _docs(seed=11)
    opts = SAOptions(backend="jax")
    text, _, _ = encode_docs(docs)
    sha = corpus_fingerprint(text)
    store = IndexStore(str(tmp_path / "store"))
    builds = []

    def build():
        builds.append(1)
        return SuffixArrayIndex.from_docs(docs, opts)

    _, s1 = store.get_or_build("c", build, options=opts, corpus_sha=sha)
    _, s2 = store.get_or_build("c", build, options=opts, corpus_sha=sha)
    assert (s1, s2) == ("miss", "hit") and len(builds) == 1
    # corpus changed → stale → rebuild + re-persist
    _, s3 = store.get_or_build("c", build, options=opts,
                               corpus_sha="f" * 64)
    assert s3 == "stale" and len(builds) == 2
    assert store.stats() == {"entries": 1, "hits": 1, "misses": 1,
                             "stale": 1}
    assert store.entries() == ["c"]
    assert store.manifest_age("c") is not None
    assert store.manifest_age("nope") is None
    with pytest.raises(ValueError):
        store.path("../escape")
    with pytest.raises(FileNotFoundError):
        store.load("nope")


def test_get_or_build_stats_are_atomic(tmp_path):
    """Stats must update atomically with the returned (index, status) —
    a build_fn that raises on the miss or stale-then-rebuild path leaves
    `stats()` untouched instead of recording a rebuild that never
    completed."""
    docs = _docs(seed=21)
    opts = SAOptions(backend="jax")
    store = IndexStore(str(tmp_path / "store"))

    def boom():
        raise RuntimeError("builder exploded")

    # failing build on the MISS path: no phantom miss recorded
    with pytest.raises(RuntimeError, match="exploded"):
        store.get_or_build("c", boom, options=opts)
    assert store.stats() == {"entries": 0, "hits": 0, "misses": 0,
                             "stale": 0}

    build = lambda: SuffixArrayIndex.from_docs(docs, opts)
    _, s = store.get_or_build("c", build, options=opts)
    assert s == "miss"
    # failing build on the STALE-then-rebuild path: entry exists but the
    # plan mismatches; the rebuild raises → no phantom stale recorded
    with pytest.raises(RuntimeError, match="exploded"):
        store.get_or_build("c", boom,
                           options=SAOptions(backend="jax", v0=7))
    assert store.stats() == {"entries": 1, "hits": 0, "misses": 1,
                             "stale": 0}
    # and the surviving entry still hits
    _, s = store.get_or_build("c", build, options=opts)
    assert s == "hit"


def test_get_or_build_stats_under_concurrency(tmp_path):
    """Concurrent warm readers must not lose stat increments."""
    import threading
    docs = _docs(seed=22)
    opts = SAOptions(backend="jax")
    store = IndexStore(str(tmp_path / "store"))
    idx = SuffixArrayIndex.from_docs(docs, opts)
    store.save("c", idx)
    statuses, errs = [], []

    def worker():
        try:
            _, s = store.get_or_build("c", lambda: idx, options=opts)
            statuses.append(s)
        except Exception as e:                      # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker) for _ in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs and statuses == ["hit"] * 16
    assert store.stats()["hits"] == 16


# --------------------------------------- backend × sort_impl round-trips
#: every meaningful persistence cell: oracle/seq ignore sort_impl (one
#: cell each), jax takes every impl, bsp everything but pallas.
_RT_CELLS = ([("oracle", "auto"), ("seq", "auto")]
             + [("jax", s) for s in ("auto", "radix", "lax", "bitonic",
                                     "pallas")]
             + [("bsp", s) for s in ("auto", "radix", "lax", "bitonic")])


@pytest.mark.parametrize("backend,sort_impl", _RT_CELLS,
                         ids=[f"{b}-{s}" for b, s in _RT_CELLS])
def test_roundtrip_matrix(backend, sort_impl, tmp_path):
    """Save → load → query for every backend × sort_impl cell; the
    restored index must re-check against the SAME plan fingerprint and
    answer queries identically."""
    # pallas row-sort kernels run interpret=True on CPU: keep n tiny
    docs = (_docs(seed=7, n_docs=2, max_len=12) if sort_impl == "pallas"
            else _docs(seed=7))
    opts = SAOptions(backend=backend, sort_impl=sort_impl,
                     base_threshold=64)
    idx = SuffixArrayIndex.from_docs(docs, opts)
    path = str(tmp_path / "idx")
    save_index(path, idx)
    got = load_index(path, options=opts)
    assert np.array_equal(got.sa, idx.sa)
    assert np.array_equal(got.text, idx.text)
    pats = [docs[0][:3].tolist(), [4, 4, 4], [0]]
    assert got.count_batch(pats).tolist() == idx.count_batch(pats).tolist()
    # a different sort_impl is a different plan → stale, never silent
    other = "lax" if sort_impl != "lax" else "radix"
    with pytest.raises(StaleIndexError, match="plan"):
        load_index(path, options=opts.replace(sort_impl=other))


def test_bsp_rejects_pallas_sort_impl():
    docs = _docs(seed=7, n_docs=2, max_len=12)
    with pytest.raises(ValueError, match="pallas"):
        SuffixArrayIndex.from_docs(
            docs, SAOptions(backend="bsp", sort_impl="pallas"))


def test_tampered_segment_manifest_surfaces_through_store(tmp_path):
    """Segmented persistence: hand-editing one SEGMENT's own checkpoint
    manifest (not the corpus manifest) must surface as StaleIndexError
    through SegmentedIndexStore.load — the per-segment corpus sha check
    catches it."""
    from repro.api import SegmentedIndex, SegmentedIndexStore
    store = SegmentedIndexStore(str(tmp_path / "segstore"))
    sidx = SegmentedIndex.from_docs(_docs(seed=8), SAOptions(backend="seq"),
                                    segment_docs=2)
    store.save("corpus", sidx)
    seg_id = sidx.segments[0].seg_id
    mpath = os.path.join(store.path("corpus"), "segments", seg_id,
                         "step_00000000", "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["extras"]["corpus_sha256"] = "f" * 64
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(StaleIndexError, match="corpus"):
        store.load("corpus")


def test_fingerprint_covers_plan_not_runtime():
    base = SAOptions(backend="jax", v0=3)
    assert base.fingerprint() == SAOptions(backend="jax").fingerprint()
    # runtime objects and execution knobs don't invalidate artifacts
    assert base.fingerprint() == \
        SAOptions(backend="jax", cache=False, counters=object(),
                  stats=object(), validate=False).fingerprint()
    # construction fields do — sample_rate included: a sparse artifact
    # answers a different query contract than a dense one
    for change in ({"v0": 7}, {"schedule": "fixed"}, {"base_threshold": 99},
                   {"sort_impl": "lax"}, {"backend": "seq"},
                   {"sample_rate": 4}):
        assert base.replace(**change).fingerprint() != base.fingerprint()


# ------------------------------------------------ sparse index persistence
def test_sparse_roundtrip_and_rate_mismatch(tmp_path):
    """Sparse save → load restores a SparseSuffixArrayIndex that answers
    identically; loading against a plan with a different sample_rate (or
    a dense plan) is stale, never a silently wrong index."""
    from repro.sparse import SparseSuffixArrayIndex
    docs = _docs(seed=31, max_len=80)
    opts = SAOptions(sample_rate=4)
    idx = SuffixArrayIndex.from_docs(docs, opts)
    path = str(tmp_path / "sparse")
    save_index(path, idx)
    got = load_index(path, options=opts)
    assert isinstance(got, SparseSuffixArrayIndex)
    assert got.sample_rate == 4
    assert np.array_equal(got.sa, idx.sa)
    assert np.array_equal(got.text, idx.text)
    pats = [docs[0][:4].tolist(), docs[0][:5].tolist(), [4, 4, 4, 4]]
    assert got.count_batch(pats).tolist() == idx.count_batch(pats).tolist()
    assert got.locate(pats[0]).tolist() == idx.locate(pats[0]).tolist()
    # load WITHOUT options: the persisted plan re-attaches, rate included
    restored = load_index(path)
    assert isinstance(restored, SparseSuffixArrayIndex)
    assert restored.options.sample_rate == 4
    assert restored.options.fingerprint() == opts.fingerprint()
    # mismatched rate → different plan fingerprint → stale
    with pytest.raises(StaleIndexError, match="plan"):
        load_index(path, options=opts.replace(sample_rate=8))
    with pytest.raises(StaleIndexError, match="plan"):
        load_index(path, options=SAOptions())      # dense plan, sparse disk


def test_sparse_kind_rate_tamper_is_stale(tmp_path):
    """A manifest whose kind and sample_rate disagree (hand-edited or
    half-written) must refuse to load in BOTH directions."""
    text = np.arange(64) % 5
    for build_rate, forged in ((4, 1), (1, 4)):
        idx = SuffixArrayIndex.build(text, SAOptions(sample_rate=build_rate))
        path = str(tmp_path / f"r{build_rate}")
        save_index(path, idx)
        mpath = os.path.join(path, "step_00000000", "manifest.json")
        with open(mpath) as f:
            manifest = json.load(f)
        manifest["extras"]["sample_rate"] = forged
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        with pytest.raises(StaleIndexError, match="tampered|half-written"):
            load_index(path)


# ------------------------------------------- restore_checkpoint hardening
def _tree():
    return {"a": np.arange(6, dtype=np.int32),
            "b": np.ones((2, 3), np.float32)}


def test_restore_validates_shape_dtype_and_count(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 0, _tree())
    ok, _ = restore_checkpoint(d, 0, _tree())
    assert np.array_equal(ok["a"], _tree()["a"])
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(d, 0, {"a": np.zeros(5, np.int32),
                                  "b": np.ones((2, 3), np.float32)})
    with pytest.raises(ValueError, match="dtype"):
        restore_checkpoint(d, 0, {"a": np.zeros(6, np.int64),
                                  "b": np.ones((2, 3), np.float32)})
    with pytest.raises(ValueError, match="leaves"):
        restore_checkpoint(d, 0, {"a": np.zeros(6, np.int32)})
    with pytest.raises(FileNotFoundError, match="COMMITTED"):
        restore_checkpoint(d, 99, _tree())


def test_restore_detects_manifest_npz_disagreement(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 0, _tree())
    step = os.path.join(d, "step_00000000")
    # arrays.npz rewritten with a different shape for leaf 0, manifest kept:
    # like_tree matching the *new* npz must still fail on the manifest check
    np.savez(os.path.join(step, "arrays.npz"),
             **{"0": np.arange(4, dtype=np.int32),
                "1": np.ones((2, 3), np.float32)})
    with pytest.raises(ValueError, match="manifest"):
        restore_checkpoint(d, 0, {"a": np.zeros(4, np.int32),
                                  "b": np.ones((2, 3), np.float32)})


def test_restore_detects_truncated_npz(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 0, _tree())
    step = os.path.join(d, "step_00000000")
    npz = os.path.join(step, "arrays.npz")
    with zipfile.ZipFile(npz) as z:
        keep = z.read("0.npy")
    with zipfile.ZipFile(npz, "w") as z:      # drop leaf 1 entirely
        z.writestr("0.npy", keep)
    with pytest.raises(ValueError, match="leaves|missing"):
        restore_checkpoint(d, 0, _tree())


def test_store_surfaces_tampered_arrays(tmp_path):
    """The full stack: a corrupted store entry raises a descriptive error
    through IndexStore.load instead of restoring garbage."""
    idx = SuffixArrayIndex.build(np.asarray([0, 1, 2, 1, 0]))
    path = str(tmp_path / "idx")
    save_index(path, idx)
    step = os.path.join(path, "step_00000000")
    data = dict(np.load(os.path.join(step, "arrays.npz")))
    data["2"] = data["2"][:2]                 # truncate one leaf
    np.savez(os.path.join(step, "arrays.npz"), **data)
    with pytest.raises(ValueError, match="shape"):
        load_index(path)


# ------------------------------------------------- warm serve (subprocess)
def test_serve_restart_with_warm_store_skips_build(tmp_path):
    """Acceptance: a serve restart with a warm IndexStore restores instead
    of rebuilding — the second process reports a store hit and ZERO
    builder-cache traffic (no build_suffix_array call at all)."""
    code = textwrap.dedent(f"""
    from repro.api import builder_cache_stats
    from repro.configs import get_config
    from repro.launch.serve import serve_sa_queries
    serve_sa_queries(get_config("suffix-array"), n_chars=4000, n_docs=2,
                     n_queries=8, pattern_len=8,
                     store_dir={str(tmp_path / 'store')!r}, query_batch=8)
    print("BUILDER_STATS", builder_cache_stats())
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    outs = []
    for _ in range(2):
        r = subprocess.run([sys.executable, "-c", code], env=env, text=True,
                           capture_output=True, timeout=420)
        assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
        outs.append(r.stdout)
    assert "index store: miss" in outs[0]
    assert "indexed" in outs[0]
    assert "index store: hit" in outs[1]
    assert "restored" in outs[1]
    assert "BUILDER_STATS {'entries': 0, 'hits': 0, 'misses': 0}" in outs[1]
