"""Equivalence + caching tests for the jax backend's pluggable sort path.

Two families:

* every `sort_impl` choice must reproduce the oracle suffix array on
  random and degenerate inputs (all-equal characters, tiny n, lengths
  exactly at / just past a pad-bucket boundary), with and without bucketed
  padding;
* the compiled-builder cache must actually prevent re-tracing: a second
  build of the same bucketed shape adds zero jax trace events and counts
  as a cache hit.
"""
import numpy as np
import pytest

from repro.api import (SAOptions, build_suffix_array, builder_cache_stats,
                       clear_builder_cache)
from repro.core import dcv_jax
from repro.core.dcv_jax import pad_bucket, resolve_sort_impl, suffix_array_jax

RNG = np.random.default_rng(20260731)

#: name → text. Degenerate shapes on purpose; see ISSUE 2 satellite 5.
TEXTS = {
    "rand256": RNG.integers(0, 256, 900),
    "rand4": RNG.integers(0, 4, 700),
    "binary": RNG.integers(0, 2, 500),
    "all_equal": np.full(400, 7),
    "periodic": np.tile([2, 1, 3], 150),
    "tiny2": np.array([1, 0]),
    "tiny3": np.array([2, 2, 2]),
    "tiny5": np.array([4, 1, 4, 1, 0]),
    "bucket_exact": RNG.integers(0, 16, pad_bucket(700)),      # == a bucket
    "bucket_plus1": RNG.integers(0, 16, pad_bucket(700) + 1),  # spills over
}

# "pallas" runs interpret=True on CPU (Python-speed) — keep its n small.
_PALLAS_MAX_N = 256


def _oracle(x):
    return build_suffix_array(x, backend="oracle")


@pytest.mark.parametrize("impl", ["auto", "radix", "lax", "bitonic", "pallas"])
@pytest.mark.parametrize("name", sorted(TEXTS))
@pytest.mark.parametrize("bucket", [False, True])
def test_sort_impl_matches_oracle(impl, name, bucket):
    x = TEXTS[name]
    if impl == "pallas" and len(x) > _PALLAS_MAX_N:
        x = x[:_PALLAS_MAX_N]
    got = suffix_array_jax(x, base_threshold=16, sort_impl=impl,
                           bucket=bucket)
    assert np.array_equal(got, _oracle(x)), (impl, name, bucket)


@pytest.mark.parametrize("impl", ["radix", "lax"])
def test_sort_impl_through_facade(impl):
    x = TEXTS["rand256"]
    got = build_suffix_array(x, backend="jax", sort_impl=impl)
    assert np.array_equal(got, _oracle(x))


def test_unknown_sort_impl_rejected():
    with pytest.raises(ValueError, match="sort_impl"):
        SAOptions(sort_impl="quantum")
    with pytest.raises(ValueError, match="sort_impl"):
        suffix_array_jax(TEXTS["tiny3"], sort_impl="quantum")


def test_auto_resolves_to_platform_choice():
    assert resolve_sort_impl("auto") in ("radix", "lax")
    assert resolve_sort_impl("bitonic") == "bitonic"


def test_pad_bucket_grid():
    # grid points map to themselves; ratio between neighbours ≤ 1.25
    for n in (512, 1024, 1280, 1536, 1792, 2048, 200_000):
        assert pad_bucket(pad_bucket(n)) == pad_bucket(n) >= n
    assert pad_bucket(1025) == 1280
    assert pad_bucket(1281) == 1536
    # below the bucketing floor lengths stay exact
    assert pad_bucket(17) == 17


# ---------------------------------------------------------------------------
# compiled-builder cache
# ---------------------------------------------------------------------------
def test_no_retrace_on_same_shape_rebuild():
    """Second build of the same bucketed shape: no new jax traces."""
    rng = np.random.default_rng(7)
    opts = SAOptions(backend="jax")
    build_suffix_array(rng.integers(0, 256, 3000), opts)   # cold shapes
    before = dcv_jax.trace_events()
    build_suffix_array(rng.integers(0, 256, 3000), opts)
    assert dcv_jax.trace_events() == before


def test_no_retrace_on_same_shape_rebuild_lax():
    """Same, for the jitted lax sort path (exercises jax's trace cache).

    Identical text both times: the recursion's `distinct` short-circuit is
    data-dependent, so only same-content rebuilds have provably identical
    level shapes."""
    x = np.random.default_rng(8).integers(0, 256, 2000)
    opts = SAOptions(backend="jax", sort_impl="lax")
    build_suffix_array(x, opts)
    before = dcv_jax.trace_events()
    build_suffix_array(x.copy(), opts)
    assert dcv_jax.trace_events() == before


def test_no_retrace_within_bucket():
    """A different length in the same bucket reuses every compiled shape."""
    rng = np.random.default_rng(9)
    opts = SAOptions(backend="jax")
    n = 3000
    n2 = pad_bucket(n)                                # same bucket by constr.
    assert pad_bucket(n2) == n2 and n2 != n
    build_suffix_array(rng.integers(0, 256, n), opts)
    before = dcv_jax.trace_events()
    build_suffix_array(rng.integers(0, 256, n2), opts)
    assert dcv_jax.trace_events() == before


def test_builder_cache_hits_and_misses():
    clear_builder_cache()
    opts = SAOptions(backend="jax")
    x = np.random.default_rng(10).integers(0, 256, 2000)
    build_suffix_array(x, opts)
    s1 = builder_cache_stats()
    assert s1["misses"] >= 1 and s1["entries"] >= 1
    build_suffix_array(x, opts)
    s2 = builder_cache_stats()
    assert s2["hits"] == s1["hits"] + 1
    assert s2["entries"] == s1["entries"]             # same bucket, no growth
    # "auto" is resolved before keying: spelling out the platform choice
    # names the same compiled configuration, not a new one
    build_suffix_array(x, opts.replace(sort_impl=resolve_sort_impl("auto")))
    s3 = builder_cache_stats()
    assert s3["entries"] == s2["entries"]
    assert s3["hits"] == s2["hits"] + 1
    # a genuinely different plan is a different compiled configuration
    build_suffix_array(x, opts.replace(sort_impl="bitonic"))
    assert builder_cache_stats()["entries"] == s3["entries"] + 1


def test_cache_disabled_bypasses_builder_cache():
    clear_builder_cache()
    x = np.random.default_rng(11).integers(0, 256, 2000)
    build_suffix_array(x, SAOptions(backend="jax", cache=False))
    assert builder_cache_stats() == {"entries": 0, "hits": 0, "misses": 0}
