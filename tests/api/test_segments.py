"""Segmented serving: merged-vs-monolithic equivalence, incremental
ingest/delete builder traffic, size-tiered compaction, the
`SegmentedIndexStore` persistence contract (incremental sync, tamper and
rollback detection), and the serving tier over a segmented corpus.

The load-bearing property everywhere: a `SegmentedIndex` over ANY
segment layout answers every query byte-identically to one monolithic
`SuffixArrayIndex.from_docs` over the same documents — segmentation is
an amortization strategy, never a semantics change.
"""
import json
import os
import shutil
import subprocess
import sys

import numpy as np
import pytest

from repro.api import (SAOptions, Segment, SegmentedIndex,
                       SegmentedIndexStore, StaleIndexError,
                       SuffixArrayIndex, builder_cache_stats)

SEQ = SAOptions(backend="seq")
#: fanin high enough that compaction never fires — isolates ingest traffic
NO_COMPACT = SAOptions(backend="seq", compact_fanin=64)


def _builds():
    s = builder_cache_stats()
    return s["hits"] + s["misses"]


def _docs(seed=0, n_docs=7, sigma=5, lo=20, hi=60):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, sigma, int(rng.integers(lo, hi))).tolist()
            for _ in range(n_docs)]


def _patterns(docs):
    """Planted, random, separator-spanning, and degenerate patterns."""
    rng = np.random.default_rng(99)
    pats = [d[:3] for d in docs if len(d) >= 3]
    pats += [list(rng.integers(0, 5, l)) for l in (1, 2, 4, 7)]
    # spans a document boundary in the monolithic encoding — must match
    # in NEITHER index (separators are unique symbols)
    a, b = docs[0], docs[1]
    if len(a) >= 2 and len(b) >= 2:
        pats.append(list(a[-2:]) + list(b[:2]))
    pats.append(list(docs[-1]))          # a whole document
    return pats


def _assert_equivalent(seg, mono, pats):
    np.testing.assert_array_equal(seg.count_batch(pats),
                                  mono.count_batch(pats))
    np.testing.assert_array_equal(seg.contains_batch(pats),
                                  mono.contains_batch(pats))
    for got, want in zip(seg.locate_batch(pats),
                         mono.locate_docs_batch(pats)):
        np.testing.assert_array_equal(got, want)


# ----------------------------------------------------- merged == monolithic
@pytest.mark.parametrize("segment_docs", [1, 2, 3, 7])
def test_segmented_equals_monolithic(segment_docs):
    docs = _docs()
    seg = SegmentedIndex.from_docs(docs, SEQ, segment_docs=segment_docs)
    mono = SuffixArrayIndex.from_docs(docs, SEQ)
    assert seg.n == mono.n and seg.n_docs == mono.n_docs
    _assert_equivalent(seg, mono, _patterns(docs))
    # empty pattern counts the full encoded length, exactly as monolithic
    assert int(seg.count_batch([[]])[0]) == mono.n


def test_empty_docs_and_single_doc_segments():
    docs = [[1, 2, 3, 1, 2], [], [2, 2, 2], [], [0]]
    seg = SegmentedIndex.from_docs(docs, SEQ, segment_docs=1)
    mono = SuffixArrayIndex.from_docs(docs, SEQ)
    _assert_equivalent(seg, mono, [[1, 2], [2, 2], [0], [3, 1]])
    assert seg.n_docs == 5 and seg.n_segments == 5


def test_empty_corpus():
    seg = SegmentedIndex.from_docs([], SEQ)
    assert seg.n == 0 and seg.n_docs == 0
    assert seg.count([1, 2]) == 0
    assert not seg.contains([1])
    assert seg.locate([5]).shape == (0, 2)


def test_scalar_shims_and_doc_accessor():
    docs = _docs(n_docs=4)
    seg = SegmentedIndex.from_docs(docs, SEQ, segment_docs=2)
    mono = SuffixArrayIndex.from_docs(docs, SEQ)
    p = docs[2][:4]
    assert seg.count(p) == mono.count(p)
    assert seg.contains(p) == bool(mono.contains_batch([p])[0])
    np.testing.assert_array_equal(seg.doc(2), np.asarray(docs[2]))
    with pytest.raises(KeyError):
        seg.doc(99)


def test_locate_rejects_empty_pattern():
    seg = SegmentedIndex.from_docs(_docs(n_docs=2), SEQ, segment_docs=1)
    with pytest.raises(ValueError, match="empty pattern"):
        seg.locate_batch([[]])


def test_pattern_validation_matches_monolithic():
    docs = _docs(n_docs=3)
    seg = SegmentedIndex.from_docs(docs, SEQ, segment_docs=1, sigma=5)
    with pytest.raises(ValueError, match="≥ 0"):
        seg.count([-1])
    with pytest.raises(ValueError, match="outside the corpus alphabet"):
        seg.count([7])


def test_locate_rows_are_global_and_sorted():
    docs = [[1, 2, 1, 2], [2, 1, 2], [1, 2]]
    seg = SegmentedIndex.from_docs(docs, SEQ, segment_docs=1)
    rows = seg.locate([1, 2])
    # (doc, offset) rows, lexicographically sorted, global doc ids
    assert rows.tolist() == [[0, 0], [0, 2], [1, 1], [2, 0]]


# --------------------------------------------------- ingest/delete traffic
def test_single_doc_ingest_builds_exactly_one_segment():
    seg = SegmentedIndex.from_docs(_docs(), NO_COMPACT, segment_docs=2)
    before = _builds()
    ids = seg.add_docs([[4, 0, 4, 0, 4]])
    assert _builds() - before == 1, "ingest must build ONE segment"
    assert ids == [7] and seg.n_docs == 8
    assert seg.count([4, 0, 4]) >= 1


def test_ingest_matches_full_rebuild():
    docs = _docs(n_docs=5)
    seg = SegmentedIndex.from_docs(docs, NO_COMPACT, segment_docs=2)
    extra = [[0, 1, 0, 1, 0, 1], [3, 3, 3]]
    seg.add_docs(extra)
    mono = SuffixArrayIndex.from_docs(docs + extra, SEQ)
    _assert_equivalent(seg, mono, _patterns(docs + extra))


def test_delete_rebuilds_only_owning_segment():
    seg = SegmentedIndex.from_docs(_docs(), NO_COMPACT, segment_docs=2)
    before = _builds()
    seg.delete_doc(2)                    # shares a segment with doc 3
    assert _builds() - before == 1, "delete must rebuild ONE segment"
    docs_left = [d for i, d in enumerate(_docs()) if i != 2]
    mono = SuffixArrayIndex.from_docs(docs_left, SEQ)
    # doc ids keep their global numbering after the delete
    np.testing.assert_array_equal(
        seg.doc_ids, [i for i in range(7) if i != 2])
    np.testing.assert_array_equal(seg.count_batch(_patterns(docs_left)),
                                  mono.count_batch(_patterns(docs_left)))
    with pytest.raises(KeyError):
        seg.doc(2)


def test_delete_sole_doc_drops_segment_with_zero_builds():
    seg = SegmentedIndex.from_docs(_docs(n_docs=3), NO_COMPACT,
                                   segment_docs=1)
    before = _builds()
    seg.delete_doc(1)
    assert _builds() - before == 0
    assert seg.n_segments == 2 and seg.n_docs == 2


def test_doc_ids_never_reused_after_delete():
    seg = SegmentedIndex.from_docs(_docs(n_docs=4), NO_COMPACT,
                                   segment_docs=2)
    seg.delete_doc(3)
    assert seg.add_docs([[1, 1]]) == [4], "freed ids must not be recycled"


# ------------------------------------------------------------- compaction
def test_compaction_bounds_fanout_and_preserves_results():
    docs = _docs(n_docs=9, lo=30, hi=40)      # 9 same-tier segments
    opts = SAOptions(backend="seq", compact_fanin=3)
    seg = SegmentedIndex.from_docs(docs, opts, segment_docs=1)
    assert seg.n_segments == 9
    merges = seg.compact()
    assert merges >= 1 and seg.n_segments < 9
    mono = SuffixArrayIndex.from_docs(docs, SEQ)
    _assert_equivalent(seg, mono, _patterns(docs))


def test_ingest_stream_amortized_builds():
    """Streaming ingests with compaction on: per-ingest builds are 1 +
    occasional merges, and the segment count stays logarithmic instead
    of linear in the number of ingests."""
    rng = np.random.default_rng(5)
    opts = SAOptions(backend="seq", compact_fanin=4)
    seg = SegmentedIndex.from_docs([], opts)
    n_ingests = 12
    before = _builds()
    for _ in range(n_ingests):
        seg.add_docs([rng.integers(0, 4, 25).tolist()])
    built = _builds() - before
    assert built >= n_ingests                       # one per ingest...
    assert built < 2 * n_ingests                    # ...plus few merges
    assert seg.n_segments <= 8, "compaction must bound fan-out"
    assert seg.n_docs == n_ingests


def test_from_docs_layout_is_exact():
    # from_docs never compacts: tests may pin per-segment structure
    seg = SegmentedIndex.from_docs(_docs(n_docs=6, lo=30, hi=31),
                                   SAOptions(backend="seq",
                                             compact_fanin=2),
                                   segment_docs=1)
    assert seg.n_segments == 6
    assert [len(s.doc_ids) for s in seg.segments] == [1] * 6


# ------------------------------------------------- serving-tier protocol
def test_staging_protocol_merges_counts():
    docs = _docs(n_docs=6)
    seg = SegmentedIndex.from_docs(docs, SEQ, segment_docs=2)
    mono = SuffixArrayIndex.from_docs(docs, SEQ)
    pats = _patterns(docs)
    enc = [seg._encode_pattern(p) for p in pats]
    lo, hi = seg.ranges_staged(seg.stage_encoded(enc))
    assert (lo == 0).all(), "segmented ranges are virtual [0, count)"
    np.testing.assert_array_equal(hi - lo, mono.count_batch(pats))


def test_query_session_over_segmented_index():
    from repro.api import QuerySession
    docs = _docs(n_docs=6)
    seg = SegmentedIndex.from_docs(docs, SEQ, segment_docs=2)
    mono = SuffixArrayIndex.from_docs(docs, SEQ)
    sess = QuerySession(seg, batch_size=4)
    pats = _patterns(docs)
    np.testing.assert_array_equal(sess.count(pats), mono.count_batch(pats))
    for got, want in zip(sess.locate(pats), mono.locate_docs_batch(pats)):
        np.testing.assert_array_equal(got, want)
    assert sess.queries_served == 2 * len(pats)


def test_sa_server_over_segmented_index():
    from repro.serve import SAServer
    docs = _docs(n_docs=6)
    seg = SegmentedIndex.from_docs(docs, SEQ, segment_docs=2)
    mono = SuffixArrayIndex.from_docs(docs, SEQ)
    pats = _patterns(docs)
    with SAServer(seg, max_batch=8, coalesce_max_wait_us=200.0) as srv:
        srv.warmup(pattern_lens=(4,), batch_buckets=[1, 4])
        futs = [srv.submit(p) for p in pats]
        got = [f.result(timeout=30) for f in futs]
    want = mono.count_batch(pats)
    assert all(r.ok for r in got)
    assert [r.count for r in got] == list(want)
    assert all(r.lo == 0 and r.hi == r.count for r in got)


# ------------------------------------------------------------ persistence
@pytest.fixture
def store(tmp_path):
    return SegmentedIndexStore(str(tmp_path / "segstore"))


def test_store_round_trip(store):
    docs = _docs(n_docs=5)
    seg = SegmentedIndex.from_docs(docs, NO_COMPACT, segment_docs=2,
                                   sigma=5)
    traffic = store.save("corpus", seg)
    assert traffic == {"segments_written": 3, "segments_deleted": 0}
    before = _builds()
    loaded = store.load("corpus", options=NO_COMPACT)
    assert _builds() - before == 0, "load must not build"
    _assert_equivalent(loaded, SuffixArrayIndex.from_docs(docs, SEQ),
                       _patterns(docs))
    assert loaded.n_docs == seg.n_docs
    assert loaded._next_doc_id == seg._next_doc_id
    assert loaded._next_seg == seg._next_seg
    assert loaded.sigma == 5


def test_incremental_sync_writes_one_segment(store):
    seg = SegmentedIndex.from_docs(_docs(), NO_COMPACT, segment_docs=2)
    store.save("corpus", seg)
    seg.add_docs([[1, 2, 3]])
    traffic = store.save("corpus", seg)
    assert traffic == {"segments_written": 1, "segments_deleted": 0}
    loaded = store.load("corpus", options=NO_COMPACT)
    assert loaded.n_docs == 8 and loaded.count([1, 2, 3]) >= 1


def test_sync_garbage_collects_dropped_segments(store, tmp_path):
    docs = _docs(n_docs=6, lo=30, hi=40)
    opts = SAOptions(backend="seq", compact_fanin=3)
    seg = SegmentedIndex.from_docs(docs, opts, segment_docs=1)
    store.save("corpus", seg)
    seg.compact()                                 # merges same-tier segments
    traffic = store.save("corpus", seg)
    assert traffic["segments_deleted"] >= 2
    seg_root = os.path.join(store.path("corpus"), "segments")
    on_disk = set(os.listdir(seg_root))
    assert on_disk == {s.seg_id for s in seg.segments}


def test_unsynced_load_only_sees_last_sync(store):
    seg = SegmentedIndex.from_docs(_docs(n_docs=4), NO_COMPACT,
                                   segment_docs=2)
    store.save("corpus", seg)
    seg.add_docs([[3, 3, 3, 3]])                  # NOT synced
    loaded = store.load("corpus", options=NO_COMPACT)
    assert loaded.n_docs == 4, "pre-sync state must load"


def test_tampered_manifest_raises_stale(store):
    seg = SegmentedIndex.from_docs(_docs(n_docs=4), NO_COMPACT,
                                   segment_docs=2)
    store.save("corpus", seg)
    mpath = os.path.join(store.path("corpus"), "corpus.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["segments"][0]["n"] += 1             # tamper a recorded length
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(StaleIndexError, match="manifest records"):
        store.load("corpus", options=NO_COMPACT)


def test_corrupt_manifest_raises_stale(store):
    seg = SegmentedIndex.from_docs(_docs(n_docs=2), NO_COMPACT)
    store.save("corpus", seg)
    with open(os.path.join(store.path("corpus"), "corpus.json"), "w") as f:
        f.write("{not json")
    with pytest.raises(StaleIndexError, match="unreadable"):
        store.load("corpus")


def test_rolled_back_segment_raises_stale(store):
    seg = SegmentedIndex.from_docs(_docs(n_docs=4), NO_COMPACT,
                                   segment_docs=2)
    store.save("corpus", seg)
    # force a versioned re-save of one segment (step 0 → 1) …
    victim = seg.segments[0]
    seg.dirty.add(victim.seg_id)
    store.save("corpus", seg)
    assert victim.version == 1
    # … then roll its checkpoint back to step 0 behind the manifest's back
    spath = os.path.join(store.path("corpus"), "segments", victim.seg_id)
    shutil.rmtree(os.path.join(spath, "step_00000001"))
    with pytest.raises(StaleIndexError, match="rolled back"):
        store.load("corpus", options=NO_COMPACT)


def test_missing_segment_raises_stale(store):
    seg = SegmentedIndex.from_docs(_docs(n_docs=4), NO_COMPACT,
                                   segment_docs=2)
    store.save("corpus", seg)
    shutil.rmtree(os.path.join(store.path("corpus"), "segments",
                               seg.segments[0].seg_id))
    with pytest.raises(StaleIndexError, match="missing segment"):
        store.load("corpus", options=NO_COMPACT)


def test_options_fingerprint_mismatch_raises_stale(store):
    seg = SegmentedIndex.from_docs(_docs(n_docs=2), NO_COMPACT)
    store.save("corpus", seg)
    with pytest.raises(StaleIndexError, match="plan"):
        store.load("corpus", options=SAOptions(backend="seq", v0=7))


def test_segmentation_knobs_do_not_invalidate(store):
    """segment_docs / compact_fanin are serving-layer knobs, excluded from
    the plan fingerprint — changing them must NOT go stale."""
    seg = SegmentedIndex.from_docs(_docs(n_docs=4), NO_COMPACT,
                                   segment_docs=2)
    store.save("corpus", seg)
    relayout = SAOptions(backend="seq", compact_fanin=2, segment_docs=1)
    loaded = store.load("corpus", options=relayout)
    assert loaded.compact_fanin == 2


def test_get_or_build_statuses_and_stats(store):
    docs = _docs(n_docs=4)
    build = lambda: SegmentedIndex.from_docs(docs, NO_COMPACT,
                                             segment_docs=2)
    _, status = store.get_or_build("corpus", build, options=NO_COMPACT)
    assert status == "miss"
    _, status = store.get_or_build("corpus", build, options=NO_COMPACT)
    assert status == "hit"
    _, status = store.get_or_build("corpus", build,
                                   options=SAOptions(backend="seq", v0=7))
    assert status == "stale"
    s = store.stats()
    assert (s["hits"], s["misses"], s["stale"]) == (1, 1, 1)


def test_invalid_entry_and_segment_ids(store):
    with pytest.raises(ValueError):
        store.path("../escape")
    with pytest.raises(StaleIndexError):
        store._segment_path("corpus", "nope/../../etc")


# ------------------------------------------------- subprocess warm restart
_PHASE = r"""
import json, sys
import numpy as np
from repro.api import (SAOptions, SegmentedIndex, SegmentedIndexStore,
                       builder_cache_stats)

root, phase = sys.argv[1], sys.argv[2]
opts = SAOptions(backend="seq", compact_fanin=64)
docs = [[1, 2, 3, 1, 2], [2, 2, 2, 0], [0, 1, 0, 1, 0]]
store = SegmentedIndexStore(root)

def builds():
    s = builder_cache_stats()
    return s["hits"] + s["misses"]

if phase == "build":
    sidx = SegmentedIndex.from_docs(docs, opts, segment_docs=1)
    traffic = store.save("corpus", sidx)
    out = {"builds": builds(), **traffic}
elif phase == "ingest":
    b0 = builds()
    sidx, status = store.get_or_build(
        "corpus", lambda: (_ for _ in ()).throw(AssertionError("rebuilt!")),
        options=opts)
    load_builds = builds() - b0
    sidx.add_docs([[3, 3, 3, 3]])
    ingest_builds = builds() - b0 - load_builds
    traffic = store.save("corpus", sidx)
    out = {"status": status, "load_builds": load_builds,
           "ingest_builds": ingest_builds, **traffic}
elif phase == "verify":
    b0 = builds()
    sidx = store.load("corpus", options=opts)
    out = {"load_builds": builds() - b0, "n_docs": sidx.n_docs,
           "count": int(sidx.count([3, 3, 3, 3]))}
print(json.dumps(out))
"""


def _run_phase(root, phase):
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(os.path.dirname(__file__),
                                       "..", "..", "src"))
    proc = subprocess.run(
        [sys.executable, "-c", _PHASE, str(root), phase],
        capture_output=True, text=True, timeout=300, env=env)
    assert proc.returncode == 0, proc.stderr
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_warm_restart_across_processes(tmp_path):
    """Three real processes against one store directory: build+save, then
    a warm restart that loads with ZERO builder traffic and pays exactly
    one segment build + one segment write for an ingest, then a second
    restart that sees the ingested document."""
    root = str(tmp_path / "segstore")
    p1 = _run_phase(root, "build")
    assert p1["builds"] == 3 and p1["segments_written"] == 3

    p2 = _run_phase(root, "ingest")
    assert p2["status"] == "hit"
    assert p2["load_builds"] == 0, "warm restart must not rebuild"
    assert p2["ingest_builds"] == 1, "ingest is one segment build"
    assert p2["segments_written"] == 1, "sync writes only the new segment"

    p3 = _run_phase(root, "verify")
    assert p3["load_builds"] == 0
    assert p3["n_docs"] == 4 and p3["count"] == 1
