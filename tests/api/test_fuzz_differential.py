"""Cross-backend differential fuzzing — every backend × sort_impl cell
must produce byte-identical suffix arrays, LCPs, and query results on
seeded random corpora.

Two tiers share one body of generators and assertions:

* **tier-1 smoke** (always on, part of the plain `pytest` run): a fixed
  seed, one small corpus per family, and the cheap cells — enough to
  catch a broken backend in seconds.
* **full matrix** (`FUZZ_FULL=1`, the nightly CI job): every registered
  backend × every sort_impl it accepts, larger corpora, several
  repetitions. `FUZZ_SEED=<int>` overrides the seed; the harness prints
  the active seed so a red nightly is reproducible locally with
  `FUZZ_FULL=1 FUZZ_SEED=<logged> pytest -m fuzz`.

Corpus families target the construction edge cases that uniform-random
data never hits:

* ``uniform``          — i.i.d. symbols, the baseline
* ``all_equal``        — one repeated symbol: maximal LCPs, worst-case
                         ties through every sort path
* ``periodic``         — short repeating period: deep DC-v recursion,
                         long runs of equal difference-cover keys
* ``sentinel_adjacent``— values clustered at 0, the boundary against the
                         shifted separator band in `encode_docs`
* ``sigma_boundary``   — values clustered at sigma-1, the top of the
                         declared alphabet (exercises the int32 clamp in
                         `QueryBatch.from_encoded` at large sigma)

Run explicitly with `pytest -m fuzz`.
"""
import os

import numpy as np
import pytest

from repro.api import SAOptions, SuffixArrayIndex, build_suffix_array

pytestmark = pytest.mark.fuzz

FULL = os.environ.get("FUZZ_FULL", "") == "1"
SEED = int(os.environ.get("FUZZ_SEED", "3405691582"))

# ------------------------------------------------------------------ corpora

def _uniform(rng, n, sigma):
    return rng.integers(0, sigma, n)


def _all_equal(rng, n, sigma):
    return np.full(n, int(rng.integers(0, sigma)))


def _periodic(rng, n, sigma):
    period = rng.integers(0, sigma, int(rng.integers(2, 6)))
    return np.tile(period, n // len(period) + 1)[:n]


def _sentinel_adjacent(rng, n, sigma):
    # mass at the bottom of the alphabet: encoded values sit right above
    # the separator band (separators are < shift, data is value + shift)
    return np.minimum(rng.geometric(0.6, n) - 1, sigma - 1)


def _sigma_boundary(rng, n, sigma):
    # mass at the top of the alphabet, including sigma-1 itself
    return np.maximum(sigma - rng.geometric(0.6, n), 0)


FAMILIES = {
    "uniform": _uniform,
    "all_equal": _all_equal,
    "periodic": _periodic,
    "sentinel_adjacent": _sentinel_adjacent,
    "sigma_boundary": _sigma_boundary,
}

# ------------------------------------------------------------------- matrix
# (backend, sort_impl) cells. seq/oracle ignore sort_impl (run once with
# "auto"); jax accepts every impl; bsp rejects "pallas" by contract.
_SMOKE_CELLS = [("seq", "auto"), ("jax", "auto"), ("bsp", "auto")]
_FULL_CELLS = _SMOKE_CELLS + [
    ("jax", "radix"), ("jax", "lax"), ("jax", "bitonic"), ("jax", "pallas"),
    ("bsp", "radix"), ("bsp", "lax"), ("bsp", "bitonic"),
]
CELLS = _FULL_CELLS if FULL else _SMOKE_CELLS
REPS = range(3) if FULL else range(1)


def _size_for(cell):
    # pallas row-sort kernels run interpret=True on CPU hosts — keep the
    # cell meaningful but small so the matrix stays nightly-sized
    if cell[1] == "pallas":
        return 48
    return 240 if FULL else 64


def _rng(*key):
    """Deterministic per-case stream: the logged SEED plus stable ints
    derived from the case identity — no cross-case coupling, and any
    single cell reproduces in isolation."""
    parts = [SEED] + [abs(hash(k)) % (2 ** 31) for k in key]
    return np.random.default_rng(parts)


@pytest.fixture(scope="module", autouse=True)
def _log_seed():
    # surfaces in `pytest -s` output and in the nightly artifact, so a
    # failing run is reproducible via FUZZ_SEED
    print(f"\n[fuzz] FUZZ_SEED={SEED} FUZZ_FULL={int(FULL)} "
          f"cells={len(CELLS)}")
    yield


# -------------------------------------------------------- SA / LCP equality
@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("cell", CELLS, ids=lambda c: f"{c[0]}-{c[1]}")
@pytest.mark.parametrize("rep", REPS)
def test_suffix_array_matches_oracle(family, cell, rep):
    backend, sort_impl = cell
    n = _size_for(cell)
    rng = _rng("sa", family, cell, rep)
    sigma = int(rng.integers(2, 64))
    text = np.asarray(FAMILIES[family](rng, n, sigma), np.int64)

    want = build_suffix_array(text, backend="oracle")
    got = build_suffix_array(
        text, SAOptions(backend=backend, sort_impl=sort_impl))
    np.testing.assert_array_equal(
        got, want,
        err_msg=f"SA mismatch: {family} seed={SEED} cell={cell} rep={rep}")


@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("cell", CELLS, ids=lambda c: f"{c[0]}-{c[1]}")
def test_lcp_matches_oracle(family, cell):
    backend, sort_impl = cell
    n = _size_for(cell)
    rng = _rng("lcp", family, cell)
    sigma = int(rng.integers(2, 16))
    docs = [FAMILIES[family](rng, int(rng.integers(8, n // 2 + 9)), sigma)
            for _ in range(3)]

    ref = SuffixArrayIndex.from_docs(docs, SAOptions(backend="oracle"))
    idx = SuffixArrayIndex.from_docs(
        docs, SAOptions(backend=backend, sort_impl=sort_impl))
    np.testing.assert_array_equal(idx.sa, ref.sa)
    np.testing.assert_array_equal(
        idx.lcp, ref.lcp,
        err_msg=f"LCP mismatch: {family} seed={SEED} cell={cell}")


# ----------------------------------------------------------- query equality
@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("cell", CELLS, ids=lambda c: f"{c[0]}-{c[1]}")
@pytest.mark.parametrize("rep", REPS)
def test_queries_match_oracle(family, cell, rep):
    backend, sort_impl = cell
    n = _size_for(cell)
    rng = _rng("query", family, cell, rep)
    sigma = int(rng.integers(2, 32))
    docs = [FAMILIES[family](rng, int(rng.integers(6, n // 3 + 7)), sigma)
            for _ in range(4)]

    ref = SuffixArrayIndex.from_docs(docs, SAOptions(backend="oracle"),
                                     sigma=sigma)
    idx = SuffixArrayIndex.from_docs(
        docs, SAOptions(backend=backend, sort_impl=sort_impl), sigma=sigma)

    pats = []
    for d in docs:                      # planted substrings — must hit
        at = int(rng.integers(0, max(len(d) - 3, 1)))
        pats.append(np.asarray(d[at:at + 3], np.int64))
    pats += [rng.integers(0, sigma, int(l)) for l in (1, 2, 5, 9)]
    pats.append(np.asarray(docs[0], np.int64))          # whole doc
    pats.append(np.zeros(0, np.int64))                  # empty → count n

    msg = f"{family} seed={SEED} cell={cell} rep={rep}"
    np.testing.assert_array_equal(
        idx.count_batch(pats), ref.count_batch(pats), err_msg=msg)
    np.testing.assert_array_equal(
        idx.contains_batch(pats), ref.contains_batch(pats), err_msg=msg)
    locatable = [p for p in pats if len(p)]
    for got, want in zip(idx.locate_batch(locatable),
                         ref.locate_batch(locatable)):
        np.testing.assert_array_equal(got, want, err_msg=msg)


# ------------------------------------------- sparse sampled-position parity
# sparse-vs-dense is its own differential axis on top of the backend
# matrix: same corpus, same queries, the dense index as the oracle.
# Pattern lengths straddle the sample_rate threshold (== rate is the
# shortest legal pattern), and doc-spanning patterns check that the
# head-verification step never matches across a separator. all_equal and
# periodic corpora drive the stride-doubling tie-break through its
# worst case (every sampled suffix shares every head window).
SPARSE_RATES = (4, 8, 16, 32) if FULL else (4, 16)


@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("rate", SPARSE_RATES)
@pytest.mark.parametrize("rep", REPS)
def test_sparse_matches_dense(family, rate, rep):
    from repro.sparse import PatternTooShortError, SparseSuffixArrayIndex

    rng = _rng("sparse", family, rate, rep)
    sigma = int(rng.integers(2, 32))
    docs = [FAMILIES[family](rng, int(rng.integers(rate + 2, 4 * rate + 3)),
                             sigma)
            for _ in range(4)]

    ref = SuffixArrayIndex.from_docs(docs, SAOptions(), sigma=sigma)
    idx = SuffixArrayIndex.from_docs(docs, SAOptions(sample_rate=rate),
                                     sigma=sigma)
    assert isinstance(idx, SparseSuffixArrayIndex)
    msg = f"{family} seed={SEED} rate={rate} rep={rep}"

    # construction oracle: the dense SA restricted to sampled positions
    np.testing.assert_array_equal(
        idx.sa, ref.sa[np.asarray(ref.sa, np.int64) % rate == 0],
        err_msg=msg)

    pats = []
    for m in (rate, rate + 1, 2 * rate - 1, 2 * rate):  # straddle threshold
        for d in docs:
            if len(d) >= m:                              # planted — must hit
                at = int(rng.integers(0, len(d) - m + 1))
                pats.append(np.asarray(d[at:at + m], np.int64))
        pats.append(rng.integers(0, sigma, m))           # usually absent
    # separator-spanning: a suffix of doc0 glued to a prefix of doc1 is
    # NOT an occurrence unless it also appears inside a single document —
    # the dense answer is the oracle either way
    half = max(rate // 2, 1)
    pats.append(np.concatenate([np.asarray(docs[0][-half:], np.int64),
                                np.asarray(docs[1][:rate - half + 1],
                                           np.int64)]))

    np.testing.assert_array_equal(
        idx.count_batch(pats), ref.count_batch(pats), err_msg=msg)
    np.testing.assert_array_equal(
        idx.contains_batch(pats), ref.contains_batch(pats), err_msg=msg)
    for got, want in zip(idx.locate_batch(pats), ref.locate_batch(pats)):
        np.testing.assert_array_equal(got, want, err_msg=msg)
    for got, want in zip(idx.locate_docs_batch(pats),
                         ref.locate_docs_batch(pats)):
        np.testing.assert_array_equal(got, want, err_msg=msg)

    # longest_match floors at the rate: identical to dense whenever the
    # dense answer is a legal sparse pattern length, 0 below the floor
    probe = np.asarray(docs[2][: 2 * rate], np.int64)
    want_lm = ref.longest_match(probe)
    assert idx.longest_match(probe) == (want_lm if want_lm >= rate else 0), \
        msg

    with pytest.raises(PatternTooShortError):
        idx.count_batch([rng.integers(0, sigma, rate - 1)])
