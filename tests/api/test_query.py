"""Equivalence + contract tests for the batched query engine
(`repro.api.query`): the jitted vectorised binary search must agree with
the scalar `_sa_range` loop pattern-for-pattern on oracle-built indexes
(mixed lengths, empty, absent, full-text, cross-separator), re-used
buckets must not re-trace, and the new pattern-alphabet semantics
(`count("") == n`, out-of-alphabet → ValueError) must hold on both paths.
"""
import numpy as np
import pytest

from repro.api import (QueryBatch, QuerySession, SAOptions, SuffixArrayIndex,
                       query_cache_stats)
from repro.api.query import _pow2_bucket, trace_events

ORACLE = SAOptions(backend="oracle")


def scalar_ranges(idx, patterns):
    """The pre-batch reference: one numpy bisection loop per pattern."""
    return [idx._sa_range(idx._encode_pattern(p)) for p in patterns]


def _single_doc_index():
    rng = np.random.default_rng(5)
    return SuffixArrayIndex.build(rng.integers(0, 4, 300), ORACLE), None


def _multi_doc_index():
    rng = np.random.default_rng(6)
    docs = [rng.integers(0, 4, int(rng.integers(10, 80))) for _ in range(4)]
    return SuffixArrayIndex.from_docs(docs, ORACLE), docs


def _periodic_index():
    return SuffixArrayIndex.build(np.tile([0, 1, 2], 60), ORACLE), None


CORPORA = {"single": _single_doc_index, "multi": _multi_doc_index,
           "periodic": _periodic_index}


def _pattern_matrix(idx, docs):
    """Mixed-length pattern set exercising every edge the issue names."""
    rng = np.random.default_rng(7)
    raw = (idx.text - idx.shift) if idx.shift else idx.text
    pats = [[]]                                        # empty
    for m in (1, 2, 3, 7, 16, 33):                     # planted, mixed len
        at = int(rng.integers(0, max(idx.n - m, 1)))
        seg = raw[at:at + m]
        if idx.shift == 0 or (idx.text[at:at + m] >= idx.shift).all():
            pats.append(seg.tolist())
        pats.append(rng.integers(0, idx.sigma, size=m).tolist())  # random
    pats.append([idx.sigma - 1] * 40)                  # likely absent run
    if docs is None:
        pats.append(raw.tolist())                      # the full text
        pats.append(raw.tolist() + [0])                # longer than the text
    else:
        for d in docs:
            pats.append(np.asarray(d).tolist())        # each full document
        # cross-separator: tail of doc0 + head of doc1 — must never match
        pats.append(np.concatenate([docs[0][-2:], docs[1][:2]]).tolist())
    return pats


@pytest.mark.parametrize("corpus", sorted(CORPORA))
def test_batch_matches_scalar_loop(corpus):
    idx, docs = CORPORA[corpus]()
    pats = _pattern_matrix(idx, docs)
    want = scalar_ranges(idx, pats)
    lo, hi = idx.sa_ranges_batch(pats)
    assert lo.tolist() == [w[0] for w in want], corpus
    assert hi.tolist() == [w[1] for w in want], corpus
    counts = idx.count_batch(pats)
    assert counts.tolist() == [h - l for l, h in want]
    # locate agrees entry-for-entry (empty pattern excluded by contract)
    non_empty = [p for p in pats if len(p)]
    located = idx.locate_batch(non_empty)
    for p, pos in zip(non_empty, located):
        l, h = idx._sa_range(idx._encode_pattern(p))
        assert pos.tolist() == sorted(idx.sa[l:h].tolist()), p
    # and the scalar shims are literally batch-of-one
    for p in non_empty[:5]:
        assert idx.count(p) == int(idx.count_batch([p])[0])
        assert idx.locate(p).tolist() == idx.locate_batch([p])[0].tolist()


def test_cross_separator_pattern_never_matches():
    docs = [[0, 1], [0, 1]]
    idx = SuffixArrayIndex.from_docs(docs, ORACLE)
    got = idx.count_batch([[0, 1], [1, 0]])
    assert got.tolist() == [2, 0]       # "ba" spans the boundary: no match


def test_contains_batch():
    idx, _ = _single_doc_index()
    flags = idx.contains_batch([idx.text[:4].tolist(), [3, 3, 3, 3, 3, 3]])
    assert flags.dtype == np.bool_
    assert flags[0] and flags.shape == (2,)


# ------------------------------------------------------ pattern semantics
def test_empty_pattern_counts_n_and_locate_raises():
    idx, _ = _single_doc_index()
    assert idx.count([]) == idx.n
    assert int(idx.count_batch([[]])[0]) == idx.n
    with pytest.raises(ValueError, match="empty pattern"):
        idx.locate([])
    with pytest.raises(ValueError, match="empty pattern"):
        idx.locate_batch([[1], []])
    # empty index: n == 0, so the empty pattern counts 0 consistently
    empty = SuffixArrayIndex.build(np.zeros(0, np.int64), ORACLE)
    assert empty.count([]) == 0


def test_out_of_alphabet_pattern_rejected():
    idx = SuffixArrayIndex.build(np.asarray([0, 2, 1, 2]), ORACLE)
    assert idx.sigma == 3
    with pytest.raises(ValueError, match="alphabet"):
        idx.count([3])
    with pytest.raises(ValueError, match="alphabet"):
        idx.count_batch([[0], [5]])
    with pytest.raises(ValueError):
        idx.count([-1])
    # an empty index rejects nothing (sigma is vacuous; every count is 0)
    empty = SuffixArrayIndex.build(np.zeros(0, np.int64), ORACLE)
    assert empty.count([7]) == 0


def test_declared_sigma_widens_alphabet():
    idx = SuffixArrayIndex.build(np.asarray([0, 1, 0]), ORACLE, sigma=10)
    assert idx.sigma == 10
    assert idx.count([9]) == 0          # valid (declared), just absent
    with pytest.raises(ValueError):
        idx.count([10])


def test_declared_sigma_past_int32_never_false_matches():
    # pattern values past int32 must not wrap into the device buffer and
    # alias real symbols (2**32 wrapping to 0 would "match" the zeros)
    idx = SuffixArrayIndex.build(np.asarray([0, 1, 2, 0]), ORACLE,
                                 sigma=2 ** 40)
    assert idx.count([2 ** 32]) == 0
    assert idx.count_batch([[2 ** 32], [0], [2 ** 33, 1]]).tolist() \
        == [0, 2, 0]


def test_pattern_longer_than_text_batched():
    idx = SuffixArrayIndex.build(np.asarray([1, 2]), ORACLE)
    got = idx.count_batch([[1, 2, 1], [1, 2]])
    assert got.tolist() == [0, 1]


# ------------------------------------------------------- buckets / retrace
def test_query_batch_bucket_shapes():
    idx, _ = _single_doc_index()
    qb = QueryBatch.encode(idx, [[1], [1, 2, 3]])
    assert qb.bucket == (2, 8) and qb.n_queries == 2     # L floor is 8
    assert qb.lens[:2].tolist() == [1, 3]
    qb2 = QueryBatch.encode(idx, [[0]] * 5)
    assert qb2.bucket == (8, 8)                          # B rounds up to 8
    qb3 = QueryBatch.encode(idx, [list(range(3)) * 4])
    assert qb3.bucket == (1, 16)
    assert _pow2_bucket(0) == 1 and _pow2_bucket(9) == 16


def test_reused_bucket_does_not_retrace():
    rng = np.random.default_rng(8)
    idx = SuffixArrayIndex.build(rng.integers(0, 4, 256), ORACLE)
    idx.count_batch([[0, 1], [1, 2], [2, 3]])            # bucket (4, 8)
    before = trace_events()
    stats0 = query_cache_stats()
    # same bucket: different patterns, different batch size (3 vs 4)
    idx.count_batch([[1], [2], [3], [0, 0]])
    idx.count_batch([rng.integers(0, 4, 8).tolist()] * 4)
    assert trace_events() == before                      # no new traces
    stats1 = query_cache_stats()
    assert stats1["hits"] >= stats0["hits"] + 2
    assert stats1["buckets"] == stats0["buckets"]
    # a genuinely new shape does trace (longer patterns → new L bucket)
    idx.count_batch([rng.integers(0, 4, 20).tolist()])
    assert trace_events() == before + 1


def test_query_batch_reuse_skips_encoding():
    idx, _ = _multi_doc_index()
    pats = [[0, 1], [2], [1, 1, 1]]
    qb = QueryBatch.encode(idx, pats)
    a = idx.count_batch(qb)
    b = idx.count_batch(pats)
    assert a.tolist() == b.tolist()
    assert len(qb) == 3 and "bucket" in repr(qb)


def test_query_batch_rejects_foreign_index():
    """The encoding shift/sigma are index-specific: a batch run against a
    different index must raise, not silently return wrong counts."""
    multi, _ = _multi_doc_index()
    single, _ = _single_doc_index()
    qb = QueryBatch.encode(multi, [[1, 2]])
    with pytest.raises(ValueError, match="different index"):
        single.count_batch(qb)
    with pytest.raises(ValueError, match="different index"):
        single.locate_batch(qb)


# ------------------------------------------------------------- session
def test_query_session_matches_index_and_tracks_latency():
    idx, _ = _single_doc_index()
    rng = np.random.default_rng(9)
    pats = [rng.integers(0, 4, int(rng.integers(1, 9))).tolist()
            for _ in range(23)]
    sess = QuerySession(idx, batch_size=8)
    counts = sess.count(pats)
    assert counts.tolist() == [idx.count(p) for p in pats]
    assert sess.contains(pats).tolist() == [c > 0 for c in counts]
    located = sess.locate(pats[:5])
    for p, pos in zip(pats, located):
        assert pos.tolist() == idx.locate(p).tolist()
    lat = sess.latency_summary()
    assert lat["queries"] == sess.queries_served == 23 + 23 + 5
    assert lat["ticks"] == 3 + 3 + 1                    # ceil(23/8) twice + 1
    assert 0 < lat["p50_us"] <= lat["p95_us"] <= lat["p99_us"]
    assert lat["qps"] > 0
    sess.reset_latency()
    assert sess.latency_summary()["ticks"] == 0


def test_query_session_validates_batch_size_and_empty_stream():
    idx, _ = _single_doc_index()
    with pytest.raises(ValueError):
        QuerySession(idx, batch_size=0)
    sess = QuerySession(idx)
    assert sess.count([]).tolist() == []
    assert sess.locate([]) == []
    # empty session: stats are absent (None), never a fake zero
    lat = sess.latency_summary()
    assert lat["ticks"] == 0 and lat["queries"] == 0
    assert lat["qps"] is None
    assert lat["p50_us"] is None
    assert lat["p95_us"] is None and lat["p99_us"] is None


def test_query_session_warmup_excluded_from_latency():
    idx, _ = _single_doc_index()
    sess = QuerySession(idx, batch_size=4)
    warmed = sess.warmup(pattern_lens=(4, 8))
    assert warmed == 2
    lat = sess.latency_summary()
    # warmup ticks (the JIT-compile ticks) never enter the percentiles
    assert lat["warmup_ticks"] == 2
    assert lat["ticks"] == 0 and lat["p99_us"] is None
    sess.count([[0, 1]])
    lat = sess.latency_summary()
    assert lat["ticks"] == 1 and lat["p99_us"] is not None
    sess.reset_latency()
    assert sess.latency_summary()["warmup_ticks"] == 0


def test_query_session_submit_routes_through_server():
    idx, _ = _single_doc_index()
    with QuerySession(idx, batch_size=4) as sess:
        assert sess.server is None
        futs = [sess.submit([0, 1]), sess.submit([3, 3, 3, 3])]
        got = [f.result(timeout=30.0) for f in futs]
        assert sess.server is not None
        assert got[0].ok and got[0].count == idx.count([0, 1])
        assert got[1].ok and got[1].count == idx.count([3, 3, 3, 3])
        # server knobs are constructor-time only: rejected once running
        with pytest.raises(ValueError, match="knobs"):
            sess.submit([0], queue_depth=2)
    assert sess.server is None      # close() on context exit
