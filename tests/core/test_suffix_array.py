import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dcv_jax import suffix_array_jax
from repro.core.oracle import (rank_of_suffixes, suffix_array_doubling,
                               suffix_array_naive)
from repro.core.seq_ref import (SeqStats, accelerated_next_v, fixed_next_v,
                                suffix_array_dcv)


def _is_valid_sa(x, sa):
    n = len(x)
    assert sorted(sa) == list(range(n))
    for a, b in zip(sa[:-1], sa[1:]):
        assert tuple(x[a:]) < tuple(x[b:])


# ---------------------------------------------------------------- paper ex.
def test_paper_table1_example():
    """Table 1: X' = 0 2 1 0 0 2 4 3 1 1 4 0 → SA = 11 3 0 4 2 8 9 1 5 7 10 6."""
    x = [0, 2, 1, 0, 0, 2, 4, 3, 1, 1, 4, 0]
    want = [11, 3, 0, 4, 2, 8, 9, 1, 5, 7, 10, 6]
    assert suffix_array_naive(x).tolist() == want
    assert suffix_array_dcv(np.array(x), base_threshold=4).tolist() == want
    assert suffix_array_jax(np.array(x), base_threshold=4).tolist() == want


# ------------------------------------------------------------- oracles agree
@given(st.lists(st.integers(min_value=0, max_value=8), min_size=1,
                max_size=300))
@settings(max_examples=60, deadline=None)
def test_doubling_oracle_matches_naive(xs):
    x = np.asarray(xs)
    assert np.array_equal(suffix_array_doubling(x), suffix_array_naive(x))


# --------------------------------------------------------------- seq DC-v
@given(st.lists(st.integers(min_value=0, max_value=5), min_size=1,
                max_size=260),
       st.sampled_from([accelerated_next_v, fixed_next_v]))
@settings(max_examples=80, deadline=None)
def test_seq_dcv_matches_oracle(xs, schedule):
    x = np.asarray(xs)
    got = suffix_array_dcv(x, schedule=schedule, base_threshold=4)
    assert np.array_equal(got, suffix_array_naive(x))


@pytest.mark.parametrize("pattern", [
    np.zeros(120, np.int64),                       # all equal
    np.tile([0, 1], 80),                           # period 2
    np.tile([2, 1, 0], 50),                        # period 3 descending
    np.arange(100)[::-1].copy(),                   # strictly descending
    np.r_[np.zeros(60, np.int64), np.arange(60)],  # mixed
])
def test_seq_dcv_adversarial(pattern):
    got = suffix_array_dcv(pattern, base_threshold=4)
    assert np.array_equal(got, suffix_array_doubling(pattern))


def test_seq_dcv_big_alphabet():
    rng = np.random.default_rng(0)
    x = rng.permutation(500)          # all distinct → argsort shortcut
    assert np.array_equal(suffix_array_dcv(x), np.argsort(x))


# --------------------------------------------------------------- JAX DC-v
@given(st.lists(st.integers(min_value=0, max_value=4), min_size=2,
                max_size=300))
@settings(max_examples=40, deadline=None)
def test_jax_dcv_matches_oracle(xs):
    x = np.asarray(xs)
    got = suffix_array_jax(x, base_threshold=8)
    assert np.array_equal(got, suffix_array_naive(x))


def test_jax_dcv_medium():
    rng = np.random.default_rng(3)
    for sigma in (2, 7, 200):
        x = rng.integers(0, sigma, size=3000)
        assert np.array_equal(suffix_array_jax(x),
                              suffix_array_doubling(x))


def test_jax_matches_seq_exactly():
    rng = np.random.default_rng(4)
    for _ in range(10):
        x = rng.integers(0, 4, size=int(rng.integers(10, 500)))
        a = suffix_array_dcv(x, base_threshold=4)
        b = suffix_array_jax(x, base_threshold=4)
        assert np.array_equal(a, b)


# ----------------------------------------------------------- instrumentation
def _model_rounds(n, stop, schedule):
    """Recursion depth without the all-distinct early exit (worst case —
    the regime of the paper's Table 3)."""
    from repro.core.difference_cover import difference_cover
    v, rounds = 3, 0
    while n > stop and rounds < 500:
        D = difference_cover(min(max(v, 3), 2048))
        n = len(D) * -(-n // v)
        v = schedule(v, len(D), n)
        rounds += 1
    return rounds


def test_accelerated_rounds_fewer_than_fixed():
    """C4 (sequential view): in the worst case (no distinctness early exit)
    accelerated sampling needs far fewer recursion rounds than fixed v = 3,
    and its round count grows ~log log while fixed grows ~log.

    (On easy random inputs the early exit can terminate fixed-v sooner —
    the paper's claim is about the worst case; see benchmarks/table3.)"""
    n = 1 << 40
    prev_a = prev_f = None
    for k in (8, 12, 16, 20):
        p = 1 << k
        ra = _model_rounds(n, n // p, accelerated_next_v)
        rf = _model_rounds(n, n // p, fixed_next_v)
        assert ra <= rf
        if prev_a is not None:
            # fixed grows linearly in log p; accelerated sub-linearly
            assert (rf - prev_f) >= 2
            assert (ra - prev_a) <= (rf - prev_f)
        prev_a, prev_f = ra, rf
    # deep-regime separation (p = 2^20: 10 vs 35 rounds)
    assert _model_rounds(n, n >> 20, accelerated_next_v) < \
        0.5 * _model_rounds(n, n >> 20, fixed_next_v)


def test_measured_work_decreases_per_round():
    """Table 3: per-round work is non-increasing under the accelerated
    schedule (measured on a real input, early exits allowed)."""
    rng = np.random.default_rng(5)
    x = rng.integers(0, 2, size=20_000)
    sa = SeqStats()
    suffix_array_dcv(x, schedule=accelerated_next_v, base_threshold=16,
                     stats=sa)
    works = [r["work"] for r in sa.rounds if r["D"] > 0]
    assert all(w1 >= w2 for w1, w2 in zip(works, works[1:]))


def test_schedule_respects_work_bound():
    """v' < v²/|D| (paper §3 Step 1) and v' ≥ 3."""
    from repro.core.difference_cover import difference_cover
    v = 3
    for _ in range(6):
        D = difference_cover(v)
        v2 = accelerated_next_v(v, len(D), 10**9)
        assert 3 <= v2 < max(v * v / len(D), 4)
        v = v2


def test_rank_of_suffixes_inverse():
    x = np.array([1, 0, 1, 0, 1])
    sa = suffix_array_naive(x)
    r = rank_of_suffixes(sa)
    assert np.array_equal(sa[r], np.arange(len(x)))
