import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.oracle import suffix_array_naive
from repro.text.dedup import dedup_corpus, find_duplicates
from repro.text.lcp import lcp_kasai, ngram_counts, repeated_substring_spans


def _lcp_naive(x, sa):
    out = np.zeros(len(x), dtype=np.int64)
    for r in range(1, len(sa)):
        a, b = x[sa[r - 1]:], x[sa[r]:]
        h = 0
        while h < len(a) and h < len(b) and a[h] == b[h]:
            h += 1
        out[r] = h
    return out


@given(st.lists(st.integers(min_value=0, max_value=3), min_size=1,
                max_size=200))
@settings(max_examples=50, deadline=None)
def test_kasai_matches_naive(xs):
    x = np.asarray(xs)
    sa = suffix_array_naive(x)
    assert np.array_equal(lcp_kasai(x, sa), _lcp_naive(x, sa))


def test_repeated_spans_detects_planted_duplicate():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 50, 600)
    x[300:360] = x[100:160]                    # plant a 60-char duplicate
    rep = find_duplicates(x, min_len=40)
    assert rep.dup_chars >= 60
    covered = set()
    for s, e in rep.spans:
        covered.update(range(s, e))
    assert set(range(300, 360)) <= covered or set(range(100, 160)) <= covered


def test_dedup_removes_duplicates_idempotent():
    rng = np.random.default_rng(1)
    x = rng.integers(0, 64, 800)
    x[500:620] = x[100:220]
    out, rep = dedup_corpus(x, min_len=64)
    assert len(out) < len(x)
    out2, rep2 = dedup_corpus(out, min_len=64)
    assert rep2.dup_chars == 0 or len(out2) == len(out)


def test_ngram_counts():
    x = np.array([0, 1, 0, 1, 0])
    sa = suffix_array_naive(x)
    lcp = lcp_kasai(x, sa)
    # distinct 2-grams: (0,1), (1,0) → 2
    assert ngram_counts(x, sa, lcp, 2) == 2


# ---------------------------------------------------------------- drop rule
def test_dedup_keep_first_keeps_earliest_copy():
    rng = np.random.default_rng(2)
    x = rng.integers(0, 64, 900)
    x[600:700] = x[100:200]                    # plant: later copy of 100:200
    out, rep = dedup_corpus(x, min_len=64, keep_first=True)
    assert rep.dropped_chars >= 100
    # the earliest copy survives verbatim at its original offset
    assert np.array_equal(out[100:200], x[100:200])


def test_dedup_keep_first_false_keeps_latest_copy():
    rng = np.random.default_rng(2)
    x = rng.integers(0, 64, 900)
    x[600:700] = x[100:200]
    out, rep = dedup_corpus(x, min_len=64, keep_first=False)
    assert rep.dropped_chars >= 100
    assert len(out) == 900 - rep.dropped_chars
    # the latest copy survives: its 100 chars appear after position ~500
    tail = out[-(900 - 600 - rep.dropped_chars + 100):]
    window = np.lib.stride_tricks.sliding_window_view(tail, 100)
    assert any(np.array_equal(w, x[600:700]) for w in window)


def test_dedup_both_policies_drop_the_same_char_count():
    rng = np.random.default_rng(3)
    x = rng.integers(0, 32, 1200)
    x[800:900] = x[50:150]
    x[1000:1100] = x[50:150]                   # three interleaved copies
    _, first = dedup_corpus(x, min_len=48, keep_first=True)
    _, last = dedup_corpus(x, min_len=48, keep_first=False)
    assert first.dropped_chars == last.dropped_chars >= 200


def test_dedup_default_min_len_is_pinned():
    # one documented default everywhere (48); the config used to say 48
    # while dedup_corpus said 32
    import inspect

    from repro.data.pipeline import PipelineConfig
    from repro.text.dedup import DEDUP_MIN_LEN, dedup_docs

    assert DEDUP_MIN_LEN == 48
    assert inspect.signature(dedup_corpus).parameters["min_len"].default \
        == DEDUP_MIN_LEN
    assert inspect.signature(dedup_docs).parameters["min_len"].default \
        == DEDUP_MIN_LEN
    assert PipelineConfig().dedup_min_len == DEDUP_MIN_LEN
    assert PipelineConfig().gate_min_len == DEDUP_MIN_LEN


def test_dedup_empty_corpus_roundtrips():
    out, rep = dedup_corpus(np.zeros(0, np.int64))
    assert len(out) == 0
    assert rep.n_chars == rep.dup_chars == rep.dropped_chars == 0
    assert rep.spans == []


def test_dedup_no_spans_returns_corpus_unchanged():
    x = np.arange(200)                         # all-distinct: nothing ≥ 48
    out, rep = dedup_corpus(x)
    assert np.array_equal(out, x)
    assert rep.dup_chars == rep.dropped_chars == 0
