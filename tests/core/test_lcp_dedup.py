import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.oracle import suffix_array_naive
from repro.text.dedup import dedup_corpus, find_duplicates
from repro.text.lcp import lcp_kasai, ngram_counts, repeated_substring_spans


def _lcp_naive(x, sa):
    out = np.zeros(len(x), dtype=np.int64)
    for r in range(1, len(sa)):
        a, b = x[sa[r - 1]:], x[sa[r]:]
        h = 0
        while h < len(a) and h < len(b) and a[h] == b[h]:
            h += 1
        out[r] = h
    return out


@given(st.lists(st.integers(min_value=0, max_value=3), min_size=1,
                max_size=200))
@settings(max_examples=50, deadline=None)
def test_kasai_matches_naive(xs):
    x = np.asarray(xs)
    sa = suffix_array_naive(x)
    assert np.array_equal(lcp_kasai(x, sa), _lcp_naive(x, sa))


def test_repeated_spans_detects_planted_duplicate():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 50, 600)
    x[300:360] = x[100:160]                    # plant a 60-char duplicate
    rep = find_duplicates(x, min_len=40)
    assert rep.dup_chars >= 60
    covered = set()
    for s, e in rep.spans:
        covered.update(range(s, e))
    assert set(range(300, 360)) <= covered or set(range(100, 160)) <= covered


def test_dedup_removes_duplicates_idempotent():
    rng = np.random.default_rng(1)
    x = rng.integers(0, 64, 800)
    x[500:620] = x[100:220]
    out, rep = dedup_corpus(x, min_len=64)
    assert len(out) < len(x)
    out2, rep2 = dedup_corpus(out, min_len=64)
    assert rep2.dup_chars == 0 or len(out2) == len(out)


def test_ngram_counts():
    x = np.array([0, 1, 0, 1, 0])
    sa = suffix_array_naive(x)
    lcp = lcp_kasai(x, sa)
    # distinct 2-grams: (0,1), (1,0) → 2
    assert ngram_counts(x, sa, lcp, 2) == 2
