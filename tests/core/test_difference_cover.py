import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.difference_cover import (cover_size_lower_bound, cover_tables,
                                         difference_cover,
                                         is_difference_cover)


@given(st.integers(min_value=3, max_value=600))
@settings(max_examples=120, deadline=None)
def test_cover_is_valid_and_zero_free(v):
    D = difference_cover(v)
    assert is_difference_cover(D, v)
    assert 0 not in D
    assert len(D) < v
    assert len(set(D)) == len(D)


@given(st.integers(min_value=3, max_value=400))
@settings(max_examples=60, deadline=None)
def test_cover_size_near_optimal(v):
    """|D| = O(√v): stay within a small factor of the lower bound."""
    D = difference_cover(v)
    lb = cover_size_lower_bound(v)
    assert len(D) <= max(4, 3.0 * lb)


@given(st.integers(min_value=3, max_value=200))
@settings(max_examples=50, deadline=None)
def test_lemma1_tables(v):
    """Λ[k1,k2] satisfies Lemma 1; shifts rows enumerate {l : (k+l) ∈ D}."""
    t = cover_tables(v)
    D = set(t.D)
    for k in range(v):
        for l in t.shifts[k]:
            assert (k + int(l)) % v in D
    rng = np.random.default_rng(v)
    ks = rng.integers(0, v, size=(20, 2))
    for k1, k2 in ks:
        l = int(t.lam[k1, k2])
        assert 0 <= l < v
        assert (k1 + l) % v in D and (k2 + l) % v in D
        # lam_idx point back into the shifts rows
        assert int(t.shifts[k1][t.lam_idx1[k1, k2]]) == l
        assert int(t.shifts[k2][t.lam_idx2[k1, k2]]) == l


def test_paper_table2_sizes():
    """C2: our constructor vs the paper's Colbourn–Ling sizes (Table 2).
    Ours may differ by a constant factor but must stay O(√v)."""
    paper = {5: 4, 13: 4, 14: 10, 73: 10, 74: 16, 181: 16, 182: 22,
             337: 22, 338: 28, 541: 28, 1024: 40, 2048: 58}
    for v, cl_size in paper.items():
        ours = len(difference_cover(v))
        assert ours <= 2.5 * cl_size + 4, (v, ours, cl_size)


def test_rejects_v_below_3():
    with pytest.raises(ValueError):
        difference_cover(2)
