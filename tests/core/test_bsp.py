"""BSP layer tests — run in a subprocess with 8 fake CPU devices (the main
pytest process must keep the default 1-device view)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                   "src"))


def _run(body: str, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_psort_key_and_comparator_modes():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.bsp.psort import run_psort, make_local_sort_bitonic, lex_lt_full
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("bsp",))
    rng = np.random.default_rng(0)
    def rows_of(vals):
        N = len(vals)
        return np.stack([np.zeros(N, np.int32), np.asarray(vals, np.int32),
                         np.arange(N, dtype=np.int32)], axis=1)
    for vals in [rng.integers(0, 50, 256), np.zeros(512, np.int64),
                 np.arange(512)[::-1].copy()]:
        out, over = run_psort(mesh, "bsp", jnp.asarray(rows_of(vals)))
        assert not bool(np.asarray(over)[0])
        got = np.asarray(out); got = got[got[:, 0] == 0]
        want = np.lexsort((np.arange(len(vals)), vals))
        assert np.array_equal(got[:, 2], want)
    vals = rng.integers(0, 9, 256)
    ls = make_local_sort_bitonic(lex_lt_full)
    out, over = run_psort(mesh, "bsp", jnp.asarray(rows_of(vals)),
                          lt_fn=lex_lt_full, local_sort=ls)
    got = np.asarray(out); got = got[got[:, 0] == 0]
    assert np.array_equal(got[:, 2], np.lexsort((np.arange(256), vals)))
    print("OK")
    """)


def test_exchange_adversarial_skew():
    _run("""
    import jax, jax.numpy as jnp, numpy as np, functools
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.bsp.exchange import exchange
    from repro.core.compat import shard_map
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("bsp",))
    p, m = 8, 32
    rng = np.random.default_rng(1)
    # adversarial: every shard sends everything to shard 3
    dest = np.full((p * m,), 3, np.int32)
    rows = np.stack([np.arange(p * m, dtype=np.int32),
                     rng.integers(0, 99, p * m).astype(np.int32)], axis=1)
    def f(r, d):
        out, valid, over = exchange(r, d[:, 0], jnp.ones(m, bool), p=p,
                                    cap_out=p * m, axis="bsp")
        return out, valid[:, None], over[None]
    fn = jax.jit(shard_map(f, mesh=mesh,
        in_specs=(P("bsp"), P("bsp")), out_specs=(P("bsp"), P("bsp"), P("bsp"))))
    out, valid, over = fn(jnp.asarray(rows), jnp.asarray(dest[:, None]))
    assert not bool(np.asarray(over).any())
    out, valid = np.asarray(out), np.asarray(valid)[:, 0]
    # shard 3 (rows p*m*3/... layout: out is [p * p*m, 2] global) —
    # reshape per shard: each shard got cap_out=p*m rows
    per = out.reshape(p, p * m, 2)
    pv = valid.reshape(p, p * m)
    assert pv[3].sum() == p * m            # all rows arrived at shard 3
    assert sorted(per[3][pv[3]][:, 0].tolist()) == list(range(p * m))
    assert pv[[0,1,2,4,5,6,7]].sum() == 0
    print("OK")
    """)


def test_bsp_suffix_array_matches_oracle():
    _run("""
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.bsp.suffix_array import suffix_array_bsp
    from repro.bsp.counters import BSPCounters
    from repro.core.oracle import suffix_array_doubling
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("bsp",))
    rng = np.random.default_rng(2)
    for n, sig in [(900, 3), (2048, 2), (1500, 30)]:
        x = rng.integers(0, sig, size=n)
        ct = BSPCounters()
        got = suffix_array_bsp(x, mesh, base_threshold=64, counters=ct)
        assert np.array_equal(got, suffix_array_doubling(x)), (n, sig)
        assert ct.supersteps > 0 and ct.comm_words > 0
    print("OK")
    """)


def test_bsp_superstep_scaling_model():
    """C4: cost-model round counts — accelerated O(log log p) vs fixed."""
    from repro.core.seq_ref import accelerated_next_v, fixed_next_v
    from repro.core.difference_cover import difference_cover

    def rounds(n, p, schedule):
        v, cnt = 3, 0
        while n > max(4096, 1):
            if n <= max(4096, n and 0) or n <= p * v * 2:
                break
            D = difference_cover(min(v, max(n, 3)))
            n = len(D) * -(-n // v)
            v = schedule(v, len(D), n)
            cnt += 1
            if cnt > 200:
                break
        return cnt

    n = 1 << 40
    for p in [2 ** k for k in range(4, 16, 2)]:
        ra = rounds(n, p, accelerated_next_v)
        rf = rounds(n, p, fixed_next_v)
        assert ra <= rf
