"""BSP layer tests — run in a subprocess with 8 fake CPU devices (the main
pytest process must keep the default 1-device view)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                   "src"))


def _run(body: str, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_psort_key_and_comparator_modes():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.bsp.psort import run_psort, make_local_sort_bitonic, lex_lt_full
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("bsp",))
    rng = np.random.default_rng(0)
    def rows_of(vals):
        N = len(vals)
        return np.stack([np.zeros(N, np.int32), np.asarray(vals, np.int32),
                         np.arange(N, dtype=np.int32)], axis=1)
    for vals in [rng.integers(0, 50, 256), np.zeros(512, np.int64),
                 np.arange(512)[::-1].copy()]:
        out, over = run_psort(mesh, "bsp", jnp.asarray(rows_of(vals)))
        assert not bool(np.asarray(over)[0])
        got = np.asarray(out); got = got[got[:, 0] == 0]
        want = np.lexsort((np.arange(len(vals)), vals))
        assert np.array_equal(got[:, 2], want)
    vals = rng.integers(0, 9, 256)
    ls = make_local_sort_bitonic(lex_lt_full)
    out, over = run_psort(mesh, "bsp", jnp.asarray(rows_of(vals)),
                          lt_fn=lex_lt_full, local_sort=ls)
    got = np.asarray(out); got = got[got[:, 0] == 0]
    assert np.array_equal(got[:, 2], np.lexsort((np.arange(256), vals)))
    print("OK")
    """)


def test_exchange_adversarial_skew():
    _run("""
    import jax, jax.numpy as jnp, numpy as np, functools
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.bsp.exchange import exchange
    from repro.core.compat import shard_map
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("bsp",))
    p, m = 8, 32
    rng = np.random.default_rng(1)
    # adversarial: every shard sends everything to shard 3
    dest = np.full((p * m,), 3, np.int32)
    rows = np.stack([np.arange(p * m, dtype=np.int32),
                     rng.integers(0, 99, p * m).astype(np.int32)], axis=1)
    def f(r, d):
        out, valid, over = exchange(r, d[:, 0], jnp.ones(m, bool), p=p,
                                    cap_out=p * m, axis="bsp")
        return out, valid[:, None], over[None]
    fn = jax.jit(shard_map(f, mesh=mesh,
        in_specs=(P("bsp"), P("bsp")), out_specs=(P("bsp"), P("bsp"), P("bsp"))))
    out, valid, over = fn(jnp.asarray(rows), jnp.asarray(dest[:, None]))
    assert not bool(np.asarray(over).any())
    out, valid = np.asarray(out), np.asarray(valid)[:, 0]
    # shard 3 (rows p*m*3/... layout: out is [p * p*m, 2] global) —
    # reshape per shard: each shard got cap_out=p*m rows
    per = out.reshape(p, p * m, 2)
    pv = valid.reshape(p, p * m)
    assert pv[3].sum() == p * m            # all rows arrived at shard 3
    assert sorted(per[3][pv[3]][:, 0].tolist()) == list(range(p * m))
    assert pv[[0,1,2,4,5,6,7]].sum() == 0
    print("OK")
    """)


def test_bsp_suffix_array_matches_oracle():
    _run("""
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.bsp.suffix_array import suffix_array_bsp
    from repro.bsp.counters import BSPCounters
    from repro.core.oracle import suffix_array_doubling
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("bsp",))
    rng = np.random.default_rng(2)
    for n, sig in [(900, 3), (2048, 2), (1500, 30)]:
        x = rng.integers(0, sig, size=n)
        ct = BSPCounters()
        got = suffix_array_bsp(x, mesh, base_threshold=64, counters=ct)
        assert np.array_equal(got, suffix_array_doubling(x)), (n, sig)
        assert ct.supersteps > 0 and ct.comm_words > 0
    print("OK")
    """)


def test_bsp_sort_impls_edge_texts():
    """Packed-key / unpacked-key / comparator local sorts all match the
    oracle, including on all-equal and adversarial-periodic texts."""
    _run("""
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.bsp.suffix_array import suffix_array_bsp
    from repro.core.oracle import suffix_array_doubling
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("bsp",))
    rng = np.random.default_rng(0)
    texts = [rng.integers(0, 256, size=1000),      # realistic bytes
             np.zeros(600, np.int64),              # all-equal (max ties)
             np.tile([1, 0, 2, 1, 0], 120)]        # adversarial periodic
    for impl in ("radix", "lax", "bitonic"):
        for x in texts:
            got = suffix_array_bsp(x, mesh, base_threshold=128,
                                   sort_impl=impl)
            want = suffix_array_doubling(np.asarray(x, np.int64))
            assert np.array_equal(got, want), impl
    print("OK")
    """, timeout=900)


def test_bsp_nonpow2_meshes_match_oracle():
    """Algorithm 3 on non-power-of-two p (the splitter machinery and the
    two-hop exchange caps make no power-of-two assumption)."""
    _run("""
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.bsp.suffix_array import suffix_array_bsp
    from repro.core.oracle import suffix_array_doubling
    devs = np.array(jax.devices())
    rng = np.random.default_rng(1)
    for p in (3, 5, 6):
        mesh = Mesh(devs[:p].reshape(p), ("bsp",))
        for x in [rng.integers(0, 9, size=1000), np.tile([3, 3, 1], 220)]:
            got = suffix_array_bsp(x, mesh, base_threshold=128)
            want = suffix_array_doubling(np.asarray(x, np.int64))
            assert np.array_equal(got, want), p
    print("OK")
    """, timeout=900)


def test_bsp_counters_match_estimate_and_overflow_is_hard_error():
    """C4/C5 reconciliation: measured superstep log == the analytic replay
    (`estimate_costs`) on a worst-case text, with SM1=11 / SM2=9 per round;
    and exchange capacity overflow is a detected, hard error."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.bsp.counters import BSPCounters
    from repro.bsp.exchange import exchange
    from repro.bsp.suffix_array import estimate_costs, suffix_array_bsp
    from repro.core.compat import shard_map
    import repro.bsp.psort as psort

    mesh = Mesh(np.array(jax.devices()).reshape(8), ("bsp",))

    # --- measured == analytic replay (all-equal text never short-circuits)
    x = np.zeros(3000, np.int64)
    ct = BSPCounters()
    sa = suffix_array_bsp(x, mesh, base_threshold=64, counters=ct)
    assert np.array_equal(sa, np.arange(3000)[::-1])
    est = estimate_costs(3000, 8, base_threshold=64, sigma=1)
    assert ct.supersteps == est.supersteps
    assert [e["label"] for e in ct.log] == [e["label"] for e in est.log]
    labels = [e["label"] for e in ct.log]
    assert labels.count("base/gather") == 1
    sm1 = sum(1 for l in labels if l.startswith("SM1/"))
    sm2 = sum(1 for l in labels if l.startswith("SM2/"))
    assert sm1 == 11 * ct.rounds and sm2 == 9 * ct.rounds
    assert ct.supersteps == 20 * ct.rounds + 1 and ct.rounds >= 2

    # --- exchange overflow is detected (cap_out far below the h-relation)
    p, m = 8, 32
    rows = np.stack([np.arange(p * m, dtype=np.int32),
                     np.arange(p * m, dtype=np.int32)], axis=1)
    dest = np.zeros((p * m, 1), np.int32)          # everything to shard 0
    def f(r, d):
        out, valid, over = exchange(r, d[:, 0], jnp.ones(m, bool), p=p,
                                    cap_out=4, axis="bsp")
        return out, valid[:, None], over[None]
    fn = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("bsp"), P("bsp")),
                           out_specs=(P("bsp"), P("bsp"), P("bsp"))))
    _, _, over = fn(jnp.asarray(rows), jnp.asarray(dest))
    assert bool(np.asarray(over).any())

    # --- run_psort surfaces a set flag as RuntimeError (flag forced on one
    #     shard; real flag-raising is covered by the cap_out=4 case above)
    orig = psort.exchange
    def forced(rows, dest, valid, *, p, cap_out, axis):
        out, val, over = orig(rows, dest, valid, p=p, cap_out=cap_out,
                              axis=axis)
        return out, val, over | (jax.lax.axis_index(axis) == 0)
    psort.exchange = forced
    try:
        N = 512
        rows_g = jnp.asarray(np.stack(
            [np.zeros(N, np.int32), np.arange(N, dtype=np.int32) % 7,
             np.arange(N, dtype=np.int32)], axis=1))
        try:
            psort.run_psort(mesh, "bsp", rows_g)
            raise SystemExit("expected RuntimeError")
        except RuntimeError as e:
            assert "overflow" in str(e)
    finally:
        psort.exchange = orig

    # --- the driver-side check itself is a hard error on any set flag
    from repro.bsp.suffix_array import _check_overflow
    _check_overflow(np.zeros(8, bool), "SM1")          # all clear: no-op
    try:
        _check_overflow(np.asarray([False, True] + [False] * 6), "SM1")
        raise SystemExit("expected RuntimeError")
    except RuntimeError as e:
        assert "SM1" in str(e)
    print("OK")
    """, timeout=900)


def test_bsp_p1_degenerate_and_estimate_model():
    """p=1 degenerates to the single-device path (one base superstep), and
    the analytic model shows the accelerated schedule's round advantage."""
    import numpy as np

    from repro.bsp.counters import BSPCounters
    from repro.bsp.suffix_array import estimate_costs, suffix_array_bsp
    from repro.core.oracle import suffix_array_doubling
    from repro.core.seq_ref import fixed_next_v
    from repro.launch.mesh import make_sa_mesh

    x = np.random.default_rng(3).integers(0, 5, 600)
    ct = BSPCounters()
    got = suffix_array_bsp(x, make_sa_mesh(1), counters=ct)
    assert np.array_equal(got, suffix_array_doubling(x))
    assert ct.supersteps == 1 and ct.rounds == 0
    assert estimate_costs(600, 1).supersteps == 1

    # accelerated schedule: never more rounds than fixed-v, and an
    # O(log log) round count at realistic sizes (paper C4)
    for n, p in ((1 << 20, 16), (1 << 22, 64)):
        acc = estimate_costs(n, p)
        fix = estimate_costs(n, p, schedule=fixed_next_v)
        assert acc.rounds <= fix.rounds
        assert acc.supersteps == 20 * acc.rounds + 1
        assert acc.rounds <= 6          # log log n envelope at these sizes


def test_bsp_rejects_pallas_sort_impl():
    import numpy as np
    import pytest

    from repro.bsp.suffix_array import suffix_array_bsp
    from repro.launch.mesh import make_sa_mesh

    with pytest.raises(ValueError, match="pallas"):
        suffix_array_bsp(np.arange(100) % 7, make_sa_mesh(1),
                         sort_impl="pallas")


def test_bsp_superstep_scaling_model():
    """C4: cost-model round counts — accelerated O(log log p) vs fixed."""
    from repro.core.seq_ref import accelerated_next_v, fixed_next_v
    from repro.core.difference_cover import difference_cover

    def rounds(n, p, schedule):
        v, cnt = 3, 0
        while n > max(4096, 1):
            if n <= max(4096, n and 0) or n <= p * v * 2:
                break
            D = difference_cover(min(v, max(n, 3)))
            n = len(D) * -(-n // v)
            v = schedule(v, len(D), n)
            cnt += 1
            if cnt > 200:
                break
        return cnt

    n = 1 << 40
    for p in [2 ** k for k in range(4, 16, 2)]:
        ra = rounds(n, p, accelerated_next_v)
        rf = rounds(n, p, fixed_next_v)
        assert ra <= rf
