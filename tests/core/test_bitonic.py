import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.bitonic import (bitonic_sort, lex_lt_int, next_pow2,
                                sort_rows_with_index)


@given(st.lists(st.integers(min_value=-5, max_value=5), min_size=1,
                max_size=64))
@settings(max_examples=40, deadline=None)
def test_bitonic_sorts_any_comparator_input(xs):
    n = next_pow2(len(xs))
    vals = np.asarray(xs + [10**6] * (n - len(xs)), np.int32)
    idx = np.arange(n, dtype=np.int32)
    payload = {"v": jnp.asarray(vals), "i": jnp.asarray(idx)}

    def lt(a, b):
        return jnp.where(a["v"] != b["v"], a["v"] < b["v"], a["i"] < b["i"])

    out = bitonic_sort(payload, lt)
    got = np.asarray(out["v"])[:len(xs)]
    assert np.array_equal(got, np.sort(np.asarray(xs)))
    # stability via index tiebreak
    got_i = np.asarray(out["i"])[:len(xs)]
    want_i = np.lexsort((np.arange(len(xs)), np.asarray(xs)))
    assert np.array_equal(got_i, want_i)


def test_bitonic_reverse_comparator():
    vals = np.arange(32, dtype=np.int32)
    payload = {"v": jnp.asarray(vals), "i": jnp.arange(32, dtype=jnp.int32)}
    out = bitonic_sort(payload, lambda a, b: jnp.where(
        a["v"] != b["v"], a["v"] > b["v"], a["i"] < b["i"]))
    assert np.array_equal(np.asarray(out["v"]), vals[::-1])


@given(st.integers(min_value=1, max_value=6), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_lex_lt_int(w, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(-3, 3, (40, w)).astype(np.int32)
    b = rng.integers(-3, 3, (40, w)).astype(np.int32)
    lt, eq = lex_lt_int(jnp.asarray(a), jnp.asarray(b))
    for i in range(40):
        assert bool(lt[i]) == (tuple(a[i]) < tuple(b[i]))
        assert bool(eq[i]) == (tuple(a[i]) == tuple(b[i]))


def test_sort_rows_with_index_stable():
    rng = np.random.default_rng(0)
    rows = rng.integers(0, 3, (100, 2)).astype(np.int32)
    perm = np.asarray(sort_rows_with_index(jnp.asarray(rows), 2))
    want = np.lexsort((np.arange(100), rows[:, 1], rows[:, 0]))
    assert np.array_equal(perm, want)
