"""Property tests for the shard-local BSP primitives (pure, no mesh)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.bsp.exchange import hop_caps
from repro.bsp.primitives import (counts_per_bucket, lex_lt_rows,
                                  searchsorted_rows, within_group_index)
from repro.bsp.psort import (pack_key_columns, packed_width, quantize_sigma,
                             resolve_bsp_sort_impl)
from repro.bsp.suffix_array import pack_window_columns


@given(st.lists(st.tuples(st.integers(0, 5), st.booleans()), min_size=1,
                max_size=120))
@settings(max_examples=60, deadline=None)
def test_within_group_index(items):
    group = np.array([g for g, _ in items], np.int32)
    valid = np.array([v for _, v in items], bool)
    out = np.asarray(within_group_index(jnp.asarray(group),
                                        jnp.asarray(valid)))
    seen: dict = {}
    for i, (g, v) in enumerate(items):
        if not v:
            assert out[i] == 0
            continue
        assert out[i] == seen.get(g, 0), (i, g)
        seen[g] = seen.get(g, 0) + 1


@given(st.integers(1, 200), st.integers(1, 16), st.integers(0, 100))
@settings(max_examples=40, deadline=None)
def test_hop_caps_bound_round_robin(m, p, seed):
    """The two-hop caps are sufficient for ANY destination pattern."""
    rng = np.random.default_rng(seed)
    dest = rng.integers(0, p, m)
    cap1, cap2 = hop_caps(m, p, cap_out=2 * m + 2 * p + 4)
    # hop 1: rows to intermediate q = (per-dest round robin)
    i_d = np.zeros(m, int)
    cnt: dict = {}
    for i, d in enumerate(dest):
        i_d[i] = cnt.get(d, 0)
        cnt[d] = cnt.get(d, 0) + 1
    inter = i_d % p
    assert np.bincount(inter, minlength=p).max() <= cap1


@given(st.integers(2, 40), st.integers(2, 9), st.integers(0, 50))
@settings(max_examples=40, deadline=None)
def test_pack_window_columns_preserves_order(n, v, seed):
    rng = np.random.default_rng(seed)
    sigma = int(rng.integers(2, 300))
    win = rng.integers(-1, sigma, (n, v)).astype(np.int32)
    packed = np.asarray(pack_window_columns(jnp.asarray(win), sigma))
    # lexicographic order identical before/after packing
    o1 = np.lexsort(tuple(win[:, c] for c in range(v - 1, -1, -1)))
    o2 = np.lexsort(tuple(packed[:, c]
                          for c in range(packed.shape[1] - 1, -1, -1)))
    k1 = [tuple(win[i]) for i in o1]
    k2 = [tuple(win[i]) for i in o2]
    assert k1 == k2           # same sorted key sequence (ties may permute)
    # equality is preserved exactly (injective packing)
    for i in range(min(n, 10)):
        for j in range(min(n, 10)):
            assert (tuple(win[i]) == tuple(win[j])) == \
                (tuple(packed[i]) == tuple(packed[j]))


@given(st.integers(2, 40), st.integers(1, 9), st.integers(0, 50),
       st.integers(-1, 4))
@settings(max_examples=40, deadline=None)
def test_pack_key_columns_generic_ranges(n, k, seed, lo):
    """The generic packer preserves lexicographic order and row equality
    for arbitrary [lo, hi] ranges, and its width matches `packed_width`."""
    rng = np.random.default_rng(seed)
    hi = lo + int(rng.integers(1, 500))
    cols = rng.integers(lo, hi + 1, (n, k)).astype(np.int32)
    packed = np.asarray(pack_key_columns(jnp.asarray(cols), lo, hi))
    assert packed.shape == (n, packed_width(k, lo, hi))
    assert packed.max(initial=0) < np.iinfo(np.int32).max
    o1 = np.lexsort(tuple(cols[:, c] for c in range(k - 1, -1, -1)))
    o2 = np.lexsort(tuple(packed[:, c]
                          for c in range(packed.shape[1] - 1, -1, -1)))
    assert [tuple(cols[i]) for i in o1] == [tuple(cols[i]) for i in o2]
    for i in range(min(n, 8)):
        for j in range(min(n, 8)):
            assert (tuple(cols[i]) == tuple(cols[j])) == \
                (tuple(packed[i]) == tuple(packed[j]))


@given(st.integers(0, 100_000), st.integers(1, 12))
@settings(max_examples=60, deadline=None)
def test_quantize_sigma_preserves_packed_width(sigma, k):
    """Quantisation keeps the packed layout identical (same bit width, so
    same lane count), never shrinks the value range, and is idempotent —
    the properties that make it a sound static-arg key."""
    q = quantize_sigma(sigma)
    assert q >= sigma
    assert quantize_sigma(q) == q
    assert (sigma + 1).bit_length() == (q + 1).bit_length()
    assert packed_width(k, -1, sigma) == packed_width(k, -1, q)


def test_resolve_bsp_sort_impl():
    assert resolve_bsp_sort_impl("auto") == "radix"
    assert resolve_bsp_sort_impl("auto", pack_keys=False) == "lax"
    assert resolve_bsp_sort_impl("bitonic") == "bitonic"
    assert resolve_bsp_sort_impl("lax", pack_keys=True) == "lax"
    for bad in ("pallas", "nope"):
        try:
            resolve_bsp_sort_impl(bad)
            raise AssertionError(f"{bad} accepted")
        except ValueError:
            pass


@given(st.integers(1, 50), st.integers(1, 12), st.integers(0, 99))
@settings(max_examples=30, deadline=None)
def test_searchsorted_rows_matches_linear(m, q, seed):
    rng = np.random.default_rng(seed)
    W = 3
    rows = rng.integers(0, 4, (m, W)).astype(np.int32)
    spl = np.sort(rng.integers(0, 4, (q, W)).astype(np.int32), axis=0)
    spl = spl[np.lexsort(tuple(spl[:, c] for c in range(W - 1, -1, -1)))]
    got = np.asarray(searchsorted_rows(jnp.asarray(spl), jnp.asarray(rows)))
    for i in range(m):
        want = sum(1 for s in spl if tuple(s) < tuple(rows[i]))
        assert got[i] == want


def test_counts_per_bucket():
    dest = jnp.asarray([0, 1, 1, 3, 3, 3], jnp.int32)
    valid = jnp.asarray([True, True, False, True, True, True])
    out = np.asarray(counts_per_bucket(dest, valid, 4))
    assert out.tolist() == [1, 1, 0, 3]
