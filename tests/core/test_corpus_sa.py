import numpy as np
from hypothesis import given, settings, strategies as st

from repro.text.corpus_sa import (build_corpus_sa, count_occurrences,
                                  cross_doc_duplicates)


@given(st.lists(st.lists(st.integers(0, 3), min_size=1, max_size=40),
                min_size=1, max_size=5),
       st.lists(st.integers(0, 3), min_size=1, max_size=3))
@settings(max_examples=30, deadline=None)
def test_count_occurrences_matches_naive(docs, pattern):
    csa = build_corpus_sa([np.asarray(d) for d in docs])
    got = count_occurrences(csa, pattern)
    want = 0
    m = len(pattern)
    for d in docs:
        for i in range(len(d) - m + 1):
            if list(d[i:i + m]) == list(pattern):
                want += 1
    assert got == want


def test_cross_doc_duplicates_detects_contamination():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 50, 300)
    b = rng.integers(0, 50, 300)
    b[100:180] = a[50:130]                     # contaminate doc 1 with doc 0
    csa = build_corpus_sa([a, b])
    hits = cross_doc_duplicates(csa, min_len=60)
    assert any(l >= 80 for _, _, l in hits)
    assert all(i == 0 and j == 1 for i, j, _ in hits)


def test_no_cross_document_suffix_confusion():
    # "ab" + "ab": suffixes must not extend across the boundary — pattern
    # "ba" does not occur (the separator splits it)
    csa = build_corpus_sa([[0, 1], [0, 1]])
    assert count_occurrences(csa, [0, 1]) == 2
    assert count_occurrences(csa, [1, 0]) == 0
