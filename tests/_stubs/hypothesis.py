"""Minimal deterministic stand-in for the `hypothesis` package.

The container image does not ship `hypothesis`, and the tier-1 suite must run
without installing anything. This stub implements the tiny slice of the API
the tests use — `given`, `settings`, and the `strategies` constructors
`integers / booleans / lists / tuples / sampled_from / just / floats` — as a
deterministic random sampler: each test gets a PRNG seeded from its qualified
name, so runs are reproducible and failures replayable. No shrinking, no
database, no phases. `tests/conftest.py` puts this directory on sys.path ONLY
when the real hypothesis is not importable, so environments that do have it
(e.g. CI) use the real thing.
"""
from __future__ import annotations

import functools
import zlib


class SearchStrategy:
    """A strategy is just a draw function rng -> value."""

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self.draw(rng)))

    def filter(self, pred, _tries: int = 100):
        def draw(rng):
            for _ in range(_tries):
                v = self.draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return SearchStrategy(draw)


def _as_strategy(obj) -> SearchStrategy:
    if isinstance(obj, SearchStrategy):
        return obj
    raise TypeError(f"expected a strategy, got {obj!r}")


class _Strategies:
    @staticmethod
    def integers(min_value=-(2 ** 31), max_value=2 ** 31 - 1):
        return SearchStrategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def booleans():
        return SearchStrategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return SearchStrategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def lists(elements, min_size=0, max_size=None, unique=False):
        elements = _as_strategy(elements)

        def draw(rng):
            hi = max_size if max_size is not None else min_size + 10
            # bias toward small sizes like real hypothesis (half the draws)
            size = (rng.randint(min_size, max(min_size, (min_size + hi) // 2))
                    if rng.random() < 0.5 else rng.randint(min_size, hi))
            out, seen = [], set()
            tries = 0
            while len(out) < size and tries < 50 * (size + 1):
                v = elements.draw(rng)
                tries += 1
                if unique:
                    key = v if not isinstance(v, list) else tuple(v)
                    if key in seen:
                        continue
                    seen.add(key)
                out.append(v)
            return out
        return SearchStrategy(draw)

    @staticmethod
    def tuples(*strats):
        strats = tuple(_as_strategy(s) for s in strats)
        return SearchStrategy(lambda rng: tuple(s.draw(rng) for s in strats))

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        if not seq:
            raise ValueError("sampled_from requires a non-empty collection")
        return SearchStrategy(lambda rng: seq[rng.randrange(len(seq))])

    @staticmethod
    def just(value):
        return SearchStrategy(lambda rng: value)

    @staticmethod
    def one_of(*strats):
        strats = tuple(_as_strategy(s) for s in strats)
        return SearchStrategy(
            lambda rng: strats[rng.randrange(len(strats))].draw(rng))


strategies = _Strategies()


class settings:
    """Decorator/record: only max_examples is honoured; deadline et al. are
    accepted and ignored (the stub never times out a test body)."""

    default_max_examples = 20

    def __init__(self, max_examples: int = 20, deadline=None, **_kw):
        self.max_examples = int(max_examples)

    def __call__(self, fn):
        fn._hyp_settings = self
        return fn


def given(*arg_strats, **kw_strats):
    arg_strats = tuple(_as_strategy(s) for s in arg_strats)
    kw_strats = {k: _as_strategy(s) for k, s in kw_strats.items()}

    def decorate(fn):
        import random

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = (getattr(wrapper, "_hyp_settings", None)
                   or getattr(fn, "_hyp_settings", None))
            n = cfg.max_examples if cfg else settings.default_max_examples
            seed = zlib.crc32(fn.__qualname__.encode())  # stable across runs
            rng = random.Random(seed)
            for i in range(n):
                drawn = [s.draw(rng) for s in arg_strats]
                kdrawn = {k: s.draw(rng) for k, s in kw_strats.items()}
                try:
                    fn(*args, *drawn, **kwargs, **kdrawn)
                except Exception:
                    print(f"hypothesis-stub: falsifying example "
                          f"(run {i}): args={drawn!r} kwargs={kdrawn!r}")
                    raise
        # pytest must see a zero-arg signature, not fn's drawn params
        # (it would otherwise look for fixtures named after them).
        del wrapper.__wrapped__
        wrapper.is_hypothesis_test = True
        return wrapper
    return decorate


def example(*_a, **_k):
    """@example is a no-op in the stub (explicit examples are not replayed)."""
    def decorate(fn):
        return fn
    return decorate


class HealthCheck:
    too_slow = data_too_large = filter_too_much = all = None

    @staticmethod
    def all():  # type: ignore[misc]
        return []


def assume(condition) -> bool:
    """Raise-free approximation: silently accept (stub draws are unshrunk)."""
    return bool(condition)


__version__ = "0.0.0-repro-stub"
