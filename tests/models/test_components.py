"""Component-level model tests: flash == naive attention, GQA degeneracy,
window masks, softcap, MoE invariants, RG-LRU/RWKV scan-vs-step equivalence."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import decode_attention, flash_attention
from repro.models.config import ModelConfig
from repro.models.ffn import _moe_local, init_moe, moe_layer
from repro.models.layers import softcap
from repro.models.rglru import init_rglru, init_rglru_state, rglru_layer
from repro.models.rwkv6 import (init_rwkv_state, init_rwkv_time_mix,
                                rwkv_time_mix)
from repro.models.sharding import ParamCollector


def _naive_attn(q, k, v, causal, window, cap=None):
    B, S, H, hd = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    s = jnp.einsum("bqkgd,bckd->bqkgc", q.reshape(B, S, Hk, G, hd), k) \
        / math.sqrt(hd)
    if cap:
        s = softcap(s, cap)
    qp, kp = jnp.arange(S)[:, None], jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= qp >= kp
    if window:
        ok &= (qp - kp) < window
    s = jnp.where(ok[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bqkgc,bckd->bqkgd", p, v).reshape(B, S, H, hd)


@pytest.mark.parametrize("S,H,Hk,causal,window,cap", [
    (64, 4, 2, True, None, None),
    (64, 4, 4, True, 9, None),
    (100, 4, 1, True, 16, 50.0),
    (48, 2, 2, False, None, None),
])
def test_flash_matches_naive(S, H, Hk, causal, window, cap):
    rng = np.random.default_rng(S + H)
    q = jnp.asarray(rng.normal(size=(2, S, H, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, S, Hk, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, S, Hk, 16)), jnp.float32)
    a = flash_attention(q, k, v, causal=causal, window=window,
                        attn_softcap=cap, q_chunk=32, kv_chunk=32)
    b = _naive_attn(q, k, v, causal, window, cap)
    assert float(jnp.max(jnp.abs(a - b))) < 2e-2


def test_gqa_equals_mha_when_kv_heads_match():
    """GQA with Hk == H must equal MHA head-for-head."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 32, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 32, 4, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 32, 4, 8)), jnp.float32)
    full = flash_attention(q, k, v, q_chunk=16, kv_chunk=16)
    per_head = jnp.stack([
        flash_attention(q[:, :, h:h+1], k[:, :, h:h+1], v[:, :, h:h+1],
                        q_chunk=16, kv_chunk=16)[:, :, 0]
        for h in range(4)], axis=2)
    assert float(jnp.max(jnp.abs(full - per_head))) < 1e-2


def test_decode_ring_buffer_matches_full():
    """Ring-buffer decode over a window-C cache == full attention restricted
    to the window."""
    rng = np.random.default_rng(1)
    B, C, Hk, hd = 1, 8, 2, 8
    T = 20                                   # decode past the ring capacity
    ks = jnp.asarray(rng.normal(size=(B, T, Hk, hd)), jnp.float32)
    vs = jnp.asarray(rng.normal(size=(B, T, Hk, hd)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, 1, 2, hd)), jnp.float32)
    ck = jnp.zeros((B, C, Hk, hd)); cv = jnp.zeros((B, C, Hk, hd))
    from repro.models.attention import update_cache
    for t in range(T):
        ck, cv = update_cache(ck, cv, ks[:, t:t+1], vs[:, t:t+1], t)
    out = decode_attention(q, ck, cv, T - 1, window=C)
    want = _naive_attn(q, ks[:, T-C:], vs[:, T-C:], False, None)
    assert float(jnp.max(jnp.abs(out - want))) < 2e-2


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    assert float(jnp.max(jnp.abs(softcap(x, None) - x))) == 0.0


# ------------------------------------------------------------------- MoE
def _moe_cfg(**kw):
    base = dict(name="m", family="moe", n_layers=2, d_model=16, n_heads=2,
                n_kv_heads=2, d_ff=32, vocab_size=64, n_experts=4, top_k=2)
    base.update(kw)
    return ModelConfig(**base)


def test_moe_local_routing_invariants():
    cfg = _moe_cfg()
    col = ParamCollector(jax.random.PRNGKey(0))
    init_moe(col, "moe", cfg)
    p = col.params["moe"]
    x = jnp.asarray(np.random.default_rng(0).normal(size=(32, 16)),
                    jnp.float32)
    out, aux = _moe_local(x, p["router"], p["wg"], p["wu"], p["wd"],
                          cfg=cfg, tp=1, axis=None)
    assert out.shape == (32, 16)
    assert np.isfinite(float(aux)) and float(aux) > 0
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))


def test_moe_capacity_drop_is_graceful():
    cfg = _moe_cfg(capacity_factor=0.01)      # force drops
    col = ParamCollector(jax.random.PRNGKey(0))
    init_moe(col, "moe", cfg)
    p = col.params["moe"]
    x = jnp.ones((64, 16), jnp.float32)
    out, _ = _moe_local(x, p["router"], p["wg"], p["wu"], p["wd"],
                        cfg=cfg, tp=1, axis=None)
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))


# ---------------------------------------------------------------- RG-LRU
def test_rglru_scan_matches_stepwise():
    cfg = ModelConfig(name="g", family="hybrid", n_layers=2, d_model=16,
                      n_heads=2, n_kv_heads=1, d_ff=32, vocab_size=64,
                      lru_dim=16, conv_width=4)
    col = ParamCollector(jax.random.PRNGKey(2))
    init_rglru(col, "rnn", cfg)
    p = col.params["rnn"]
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, 10, 16)) * 0.3,
                    jnp.float32)
    full, _ = rglru_layer(p, cfg, x)
    st = init_rglru_state(cfg, 1)
    outs = []
    for t in range(10):
        o, st = rglru_layer(p, cfg, x[:, t:t+1], state=st)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(full.astype(jnp.float32)
                                 - step.astype(jnp.float32)))) < 3e-2


# ---------------------------------------------------------------- RWKV6
def test_rwkv_scan_matches_stepwise():
    cfg = ModelConfig(name="w", family="ssm", n_layers=2, d_model=16,
                      n_heads=2, n_kv_heads=2, head_dim=8, d_ff=32,
                      vocab_size=64)
    col = ParamCollector(jax.random.PRNGKey(3))
    init_rwkv_time_mix(col, "tm", cfg)
    p = col.params["tm"]
    x = jnp.asarray(np.random.default_rng(1).normal(size=(1, 8, 16)) * 0.3,
                    jnp.float32)
    full, _ = rwkv_time_mix(p, cfg, x)
    st = init_rwkv_state(cfg, 1)["tm"]
    outs = []
    for t in range(8):
        o, st = rwkv_time_mix(p, cfg, x[:, t:t+1], state=st)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(full.astype(jnp.float32)
                                 - step.astype(jnp.float32)))) < 3e-2
