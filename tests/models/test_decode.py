"""Prefill-vs-decode consistency: full forward logits == stepwise decode
logits (exercises KV ring buffers, recurrent states, positions)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.layers import logits_from_embedding
from repro.models.lm import (decode_step, encode, forward_hidden,
                             init_decode_states, lm_init)

ARCHS = ["gemma2_27b", "gemma3_1b", "recurrentgemma_2b", "rwkv6_1_6b",
         "kimi_k2_1t_a32b", "whisper_small"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(0)
    B, S = 2, 10
    params, _ = lm_init(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    enc_out = None
    if cfg.is_encdec:
        enc = 0.02 * jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
        enc_out = encode(params, cfg, enc)
    hidden, _, _ = forward_hidden(params, cfg, tokens=toks, enc_out=enc_out)
    full = logits_from_embedding(hidden, params["embed"],
                                 cap=cfg.logit_softcap)
    states = init_decode_states(cfg, B, cache_len=S)
    step = jax.jit(lambda p, t, st, pos: decode_step(
        p, cfg, t, st, pos, enc_out=enc_out))
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    for t in range(S):
        lg, states = step(params, toks[:, t:t + 1], states, jnp.int32(t))
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, t])))
        assert err / scale < 0.05, (arch, t, err, scale)
