"""Per-arch REDUCED-config smoke tests: one forward/train step on CPU,
asserting output shapes + no NaNs (the FULL configs are exercised only via
the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, model_archs
from repro.models.lm import forward_hidden, lm_init, lm_loss, encode
from repro.train.optim import OptConfig
from repro.train.train_step import (TrainConfig, make_train_state,
                                    make_train_step)

B, S = 2, 24


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S + 1), 0,
                                          cfg.vocab_size)}
    if cfg.is_encdec:
        batch["enc_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", model_archs())
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(0)
    params, axes = lm_init(key, cfg)
    batch = _batch(cfg, key)
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["enc_embeds"])
        assert enc_out.shape == (B, cfg.enc_seq, cfg.d_model)
    hidden, _, aux = forward_hidden(params, cfg,
                                    tokens=batch["tokens"][:, :-1],
                                    enc_out=enc_out)
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))
    loss, metrics = lm_loss(params, cfg, batch)
    assert np.isfinite(float(loss))
    # initial loss near ln(V): untrained model ≈ uniform
    assert float(metrics["xent"]) < np.log(cfg.vocab_size) + 3.0


@pytest.mark.parametrize("arch", ["minicpm_2b", "kimi_k2_1t_a32b",
                                  "recurrentgemma_2b", "rwkv6_1_6b",
                                  "whisper_small"])
def test_train_step_updates_params(arch):
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(1)
    params, _ = lm_init(key, cfg)
    tcfg = TrainConfig(opt=OptConfig(name=cfg.optimizer, lr=1e-3), warmup=0,
                       total_steps=10)
    state = make_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg, key)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # at least one param changed
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(state["params"]),
                        jax.tree_util.tree_leaves(new_state["params"])))
    assert changed
