"""Per-kernel interpret-mode validation: sweep shapes/dtypes, assert against
the pure-jnp ref.py oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import (bitonic_sort, bitonic_stage, dense_rank_sorted,
                               radix_histogram)


@pytest.mark.parametrize("n,bins,block", [
    (2048, 256, 1024), (1024, 16, 256), (4096, 64, 512), (999, 8, 128),
    (128, 2, 128),
])
def test_radix_histogram(n, bins, block):
    rng = np.random.default_rng(n + bins)
    d = jnp.asarray(rng.integers(0, bins, n), jnp.int32)
    got = np.asarray(radix_histogram(d, bins, block=block))
    want = np.bincount(np.asarray(d), minlength=bins)
    assert np.array_equal(got, want)


def test_radix_histogram_matches_blockwise_ref():
    rng = np.random.default_rng(0)
    d = jnp.asarray(rng.integers(0, 32, 2048), jnp.int32)
    from repro.kernels.radix_hist import radix_histogram_pallas
    got = np.asarray(radix_histogram_pallas(d, 32, block=512))
    want = np.asarray(ref.radix_histogram_ref(d, 32, 512))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("n,W,tile", [(256, 3, 64), (512, 5, 128),
                                      (1024, 2, 256), (128, 8, 32)])
def test_bitonic_stage_sweep(n, W, tile):
    rng = np.random.default_rng(n * W)
    rows = rng.integers(-4, 9, (n, W)).astype(np.int32)
    rows[:, -1] = rng.permutation(n)
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            got = bitonic_stage(jnp.asarray(rows), int(k), int(j), tile=tile)
            want = ref.bitonic_stage_ref(jnp.asarray(rows), int(k), int(j))
            assert np.array_equal(np.asarray(got), np.asarray(want)), (k, j)
            j //= 4 if j >= 4 else 2          # sparse sweep for speed
        k *= 4
    # full sort end-to-end
    out = bitonic_sort(jnp.asarray(rows), tile=tile)
    want = ref.bitonic_sort_ref(jnp.asarray(rows))
    assert np.array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("n,W,block", [(1000, 3, 128), (512, 2, 512),
                                       (77, 4, 32), (4096, 1, 1024)])
def test_dense_rank_sweep(n, W, block):
    rng = np.random.default_rng(n + W)
    rows = rng.integers(0, 5, (n, W)).astype(np.int32)
    order = np.lexsort(tuple(rows[:, c] for c in range(W - 1, -1, -1)))
    rows = rows[order]
    got, ndist = dense_rank_sorted(jnp.asarray(rows), block=block)
    b = np.ones(n, bool)
    b[1:] = np.any(rows[1:] != rows[:-1], axis=1)
    want = np.cumsum(b) - 1
    assert np.array_equal(np.asarray(got), want)
    assert int(ndist) == want[-1] + 1


def test_seg_boundary_kernel_matches_ref():
    rng = np.random.default_rng(9)
    rows = np.sort(rng.integers(0, 4, (1024, 3)).astype(np.int32), axis=0)
    from repro.kernels.seg_boundary import seg_boundary_pallas
    f, c, t = seg_boundary_pallas(jnp.asarray(rows), block=256)
    rf, rc, rt = ref.seg_boundary_ref(jnp.asarray(rows), block=256)
    assert np.array_equal(np.asarray(f), np.asarray(rf))
    assert np.array_equal(np.asarray(c), np.asarray(rc))
    assert np.array_equal(np.asarray(t), np.asarray(rt))
