"""Exhaustive interpret-mode parity sweeps: the standalone Pallas kernels
(`seg_boundary_pallas`, `radix_histogram_pallas`) against their pure-jnp
oracles in `ref.py`, over shapes, block sizes, key widths and adversarial
inputs (all-equal, all-distinct, single-block, boundary digits)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.radix_hist import radix_histogram_pallas
from repro.kernels.seg_boundary import seg_boundary_pallas


def _sorted_rows(rng, n, W, lo=0, hi=5):
    rows = rng.integers(lo, hi, (n, W)).astype(np.int32)
    order = np.lexsort(tuple(rows[:, c] for c in range(W - 1, -1, -1)))
    return rows[order]


def _assert_seg_parity(rows, block, num_keys=None):
    rows = jnp.asarray(rows)
    f, c, t = seg_boundary_pallas(rows, num_keys=num_keys, block=block)
    rf, rc, rt = ref.seg_boundary_ref(rows, num_keys=num_keys, block=block)
    np.testing.assert_array_equal(np.asarray(f), np.asarray(rf))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(t), np.asarray(rt))


@pytest.mark.parametrize("n,W,block", [
    (256, 1, 64), (512, 3, 128), (1024, 4, 256), (2048, 2, 512),
    (512, 5, 512),            # single block: n == block
    (128, 8, 32),             # wide rows, small blocks
])
def test_seg_boundary_shape_sweep(n, W, block):
    rng = np.random.default_rng(n * W + block)
    _assert_seg_parity(_sorted_rows(rng, n, W), block)


@pytest.mark.parametrize("num_keys", [1, 2, 3])
def test_seg_boundary_num_keys_prefix(num_keys):
    # only the first num_keys columns participate in the boundary test;
    # trailing columns differ everywhere and must be ignored
    rng = np.random.default_rng(num_keys)
    rows = _sorted_rows(rng, 512, 4, hi=3)
    rows[:, 3] = np.arange(512, dtype=np.int32)
    _assert_seg_parity(rows, block=128, num_keys=num_keys)


def test_seg_boundary_all_equal_rows():
    rows = np.full((1024, 3), 7, np.int32)
    _assert_seg_parity(rows, block=256)
    f, _c, t = seg_boundary_pallas(jnp.asarray(rows), block=256)
    # one boundary per block (block-local convention), nothing else
    assert int(np.asarray(f).sum()) == 1024 // 256
    np.testing.assert_array_equal(np.asarray(t), np.ones(4, np.int32))


def test_seg_boundary_all_distinct_rows():
    rows = np.arange(512, dtype=np.int32)[:, None] * np.ones((1, 2), np.int32)
    _assert_seg_parity(rows, block=128)
    f, c, t = seg_boundary_pallas(jnp.asarray(rows), block=128)
    assert int(np.asarray(f).sum()) == 512          # every row a boundary
    np.testing.assert_array_equal(np.asarray(t), np.full(4, 128, np.int32))


def _assert_hist_parity(digits, n_bins, block):
    digits = jnp.asarray(digits, jnp.int32)
    got = np.asarray(radix_histogram_pallas(digits, n_bins, block=block))
    want = np.asarray(ref.radix_histogram_ref(digits, n_bins, block))
    np.testing.assert_array_equal(got, want)
    # blockwise sums must also agree with the global histogram
    np.testing.assert_array_equal(
        got.sum(axis=0), np.bincount(np.asarray(digits), minlength=n_bins))


@pytest.mark.parametrize("n,bins,block", [
    (1024, 256, 256), (2048, 8, 1024), (512, 2, 128), (4096, 128, 512),
    (256, 16, 256),           # single block: n == block
    (128, 1, 64),             # degenerate single-bin histogram
])
def test_radix_histogram_shape_sweep(n, bins, block):
    rng = np.random.default_rng(n + bins + block)
    _assert_hist_parity(rng.integers(0, bins, n), bins, block)


def test_radix_histogram_constant_digits():
    _assert_hist_parity(np.full(1024, 5, np.int32), 8, 256)


def test_radix_histogram_boundary_digits():
    # digits pinned to the first/last bin — one-hot edge columns
    d = np.where(np.arange(2048) % 2 == 0, 0, 255).astype(np.int32)
    _assert_hist_parity(d, 256, 512)


def test_radix_histogram_skewed_blocks():
    # each block holds a single distinct digit: per-block rows are one-hot
    d = np.repeat(np.arange(8, dtype=np.int32), 256)
    got = np.asarray(radix_histogram_pallas(jnp.asarray(d), 8, block=256))
    np.testing.assert_array_equal(got, np.eye(8, dtype=np.int32) * 256)
