"""Serving SLO benchmark: latency vs offered open-loop load.

The build benchmarks measure construction, `query_throughput` measures
the closed-loop kernel; this one measures what a *client* experiences
when traffic is open-loop and bursty — the number the serving tier
(`repro.serve`) exists for. The harness:

1. **calibrates** system capacity: climb a probe ladder with coalescing
   but NO admission control and take the highest offered QPS the server
   still serves at >= 85 % goodput with p99 <= the SLO budget. Measured
   on THIS machine, so the grid lands in the interesting region
   everywhere;
2. sweeps a grid of offered loads (fractions of capacity, from
   comfortable to 4x past saturation) across three serving modes:

   * ``coalesce+admit`` — the full tier: pow2-bucket coalescing with a
     max-wait window, bounded queue + queue-age bound,
     reject-with-retry-after;
   * ``coalesce+none`` — coalescing but NO admission control: the
     unbounded baseline whose p99 diverges past saturation;
   * ``batch1+admit`` — admission but NO coalescing (max_batch=1): the
     batch-of-one baseline that shows what coalescing is worth;

3. adds one bursty ON-OFF record at the 2x point for the full tier
   (mean rate equal to the Poisson point — only the arrival
   correlations differ);
4. derives the two SLO findings the curves exist to show:
   (a) at a fixed p99 budget the coalesced tier sustains strictly more
   goodput than batch-of-one, and (b) past saturation (the 2x-capacity
   point) the admitted tier's accepted-request p99 stays within the SLO
   while the no-admission baseline's diverges.

The workload is long patterns (dedup-span length, 512 chars) over a
1M-char corpus: each binary-search step compares a long pattern slice,
so the device kernel — not the Python submit loop — is the bottleneck,
and queueing theory (not host scheduling noise) decides the curves.

Latency percentiles cover accepted-and-served requests, dated from
their *scheduled* arrival (no coordinated omission; see
`repro.serve.loadgen`), with every kernel shape warmed before timing so
JIT compiles never pollute a percentile. Arrivals are seeded — same
seed, same schedule.

    PYTHONPATH=src python -m benchmarks.serve_slo [--smoke] [--out PATH]
    PYTHONPATH=src python -m benchmarks.serve_slo --check BENCH_serve_slo.json
"""
import argparse
import gc
import json
import platform
import sys

import numpy as np

from repro.api import SuffixArrayIndex
from repro.serve import SAServer, make_arrivals, run_open_loop, summarize

from .bench_util import emit

N = 1_000_000
PATTERN_LEN = 512
MAX_BATCH = 32
QUEUE_DEPTH = 64
SEED = 0
DURATION_S = 2.0
#: p99 budget for the "sustained QPS at fixed p99" finding; also the
#: queue-age admission bound (a request older than the SLO is already
#: lost — reject it and say when to retry)
SLO_MS = 25.0
#: offered-load grid as fractions of calibrated capacity; the 0.125x
#: point exists so the batch-of-one baseline has a within-SLO operating
#: point too — its sustained QPS is then a real number, not zero
GRID_FRACTIONS = (0.125, 0.5, 1.0, 2.0, 4.0)
#: calibration probe ladder (offered QPS) and goodput pass threshold
PROBE_QPS = (500, 1000, 2000, 4000, 8000, 16000)
PROBE_GOODPUT = 0.85
#: loadgen sleep quantum — fine-grained so submit lateness stays well
#: under the latencies being measured
TICK_S = 0.0005

MODES = {
    "coalesce+admit": dict(overload_policy="reject",
                           max_queue_age_us=SLO_MS * 1e3),
    "coalesce+none": dict(overload_policy="none"),
    "batch1+admit": dict(overload_policy="reject",
                         max_queue_age_us=SLO_MS * 1e3,
                         max_batch=1, coalesce_max_wait_us=0.0),
}

#: every record must carry exactly these measurement keys (CI schema gate)
RECORD_KEYS = frozenset({
    "mode", "arrival", "offered_qps", "duration_s", "offered", "ok",
    "rejected", "shed", "goodput_qps", "p50_ms", "p95_ms", "p99_ms",
    "queue_p99_ms", "max_ms", "batch_size_mean", "bucket_occupancy_mean",
    "counters",
})


def make_patterns(rng, text, count: int, m: int) -> list:
    """Half planted substrings (guaranteed hits), half random."""
    pats = []
    for q in range(count):
        if q % 2 == 0:
            at = int(rng.integers(0, len(text) - m))
            pats.append(text[at:at + m])
        else:
            pats.append(rng.integers(0, int(text.max()) + 1, size=m))
    return pats


def _timed_open_loop(server, patterns, arrivals):
    """run_open_loop with the garbage collector paused: cyclic GC sweeps
    tens of ms of GIL time on this box — a measurement artifact that
    would otherwise dominate every p99 (a production deployment would
    gc.freeze() its index and tune thresholds instead)."""
    gc.collect()
    gc.disable()
    try:
        return run_open_loop(server, patterns, arrivals,
                             result_timeout_s=180.0, tick_s=TICK_S)
    finally:
        gc.enable()


def make_server(index, mode: str, *, max_batch: int, queue_depth: int,
                wait_us: float, pattern_len: int) -> SAServer:
    knobs = dict(MODES[mode])
    server = SAServer(index,
                      max_batch=knobs.pop("max_batch", max_batch),
                      coalesce_max_wait_us=knobs.pop("coalesce_max_wait_us",
                                                     wait_us),
                      queue_depth=queue_depth, **knobs)
    server.start()
    server.warmup(pattern_lens=(pattern_len,))  # jit-cached after 1st mode
    return server


def run_point(index, patterns, mode: str, arrival: str, qps: float,
              duration_s: float, *, max_batch: int, queue_depth: int,
              wait_us: float, pattern_len: int, seed: int) -> dict:
    """One (mode, arrival, offered-QPS) cell: fresh server, fresh metrics."""
    server = make_server(index, mode, max_batch=max_batch,
                         queue_depth=queue_depth, wait_us=wait_us,
                         pattern_len=pattern_len)
    arrivals = make_arrivals(arrival, qps, duration_s, seed=seed)
    responses = _timed_open_loop(server, patterns, arrivals)
    server.stop()
    slo = summarize(responses, duration_s)
    m = server.metrics.snapshot()
    rec = {"mode": mode, "arrival": arrival, "offered_qps": round(qps, 1),
           "duration_s": duration_s,
           **{k: (round(v, 3) if isinstance(v, float) else v)
              for k, v in slo.items()},
           "batch_size_mean": m["batch_size"]["mean"],
           "bucket_occupancy_mean": m["bucket_occupancy"]["mean"],
           "counters": m["counters"]}
    p99 = "absent" if rec["p99_ms"] is None else f"{rec['p99_ms']:.1f}ms"
    emit(f"serve_slo/{mode}/{arrival}/qps={qps:.0f}", 0.0,
         f"goodput={rec['goodput_qps']:.0f};p99={p99};"
         f"rejected={rec['rejected']}")
    return rec


def calibrate(index, patterns, *, max_batch: int, wait_us: float,
              pattern_len: int, probe_qps, probe_s: float, slo_ms: float,
              seed: int) -> float:
    """Climb the probe ladder with NO admission control; capacity = the
    last offered rate served at >= PROBE_GOODPUT goodput with p99 within
    the SLO budget. (Probing without admission means rejections can't
    mask saturation — the p99 itself is the signal.)"""
    capacity = probe_qps[0]
    # discarded warm pass: the very first open-loop run pays one-time
    # thread/allocator startup costs that would otherwise fail the
    # lowest rung and wreck the grid
    for qps in (None, *probe_qps):
        if qps is None:
            qps, timed = probe_qps[0], False
        else:
            timed = True
        server = make_server(index, "coalesce+none", max_batch=max_batch,
                             queue_depth=1, wait_us=wait_us,
                             pattern_len=pattern_len)
        arrivals = make_arrivals("poisson", qps, probe_s, seed=seed)
        responses = _timed_open_loop(server, patterns, arrivals)
        server.stop()
        if not timed:
            continue
        s = summarize(responses, probe_s)
        ok = (s["goodput_qps"] >= PROBE_GOODPUT * qps
              and s["p99_ms"] is not None and s["p99_ms"] <= slo_ms)
        p99 = "absent" if s["p99_ms"] is None else f"{s['p99_ms']:.1f}ms"
        print(f"# calibrate: {qps} qps -> goodput {s['goodput_qps']:.0f}, "
              f"p99 {p99} ({'pass' if ok else 'fail'})")
        if not ok:
            break
        capacity = qps
    return float(capacity)


def derive_findings(records: list, slo_ms: float) -> dict:
    """The two claims the curves exist to show, computed from records."""
    poisson = [r for r in records if r["arrival"] == "poisson"]
    grid = sorted({r["offered_qps"] for r in poisson})

    def sustained(mode):
        good = [r["goodput_qps"] for r in poisson
                if r["mode"] == mode and r["p99_ms"] is not None
                and r["p99_ms"] <= slo_ms]
        return max(good) if good else 0.0

    def p99_at(mode, qps):
        for r in poisson:
            if r["mode"] == mode and r["offered_qps"] == qps:
                return r["p99_ms"]
        return None

    # the 2x-capacity point: first grid point clearly past saturation
    over = grid[-2] if len(grid) >= 2 else grid[-1]
    sus = {m: round(sustained(m), 1) for m in ("coalesce+admit",
                                               "batch1+admit")}
    p99s = {m: p99_at(m, over) for m in ("coalesce+admit", "coalesce+none")}
    admit, none = p99s["coalesce+admit"], p99s["coalesce+none"]
    return {
        "slo_ms": slo_ms,
        "sustained_qps_at_slo": sus,
        "coalescing_sustains_higher_qps":
            sus["coalesce+admit"] > sus["batch1+admit"],
        "overload_qps": over,
        "p99_past_saturation_ms": p99s,
        "admission_bounds_p99":
            admit is not None and none is not None
            and admit <= slo_ms and admit < 0.5 * none,
    }


def validate_artifact(art: dict) -> list:
    """Schema gate for BENCH_serve_slo.json; returns a list of problems
    (empty = valid). Asserted by the CI serve-slo-smoke job and
    `tests/serve/test_serve_slo.py`."""
    problems = []
    for key in ("bench", "smoke", "n", "pattern_len", "max_batch",
                "queue_depth", "seed", "duration_s", "capacity_qps",
                "grid_qps", "records", "findings"):
        if key not in art:
            problems.append(f"missing top-level key {key!r}")
    if art.get("bench") != "serve_slo":
        problems.append(f"bench != serve_slo: {art.get('bench')!r}")
    grid = art.get("grid_qps", [])
    if len(grid) < 3:
        problems.append(f"grid_qps needs >= 3 offered points, got {grid}")
    if sorted(grid) != list(grid):
        problems.append("grid_qps must be increasing")
    records = art.get("records", [])
    for mode in MODES:
        pts = [r for r in records
               if r.get("mode") == mode and r.get("arrival") == "poisson"]
        if len(pts) < 3:
            problems.append(f"mode {mode!r} needs >= 3 poisson points, "
                            f"got {len(pts)}")
    if not any(r.get("arrival") == "onoff" for r in records):
        problems.append("missing the bursty (onoff) record")
    for i, r in enumerate(records):
        missing = RECORD_KEYS - set(r)
        if missing:
            problems.append(f"record {i} missing keys {sorted(missing)}")
        if r.get("ok", 0) and r.get("p99_ms") is None:
            problems.append(f"record {i} served requests but p99 is absent")
        if not r.get("ok", 0) and r.get("p99_ms") is not None:
            problems.append(f"record {i} served nothing but p99 is set")
    f = art.get("findings", {})
    for key in ("sustained_qps_at_slo", "coalescing_sustains_higher_qps",
                "p99_past_saturation_ms", "admission_bounds_p99"):
        if key not in f:
            problems.append(f"missing finding {key!r}")
    return problems


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve_slo.json",
                    help="JSON artifact path ('' disables)")
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus, short windows (CI gate: proves the "
                         "tier serves open-loop load and the artifact "
                         "schema holds)")
    ap.add_argument("--check", default=None, metavar="PATH",
                    help="validate an existing artifact and exit")
    args = ap.parse_args(argv)

    if args.check:
        problems = validate_artifact(json.load(open(args.check)))
        for p in problems:
            print(f"SCHEMA: {p}", file=sys.stderr)
        print(f"# {args.check}: "
              f"{'INVALID' if problems else 'schema ok'}")
        return sys.exit(1) if problems else None

    # finer GIL timeslice: on a single-core box the default 5 ms switch
    # interval lets the submit loop starve the coalesce/device threads
    # (and vice versa) for multiple milliseconds — visible directly in
    # tail latency. This is a measurement-harness setting, not a
    # serving-tier requirement.
    sys.setswitchinterval(0.0005)

    n = 50_000 if args.smoke else N
    pattern_len = 64 if args.smoke else PATTERN_LEN
    max_batch = 16 if args.smoke else MAX_BATCH
    queue_depth = 64 if args.smoke else QUEUE_DEPTH
    duration = 0.4 if args.smoke else DURATION_S
    probe_s = 0.2 if args.smoke else 0.5
    probe_qps = PROBE_QPS[:2] if args.smoke else PROBE_QPS
    fractions = (0.5, 2.0, 4.0) if args.smoke else GRID_FRACTIONS
    wait_us = 2000.0

    rng = np.random.default_rng(SEED)
    text = rng.integers(0, 256, size=n)
    index = SuffixArrayIndex.build(text, sigma=256)
    patterns = make_patterns(rng, text, 512, pattern_len)

    print("# serve_slo: calibrating system capacity")
    capacity = calibrate(index, patterns, max_batch=max_batch,
                         wait_us=wait_us, pattern_len=pattern_len,
                         probe_qps=probe_qps, probe_s=probe_s,
                         slo_ms=SLO_MS, seed=SEED)
    grid = [round(f * capacity, 1) for f in fractions]
    print(f"# capacity ~{capacity:.0f} qps; offered grid {grid}")

    records = []
    for mode in MODES:
        for qps in grid:
            records.append(run_point(
                index, patterns, mode, "poisson", qps, duration,
                max_batch=max_batch, queue_depth=queue_depth,
                wait_us=wait_us, pattern_len=pattern_len, seed=SEED))
    # burst resilience: same mean rate as the 2x poisson point
    records.append(run_point(
        index, patterns, "coalesce+admit", "onoff", grid[-2], duration,
        max_batch=max_batch, queue_depth=queue_depth, wait_us=wait_us,
        pattern_len=pattern_len, seed=SEED))

    # in-run sanity: the tier agrees with the closed-loop engine on
    # planted patterns (even-indexed patterns must hit)
    want = index.count_batch(patterns[:8])
    assert all(int(c) >= 1 for c in want[::2]), "planted patterns must hit"

    findings = derive_findings(records, SLO_MS)
    print(f"# findings: {json.dumps(findings)}")
    if not args.smoke:
        assert findings["coalescing_sustains_higher_qps"], findings
        assert findings["admission_bounds_p99"], findings

    artifact = {
        "bench": "serve_slo",
        "python": sys.version.split()[0],
        "machine": platform.machine(),
        "smoke": bool(args.smoke),
        "n": n, "pattern_len": pattern_len, "max_batch": max_batch,
        "queue_depth": queue_depth, "seed": SEED, "duration_s": duration,
        "coalesce_max_wait_us": wait_us,
        "capacity_qps": capacity,
        "grid_qps": grid,
        "records": records,
        "findings": findings,
    }
    problems = validate_artifact(artifact)
    assert not problems, problems
    if args.out:
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"# wrote {args.out} ({len(records)} records)")
    return artifact


if __name__ == "__main__":
    main()
