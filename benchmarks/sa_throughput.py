"""SA construction throughput vs n across the `repro.api` backend registry
(sequential-side evidence for the paper's O(vn)). Emits the usual CSV lines
plus a machine-readable `BENCH_sa_throughput.json` artifact so the perf
trajectory is recorded run over run.

Since the jax backend's sort primitive became pluggable
(`SAOptions.sort_impl`), the shipping configuration is benchmarked as
backend "jax" (sort_impl="auto") and the non-default implementations are
recorded as explicit variant rows ("jax[lax]", "jax[bitonic]") so
regressions of any path stay visible in the trajectory — the legacy fused
bitonic network is capped at small n (it is O(n log² n) compare-exchanges
by design). Every record carries its `sort_impl`.

    PYTHONPATH=src python -m benchmarks.sa_throughput [--out PATH]
"""
import argparse
import json
import platform
import sys

import numpy as np

from repro.api import SAOptions, build_suffix_array, registered_backends

from .bench_util import emit, time_call

SIZES = (10_000, 50_000, 200_000)
#: per-backend n ceiling: the references are executable specs, not fast paths
MAX_N = {"seq": 50_000}
#: non-default jax sort_impl variants: impl → n ceiling
JAX_VARIANTS = {"lax": 50_000, "bitonic": 10_000}


def bench_config(backend: str, x: np.ndarray, sort_impl: str = "auto") -> float:
    opts = SAOptions(backend=backend, sort_impl=sort_impl)
    return time_call(lambda: build_suffix_array(x, opts), iters=2)


def record(records, label, n, us, sort_impl="auto"):
    mchars = n / us
    emit(f"sa_throughput/{label}/n={n}", us, f"Mchars_s={mchars:.2f}")
    records.append({"backend": label, "sort_impl": sort_impl, "n": n,
                    "us": round(us, 1), "mchars_per_s": round(mchars, 3)})


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_sa_throughput.json",
                    help="JSON artifact path ('' disables)")
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    records = []
    print("# sa_throughput: backend, n, us, Mchars/s")
    for n in SIZES:
        x = rng.integers(0, 256, size=n)
        for backend in registered_backends():
            if backend == "bsp":
                continue       # needs a multi-device mesh; see supersteps.py
            if n > MAX_N.get(backend, n):
                continue
            us = bench_config(backend, x)
            record(records, backend, n, us)
        for impl, cap in JAX_VARIANTS.items():
            if n > cap:
                continue
            us = bench_config("jax", x, sort_impl=impl)
            record(records, f"jax[{impl}]", n, us, sort_impl=impl)

    if args.out:
        artifact = {
            "bench": "sa_throughput",
            "python": sys.version.split()[0],
            "machine": platform.machine(),
            "records": records,
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"# wrote {args.out} ({len(records)} records)")
    return records


if __name__ == "__main__":
    main()
