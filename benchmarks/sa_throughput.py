"""SA construction throughput vs n across the `repro.api` backend registry
(sequential-side evidence for the paper's O(vn)). Emits the usual CSV lines
plus a machine-readable `BENCH_sa_throughput.json` artifact so the perf
trajectory is recorded run over run.

    PYTHONPATH=src python -m benchmarks.sa_throughput [--out PATH]
"""
import argparse
import json
import platform
import sys

import numpy as np

from repro.api import SAOptions, build_suffix_array, registered_backends

from .bench_util import emit, time_call

SIZES = (10_000, 50_000, 200_000)
#: per-backend n ceiling: the references are executable specs, not fast paths
MAX_N = {"oracle": 50_000, "seq": 50_000}


def bench_backend(backend: str, x: np.ndarray) -> float:
    opts = SAOptions(backend=backend)
    return time_call(lambda: build_suffix_array(x, opts), iters=2)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_sa_throughput.json",
                    help="JSON artifact path ('' disables)")
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    records = []
    print("# sa_throughput: backend, n, us, Mchars/s")
    for n in SIZES:
        x = rng.integers(0, 256, size=n)
        for backend in registered_backends():
            if backend == "bsp":
                continue       # needs a multi-device mesh; see supersteps.py
            if n > MAX_N.get(backend, n):
                continue
            us = bench_backend(backend, x)
            mchars = n / us
            emit(f"sa_throughput/{backend}/n={n}", us,
                 f"Mchars_s={mchars:.2f}")
            records.append({"backend": backend, "n": n, "us": round(us, 1),
                            "mchars_per_s": round(mchars, 3)})

    if args.out:
        artifact = {
            "bench": "sa_throughput",
            "python": sys.version.split()[0],
            "machine": platform.machine(),
            "records": records,
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"# wrote {args.out} ({len(records)} records)")
    return records


if __name__ == "__main__":
    main()
