"""SA construction throughput vs n: JAX DC-v vs numpy reference vs
prefix-doubling oracle (sequential-side evidence for the paper's O(vn))."""
import numpy as np

from repro.core.dcv_jax import suffix_array_jax
from repro.core.oracle import suffix_array_doubling
from repro.core.seq_ref import suffix_array_dcv

from .bench_util import emit, time_call


def main():
    rng = np.random.default_rng(0)
    print("# sa_throughput: builder, n, us, Mchars/s")
    for n in (10_000, 50_000, 200_000):
        x = rng.integers(0, 256, size=n)
        for name, fn in (
            ("jax_dcv", lambda: suffix_array_jax(x)),
            ("seq_ref", lambda: suffix_array_dcv(x)),
            ("doubling", lambda: suffix_array_doubling(x)),
        ):
            if name == "seq_ref" and n > 50_000:
                continue          # reference is the executable spec, slow
            us = time_call(fn, iters=2)
            emit(f"sa_throughput/{name}/n={n}", us,
                 f"Mchars_s={n / us:.2f}")


if __name__ == "__main__":
    main()
