"""Paper Table 2: difference-cover sizes |D_v| — ours vs Colbourn–Ling vs the
(1+√(4v−3))/2 lower bound."""
from repro.core.difference_cover import (cover_size_lower_bound,
                                         difference_cover)

from .bench_util import emit, time_call

PAPER_CL = {5: 4, 13: 4, 14: 10, 73: 10, 74: 16, 181: 16, 182: 22, 337: 22,
            338: 28, 541: 28, 1024: 40, 2048: 58}


def main():
    print("# table2: v, |D|_ours, |D|_paper(CL), lower_bound")
    for v in sorted(PAPER_CL):
        us = time_call(lambda: difference_cover.__wrapped__(v), iters=1)
        D = difference_cover(v)
        emit(f"table2/v={v}", us,
             f"ours={len(D)};paper={PAPER_CL[v]};lb={cover_size_lower_bound(v):.1f}")


if __name__ == "__main__":
    main()
