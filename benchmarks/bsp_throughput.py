"""BSP (Algorithm 3) construction throughput over an n × p grid on
simulated multi-device CPU, per shard-local `sort_impl` — the distributed
side of the perf trajectory. Emits the usual CSV lines plus a
machine-readable `BENCH_bsp_throughput.json` artifact.

Each device count p runs in its own subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=p`` (the device count is
fixed at backend init, so a single process cannot sweep p). Within a
subprocess every (n, sort_impl) cell is timed warm (jit compile excluded)
and its `BSPCounters` are recorded, so the O(log log p) superstep schedule
is visible in the artifact next to the wall-clock numbers. The
comparator-bitonic local sort is kept as the regression row — it is the
*before* of the packed-key psort rework, exactly like `jax[bitonic]` in
`BENCH_sa_throughput.json`.

    PYTHONPATH=src python -m benchmarks.bsp_throughput [--smoke] [--out PATH]
"""
import argparse
import json
import os
import platform
import subprocess
import sys

from .bench_util import emit

SIZES = (20_000, 100_000)
PS = (4, 8)
IMPLS = ("radix", "lax", "bitonic")
#: the comparator network is O(m log² m) compare-exchanges by design; cap
#: it at the acceptance size so the regression row stays measurable.
BITONIC_MAX_N = 100_000
#: sizes up to this are verified against the prefix-doubling oracle in-run.
CHECK_MAX_N = 20_000

INNER = """
import json, time
import numpy as np
import jax
from jax.sharding import Mesh
from repro.bsp.counters import BSPCounters
from repro.bsp.suffix_array import suffix_array_bsp
from repro.core.oracle import suffix_array_doubling

p = {p}
mesh = Mesh(np.array(jax.devices()).reshape(p), ("bsp",))
rng = np.random.default_rng(0)
for n in {sizes}:
    x = rng.integers(0, 256, size=n)
    for impl in {impls}:
        if impl == "bitonic" and n > {bitonic_max}:
            continue
        ct = BSPCounters()
        sa = suffix_array_bsp(x, mesh, sort_impl=impl, counters=ct)  # warmup
        if n <= {check_max}:
            assert np.array_equal(sa, suffix_array_doubling(x)), (n, impl)
        ts = []
        for _ in range({iters}):
            t0 = time.perf_counter()
            suffix_array_bsp(x, mesh, sort_impl=impl)
            ts.append(time.perf_counter() - t0)
        us = 1e6 * float(np.median(ts))
        rec = {{"backend": f"bsp[{{impl}}]", "sort_impl": impl, "n": n,
                "p": p, "us": round(us, 1),
                "mchars_per_s": round(n / us, 3),
                "supersteps": ct.supersteps, "rounds": ct.rounds,
                "comm_words": ct.comm_words, "work": ct.work}}
        if impl == "radix":
            rec["superstep_log"] = ct.log
        print("RECORD " + json.dumps(rec), flush=True)
"""


def run_grid(ps, sizes, impls, iters, bitonic_max, timeout=3600):
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    records = []
    for p in ps:
        code = INNER.format(p=p, sizes=tuple(sizes), impls=tuple(impls),
                            iters=iters, bitonic_max=bitonic_max,
                            check_max=CHECK_MAX_N)
        env = dict(os.environ)
        env["XLA_FLAGS"] = " ".join(
            [env.get("XLA_FLAGS", ""),
             f"--xla_force_host_platform_device_count={p}"]).strip()
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(root, "src")]
            + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
        r = subprocess.run([sys.executable, "-c", code], env=env, text=True,
                           capture_output=True, timeout=timeout)
        if r.returncode != 0:
            raise RuntimeError(
                f"bsp_throughput subprocess (p={p}) failed:\n{r.stderr}")
        for line in r.stdout.splitlines():
            if not line.startswith("RECORD "):
                continue
            rec = json.loads(line[len("RECORD "):])
            records.append(rec)
            emit(f"bsp_throughput/{rec['backend']}/n={rec['n']}/p={p}",
                 rec["us"],
                 f"Mchars_s={rec['mchars_per_s']};S={rec['supersteps']};"
                 f"rounds={rec['rounds']}")
    return records


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_bsp_throughput.json",
                    help="JSON artifact path ('' disables)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny n on 4 simulated devices (CI gate: proves the "
                         "distributed path builds, runs, and matches the "
                         "oracle — radix + bitonic regression row)")
    args = ap.parse_args(argv)

    if args.smoke:
        ps, sizes, impls, iters = (4,), (4_000,), ("radix", "bitonic"), 1
    else:
        ps, sizes, impls, iters = PS, SIZES, IMPLS, 2

    print("# bsp_throughput: backend, n, p, us, Mchars/s + BSP counters")
    records = run_grid(ps, sizes, impls, iters, BITONIC_MAX_N)

    if args.out:
        artifact = {
            "bench": "bsp_throughput",
            "python": sys.version.split()[0],
            "machine": platform.machine(),
            "smoke": bool(args.smoke),
            "records": records,
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"# wrote {args.out} ({len(records)} records)")
    return records


if __name__ == "__main__":
    main()
