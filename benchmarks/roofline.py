"""§Roofline generator: reads the dry-run records and derives the three-term
roofline per (arch × shape × mesh).

  compute   = HLO_FLOPs / (chips · 197 TF/s)   [HLO_FLOPs = per-dev · chips]
  memory    = HLO_bytes / (chips · 819 GB/s)
  collective= coll_bytes / (chips · 50 GB/s)

`*_corrected` fields are loop-corrected per-device totals (hlo_stats.py), so
term_x = per_device_x / per_chip_rate. Roofline fraction (the §Perf score) =
(MODEL_FLOPS/(chips·peak)) / max(terms) — how close the useful work runs to
the machine's binding limit. Writes results/roofline.md + CSV lines.
"""
import json
import os

PEAK = 197e12          # bf16 FLOP/s per chip
HBM = 819e9            # B/s per chip
ICI = 50e9             # B/s per link

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun2")


def load(d=None):
    d = d or RESULTS
    recs = []
    if not os.path.isdir(d):
        return recs
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            r = json.load(open(os.path.join(d, f)))
            if r.get("status") == "ok" and not r.get("tag"):
                recs.append(r)
    return recs


def terms(r):
    chips = r["chips"]
    comp = r.get("flops_corrected", 0.0) / PEAK
    mem = r.get("bytes_corrected", 0.0) / HBM
    coll = r.get("collective_bytes_corrected", 0.0) / ICI
    useful = r.get("model_flops", 0.0) / (chips * PEAK)
    dom = max(comp, mem, coll, 1e-30)
    which = ("compute" if dom == comp else
             "memory" if dom == mem else "collective")
    frac = useful / dom
    return dict(compute_s=comp, memory_s=mem, collective_s=coll,
                dominant=which, useful_s=useful, roofline_frac=frac,
                flops_ratio=r.get("model_flops", 0) /
                max(r.get("flops_corrected", 1) * chips, 1))


def advice(t, r):
    if t["dominant"] == "collective":
        return ("cut TP collective volume: larger per-chip batch, overlap "
                "psum with compute, reduce-scatter instead of all-reduce")
    if t["dominant"] == "memory":
        return ("bf16 stashes + fusion; raise arithmetic intensity with "
                "bigger microbatch per chip")
    if t["flops_ratio"] < 0.5:
        return ("trim non-useful compute: remat recompute, causal-cond "
                "overcount, replicated attention heads")
    return "compute-bound at healthy ratio: tune kernel tiling / MXU shapes"


def main():
    recs = load()
    if not recs:
        print(f"# roofline: no dry-run records under {RESULTS} — run the "
              f"dry-run sweep first (table skipped, not an error)")
        print("roofline/skipped,0.0,records=0")
        return
    print("# roofline: arch, shape, mesh, compute_s, memory_s, collective_s,"
          " dominant, roofline_frac, model/HLO")
    lines = ["| arch | shape | mesh | compute (s) | memory (s) | "
             "collective (s) | dominant | roofline frac | model/HLO | "
             "what moves it |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        t = terms(r)
        print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0.0,"
              f"comp={t['compute_s']:.3g};mem={t['memory_s']:.3g};"
              f"coll={t['collective_s']:.3g};dom={t['dominant']};"
              f"frac={t['roofline_frac']:.3f}")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
            f" {t['compute_s']:.3g} | {t['memory_s']:.3g} |"
            f" {t['collective_s']:.3g} | {t['dominant']} |"
            f" {t['roofline_frac']:.3f} | {t['flops_ratio']:.2f} |"
            f" {advice(t, r)} |")
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"# wrote results/roofline.md ({len(recs)} cells)")


if __name__ == "__main__":
    main()
