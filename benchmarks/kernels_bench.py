"""Pallas kernel micro-bench (interpret mode on CPU: correctness-grade
timing only — Mosaic-compiled TPU numbers are the deploy target)."""
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import bitonic_stage, dense_rank_sorted, radix_histogram

from .bench_util import emit, time_call


def main():
    rng = np.random.default_rng(0)
    d = jnp.asarray(rng.integers(0, 256, 1 << 14), jnp.int32)
    us = time_call(lambda: radix_histogram(d, 256).block_until_ready())
    emit("kernels/radix_hist/16k", us, "interpret=True")

    rows = jnp.asarray(
        np.c_[rng.integers(0, 9, (1 << 12, 4)), rng.permutation(1 << 12)],
        jnp.int32)
    us = time_call(lambda: bitonic_stage(rows, 1 << 12, 1 << 11)
                   .block_until_ready())
    emit("kernels/bitonic_stage/4k", us, "interpret=True")

    sr = jnp.sort(jnp.asarray(rng.integers(0, 64, (1 << 14, 1)), jnp.int32),
                  axis=0)
    us = time_call(lambda: dense_rank_sorted(sr)[0].block_until_ready())
    emit("kernels/dense_rank/16k", us, "interpret=True")


if __name__ == "__main__":
    main()
