"""Paper Table 3: accelerated-sampling round analysis — measured per-round
(v_i, |D_i|, n_i, work) on a real input vs the closed-form model, plus the
headline: rounds(accelerated) = O(log log p) vs rounds(fixed v) = O(log p).
"""
import numpy as np

from repro.core.difference_cover import difference_cover
from repro.core.seq_ref import (SeqStats, accelerated_next_v, fixed_next_v,
                                suffix_array_dcv)

from .bench_util import emit, time_call


def measured_rounds():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 2, size=200_000)
    st = SeqStats()
    us = time_call(lambda: suffix_array_dcv(x, stats=SeqStats(),
                                            base_threshold=64), iters=1)
    suffix_array_dcv(x, stats=st, base_threshold=64)
    print("# table3(measured): round, v_i, |D_i|, n_i, work_i  (n=2e5)")
    for i, r in enumerate(st.rounds):
        emit(f"table3/round={i}", us if i == 0 else 0.0,
             f"v={r['v']};D={r['D']};n={r['n']};work={r['work']}")


def model_rounds(n, p, schedule):
    """Closed-form recursion-depth model (stops at n/p, the paper's base)."""
    v, rounds, work = 3, 0, 0
    while n > max(n0 // p, 4) and rounds < 500:
        D = difference_cover(min(max(v, 3), 2048))
        work += v * n
        n = len(D) * -(-n // v)
        v = schedule(v, len(D), n)
        rounds += 1
    return rounds, work


def round_scaling():
    global n0
    print("# table3(model): p, rounds_accelerated, rounds_fixed_v3, "
          "paper_loglog=log_5/4(log_3 sqrt(p)+1)")
    n0 = 1 << 44
    for k in range(4, 22, 2):
        p = 1 << k
        ra, _ = model_rounds(n0, p, accelerated_next_v)
        rf, _ = model_rounds(n0, p, fixed_next_v)
        paper = np.log(np.log(np.sqrt(p)) / np.log(3) + 1) / np.log(1.25)
        emit(f"table3/p=2^{k}", 0.0,
             f"accel={ra};fixed={rf};paper_bound={paper:.1f}")


def main():
    measured_rounds()
    round_scaling()


if __name__ == "__main__":
    main()
