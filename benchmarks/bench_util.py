import time

import numpy as np


def time_call(fn, *args, warmup=1, iters=3, **kw):
    for _ in range(warmup):
        fn(*args, **kw)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return 1e6 * float(np.median(ts))


def emit(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
