"""Training data plane: streaming dedup + contamination-gate throughput.

Three tables over the same synthetic shard stream:

* **dedup** — chars/s through `StreamingDedup` as shard size varies,
  against the monolithic `dedup_docs` rebuild of the full corpus each
  streaming run is compared to. Every streaming record carries the
  builder-cache delta (must equal the shard count — the
  one-build-per-shard contract) and the run asserts byte-identical
  output to the monolithic pass before emitting anything.
* **gate** — windows/s through `ContaminationGate.check` as the batch
  grows (all grams of a batch resolve in one chunked `count_batch`).
* **probe** — `longest_match` scoring latency per sample.

    PYTHONPATH=src python -m benchmarks.data_plane_bench [--smoke] [--out P]
"""
import argparse
import json
import platform
import sys

import numpy as np

from repro.api import builder_cache_stats
from repro.data.pipeline import (ContaminationGate, PipelineConfig,
                                 TrainingDataPlane, synthetic_corpus,
                                 synthetic_doc_shards)
from repro.text.dedup import dedup_docs

from .bench_util import emit, time_call

N_CHARS = 400_000
DOC_LEN = 4_000
SHARD_DOCS = (2, 8, 32)
MIN_LEN = 48
GATE_BATCHES = (8, 64)
SEQ_LEN = 256
PROBE_SAMPLES = 8


def _builds() -> int:
    s = builder_cache_stats()
    return s["hits"] + s["misses"]


def bench_dedup(records, n_chars: int, doc_len: int, shard_docs):
    mono_docs, mono_rep, mono_us = None, None, None
    for sd in shard_docs:
        shards = synthetic_doc_shards(n_chars, 256, shard_docs=sd,
                                      doc_len=doc_len, dup_fraction=0.3,
                                      seed=11)
        if mono_docs is None:
            docs = [d for s in shards for d in s]
            t0 = _builds()
            mono_us = time_call(
                lambda: dedup_docs(docs, min_len=MIN_LEN, sigma=256),
                warmup=0, iters=1)
            mono_docs, mono_rep = dedup_docs(docs, min_len=MIN_LEN,
                                             sigma=256)
            emit("dedup_monolithic", mono_us,
                 f"chars_per_s={1e6 * n_chars / mono_us:.0f};"
                 f"builds={_builds() - t0}")
            records.append({"bench": "dedup", "mode": "monolithic",
                            "us": mono_us, "n_chars": n_chars})
        cfg = PipelineConfig(seq_len=SEQ_LEN, dedup=True,
                             dedup_min_len=MIN_LEN, vocab=256)
        plane = TrainingDataPlane(cfg)
        b0, t0 = _builds(), None
        us = time_call(lambda: [plane.ingest_shard(s) for s in shards],
                       warmup=0, iters=1)
        builds = _builds() - b0
        # contracts, measured in-run: one build per shard, byte-identical
        assert builds == len(shards), (builds, len(shards))
        assert len(plane._kept) == len(mono_docs)
        assert all(np.array_equal(a, b)
                   for a, b in zip(plane._kept, mono_docs))
        assert plane.report.dropped_chars == mono_rep.dropped_chars > 0
        emit(f"dedup_stream_shard{sd}", us,
             f"chars_per_s={1e6 * n_chars / us:.0f};builds={builds};"
             f"vs_mono={us / mono_us:.2f}x")
        records.append({"bench": "dedup", "mode": "stream",
                        "shard_docs": sd, "us": us, "builds": builds,
                        "n_chars": n_chars,
                        "dropped": plane.report.dropped_chars})


def bench_gate(records, n_chars: int, batches):
    eval_docs = [synthetic_corpus(8192, 256, seed=900 + j)
                 for j in range(4)]
    gate = ContaminationGate(eval_docs, min_len=MIN_LEN, sigma=256)
    corpus = synthetic_corpus(n_chars, 256, seed=12)
    # half the windows carry a planted eval stretch → real hit traffic
    flat = np.concatenate(eval_docs)
    for B in batches:
        rng = np.random.default_rng(B)
        starts = rng.integers(0, n_chars - SEQ_LEN - 1, size=B)
        wins = np.stack([corpus[s:s + SEQ_LEN + 1] for s in starts])
        src = rng.integers(0, len(flat) - 2 * MIN_LEN, size=B // 2)
        for i, s in enumerate(src):
            wins[2 * i, 10:10 + 2 * MIN_LEN] = flat[s:s + 2 * MIN_LEN]
        hits, _ = gate.check(wins)
        assert (hits[0::2][:B // 2] > 0).all() and (hits[1::2] == 0).all()
        us = time_call(gate.check, wins, warmup=1, iters=3)
        emit(f"gate_check_b{B}", us,
             f"windows_per_s={1e6 * B / us:.0f}")
        records.append({"bench": "gate", "batch": B, "us": us})


def bench_probe(records, n_chars: int):
    shards = synthetic_doc_shards(n_chars // 4, 256, shard_docs=8,
                                  doc_len=DOC_LEN, seed=13)
    plane = TrainingDataPlane(
        PipelineConfig(dedup=True, dedup_min_len=MIN_LEN, vocab=256),
        shards=shards)
    rng = np.random.default_rng(14)
    docs = [d for s in shards for d in s]
    samples = []
    for k in range(PROBE_SAMPLES):
        if k % 2 == 0:     # verbatim training excerpt (raw doc slice)
            d = docs[int(rng.integers(0, len(docs)))]
            at = int(rng.integers(0, len(d) - 256))
            samples.append(d[at:at + 256])
        else:              # fresh sequence
            samples.append(rng.integers(0, 256, size=256))
    us = time_call(plane.probe, samples, warmup=1, iters=3)
    m = plane.probe(samples)
    assert m["longest_copy_max"] >= 256
    emit("probe_longest_match", us,
         f"samples_per_s={1e6 * PROBE_SAMPLES / us:.0f};"
         f"copy_max={m['longest_copy_max']}")
    records.append({"bench": "probe", "samples": PROBE_SAMPLES, "us": us,
                    "longest_copy_max": m["longest_copy_max"]})


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="results/data_plane_bench.json")
    args = ap.parse_args(argv)
    n_chars = 60_000 if args.smoke else N_CHARS
    doc_len = 1_500 if args.smoke else DOC_LEN
    shard_docs = SHARD_DOCS[:2] if args.smoke else SHARD_DOCS
    batches = GATE_BATCHES[:1] if args.smoke else GATE_BATCHES
    records: list = []
    bench_dedup(records, n_chars, doc_len, shard_docs)
    bench_gate(records, n_chars, batches)
    bench_probe(records, n_chars)
    if args.out:
        payload = {"host": platform.node(), "argv": sys.argv[1:],
                   "records": records}
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
