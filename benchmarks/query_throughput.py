"""Batched query throughput: patterns/sec vs batch size × pattern length.

The build benchmarks (`sa_throughput`, `bsp_throughput`) record how fast
an index is *constructed*; this one records how fast it *answers* — the
serving-side number the query engine exists for. For each (batch, m)
cell the batched jitted path (`SuffixArrayIndex.count_batch`, one XLA
call per batch) is timed warm against a fixed pattern set, next to the
scalar-loop regression row (`_sa_range`, the pre-batch Python bisection
path — the *before* of this rework, exactly like `jax[bitonic]` in
`BENCH_sa_throughput.json`). Each batched record carries its speedup
over the scalar loop at the same (n, batch, m).

Patterns are half planted (cut from the text — realistic hit traffic)
and half random over the same alphabet, so both paths do real compare
work instead of early-outing on absent first characters.

    PYTHONPATH=src python -m benchmarks.query_throughput [--smoke] [--out PATH]
"""
import argparse
import json
import platform
import sys

import numpy as np

from repro.api import SAOptions, SuffixArrayIndex, clear_query_cache

from .bench_util import emit, time_call

N = 200_000
BATCHES = (1, 16, 64, 256)
PATTERN_LENS = (8, 32)
#: the scalar loop is O(batch) Python iterations — cap the row so the
#: regression stays measurable without dominating the harness run.
SCALAR_MAX_BATCH = 256


def make_patterns(rng, text, batch: int, m: int) -> list:
    pats = []
    for q in range(batch):
        if q % 2 == 0:
            at = int(rng.integers(0, len(text) - m))
            pats.append(text[at:at + m])
        else:
            pats.append(rng.integers(0, int(text.max()) + 1, size=m))
    return pats


def scalar_counts(index, patterns) -> np.ndarray:
    """The pre-batch path: one Python binary-search loop per pattern."""
    out = np.empty(len(patterns), np.int64)
    for i, p in enumerate(patterns):
        lo, hi = index._sa_range(index._encode_pattern(p))
        out[i] = hi - lo
    return out


def record(records, label, n, batch, m, us, **extra):
    pps = batch / us * 1e6
    emit(f"query_throughput/{label}/n={n}/b={batch}/m={m}", us,
         f"patterns_s={pps:.0f}")
    records.append({"path": label, "n": n, "batch": batch, "m": m,
                    "us": round(us, 1), "patterns_per_s": round(pps, 1),
                    **extra})


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_query_throughput.json",
                    help="JSON artifact path ('' disables)")
    ap.add_argument("--smoke", action="store_true",
                    help="small n, two batch sizes (CI gate: proves the "
                         "batched path runs and matches the scalar loop)")
    args = ap.parse_args(argv)

    n = 20_000 if args.smoke else N
    batches = (1, 64) if args.smoke else BATCHES
    lens = (16,) if args.smoke else PATTERN_LENS
    iters = 1 if args.smoke else 3

    rng = np.random.default_rng(0)
    text = rng.integers(0, 256, size=n)
    index = SuffixArrayIndex.build(text)
    clear_query_cache()
    records = []
    print("# query_throughput: path, n, batch, m, us, patterns/s")
    for m in lens:
        for batch in batches:
            pats = make_patterns(rng, text, batch, m)
            us_b = time_call(lambda: index.count_batch(pats), iters=iters)
            scalar_us = None
            if batch <= SCALAR_MAX_BATCH:
                want = scalar_counts(index, pats)          # engines agree
                assert np.array_equal(index.count_batch(pats), want), \
                    (batch, m)
                scalar_us = time_call(lambda: scalar_counts(index, pats),
                                      iters=iters)
                record(records, "scalar", n, batch, m, scalar_us)
            speedup = (round(scalar_us / us_b, 2) if scalar_us else None)
            record(records, "batched", n, batch, m, us_b, speedup=speedup)
            if speedup:
                print(f"#   batched speedup at b={batch}, m={m}: {speedup}x")

    if args.out:
        artifact = {
            "bench": "query_throughput",
            "python": sys.version.split()[0],
            "machine": platform.machine(),
            "smoke": bool(args.smoke),
            "records": records,
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"# wrote {args.out} ({len(records)} records)")
    return records


if __name__ == "__main__":
    main()
