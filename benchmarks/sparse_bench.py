"""Sparse sampled-position indexing: memory per char, build + query cost.

The sparse subsystem's claim is structural — index memory scales n/s — but
the ROADMAP's acceptance bar is measured, not asserted from the formula.
Three tables, one JSON artifact (``BENCH_sparse_mem.json``):

* **memory** — dense-vs-sparse suffix-array bytes per text char at each
  sample_rate. The run *asserts* the ≥8× reduction at ``sample_rate=16``
  (the data-plane operating point: 16 ≤ DEDUP_MIN_LEN=48).
* **equivalence** — on the same corpus, `count_batch` / `locate_batch`
  results of the sparse index are asserted byte-identical to the dense
  index for a fuzzed pattern mix (present/absent, threshold-length,
  longer) of lengths ≥ sample_rate. Build and query wall times for both
  sides ride on these records.
* **scale** — sparse-only rows at n into the tens of millions of chars
  (the sizes whose dense SA no longer fits comfortably on one host):
  build + batched-query wall time, with counts spot-verified against a
  direct numpy scan of the text, so the 10M-char cell proves a real
  build+query, not just an allocation.

    PYTHONPATH=src python -m benchmarks.sparse_bench [--smoke] [--out PATH]

Smoke mode (CI bench-smoke gate) shrinks n but keeps every assertion:
memory reduction, dense equivalence, and the scan-verified scale row.
"""
import argparse
import json
import platform
import sys

import numpy as np

from repro.api import SAOptions, SuffixArrayIndex
from repro.sparse import SparseSuffixArrayIndex

from .bench_util import emit, time_call

EQ_N = 200_000            # dense-vs-sparse cells (dense build must be cheap)
SCALE_NS = (2_000_000, 10_000_000)   # sparse-only rows
RATES = (4, 8, 16, 32)
BATCH = 64
VOCAB = 256
ASSERT_RATE = 16          # the rate the ≥8× memory claim is pinned at
MIN_REDUCTION = 8.0


def make_patterns(rng, text, rate: int, batch: int) -> list:
    """Fuzzed mix: half sampled from the text (guaranteed present), half
    random (usually absent); lengths straddle the rate threshold from
    exactly-rate up to several multiples."""
    n = len(text)
    pats = []
    for q in range(batch):
        m = int(rng.choice([rate, rate + 1, 2 * rate - 1, 2 * rate,
                            4 * rate]))
        m = min(m, n)
        if q % 2 == 0 and n > m:
            at = int(rng.integers(0, n - m))
            pats.append(np.asarray(text[at:at + m]))
        else:
            pats.append(rng.integers(0, VOCAB, size=m))
    return pats


def scan_count(text: np.ndarray, pat: np.ndarray) -> int:
    """Occurrences of `pat` in `text` by progressive candidate filtering —
    O(n + matches·m) numpy, no index involved (the oracle for the scale
    rows, where building a dense index is the thing being avoided)."""
    n, m = len(text), len(pat)
    if m == 0 or m > n:
        return n + 1 if m == 0 else 0
    cand = np.flatnonzero(text[:n - m + 1] == pat[0])
    for c in range(1, m):
        if not len(cand):
            break
        cand = cand[text[cand + c] == pat[c]]
    return int(len(cand))


def bench_equivalence(records, rng, n: int, rates, batch: int, iters: int):
    text = rng.integers(0, VOCAB, size=n)
    t_dense = time_call(lambda: SuffixArrayIndex.build(text, SAOptions()),
                        warmup=0, iters=1)
    dense = SuffixArrayIndex.build(text, SAOptions())
    for rate in rates:
        opts = SAOptions(sample_rate=rate)
        t_sparse = time_call(lambda: SuffixArrayIndex.build(text, opts),
                             warmup=0, iters=1)
        sparse = SuffixArrayIndex.build(text, opts)
        assert isinstance(sparse, SparseSuffixArrayIndex)

        # ---- memory: measured bytes of the suffix-array leaf, per char
        dense_bpc = dense.sa.nbytes / n
        sparse_bpc = sparse.sa.nbytes / n
        reduction = dense.sa.nbytes / sparse.sa.nbytes
        emit(f"sparse_bench/memory/n={n}/rate={rate}", 0.0,
             f"sa_bytes_per_char={sparse_bpc:.3f}"
             f";reduction={reduction:.1f}x")
        records.append({"table": "memory", "n": n, "rate": rate,
                        "dense_sa_bytes_per_char": round(dense_bpc, 4),
                        "sparse_sa_bytes_per_char": round(sparse_bpc, 4),
                        "reduction": round(reduction, 2)})
        if rate == ASSERT_RATE:
            assert reduction >= MIN_REDUCTION, (reduction, rate)

        # ---- equivalence + query cost: byte-identical counts & positions
        pats = make_patterns(rng, text, rate, batch)
        want_c = dense.count_batch(pats)
        got_c = sparse.count_batch(pats)
        assert np.array_equal(want_c, got_c), (rate, want_c, got_c)
        for w, g in zip(dense.locate_batch(pats), sparse.locate_batch(pats)):
            assert np.array_equal(w, g), rate
        us_d = time_call(lambda: dense.count_batch(pats), iters=iters)
        us_s = time_call(lambda: sparse.count_batch(pats), iters=iters)
        emit(f"sparse_bench/equivalence/n={n}/rate={rate}", us_s,
             f"dense_us={us_d:.1f};query_overhead={us_s / us_d:.2f}x"
             f";build_speedup={t_dense / t_sparse:.1f}x")
        records.append({
            "table": "equivalence", "n": n, "rate": rate, "batch": batch,
            "identical": True,
            "build_us_dense": round(t_dense, 1),
            "build_us_sparse": round(t_sparse, 1),
            "query_us_dense": round(us_d, 1),
            "query_us_sparse": round(us_s, 1),
            "patterns_per_s": round(batch / us_s * 1e6, 1)})


def bench_scale(records, rng, scale_ns, rate: int, batch: int):
    for n in scale_ns:
        text = rng.integers(0, VOCAB, size=n)
        opts = SAOptions(sample_rate=rate)
        t_build = time_call(lambda: SuffixArrayIndex.build(text, opts),
                            warmup=0, iters=1)
        sparse = SuffixArrayIndex.build(text, opts)
        pats = make_patterns(rng, text, rate, batch)
        sparse.count_batch(pats)                       # compile off the clock
        us_q = time_call(lambda: sparse.count_batch(pats), warmup=0, iters=1)
        counts = sparse.count_batch(pats)
        for j in range(0, batch, max(batch // 8, 1)):  # spot-verify vs scan
            want = scan_count(np.asarray(text, np.int64),
                              np.asarray(pats[j], np.int64))
            assert int(counts[j]) == want, (n, j, int(counts[j]), want)
        bpc = sparse.sa.nbytes / n
        emit(f"sparse_bench/scale/n={n}/rate={rate}", t_build,
             f"query_us={us_q:.1f};sa_bytes_per_char={bpc:.3f}"
             f";sa_mb={sparse.sa.nbytes / 1e6:.1f}")
        records.append({
            "table": "scale", "n": n, "rate": rate, "batch": batch,
            "build_us": round(t_build, 1), "query_us": round(us_q, 1),
            "sa_bytes_per_char": round(bpc, 4),
            "sa_mbytes": round(sparse.sa.nbytes / 1e6, 2),
            "dense_sa_mbytes_would_be": round(4.0 * n / 1e6, 2),
            "scan_verified": True})


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_sparse_mem.json",
                    help="JSON artifact path ('' disables)")
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus, same assertions (CI gate: ≥8× "
                         "memory reduction at rate=16 + dense-identical "
                         "query results + scan-verified scale row)")
    args = ap.parse_args(argv)

    eq_n = 40_000 if args.smoke else EQ_N
    scale_ns = (400_000,) if args.smoke else SCALE_NS
    rates = (4, 16) if args.smoke else RATES
    iters = 1 if args.smoke else 3

    rng = np.random.default_rng(0)
    records = []
    print("# sparse_bench: sampled-position index memory/build/query")
    bench_equivalence(records, rng, eq_n, rates, BATCH, iters)
    bench_scale(records, rng, scale_ns, ASSERT_RATE, BATCH)

    if args.out:
        artifact = {
            "bench": "sparse_bench",
            "python": sys.version.split()[0],
            "machine": platform.machine(),
            "smoke": bool(args.smoke),
            "records": records,
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"# wrote {args.out} ({len(records)} records)")
    return records


if __name__ == "__main__":
    main()
