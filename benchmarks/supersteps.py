"""Headline claim (C4): BSP synchronisation cost S(p).

Measured supersteps on a real 8-shard run + the analytic cost model for
p up to 2^20, against the paper's O(log log p) and Kärkkäinen et al.'s
O(log² p) baselines. The per-round superstep constant is the measured one
(SM1=11, SM2=9, base=1), which `tests/core/test_bsp.py` pins against
`repro.bsp.suffix_array.estimate_costs` — the exact-replay model for
realistic (n, p). The capped model below trades that exactness for
feasibility at astronomic sizes (difference covers clamped at v=2048)."""
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core.difference_cover import difference_cover
from repro.core.seq_ref import accelerated_next_v, fixed_next_v

from .bench_util import emit

PER_ROUND = 20          # SM1 (11) + SM2 (9), measured by BSPCounters
BASE = 1


def model_supersteps(n, p, schedule):
    """Rounds until |X'| ≤ n/p (the paper's sequential-base condition)."""
    n0, v, rounds = n, 3, 0
    while n > max(n0 // p, 2 * p * v, 1024) and rounds < 500:
        D = difference_cover(min(max(v, 3), 2048))
        n = len(D) * -(-n // v)
        v = schedule(v, len(D), n)
        rounds += 1
    return PER_ROUND * rounds + BASE


def measured():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    code = textwrap.dedent("""
    import numpy as np
    from repro.api import SAOptions, build_suffix_array
    from repro.bsp.counters import BSPCounters
    from repro.launch.mesh import make_sa_mesh
    rng = np.random.default_rng(0)
    x = rng.integers(0, 2, size=4096)
    ct = BSPCounters()
    build_suffix_array(x, SAOptions(mesh=make_sa_mesh(8), base_threshold=64,
                                    counters=ct))
    print(f"RESULT S={ct.supersteps} H={ct.comm_words} W={ct.work}")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = src
    try:
        r = subprocess.run([sys.executable, "-c", code], env=env, timeout=600,
                           capture_output=True, text=True)
        for line in r.stdout.splitlines():
            if line.startswith("RESULT"):
                emit("supersteps/measured_p8_n4096", 0.0,
                     line.replace("RESULT ", "").replace(" ", ";"))
    except Exception as e:  # pragma: no cover
        emit("supersteps/measured_p8_n4096", 0.0, f"error={e}")


def main():
    measured()
    print("# model: p, S_accelerated, S_fixed_v3, karkkainen_log2p_bound")
    n = 1 << 44
    for k in range(4, 22, 2):
        p = 1 << k
        sa = model_supersteps(n, p, accelerated_next_v)
        sf = model_supersteps(n, p, fixed_next_v)
        kk = PER_ROUND * (np.log2(p) ** 2) / 4
        emit(f"supersteps/p=2^{k}", 0.0,
             f"accel={sa};fixed={sf};log2p_sq~{kk:.0f}")


if __name__ == "__main__":
    main()
