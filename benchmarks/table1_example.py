"""Paper Table 1: the worked suffix-array example (correctness demo + the
smallest end-to-end timing), through the `repro.api` facade."""
import numpy as np

from repro.api import build_suffix_array

from .bench_util import emit, time_call

X = np.array([0, 2, 1, 0, 0, 2, 4, 3, 1, 1, 4, 0])
WANT = [11, 3, 0, 4, 2, 8, 9, 1, 5, 7, 10, 6]


def main():
    assert build_suffix_array(X, backend="seq", base_threshold=4).tolist() == WANT
    assert build_suffix_array(X, backend="jax", base_threshold=4).tolist() == WANT
    us = time_call(lambda: build_suffix_array(X, backend="jax",
                                              base_threshold=4))
    emit("table1/worked_example", us, "match=exact")


if __name__ == "__main__":
    main()
