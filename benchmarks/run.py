"""Benchmark harness: one module per paper table/figure + system benches.
Prints ``name,us_per_call,derived`` CSV lines.

The serving bench runs in smoke mode here (the full SLO sweep is a
dedicated run: ``python -m benchmarks.serve_slo``). The roofline table
needs dry-run records under results/ and is opt-in via ``--roofline``.
"""
import argparse
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--roofline", action="store_true",
                    help="include the roofline table (reads dry-run records "
                         "under results/; skipped by default)")
    args = ap.parse_args(argv)
    from . import (bsp_throughput, data_plane_bench, kernels_bench,
                   query_throughput, roofline, sa_throughput, segments_bench,
                   serve_slo, sparse_bench, supersteps, table1_example,
                   table2_covers, table3_rounds)
    mods = [table1_example, table2_covers, table3_rounds, supersteps,
            sa_throughput, query_throughput, segments_bench, sparse_bench,
            data_plane_bench, kernels_bench, bsp_throughput, serve_slo]
    if args.roofline:
        mods.insert(mods.index(bsp_throughput), roofline)
    # the harness runs the distributed + serving + data-plane benches in
    # smoke mode (full grids are dedicated runs of those modules)
    modargs = {bsp_throughput: ["--smoke", "--out", ""],
               segments_bench: ["--smoke", "--out", ""],
               sparse_bench: ["--smoke", "--out", ""],
               data_plane_bench: ["--smoke", "--out", ""],
               serve_slo: ["--smoke", "--out", ""]}
    failed = []
    for m in mods:
        name = m.__name__.split(".")[-1]
        print(f"## {name}")
        try:
            m.main(*([modargs[m]] if m in modargs else []))
        except Exception as e:
            failed.append(name)
            traceback.print_exc()
            print(f"{name},0.0,ERROR={e}")
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
