"""Benchmark harness: one module per paper table/figure + system benches.
Prints ``name,us_per_call,derived`` CSV lines."""
import sys
import traceback


def main() -> None:
    from . import (bsp_throughput, kernels_bench, query_throughput, roofline,
                   sa_throughput, supersteps, table1_example, table2_covers,
                   table3_rounds)
    mods = [table1_example, table2_covers, table3_rounds, supersteps,
            sa_throughput, query_throughput, kernels_bench, roofline,
            bsp_throughput]
    # the harness runs the distributed bench in smoke mode (full n × p grid
    # is a dedicated run: python -m benchmarks.bsp_throughput)
    argv = {bsp_throughput: ["--smoke", "--out", ""]}
    failed = []
    for m in mods:
        name = m.__name__.split(".")[-1]
        print(f"## {name}")
        try:
            m.main(*([argv[m]] if m in argv else []))
        except Exception as e:
            failed.append(name)
            traceback.print_exc()
            print(f"{name},0.0,ERROR={e}")
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
