"""Segmented serving: ingest cost and query overhead vs segment count.

`benchmarks/query_throughput` records how fast a *static* index answers;
this one records what segmentation buys and what it costs. Two tables:

* **ingest** — stream documents into an already-built corpus. The
  monolithic row re-indexes the whole corpus per ingest (the only move a
  single `SuffixArrayIndex` has); the segmented row builds ONE small
  segment (`SegmentedIndex.add_docs`). Each record carries the builder
  traffic (cache hits+misses delta) alongside wall time, so the
  "one build per ingest" claim is measured, not asserted.
* **query** — `count_batch` latency on the same corpus sliced into 1, 4,
  … segments. The fan-out runs one jitted `_ranges_kernel` call per
  segment, so this is the price of the merge; each record carries its
  overhead ratio vs the single-segment row.

    PYTHONPATH=src python -m benchmarks.segments_bench [--smoke] [--out PATH]
"""
import argparse
import json
import platform
import sys

import numpy as np

from repro.api import (SAOptions, SegmentedIndex, SuffixArrayIndex,
                       builder_cache_stats, clear_query_cache, encode_docs)

from .bench_util import emit, time_call

DOC_LEN = 20_000
N_DOCS = 8
N_INGESTS = 3
SEGMENT_COUNTS = (1, 2, 4, 8)
BATCH = 64
PATTERN_LEN = 16


def _builds() -> int:
    s = builder_cache_stats()
    return s["hits"] + s["misses"]


def make_docs(rng, n_docs: int, doc_len: int) -> list:
    return [rng.integers(0, 256, size=doc_len) for _ in range(n_docs)]


def make_patterns(rng, docs, batch: int, m: int) -> list:
    pats = []
    for q in range(batch):
        if q % 2 == 0:
            d = docs[int(rng.integers(0, len(docs)))]
            at = int(rng.integers(0, len(d) - m))
            pats.append(d[at:at + m])
        else:
            pats.append(rng.integers(0, 256, size=m))
    return pats


def bench_ingest(records, rng, doc_len: int, n_docs: int, n_ingests: int):
    docs = make_docs(rng, n_docs, doc_len)
    new = make_docs(rng, n_ingests, doc_len // 4)
    opts = SAOptions()

    # monolithic: every ingest re-encodes and rebuilds the whole corpus
    corpus = list(docs)
    t_mono, builds_mono = [], 0
    for d in new:
        corpus.append(d)
        before = _builds()
        us = time_call(lambda: SuffixArrayIndex.build(
            encode_docs(corpus)[0], opts), warmup=0, iters=1)
        builds_mono += _builds() - before
        t_mono.append(us)
    us_mono = float(np.median(t_mono))
    emit(f"segments_bench/ingest/monolithic/n_docs={n_docs}", us_mono,
         f"builds_per_ingest={builds_mono / n_ingests:.1f}")
    records.append({"table": "ingest", "path": "monolithic",
                    "n_docs": n_docs, "doc_len": doc_len,
                    "us_per_ingest": round(us_mono, 1),
                    "builds_per_ingest": builds_mono / n_ingests})

    # segmented: one small segment build per ingest (no compaction here —
    # the amortized-merge cost is its own record below)
    seg = SegmentedIndex.from_docs(docs, opts, sigma=256, segment_docs=1)
    t_seg, builds_seg = [], 0
    for d in new:
        before = _builds()
        us = time_call(lambda: seg.add_docs([d], compact=False),
                       warmup=0, iters=1)
        builds_seg += _builds() - before
        t_seg.append(us)
    us_seg = float(np.median(t_seg))
    emit(f"segments_bench/ingest/segmented/n_docs={n_docs}", us_seg,
         f"builds_per_ingest={builds_seg / n_ingests:.1f}"
         f";speedup={us_mono / us_seg:.1f}x")
    records.append({"table": "ingest", "path": "segmented",
                    "n_docs": n_docs, "doc_len": doc_len,
                    "us_per_ingest": round(us_seg, 1),
                    "builds_per_ingest": builds_seg / n_ingests,
                    "speedup_vs_monolithic": round(us_mono / us_seg, 2)})
    assert builds_seg == n_ingests, (builds_seg, n_ingests)

    # the deferred merge: one compact() over everything ingested above
    before = _builds()
    us_c = time_call(seg.compact, warmup=0, iters=1)
    emit(f"segments_bench/ingest/compact/n_docs={n_docs}", us_c,
         f"merge_builds={_builds() - before}")
    records.append({"table": "ingest", "path": "compact",
                    "n_docs": n_docs, "doc_len": doc_len,
                    "us": round(us_c, 1),
                    "merge_builds": _builds() - before})
    return docs


def bench_query(records, rng, docs, segment_counts, batch: int, m: int,
                iters: int):
    pats = make_patterns(rng, docs, batch, m)
    opts = SAOptions()
    base_us = None
    for s in segment_counts:
        per = max(-(-len(docs) // s), 1)
        seg = SegmentedIndex.from_docs(docs, opts, sigma=256,
                                       segment_docs=per)
        clear_query_cache()
        want = seg.count_batch(pats)
        us = time_call(lambda: seg.count_batch(pats), iters=iters)
        if base_us is None:
            base_us = us
            base_counts = want
        else:                                     # fan-out answers identically
            assert np.array_equal(want, base_counts), s
        overhead = us / base_us
        emit(f"segments_bench/query/segments={seg.n_segments}/b={batch}", us,
             f"patterns_s={batch / us * 1e6:.0f};overhead={overhead:.2f}x")
        records.append({"table": "query", "segments": seg.n_segments,
                        "batch": batch, "m": m, "us": round(us, 1),
                        "patterns_per_s": round(batch / us * 1e6, 1),
                        "overhead_vs_one_segment": round(overhead, 2)})


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_segments.json",
                    help="JSON artifact path ('' disables)")
    ap.add_argument("--smoke", action="store_true",
                    help="small docs, fewer cells (CI gate: proves one "
                         "build per ingest and fan-out/monolithic parity)")
    args = ap.parse_args(argv)

    doc_len = 2_000 if args.smoke else DOC_LEN
    n_docs = 4 if args.smoke else N_DOCS
    n_ingests = 2 if args.smoke else N_INGESTS
    seg_counts = (1, 4) if args.smoke else SEGMENT_COUNTS
    iters = 1 if args.smoke else 3

    rng = np.random.default_rng(0)
    records = []
    print("# segments_bench: ingest builder traffic + query fan-out overhead")
    docs = bench_ingest(records, rng, doc_len, n_docs, n_ingests)
    bench_query(records, rng, docs, seg_counts, BATCH, PATTERN_LEN, iters)

    if args.out:
        artifact = {
            "bench": "segments_bench",
            "python": sys.version.split()[0],
            "machine": platform.machine(),
            "smoke": bool(args.smoke),
            "records": records,
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"# wrote {args.out} ({len(records)} records)")
    return records


if __name__ == "__main__":
    main()
