"""Quickstart: build a suffix array three ways (paper-faithful reference,
vectorised JAX, naive oracle), verify they agree, and use it for LCP stats.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.dcv_jax import suffix_array_jax
from repro.core.oracle import suffix_array_naive
from repro.core.seq_ref import SeqStats, suffix_array_dcv
from repro.text.lcp import lcp_kasai, ngram_counts


def main():
    # the paper's Table 1 string: "acbaacedbbea$" over Σ = [0:12)
    x = np.array([0, 2, 1, 0, 0, 2, 4, 3, 1, 1, 4, 0])
    sa_ref = suffix_array_dcv(x, base_threshold=4)
    sa_jax = suffix_array_jax(x, base_threshold=4)
    sa_naive = suffix_array_naive(x)
    print("SA (paper Table 1):", sa_ref.tolist())
    assert sa_ref.tolist() == sa_jax.tolist() == sa_naive.tolist()

    # a bigger corpus with the accelerated schedule, instrumented
    rng = np.random.default_rng(0)
    big = rng.integers(0, 4, size=100_000)
    st = SeqStats()
    sa = suffix_array_dcv(big, stats=st, base_threshold=64)
    print("accelerated-sampling rounds (v_i, |D_i|, n_i):")
    for r in st.rounds:
        print(f"  v={r['v']:4d} |D|={r['D']:2d} n={r['n']}")
    lcp = lcp_kasai(big, sa)
    print(f"max repeated substring length: {int(lcp.max())}")
    print(f"distinct 8-grams: {ngram_counts(big, sa, lcp, 8)}")


if __name__ == "__main__":
    main()
