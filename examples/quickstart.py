"""Quickstart for the `repro.api` facade: build one suffix array on every
registered backend, verify they agree, then use a `SuffixArrayIndex` for
substring queries and corpus statistics.

    PYTHONPATH=src python examples/quickstart.py

Backend selection in one line: `build_suffix_array(x)` runs the vectorised
JAX DC-v; `build_suffix_array(x, mesh=mesh)` runs the paper's distributed
Algorithm 3 on that mesh; `backend="seq"`/"oracle" pin the paper-faithful
reference / naive ground truth.
"""
import numpy as np

from repro.api import (SAOptions, SegmentedIndex, SuffixArrayIndex,
                       build_suffix_array, builder_cache_stats,
                       registered_backends)
from repro.core.seq_ref import SeqStats


def main():
    # the paper's Table 1 string: "acbaacedbbea$" over Σ = [0:12)
    x = np.array([0, 2, 1, 0, 0, 2, 4, 3, 1, 1, 4, 0])
    results = {b: build_suffix_array(x, backend=b, base_threshold=4)
               for b in registered_backends()}
    print("SA (paper Table 1):", results["oracle"].tolist())
    assert all(sa.tolist() == results["oracle"].tolist()
               for sa in results.values()), results
    print(f"{len(results)} backends agree: {', '.join(sorted(results))}")

    # a bigger corpus on the paper-faithful backend with the accelerated
    # schedule, instrumented round by round
    rng = np.random.default_rng(0)
    big = rng.integers(0, 4, size=100_000)
    st = SeqStats()
    opts = SAOptions(backend="seq", stats=st, base_threshold=64)
    index = SuffixArrayIndex.build(big, opts)
    print("accelerated-sampling rounds (v_i, |D_i|, n_i):")
    for r in st.rounds:
        print(f"  v={r['v']:4d} |D|={r['D']:2d} n={r['n']}")

    # the index answers queries directly (lazy LCP, batched jitted search)
    print(f"max repeated substring length: {int(index.lcp.max())}")
    print(f"8-gram stats: {index.ngram_stats(8)}")
    pat = big[1234:1242]
    hits = index.locate(pat)
    print(f"pattern of len {len(pat)} occurs {index.count(pat)}× "
          f"(first at {hits[0] if len(hits) else '-'})")
    assert 1234 in hits

    # many patterns resolve in ONE device call (see examples/query_service.py
    # for the full serving loop with a persistent IndexStore)
    batch = [big[10:18], big[500:503], big[99_000:99_032]]
    print(f"batched counts: {index.count_batch(batch).tolist()}")

    # multi-document corpora keep the sentinel-separator layout
    docs = [rng.integers(0, 4, 500) for _ in range(3)]
    docs[2][:120] = docs[0][100:220]         # plant cross-doc contamination
    corpus = SuffixArrayIndex.from_docs(docs)
    leaks = corpus.cross_doc_duplicates(min_len=64)
    print(f"cross-doc repeats ≥ 64 chars: {len(leaks)} "
          f"(docs {sorted(set((i, j) for i, j, _ in leaks))})")

    # ingest without rebuilding the corpus: a SegmentedIndex answers the
    # same queries, but a document change rebuilds ONE small segment
    seg = SegmentedIndex.from_docs(docs, SAOptions(backend="seq"),
                                   segment_docs=1)
    before = builder_cache_stats()
    new_id, = seg.add_docs([rng.integers(0, 4, 200)])
    after = builder_cache_stats()
    builds = (after["hits"] + after["misses"]
              - before["hits"] - before["misses"])
    print(f"ingested doc {new_id}: {builds} segment build, "
          f"{seg.n_segments} segments over {seg.n_docs} docs")
    assert builds == 1
    pat = docs[2][40:48]                      # inside the planted overlap
    assert seg.count(pat) >= int(corpus.count_batch([pat])[0]) >= 2
    rows = seg.locate(pat)                    # global (doc, offset) rows
    print(f"pattern found in docs {sorted(set(rows[:, 0].tolist()))}")


if __name__ == "__main__":
    main()
