"""Batched serving example: prefill + KV-cache decode on an attention-free
arch (rwkv6) and a local/global attention arch (gemma3).

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch import serve


def main():
    for arch in ("rwkv6-1.6b", "gemma3-1b"):
        print(f"== {arch} ==")
        sys.argv = [sys.argv[0], "--arch", arch, "--smoke",
                    "--batch", "4", "--prompt-len", "12", "--gen", "20"]
        serve.main()


if __name__ == "__main__":
    main()
