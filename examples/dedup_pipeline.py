"""The paper inside the LM stack: suffix-array exact-substring dedup as a
data-pipeline stage (Lee et al. 2022-style), feeding training batches.
Suffix arrays are built through the `repro.api` facade — swap the backend
(or hand the plan a mesh for the distributed builder) without touching the
pipeline.

    PYTHONPATH=src python examples/dedup_pipeline.py
"""
import numpy as np

from repro.api import SAOptions, SuffixArrayIndex
from repro.data.pipeline import PipelineConfig, TokenPipeline, synthetic_corpus
from repro.text.dedup import find_duplicates, report_duplicates


def main():
    corpus = synthetic_corpus(60_000, vocab=256, dup_fraction=0.35, seed=7)
    opts = SAOptions()                      # auto → jax (no mesh supplied)
    print(f"backend: {opts.resolve_backend()}")

    index = SuffixArrayIndex.build(corpus, opts)
    rep = report_duplicates(index, min_len=64)
    print(f"corpus: {rep.n_chars} chars, duplicated: {rep.dup_chars} "
          f"({100 * rep.dup_fraction:.1f}%) across {len(rep.spans)} spans")
    # the same index answers content queries before dedup runs
    probe = corpus[100:116]
    print(f"16-gram at offset 100 occurs {index.count(probe)}× pre-dedup")

    pipe = TokenPipeline(corpus, PipelineConfig(
        seq_len=128, global_batch=8, dedup=True, dedup_min_len=64))
    print(f"after dedup stage: {pipe.n} chars "
          f"(-{rep.n_chars - pipe.n})")
    b = pipe.batch_at(0)
    print("first batch:", b["tokens"].shape, b["tokens"].dtype)
    # dedup is idempotent: a second pass finds (almost) nothing
    rep2 = find_duplicates(pipe.corpus, min_len=64, options=opts)
    print(f"residual duplication: {100 * rep2.dup_fraction:.2f}%")


if __name__ == "__main__":
    main()
