"""The paper inside the LM stack: the SA-backed streaming training data
plane. Document shards arrive one at a time; each is deduplicated against
everything seen so far (exact-substring, Lee et al. 2022-style) with ONE
suffix-array segment build per shard, a held-out eval set gates training
windows for contamination, and a probe scores sequences for verbatim
copies of the training data. Suffix arrays are built through the
`repro.api` facade — swap the backend (or hand the plan a mesh for the
distributed builder) without touching the plane.

    PYTHONPATH=src python examples/dedup_pipeline.py
"""
import numpy as np

from repro.data.pipeline import (PipelineConfig, TrainingDataPlane,
                                 synthetic_corpus, synthetic_doc_shards)
from repro.text.dedup import dedup_docs, find_duplicates


def main():
    shards = synthetic_doc_shards(60_000, vocab=256, shard_docs=8,
                                  doc_len=2048, dup_fraction=0.35, seed=7)
    eval_docs = [synthetic_corpus(2048, vocab=256, seed=100 + j)
                 for j in range(3)]
    # plant one contaminated stretch so the gate has a real positive
    shards[0][0][500:900] = eval_docs[0][:400]

    cfg = PipelineConfig(seq_len=128, global_batch=8, dedup=True,
                         dedup_min_len=64, gate_min_len=64,
                         gate_policy="reject", vocab=256)
    plane = TrainingDataPlane(cfg, eval_docs=eval_docs)
    for k, shard in enumerate(shards):
        st = plane.ingest_shard(shard)
        print(f"shard {k}: {st.chars} chars in, {st.dropped_chars} dropped "
              f"({st.prior_hits} prior-shard grams, {st.within_hits} "
              f"within-shard), {st.builds} segment build")
    rep = plane.report
    print(f"total: {rep.n_chars} chars → {rep.kept_chars} "
          f"({100 * rep.dup_fraction:.1f}% removed), "
          f"{rep.builds} builds for {rep.shards} shards")

    # streaming output is byte-identical to a monolithic whole-corpus pass
    mono, _ = dedup_docs([d for s in shards for d in s], min_len=64,
                         sigma=256)
    assert all(np.array_equal(a, b) for a, b in zip(plane._kept, mono))
    print("streaming == monolithic: byte-identical")

    # dedup is idempotent: a second pass finds (almost) nothing
    rep2 = find_duplicates(plane.corpus, min_len=64)
    print(f"residual duplication: {100 * rep2.dup_fraction:.2f}%")

    # gated batches: contaminated windows are resampled (policy "reject")
    b = plane.batch_at(0)
    print("first batch:", b["tokens"].shape, "gate:", plane.gate_stats())

    # memorization probe: a verbatim training excerpt vs a fresh sequence
    excerpt = shards[1][2][300:500]
    fresh = synthetic_corpus(200, vocab=256, seed=999)
    print("probe:", plane.probe([excerpt, fresh], min_len=64))


if __name__ == "__main__":
    main()
