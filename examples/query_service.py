"""Index once, serve forever: `IndexStore` + `QuerySession`.

The build side of this repo reproduces the paper's construction cost
model; this example shows the serving side added on top of it — a built
index is a persistent artifact that later processes restore instead of
rebuild, and queries run in batched ticks through one jitted vectorised
binary search.

    PYTHONPATH=src python examples/query_service.py
"""
import tempfile
import time

import numpy as np

from repro.api import (IndexStore, QuerySession, SAOptions, SuffixArrayIndex,
                       builder_cache_stats, corpus_fingerprint, encode_docs,
                       query_cache_stats)


def get_index(store, docs, opts):
    """What every serving process runs at startup: restore or build."""
    text, _, _ = encode_docs(docs)
    t0 = time.time()
    index, status = store.get_or_build(
        "corpus", lambda: SuffixArrayIndex.from_docs(docs, opts),
        options=opts, corpus_sha=corpus_fingerprint(text))
    print(f"  {status}: {index.n} chars in {time.time() - t0:.3f}s "
          f"(store={store.stats()}, builders={builder_cache_stats()})")
    return index


def main():
    rng = np.random.default_rng(0)
    docs = [rng.integers(0, 256, 50_000) for _ in range(4)]
    opts = SAOptions()

    with tempfile.TemporaryDirectory() as root:
        store = IndexStore(root)
        print("process 1 (cold store -> builds and persists):")
        get_index(store, docs, opts)
        print("process 2 (warm store -> restores, no build):")
        index = get_index(store, docs, opts)

        # a query session serves batched ticks; mixed pattern lengths are
        # padded/bucketed into one device buffer per tick
        session = QuerySession(index, batch_size=64)
        patterns = [docs[i % 4][j:j + ln] for i, (j, ln) in
                    enumerate(zip(rng.integers(0, 40_000, 256),
                                  rng.integers(4, 32, 256)))]
        counts = session.count(patterns)
        assert (counts >= 1).all()          # every pattern was cut from docs
        lat = session.latency_summary()
        print(f"served {lat['queries']} queries in {lat['ticks']} ticks: "
              f"{lat['qps']:.0f} qps, p50={lat['p50_us']:.0f}us "
              f"p95={lat['p95_us']:.0f}us p99={lat['p99_us']:.0f}us "
              f"(query buckets: {query_cache_stats()})")

        # the scalar API is the same engine, batch-of-one
        pat = docs[0][100:120]
        assert index.count(pat) == session.count([pat])[0]
        print(f"scalar shim agrees: count={index.count(pat)}")


if __name__ == "__main__":
    main()
