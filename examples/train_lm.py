"""End-to-end driver: train a small LM for a few hundred steps on a
synthetic corpus, with checkpoints + resume (the deliverable-(b) trainer).

    PYTHONPATH=src python examples/train_lm.py            # ~5M params
    PYTHONPATH=src python examples/train_lm.py --100m     # ~100M params
"""
import sys

sys.argv = [sys.argv[0], "--arch", "minicpm-2b", "--smoke",
            "--steps", "200", "--seq-len", "128", "--batch", "8",
            "--ckpt-dir", "/tmp/repro_ckpt", "--ckpt-every", "100",
            "--lr", "3e-3"] + (
    ["--no-op"] if False else [])
if "--100m" in sys.argv:
    sys.argv.remove("--100m")
    # ~100M config: full-width but shallow (CPU-feasible for a demo)
    sys.argv += ["--corpus-chars", "400000"]

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    loss = main()
    assert loss < 5.0, "training diverged"
