"""End-to-end driver: train a small LM for a few hundred steps on a
synthetic corpus, with checkpoints + resume (the deliverable-(b) trainer).

    PYTHONPATH=src python examples/train_lm.py            # ~5M params
    PYTHONPATH=src python examples/train_lm.py --100m     # ~100M params

Extra flags pass straight through to `repro.launch.train.main`, e.g.::

    PYTHONPATH=src python examples/train_lm.py --dedup --eval-gate \\
        --plant-contamination 40
"""
import math
import sys

from repro.launch.train import main

DEFAULTS = ["--arch", "minicpm-2b", "--smoke",
            "--steps", "200", "--seq-len", "128", "--batch", "8",
            "--ckpt-dir", "/tmp/repro_ckpt", "--ckpt-every", "100",
            "--lr", "3e-3"]

if __name__ == "__main__":
    # user args first, then defaults: argparse keeps the LAST occurrence
    # of a repeated flag, so anything the user passes wins
    user = sys.argv[1:]
    if "--100m" in user:
        user.remove("--100m")
        # ~100M config: full-width but shallow (CPU-feasible for a demo)
        user += ["--corpus-chars", "400000"]
    report = main(DEFAULTS + user)
    # the <5.0 convergence bar assumes the default 200-step run
    bar = float("inf") if "--steps" in user else 5.0
    assert math.isfinite(report["loss"]), "training diverged"
    assert report["loss"] < bar, "training diverged"
