"""Distributed suffix array on a multi-device mesh (the paper's Algorithm 3)
with BSP cost instrumentation. Run with fake devices on CPU:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_sa.py
"""
import jax
import numpy as np
from jax.sharding import Mesh

from repro.bsp.counters import BSPCounters
from repro.bsp.suffix_array import suffix_array_bsp
from repro.core.oracle import suffix_array_doubling


def main():
    p = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()).reshape(p), ("bsp",))
    rng = np.random.default_rng(0)
    x = rng.integers(0, 3, size=5000)
    ct = BSPCounters()
    sa = suffix_array_bsp(x, mesh, base_threshold=128, counters=ct)
    assert np.array_equal(sa, suffix_array_doubling(x))
    print(f"p={p} n={len(x)}: SA correct.")
    print(f"BSP costs: S={ct.supersteps} supersteps, "
          f"H={ct.comm_words} words, W={ct.work} ops")
    print("per-superstep log (first 12):")
    for e in ct.log[:12]:
        print("  ", e)


if __name__ == "__main__":
    main()
