"""Distributed suffix array on a multi-device mesh (the paper's Algorithm 3)
through the `repro.api` facade: the same `build_suffix_array` call used on
one device auto-selects the BSP backend the moment the plan carries a mesh.
Run with fake devices on CPU:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_sa.py
"""
import jax
import numpy as np

from repro.api import SAOptions, build_suffix_array
from repro.bsp.counters import BSPCounters
from repro.launch.mesh import make_sa_mesh


def main():
    p = len(jax.devices())
    mesh = make_sa_mesh()
    rng = np.random.default_rng(0)
    x = rng.integers(0, 3, size=5000)

    ct = BSPCounters()
    opts = SAOptions(mesh=mesh, base_threshold=128, counters=ct)
    assert opts.resolve_backend() == "bsp"   # mesh present → distributed
    sa = build_suffix_array(x, opts)

    assert np.array_equal(sa, build_suffix_array(x, backend="oracle"))
    print(f"p={p} n={len(x)}: SA correct (backend={opts.resolve_backend()}, "
          f"packed-key local sorts).")
    print(f"BSP costs: S={ct.supersteps} supersteps over {ct.rounds} rounds, "
          f"H={ct.comm_words} words, W={ct.work} ops")
    print("per-superstep log (first 12):")
    for e in ct.log[:12]:
        print("  ", e)


if __name__ == "__main__":
    main()
