"""Two-level batched queries against a sparse suffix array.

A pattern occurrence starting at text position q is anchored at the
unique sampled position ``p = q + a`` with alignment ``a = (−q) mod s``
(s = sample_rate): whenever the pattern length m is ≥ s, ``a < s ≤ m``
guarantees p is a real sampled position inside the occurrence. So every
occurrence is counted by exactly one of the s alignments, and the exact
query plan is:

1. **Suffix search (device).** `_sparse_ranges_kernel` — the jitted
   vectorised double binary search of `repro.api.query._ranges_kernel`,
   lifted from [B, 2] bound states to [B, s, 2]: alignment a of pattern
   b searches the sparse SA for the block of sampled suffixes starting
   with ``pat[a:]``. Every iteration gathers one [B, s, 2, L] window of
   text and does one masked 3-way prefix compare; ceil(log2(ns + 1))
   iterations resolve all B·s·2 bounds in a single XLA call.
2. **Head verification (host).** `verify_alignments` — for each
   candidate sampled position p in a hit range, confirm the ≤ s−1
   characters *before* the anchor: ``text[p−a : p] == pat[:a]`` (and
   p ≥ a). One vectorised gather + compare per alignment over all
   candidates of the whole batch — no per-candidate Python. Verified
   candidates yield occurrence positions q = p − a; counts are exact
   and positions identical to the dense index's `locate_batch`.

The kernel shares `QueryBatch`'s pow2 shape bucketing, so an open-ended
pattern stream compiles O(log) kernel variants; `TRACE_COUNTS` mirrors
the dense query engine's retrace accounting (`tests/sparse` pins it flat
for reused buckets).
"""
from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp
import numpy as np

#: one event per actual jax trace of the sparse query kernel.
TRACE_COUNTS: collections.Counter = collections.Counter()


def trace_events() -> int:
    """Total number of jax traces performed by the sparse kernel so far."""
    return sum(TRACE_COUNTS.values())


@functools.partial(jax.jit, static_argnames=("sample_rate",))
def _sparse_ranges_kernel(text, ssa, pats, lens, sample_rate: int):
    """All patterns × all s alignments × both bounds, in one fori_loop.

    For pattern row b and alignment a, the search key is the suffix
    ``pats[b, a:lens[b]]`` and the rank space is the sparse SA (`ssa`
    holds *text positions*, so gathers read the full text while bounds
    live in [0, ns]). Bound 0 converges to the first sampled suffix ≥
    the key, bound 1 to the first > it — `[lo, hi)` is the candidate
    block per (pattern, alignment). Rows whose length is 0 (padding)
    resolve to (0, ns) exactly like the dense kernel's empty patterns;
    callers slice them off before verification. Returns (lo, hi), each
    int32[B, s].
    """
    # saca-lint: allow[TRACE001] deliberate: trace-time retrace counter, mutated only while tracing, read by tests via trace_events()
    TRACE_COUNTS["sparse_ranges_kernel"] += 1
    n = text.shape[0]
    ns = ssa.shape[0]
    s = sample_rate
    B, L = pats.shape
    steps = max(int(ns).bit_length(), 1) + 1
    col = jnp.arange(L, dtype=jnp.int32)
    past_end = jnp.array(-1, text.dtype)   # below every real character
    # alignment-shifted pattern view: sh_pats[b, a, l] = pats[b, a + l];
    # columns past the row's true length are masked by `valid`, so the
    # clamped out-of-range gather value never participates
    aidx = jnp.arange(s, dtype=jnp.int32)[:, None] + col[None, :]   # [s, L]
    sh_pats = pats[:, jnp.minimum(aidx, L - 1)]                     # [B, s, L]
    valid = aidx[None, :, :] < lens[:, None, None]                  # [B, s, L]

    def body(_, state):
        lo, hi = state
        active = lo < hi                                    # [B, s, 2]
        mid = lo + (hi - lo) // 2
        start = ssa[jnp.where(active, mid, 0)]              # [B, s, 2]
        idx = start[..., None] + col[None, None, None, :]   # [B, s, 2, L]
        chars = jnp.where(idx < n, text[jnp.minimum(idx, n - 1)], past_end)
        pat = jnp.broadcast_to(sh_pats[:, :, None, :], chars.shape)
        v = jnp.broadcast_to(valid[:, :, None, :], chars.shape)
        diff = (chars != pat) & v
        any_diff = diff.any(axis=-1)
        first = jnp.argmax(diff, axis=-1)[..., None]
        s_at = jnp.take_along_axis(chars, first, axis=-1)[..., 0]
        p_at = jnp.take_along_axis(pat, first, axis=-1)[..., 0]
        less = any_diff & (s_at < p_at)       # suffix < shifted pattern
        greater = any_diff & (s_at > p_at)    # suffix > shifted pattern
        # bound 0 moves right while suffix < key; bound 1 while suffix ≤ key
        before = jnp.stack([less[..., 0], ~greater[..., 1]], axis=-1)
        lo = jnp.where(active & before, mid + 1, lo)
        hi = jnp.where(active & ~before, mid, hi)
        return lo, hi

    lo0 = jnp.zeros((B, s, 2), jnp.int32)
    hi0 = jnp.full((B, s, 2), ns, jnp.int32)
    lo, hi = jax.lax.fori_loop(0, steps, body, (lo0, hi0))
    return lo[..., 0], lo[..., 1]


def sparse_ranges(index, batch, *, staged=None):
    """Level 1 for a whole `QueryBatch`: per-alignment candidate ranges.

    Returns ``(lo, hi)`` int64[n_queries, s] — padding rows already
    sliced off. An empty index maps everything to empty ranges. Pass
    ``staged`` (from `repro.api.query.stage_batch`) to run against
    buffers whose host→device transfer was already started, the serving
    tier's double-buffer path.
    """
    batch.check_bound_to(index)
    k, s = batch.n_queries, index.sample_rate
    if index.ns == 0 or k == 0:
        z = np.zeros((k, s), np.int64)
        return z, z.copy()
    text_d, sa_d = index._device_state()
    pats_d, lens_d = (staged if staged is not None
                      else (jnp.asarray(batch.pats), jnp.asarray(batch.lens)))
    lo, hi = _sparse_ranges_kernel(text_d, sa_d, pats_d, lens_d, s)
    return (np.asarray(lo)[:k].astype(np.int64),
            np.asarray(hi)[:k].astype(np.int64))


def verify_alignments(index, batch, lo, hi, *, want_positions: bool = False):
    """Level 2: confirm candidate heads against the raw text.

    ``(lo, hi)`` are `sparse_ranges` outputs. For alignment a, candidate
    sampled position p matches iff ``p ≥ a`` and ``text[p−a:p] ==
    pat[:a]`` — its occurrence starts at ``q = p − a``. Returns
    ``(counts int64[k], positions)`` where positions is a list of sorted
    int64 arrays (one per pattern) when ``want_positions``, else None.
    One gather + compare per alignment over ALL candidates of the batch.
    """
    k = batch.n_queries
    s = index.sample_rate
    counts = np.zeros(k, np.int64)
    ssa = index.sa.astype(np.int64)
    text = index.text
    pats = batch.pats
    rows_acc: list = []
    pos_acc: list = []
    for a in range(s):
        sizes = hi[:, a] - lo[:, a]
        total = int(sizes.sum())
        if total == 0:
            continue
        rows = np.repeat(np.arange(k, dtype=np.int64), sizes)
        within = (np.arange(total, dtype=np.int64)
                  - np.repeat(np.cumsum(sizes) - sizes, sizes))
        p = ssa[np.repeat(lo[:, a], sizes) + within]
        ok = p >= a
        if a:
            head_idx = (p[:, None] - a
                        + np.arange(a, dtype=np.int64)[None, :])
            head = text[np.clip(head_idx, 0, None)]   # clip: rows with p < a
            ok &= (head == pats[rows, :a].astype(np.int64)).all(axis=1)
        counts += np.bincount(rows[ok], minlength=k)
        if want_positions:
            rows_acc.append(rows[ok])
            pos_acc.append(p[ok] - a)
    if not want_positions:
        return counts, None
    if not rows_acc:
        return counts, [np.zeros(0, np.int64) for _ in range(k)]
    rows_cat = np.concatenate(rows_acc)
    q_cat = np.concatenate(pos_acc)
    order = np.lexsort((q_cat, rows_cat))
    rows_cat, q_cat = rows_cat[order], q_cat[order]
    splits = np.searchsorted(rows_cat, np.arange(1, k))
    return counts, np.split(q_cat, splits)
