"""repro.sparse — sampled-position suffix-array indexing.

The dense `repro.api.SuffixArrayIndex` stores one SA entry per text
position: ~4 bytes/char for the SA alone, more once the LCP is cached.
That footprint — not build FLOPs — is what caps single-device corpora
around a few hundred thousand characters (and what
Haag/Kurpicz/Sanders/Schimek, arXiv:2412.10160, argue decides practical
SACA). This package applies the paper's own sampling idea to the *index*
rather than the construction: a **sparse suffix array** (Ayad et al.,
arXiv:2310.09023 — "Sparse Suffix and LCP Array: Simple, Direct, Small,
and Fast") stores the suffix order of every ``sample_rate``-th position
only, cutting index memory by the sampling factor (8–32× at the rates
the data plane uses) and pushing single-device n into the tens of
millions.

Three modules:

* `construct` — `build_sparse_suffix_array`: packed-word multi-key sort
  of the non-overlapping s-char head windows (reusing the MSD word sort
  from `repro.core.dcv_jax`) followed by stride-doubling tie-break, so
  build cost and memory both scale with n/s;
* `query` — the jitted two-level batched query kernel: a vectorised
  double binary search over the **s shifted alignments** of every
  pattern against the sparse SA, then a vectorised head-verification
  pass against the raw text;
* `index` — `SparseSuffixArrayIndex`, the facade class: byte-identical
  `count_batch` / `locate_batch` / `contains_batch` / `longest_match`
  results vs the dense index for every pattern of length ≥
  ``sample_rate``; shorter patterns raise the typed
  `PatternTooShortError` instead of returning wrong answers.

Select it through the existing facade: any `SAOptions(sample_rate=s)`
with ``s > 1`` makes `SuffixArrayIndex.build` / `.from_docs`,
`SegmentedIndex`, the stores, and the data plane build sparse indexes.
"""
from .construct import build_sparse_suffix_array, sparse_lcp
from .index import PatternTooShortError, SparseSuffixArrayIndex

__all__ = [
    "PatternTooShortError",
    "SparseSuffixArrayIndex",
    "build_sparse_suffix_array",
    "sparse_lcp",
]
