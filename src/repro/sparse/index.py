"""`SparseSuffixArrayIndex` — the sampled-position index behind the facade.

Subclasses `repro.api.SuffixArrayIndex` and keeps its *exact* query
semantics for every pattern of length ≥ ``sample_rate``:
`count_batch` / `locate_batch` / `contains_batch` / `locate_docs_batch` /
`longest_match` return byte-identical results to a dense index over the
same text (the differential fuzz suite pins this cell by cell). What
changes is the storage contract — ``self.sa`` holds only the suffix
order of positions ``{0, s, 2s, ...}``, so the index is ~s× smaller —
and the failure mode for patterns shorter than the rate: those raise the
typed `PatternTooShortError` at encode time instead of returning wrong
answers (a pattern of length < s can occur at a position no alignment
anchors to a sampled suffix).

Operations that intrinsically need the rank of *every* text position
(`ngram_stats`, `duplicate_spans`, `cross_doc_duplicates`,
`sa_ranges_batch`) raise `NotImplementedError` with a pointer to the
dense index — the data plane builds a transient dense index per shard
for exactly those (`repro.data.pipeline.StreamingDedup`).
"""
from __future__ import annotations

import numpy as np

from ..api.index import SuffixArrayIndex, encode_docs
from ..api.options import SAOptions
from ..api.query import QueryBatch, stage_batch
from .construct import build_sparse_suffix_array, sparse_lcp
from .query import sparse_ranges, verify_alignments


class PatternTooShortError(ValueError):
    """Pattern shorter than the index's ``sample_rate``.

    A sparse index can only anchor occurrences of patterns with length ≥
    its sampling stride (shorter occurrences may contain no sampled
    position at a predictable alignment). Raised at pattern-encode time —
    synchronously, before any device work — so callers distinguish "this
    index cannot answer that" from a genuine 0 count. Subclasses
    `ValueError` so existing pattern-validation handlers keep working.
    """

    def __init__(self, pattern_len: int, sample_rate: int):
        self.pattern_len = int(pattern_len)
        self.sample_rate = int(sample_rate)
        super().__init__(
            f"pattern of length {pattern_len} is shorter than this sparse "
            f"index's sample_rate={sample_rate}; sparse queries are exact "
            f"only for patterns of length ≥ sample_rate — use a dense "
            f"index (sample_rate=1) for shorter patterns")


class SparseSuffixArrayIndex(SuffixArrayIndex):
    """Suffix-array index over every ``sample_rate``-th text position.

    Construction (`build` / `from_docs`) runs the Ayad-style sampled
    plan from `repro.sparse.construct`; queries run the two-level plan
    from `repro.sparse.query` (jitted per-alignment double binary search
    + vectorised head verification). Everything positional — `locate`
    results, `doc_of` / `doc_offset`, document coordinates — is
    unchanged: the *text* is stored densely, only the suffix *order* is
    sampled.
    """

    def __init__(self, text, sa, *, sample_rate: int, doc_starts=None,
                 shift: int = 0, options: SAOptions | None = None,
                 lcp=None, sigma: int | None = None):
        s = int(sample_rate)
        if s < 2:
            raise ValueError(
                f"SparseSuffixArrayIndex needs sample_rate ≥ 2, got {s} "
                f"(sample_rate=1 is the dense SuffixArrayIndex)")
        self.sample_rate = s        # before super().__init__: _check_shapes
        super().__init__(text, sa, doc_starts=doc_starts, shift=shift,
                         options=options, lcp=lcp, sigma=sigma)
        if self.options.sample_rate != s:
            # keep the plan honest: fingerprint() must reflect the actual
            # stored structure even when callers pass a mismatched plan
            self.options = self.options.replace(sample_rate=s)

    def _check_shapes(self) -> None:
        ns = -(-self.n // self.sample_rate)
        if self.sa.shape != (ns,):
            raise ValueError(
                f"sparse sa shape {self.sa.shape} != ({ns},) = "
                f"ceil(n={self.n} / sample_rate={self.sample_rate})")

    # ----------------------------------------------------------- construct
    @classmethod
    def build(cls, text, options: SAOptions | None = None, *,
              sigma: int | None = None, **overrides):
        """Index a single document at ``options.sample_rate`` (must be ≥ 2).

        Deliberately bypasses the compiled-builder cache — its contract is
        the dense full-length SA; sparse construction is host-side O(n/s).
        """
        opts = options if options is not None else SAOptions()
        if overrides:
            opts = opts.replace(**overrides)
        text = np.asarray(text, np.int64)
        sa = build_sparse_suffix_array(text, opts.sample_rate)
        return cls(text, sa, sample_rate=opts.sample_rate, shift=0,
                   options=opts, sigma=sigma)

    @classmethod
    def from_docs(cls, docs, options: SAOptions | None = None, *,
                  sigma: int | None = None, **overrides):
        """Index documents with the same sentinel-separator layout as the
        dense `from_docs` — positions and (doc, offset) mapping identical."""
        opts = options if options is not None else SAOptions()
        if overrides:
            opts = opts.replace(**overrides)
        text, starts, n_docs = encode_docs(docs)
        sa = build_sparse_suffix_array(text, opts.sample_rate)
        return cls(text, sa, sample_rate=opts.sample_rate, doc_starts=starts,
                   shift=n_docs, options=opts, sigma=sigma)

    # ----------------------------------------------------------- structure
    @property
    def ns(self) -> int:
        """Number of sampled (indexed) positions: ceil(n / sample_rate)."""
        return len(self.sa)

    @property
    def min_pattern_len(self) -> int:
        """Shortest pattern this index answers exactly (= sample_rate)."""
        return self.sample_rate

    @property
    def lcp(self) -> np.ndarray:
        """Sparse LCP array (consecutive sampled suffixes), lazy + cached."""
        if self._lcp is None:
            self._lcp = sparse_lcp(self.text, self.sa)
        return self._lcp

    # ------------------------------------------------------------- queries
    def _encode_pattern(self, pattern) -> np.ndarray:
        pat = super()._encode_pattern(pattern)
        if len(pat) < self.sample_rate:
            raise PatternTooShortError(len(pat), self.sample_rate)
        return pat

    def _counts_from_batch(self, batch: QueryBatch, *,
                           staged=None) -> np.ndarray:
        lo, hi = sparse_ranges(self, batch, staged=staged)
        counts, _ = verify_alignments(self, batch, lo, hi)
        return counts

    def count_batch(self, patterns) -> np.ndarray:
        """Exact occurrence counts — one jitted per-alignment search plus
        one vectorised host verification pass for the whole batch."""
        return self._counts_from_batch(self._as_batch(patterns))

    def locate_batch(self, patterns) -> list:
        """Sorted encoded start positions per pattern — byte-identical to
        the dense index's `locate_batch` for patterns ≥ sample_rate."""
        qb = self._as_batch(patterns)
        lo, hi = sparse_ranges(self, qb)
        _, positions = verify_alignments(self, qb, lo, hi,
                                         want_positions=True)
        return positions

    def sa_ranges_batch(self, patterns):
        raise NotImplementedError(
            "a sparse index has no dense SA rank space — [lo, hi) ranges "
            "over all n suffixes do not exist at sample_rate > 1; use "
            "count_batch / locate_batch (exact), or a dense index")

    # --------------------------------------------------- encoded fan-in API
    def _counts_encoded(self, enc) -> np.ndarray:
        qb = QueryBatch.from_encoded(self, enc)
        return self._counts_from_batch(qb)

    def _positions_encoded(self, enc) -> list:
        qb = QueryBatch.from_encoded(self, enc)
        lo, hi = sparse_ranges(self, qb)
        _, positions = verify_alignments(self, qb, lo, hi,
                                         want_positions=True)
        return positions

    # ------------------------------------------------- serving-tier protocol
    def stage_encoded(self, enc):
        batch = QueryBatch.from_encoded(self, enc)
        return (batch, stage_batch(self, batch) if self.n else None)

    def ranges_staged(self, work) -> tuple[np.ndarray, np.ndarray]:
        """Resolve a staged work item to **virtual** (0, count) ranges.

        The serving tier consumes ranges only as ``hi - lo`` widths; a
        sparse index has no dense rank space to report, so it returns
        ``[0, count)`` per pattern — widths (and therefore every
        count/contains answer downstream) are exact.
        """
        batch, staged = work
        counts = self._counts_from_batch(batch, staged=staged)
        return np.zeros(len(counts), np.int64), counts

    # ---------------------------------------------------------- statistics
    def ngram_stats(self, k: int):
        raise NotImplementedError(
            "ngram_stats needs the rank of every text position (dense SA + "
            "LCP); build a dense index (sample_rate=1) for corpus stats")

    def duplicate_spans(self, min_len: int):
        raise NotImplementedError(
            "duplicate_spans needs the dense SA + LCP; "
            "repro.data.pipeline.StreamingDedup builds a transient dense "
            "index per shard for exactly this")

    def cross_doc_duplicates(self, min_len: int):
        raise NotImplementedError(
            "cross_doc_duplicates needs the dense SA + LCP; build a dense "
            "index (sample_rate=1) for this report")

    def __repr__(self) -> str:
        return (f"SparseSuffixArrayIndex(n={self.n}, ns={self.ns}, "
                f"sample_rate={self.sample_rate}, n_docs={self.n_docs}, "
                f"lcp={'cached' if self._lcp is not None else 'lazy'})")
