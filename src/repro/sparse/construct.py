"""Sparse suffix-array construction: sampled heads + stride doubling.

`build_sparse_suffix_array(text, s)` returns the text positions
``{0, s, 2s, ...}`` sorted by the lexicographic order of their (full)
suffixes — exactly the subsequence of the dense SA restricted to sampled
positions (`tests/sparse/test_construct.py` pins that equivalence).

The construction is the Ayad et al. (arXiv:2310.09023) plan specialised
to evenly-spaced samples:

1. **Head sort.** The s-char windows at multiples of s are
   *non-overlapping*, so the sampled text is just the padded text
   reshaped to [n/s, s]. Rows are packed most-significant-column-first
   into uint64 words (the same packing rule as
   `repro.core.dcv_jax._window_words`) and ordered by the MSD
   packed-word sort `repro.core.dcv_jax._order_from_words` — one
   introsort on the leading word, later words only re-sort surviving tie
   runs. After this pass, ranks reflect the first s characters.
2. **Stride doubling.** Sampled positions are closed under +s steps:
   position ``i·s + h·s`` is itself the sampled index ``i + h``. So ties
   refine exactly like Manber–Myers prefix doubling *in sampled units*:
   round h re-sorts each tie run by the current rank of the suffix h
   samples later (−1 past the end, which also orders prefix-equal
   suffixes shortest-first). h doubles until no ties remain; ranks then
   reflect ≥ n characters, i.e. the full suffix order.

Everything is host numpy on O(n/s) arrays — the sparse path deliberately
bypasses the compiled-builder cache (`repro.api.build`), whose contract
is the dense full-length SA.

`sparse_lcp` computes the companion sparse LCP array (longest common
prefix of *consecutive sampled suffixes in sparse SA order*) by chunked
vectorised comparison — lazy on the index, never needed for queries.
"""
from __future__ import annotations

import numpy as np

from ..core.dcv_jax import _order_from_words


def sampled_positions(n: int, sample_rate: int) -> np.ndarray:
    """The indexed text positions: every `sample_rate`-th, as int64."""
    return np.arange(0, max(int(n), 0), int(sample_rate), dtype=np.int64)


def _sampled_head_words(text: np.ndarray, ns: int, s: int) -> list:
    """Pack the non-overlapping s-char head windows into uint64 word lists.

    Window i covers text[i*s : (i+1)*s]; the text is padded to ns*s with
    −1 (below every real character, so a window that runs past the end
    compares smaller at its first padded column — end-of-text behaves as
    the usual smallest sentinel). Values are shifted to non-negative and
    packed most-significant-column-first, 64 // bits columns per word:
    comparing word lists lexicographically equals comparing windows
    lexicographically, exactly the `_window_words` contract.
    """
    lo = -1
    hi = int(text.max()) if len(text) else 0
    xp = np.full(ns * s, lo, np.int64)
    xp[:len(text)] = text
    bits = max(1, int(hi - lo).bit_length())
    per_word = max(1, 64 // bits)
    shift = np.uint64(bits)
    words = []
    for start in range(0, s, per_word):
        w = np.zeros(ns, dtype=np.uint64)
        for c in range(start, min(start + per_word, s)):
            w = (w << shift) | (xp[c::s] - lo).astype(np.uint64)
        words.append(w)
    return words


def build_sparse_suffix_array(text, sample_rate: int) -> np.ndarray:
    """Sampled positions sorted by full-suffix order — int32[ceil(n/s)].

    Output[k] is the k-th smallest sampled suffix's *text position* (a
    multiple of `sample_rate`), directly comparable against the dense SA
    filtered to multiples of s. `sample_rate` must be ≥ 2 — the dense
    path already covers s = 1 (and goes through the backend registry +
    builder cache instead).
    """
    s = int(sample_rate)
    if s < 2:
        raise ValueError(
            f"sample_rate must be ≥ 2 for sparse construction, got {s} "
            f"(s = 1 is the dense path: repro.api.build_suffix_array)")
    text = np.asarray(text, np.int64).ravel()
    n = len(text)
    if n and int(text.min()) < 0:
        raise ValueError("text values must be ≥ 0")
    ns = -(-n // s)                       # ceil(n / s) sampled positions
    if ns == 0:
        return np.zeros(0, np.int32)

    perm, is_start = _order_from_words(_sampled_head_words(text, ns, s))
    rank = np.empty(ns, np.int64)
    rank[perm] = np.cumsum(is_start) - 1

    # stride doubling in sampled units: each round h refines ties by the
    # rank h samples (= h·s characters) later; ranks reflect 2h·s chars
    # after the round, so h ≥ ns/2 (the last round executed) settles every
    # genuinely distinct pair and prefix-equal pairs order shortest-first
    # through the −1 past-the-end key.
    h = 1
    while h < ns:
        start_slot = np.flatnonzero(is_start)
        run_id = np.cumsum(is_start) - 1
        sizes = np.diff(start_slot, append=ns)
        sl = np.flatnonzero(sizes[run_id] > 1)     # slots inside tie runs
        if len(sl) == 0:
            break
        key2 = np.full(ns, -1, np.int64)
        key2[:ns - h] = rank[h:]
        p = perm[sl]
        rid = run_id[sl]
        local = np.lexsort((key2[p], rid))
        perm[sl] = p[local]
        kv = key2[perm[sl]]
        if len(sl) > 1:
            is_start[sl[1:]] = (rid[1:] != rid[:-1]) | (kv[1:] != kv[:-1])
        rank[perm] = np.cumsum(is_start) - 1
        h *= 2
    return (perm * s).astype(np.int32)


def sparse_lcp(text, sparse_sa, *, chunk: int = 64) -> np.ndarray:
    """LCP of consecutive sparse-SA suffixes — int64[len(sparse_sa)].

    ``out[k]`` (k ≥ 1) is the longest common prefix, in characters, of
    the suffixes at ``sparse_sa[k-1]`` and ``sparse_sa[k]``; ``out[0]``
    is 0 by convention, matching the dense Kasai layout. Computed by
    chunked vectorised comparison: every still-tied pair advances `chunk`
    characters per round, so total work is O(Σ lcp + ns·chunk) with no
    per-character Python loop. Kasai's trick needs the rank of *every*
    text position, which a sparse index precisely does not store.
    """
    text = np.asarray(text, np.int64).ravel()
    ssa = np.asarray(sparse_sa, np.int64).ravel()
    n, ns = len(text), len(ssa)
    out = np.zeros(ns, np.int64)
    if ns < 2:
        return out
    a, b = ssa[:-1], ssa[1:]
    active = np.arange(ns - 1, dtype=np.int64)
    off = np.zeros(ns - 1, np.int64)
    step = np.arange(chunk, dtype=np.int64)
    while len(active):
        ia = (a[active] + off[active])[:, None] + step[None, :]
        ib = (b[active] + off[active])[:, None] + step[None, :]
        # distinct past-the-end sentinels: two suffixes ending at the same
        # offset stop matching there (their common prefix is over), and a
        # suffix never "matches" the other's real character past its end
        va = np.where(ia < n, text[np.minimum(ia, n - 1)], np.int64(-1))
        vb = np.where(ib < n, text[np.minimum(ib, n - 1)], np.int64(-2))
        eq = va == vb
        matched = np.where(eq.all(axis=1), chunk, np.argmax(~eq, axis=1))
        out[active + 1] += matched
        off[active] += matched
        active = active[matched == chunk]
    return out
