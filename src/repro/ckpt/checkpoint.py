"""Fault-tolerant checkpointing: sharded npz + manifest, async writes, exact
resume, and **elastic reshard** — a checkpoint written on one mesh restores
onto any other mesh shape (the elastic-scaling path, DESIGN §6).

Format (directory per step):
    step_000123/
        manifest.json      — pytree structure, shapes, dtypes, step, extras
        arrays.npz         — flat {index: ndarray}, written atomically
A checkpoint is only visible once `COMMITTED` exists (crash-safe).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def tree_to_host(tree):
    return jax.tree_util.tree_map(lambda l: np.asarray(l), tree)


def save_checkpoint(ckpt_dir: str, step: int, tree, *, extras: dict | None
                    = None, async_write: bool = False):
    """Write checkpoint; with async_write=True the host copy happens on the
    calling thread (cheap device→host) and the disk write on a daemon thread
    (straggler mitigation: training never blocks on the filesystem)."""
    host_tree = tree_to_host(tree)

    def write():
        path = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        leaves, treedef = _flatten(host_tree)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{str(i): l for i, l in enumerate(leaves)})
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(host_tree).serialize_using_proto().hex()
            if hasattr(jax.tree_util.tree_structure(host_tree),
                       "serialize_using_proto") else None,
            "paths": [str(p) for p, _ in
                      jax.tree_util.tree_flatten_with_path(host_tree)[0]],
            "shapes": [list(l.shape) for l in leaves],
            "dtypes": [str(l.dtype) for l in leaves],
            "extras": extras or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        open(os.path.join(tmp, "COMMITTED"), "w").close()
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and \
                os.path.exists(os.path.join(ckpt_dir, d, "COMMITTED")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like_tree, *,
                       shardings=None):
    """Restore into the structure of `like_tree`. With `shardings` (a pytree
    of NamedSharding for a possibly *different* mesh) arrays are device_put
    shard-by-shard — this is the elastic reshard path.

    Every leaf is validated against `like_tree` (count, shape, AND dtype)
    before unflattening, and the arrays.npz payload is cross-checked
    against the manifest, so a stale, truncated, or hand-edited checkpoint
    raises a descriptive `FileNotFoundError`/`ValueError` instead of
    restoring garbage silently — `repro.api.store.IndexStore` relies on
    this contract.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, "COMMITTED")):
        raise FileNotFoundError(
            f"no committed checkpoint at {path} (missing COMMITTED marker)")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    names = manifest.get("paths") or []

    def leaf_name(i):
        return names[i] if i < len(names) else f"leaf {i}"

    if len(data.files) != len(leaves):
        raise ValueError(
            f"checkpoint {path} holds {len(data.files)} arrays but "
            f"like_tree has {len(leaves)} leaves — stale or truncated "
            f"checkpoint, or a mismatched restore target")
    m_shapes = manifest.get("shapes")
    m_dtypes = manifest.get("dtypes")
    if m_shapes is not None and len(m_shapes) != len(leaves):
        raise ValueError(
            f"checkpoint manifest {path} records {len(m_shapes)} leaves "
            f"but like_tree has {len(leaves)} — stale or truncated manifest")
    loaded = []
    for i, want in enumerate(leaves):
        if str(i) not in data.files:
            raise ValueError(f"checkpoint {path} is missing array {i} "
                             f"({leaf_name(i)}) — truncated arrays.npz")
        got = data[str(i)]
        want_shape = tuple(np.shape(want))
        want_dtype = np.dtype(getattr(want, "dtype", np.asarray(want).dtype))
        if tuple(got.shape) != want_shape:
            raise ValueError(
                f"checkpoint {path}, {leaf_name(i)}: stored shape "
                f"{tuple(got.shape)} != expected {want_shape}")
        if np.dtype(got.dtype) != want_dtype:
            raise ValueError(
                f"checkpoint {path}, {leaf_name(i)}: stored dtype "
                f"{got.dtype} != expected {want_dtype}")
        if m_shapes is not None and tuple(m_shapes[i]) != tuple(got.shape):
            raise ValueError(
                f"checkpoint {path}, {leaf_name(i)}: manifest shape "
                f"{tuple(m_shapes[i])} != stored {tuple(got.shape)} — "
                f"manifest and arrays.npz disagree (partial overwrite?)")
        if m_dtypes is not None and i < len(m_dtypes) and \
                np.dtype(m_dtypes[i]) != np.dtype(got.dtype):
            raise ValueError(
                f"checkpoint {path}, {leaf_name(i)}: manifest dtype "
                f"{m_dtypes[i]} != stored {got.dtype} — manifest and "
                f"arrays.npz disagree (partial overwrite?)")
        loaded.append(got)
    tree = jax.tree_util.tree_unflatten(treedef, loaded)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
    return tree, manifest["extras"]


def wait_for_async(thread):
    if thread is not None:
        thread.join()
