"""Fault-tolerant checkpointing: sharded npz + manifest, async writes, exact
resume, and **elastic reshard** — a checkpoint written on one mesh restores
onto any other mesh shape (the elastic-scaling path, DESIGN §6).

Format (directory per step):
    step_000123/
        manifest.json      — pytree structure, shapes, dtypes, step, extras
        arrays.npz         — flat {index: ndarray}, written atomically
A checkpoint is only visible once `COMMITTED` exists (crash-safe).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def tree_to_host(tree):
    return jax.tree_util.tree_map(lambda l: np.asarray(l), tree)


def save_checkpoint(ckpt_dir: str, step: int, tree, *, extras: dict | None
                    = None, async_write: bool = False):
    """Write checkpoint; with async_write=True the host copy happens on the
    calling thread (cheap device→host) and the disk write on a daemon thread
    (straggler mitigation: training never blocks on the filesystem)."""
    host_tree = tree_to_host(tree)

    def write():
        path = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        leaves, treedef = _flatten(host_tree)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{str(i): l for i, l in enumerate(leaves)})
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(host_tree).serialize_using_proto().hex()
            if hasattr(jax.tree_util.tree_structure(host_tree),
                       "serialize_using_proto") else None,
            "paths": [str(p) for p, _ in
                      jax.tree_util.tree_flatten_with_path(host_tree)[0]],
            "shapes": [list(l.shape) for l in leaves],
            "dtypes": [str(l.dtype) for l in leaves],
            "extras": extras or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        open(os.path.join(tmp, "COMMITTED"), "w").close()
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and \
                os.path.exists(os.path.join(ckpt_dir, d, "COMMITTED")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like_tree, *,
                       shardings=None):
    """Restore into the structure of `like_tree`. With `shardings` (a pytree
    of NamedSharding for a possibly *different* mesh) arrays are device_put
    shard-by-shard — this is the elastic reshard path."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    assert os.path.exists(os.path.join(path, "COMMITTED")), path
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    loaded = [data[str(i)] for i in range(len(leaves))]
    for want, got in zip(leaves, loaded):
        assert tuple(want.shape) == tuple(got.shape), \
            f"shape mismatch {want.shape} vs {got.shape}"
    tree = jax.tree_util.tree_unflatten(treedef, loaded)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    return tree, manifest["extras"]


def wait_for_async(thread):
    if thread is not None:
        thread.join()
