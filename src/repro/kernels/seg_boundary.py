"""Pallas TPU kernel: segment boundaries + in-block prefix sums over sorted
rows — the dense-ranking step after every sort (paper steps 1 & 3).

Per block of T rows: flag[i] = any(row[i] != row[i-1]) (block-local; the
wrapper stitches the T-boundaries), plus the block-inclusive cumsum of flags
and the block total, so the wrapper finishes global dense ranks with one tiny
exclusive scan over block totals.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _seg_kernel(rows_ref, flags_ref, csum_ref, total_ref, *, num_keys: int):
    x = rows_ref[...]                                    # [T, W]
    prev = jnp.concatenate([x[:1], x[:-1]], axis=0)
    neq = jnp.zeros(x.shape[0], jnp.bool_)
    for c in range(num_keys):
        neq = neq | (x[:, c] != prev[:, c])
    # block-local convention: the first row of every block is a boundary;
    # the wrapper stitches true cross-block boundaries.
    neq = neq.at[0].set(True)
    flags = neq.astype(jnp.int32)
    flags_ref[...] = flags
    cs = jnp.cumsum(flags)
    csum_ref[...] = cs.astype(jnp.int32)
    total_ref[...] = cs[-1:].astype(jnp.int32)


def seg_boundary_pallas(rows: jnp.ndarray, num_keys: int | None = None,
                        block: int = 512, interpret: bool = True):
    """rows int32[N, W] sorted → (flags int32[N], csum int32[N],
    totals int32[N//block]). N multiple of block."""
    n, W = rows.shape
    assert n % block == 0
    num_keys = num_keys or W
    grid = (n // block,)
    return pl.pallas_call(
        functools.partial(_seg_kernel, num_keys=num_keys),
        grid=grid,
        in_specs=[pl.BlockSpec((block, W), lambda p: (p, 0))],
        out_specs=[pl.BlockSpec((block,), lambda p: (p,)),
                   pl.BlockSpec((block,), lambda p: (p,)),
                   pl.BlockSpec((1,), lambda p: (p,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n // block,), jnp.int32)],
        interpret=interpret,
    )(rows)
