"""jit'd public wrappers for the Pallas kernels.

`interpret=True` executes kernel bodies in Python on CPU (the validation
mode for this container); on TPU pass interpret=False for compiled Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .bitonic_stage import bitonic_sort_pallas, bitonic_stage_pallas
from .radix_hist import radix_histogram_pallas
from .seg_boundary import seg_boundary_pallas


@functools.partial(jax.jit, static_argnames=("n_bins", "block", "interpret"))
def radix_histogram(digits, n_bins: int, block: int = 1024,
                    interpret: bool = True):
    """Global histogram: per-block MXU histograms + reduction."""
    n = digits.shape[0]
    pad = (-n) % block
    if pad:
        digits = jnp.concatenate(
            [digits, jnp.full((pad,), n_bins, digits.dtype)])
    per_block = radix_histogram_pallas(digits, n_bins + (1 if pad else 0),
                                       block=block, interpret=interpret)
    hist = jnp.sum(per_block, axis=0)
    return hist[:n_bins]


@functools.partial(jax.jit, static_argnames=("num_keys", "block",
                                             "interpret"))
def dense_rank_sorted(rows, num_keys: int | None = None, block: int = 512,
                      interpret: bool = True):
    """Dense ranks of lexicographically sorted rows [N, W]:
    kernel computes block-local boundaries/cumsums, wrapper stitches blocks.

    Returns (ranks int32[N], num_distinct int32[])."""
    n, W = rows.shape
    num_keys = num_keys or W
    pad = (-n) % block
    if pad:
        filler = jnp.broadcast_to(rows[-1:], (pad, W))
        rows_p = jnp.concatenate([rows, filler], axis=0)
    else:
        rows_p = rows
    flags, csum, totals = seg_boundary_pallas(
        rows_p, num_keys=num_keys, block=block, interpret=interpret)
    nb = rows_p.shape[0] // block
    # stitch: true cross-block boundary = rows differ across the block edge
    edge_prev = rows_p[block - 1::block][: nb - 1] if nb > 1 else None
    base = jnp.cumsum(totals) - totals                 # exclusive block offs
    if nb > 1:
        edge_next = rows_p[block::block]
        same = jnp.ones(nb - 1, jnp.bool_)
        for c in range(num_keys):
            same = same & (edge_prev[:, c] == edge_next[:, c])
        # block b's local flag[0] forced True; if edge rows equal, every rank
        # inside block b over-counts by 1 from that false boundary.
        corr = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                jnp.cumsum(same.astype(jnp.int32))])
        base = base - corr
    ranks = (base[:, None] + csum.reshape(nb, block) - 1).reshape(-1)
    ranks = ranks[:n]
    return ranks, ranks[-1] + 1


@functools.partial(jax.jit, static_argnames=("k", "j", "num_keys", "tile",
                                             "interpret"))
def bitonic_stage(rows, k: int, j: int, num_keys: int | None = None,
                  tile: int = 256, interpret: bool = True):
    return bitonic_stage_pallas(rows, k, j, tile=tile, num_keys=num_keys,
                                interpret=interpret)


def bitonic_sort(rows, num_keys: int | None = None, tile: int = 256,
                 interpret: bool = True):
    return bitonic_sort_pallas(rows, num_keys=num_keys, tile=tile,
                               interpret=interpret)
