"""jit'd public wrappers for the Pallas (Mosaic TPU) kernels.

These are the compiled counterparts of the sort/rank primitives the
suffix-array hot path is built from (see docs/architecture.md):

* `radix_histogram`   — per-block histograms + reduction (radix passes);
* `dense_rank_sorted` — dense ranks of lexicographically sorted rows, the
  step after every sort in the paper's Steps 1 & 3
  (`repro.core.dcv_jax` routes its sample ranking through this when
  ``sort_impl="pallas"``);
* `bitonic_stage` / `bitonic_sort` — one compare-exchange stage / a full
  row sort of the fused Lemma-1 payload.

`interpret=True` executes kernel bodies in Python on CPU — the validation
mode for this container, exercised by `tests/kernels` and the small-n
cases of `tests/api/test_sort_impl.py`. On TPU pass ``interpret=False``
for compiled Mosaic (`repro.core.compat.pallas_available` tells you which
regime the current host is in).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .bitonic_stage import bitonic_sort_pallas, bitonic_stage_pallas
from .radix_hist import radix_histogram_pallas
from .seg_boundary import seg_boundary_pallas


@functools.partial(jax.jit, static_argnames=("n_bins", "block", "interpret"))
def radix_histogram(digits, n_bins: int, block: int = 1024,
                    interpret: bool = True):
    """Global histogram of `digits` (int32[N], values in [0, n_bins)).

    Pads N up to a multiple of `block` (the pad digit gets a scratch bin
    that is dropped), computes per-block MXU histograms in the kernel, and
    reduces them. Returns int32[n_bins]."""
    n = digits.shape[0]
    pad = (-n) % block
    if pad:
        digits = jnp.concatenate(
            [digits, jnp.full((pad,), n_bins, digits.dtype)])
    per_block = radix_histogram_pallas(digits, n_bins + (1 if pad else 0),
                                       block=block, interpret=interpret)
    hist = jnp.sum(per_block, axis=0)
    return hist[:n_bins]


@functools.partial(jax.jit, static_argnames=("num_keys", "block",
                                             "interpret"))
def dense_rank_sorted(rows, num_keys: int | None = None, block: int = 512,
                      interpret: bool = True):
    """Dense ranks of lexicographically sorted rows [N, W]:
    kernel computes block-local boundaries/cumsums, wrapper stitches blocks.

    Rows must already be sorted by their first `num_keys` columns (default:
    all). Equal rows share a rank; ranks are dense (0..num_distinct-1).

    Returns (ranks int32[N], num_distinct int32[])."""
    n, W = rows.shape
    num_keys = num_keys or W
    pad = (-n) % block
    if pad:
        filler = jnp.broadcast_to(rows[-1:], (pad, W))
        rows_p = jnp.concatenate([rows, filler], axis=0)
    else:
        rows_p = rows
    flags, csum, totals = seg_boundary_pallas(
        rows_p, num_keys=num_keys, block=block, interpret=interpret)
    nb = rows_p.shape[0] // block
    # stitch: true cross-block boundary = rows differ across the block edge
    edge_prev = rows_p[block - 1::block][: nb - 1] if nb > 1 else None
    base = jnp.cumsum(totals) - totals                 # exclusive block offs
    if nb > 1:
        edge_next = rows_p[block::block]
        same = jnp.ones(nb - 1, jnp.bool_)
        for c in range(num_keys):
            same = same & (edge_prev[:, c] == edge_next[:, c])
        # block b's local flag[0] forced True; if edge rows equal, every rank
        # inside block b over-counts by 1 from that false boundary.
        corr = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                jnp.cumsum(same.astype(jnp.int32))])
        base = base - corr
    ranks = (base[:, None] + csum.reshape(nb, block) - 1).reshape(-1)
    ranks = ranks[:n]
    return ranks, ranks[-1] + 1


@functools.partial(jax.jit, static_argnames=("k", "j", "num_keys", "tile",
                                             "interpret"))
def bitonic_stage(rows, k: int, j: int, num_keys: int | None = None,
                  tile: int = 256, interpret: bool = True):
    """One bitonic compare-exchange stage (k, j) over rows int32[N, W].

    N must be a power of two; rows are compared lexicographically on their
    first `num_keys` columns (default: all). Element i exchanges with i^j,
    ascending iff (i & k) == 0 — `repro.core.bitonic._stage_schedule`
    enumerates the (k, j) pairs of a full sort."""
    return bitonic_stage_pallas(rows, k, j, tile=tile, num_keys=num_keys,
                                interpret=interpret)


def bitonic_sort(rows, num_keys: int | None = None, tile: int = 256,
                 interpret: bool = True):
    """Full bitonic row sort: all (k, j) stages of `bitonic_stage` in
    sequence. rows int32[N, W] with N a power of two; sorts ascending by
    the first `num_keys` columns (append a unique index column to make the
    order total — `repro.core.dcv_jax` does exactly that for its
    ``sort_impl="pallas"`` window sort)."""
    return bitonic_sort_pallas(rows, num_keys=num_keys, tile=tile,
                               interpret=interpret)
