"""Pallas TPU kernel: per-block radix histogram via one-hot matmul.

Counting sort's scatter-increment is TPU-hostile (serialised scatter units).
The TPU-native reformulation: one-hot-encode the digit block and reduce with
a matmul — the reduction runs on the MXU at full rate (DESIGN §3.2). This is
the inner loop of every counting/radix sort in the paper (steps 1–3).

Grid: one program per block of `block` digits; BlockSpec keeps one digit
block + one histogram row in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(digits_ref, out_ref, *, n_bins: int):
    d = digits_ref[...]                                  # [block] int32
    block = d.shape[0]
    bins = jax.lax.broadcasted_iota(jnp.int32, (block, n_bins), 1)
    onehot = (d[:, None] == bins).astype(jnp.float32)    # [block, n_bins]
    ones = jnp.ones((1, block), jnp.float32)
    # MXU matmul reduction: [1, block] @ [block, n_bins] → [1, n_bins]
    hist = jnp.dot(ones, onehot, preferred_element_type=jnp.float32)
    out_ref[...] = hist.astype(jnp.int32)


def radix_histogram_pallas(digits: jnp.ndarray, n_bins: int,
                           block: int = 1024, interpret: bool = True):
    """digits int32[N] (N multiple of block) → int32[N//block, n_bins]."""
    n = digits.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    return pl.pallas_call(
        functools.partial(_hist_kernel, n_bins=n_bins),
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, n_bins), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n // block, n_bins), jnp.int32),
        interpret=interpret,
    )(digits)
