"""Pallas TPU kernel: one bitonic compare-exchange stage over multi-column
payload rows (the inner step of the paper's fused Lemma-1 comparator sort,
DESIGN §3.3).

Stage (k, j): element i exchanges with i^j, ascending iff (i & k) == 0.
Two tiling regimes:
  * j >= tile: partners live in different tiles → the grid walks *pairs* of
    tiles (low tile t, high tile t + j/T); two input refs per program.
  * j <  tile: partners are inside one tile → single-ref program, partner
    via in-tile reshape.
The comparator is lexicographic over the first `num_keys` columns (unrolled
at trace time — the payload width v + |D| + 3 is a compile-time constant).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lex_lt(a, b, num_keys: int):
    """Strict lexicographic a < b over [T, W] int32 tiles (unrolled)."""
    lt = jnp.zeros(a.shape[:-1], jnp.bool_)
    eq = jnp.ones(a.shape[:-1], jnp.bool_)
    for c in range(num_keys):
        ac, bc = a[..., c], b[..., c]
        lt = lt | (eq & (ac < bc))
        eq = eq & (ac == bc)
    return lt


def _cross_tile_kernel(low_ref, high_ref, low_out, high_out, *,
                       k: int, tile: int, num_keys: int, j: int,
                       n_low_per_run: int):
    pid = pl.program_id(0)
    run = pid // n_low_per_run
    off = pid % n_low_per_run
    low_tile_idx = run * (2 * n_low_per_run) + off
    base = low_tile_idx * tile
    idx = base + jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
    up = (idx & k) == 0
    a = low_ref[...]
    b = high_ref[...]
    a_lt_b = _lex_lt(a, b, num_keys)
    keep = (a_lt_b == up)[:, None]
    low_out[...] = jnp.where(keep, a, b)
    high_out[...] = jnp.where(keep, b, a)


def _in_tile_kernel(x_ref, out_ref, *, k: int, j: int, tile: int,
                    num_keys: int):
    pid = pl.program_id(0)
    x = x_ref[...]                                       # [tile, W]
    base = pid * tile
    idx = base + jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
    partner = (idx ^ j) - base                           # in-tile offset
    other = x[partner]
    up = (idx & k) == 0
    lower = (idx & j) == 0
    lt = _lex_lt(x, other, num_keys)
    keep = ((lt == lower) == up)[:, None]
    out_ref[...] = jnp.where(keep, x, other)


def bitonic_stage_pallas(rows: jnp.ndarray, k: int, j: int, *,
                         tile: int = 256, num_keys: int | None = None,
                         interpret: bool = True) -> jnp.ndarray:
    """Apply one (k, j) compare-exchange stage. rows int32[N, W], N pow2."""
    n, W = rows.shape
    assert n & (n - 1) == 0, n
    tile = min(tile, n)
    num_keys = num_keys or W
    if j >= tile:
        n_tiles = n // tile
        j_t = j // tile
        n_low = n_tiles // 2
        n_low_per_run = j_t

        def low_map(p):
            run, off = p // n_low_per_run, p % n_low_per_run
            return (run * 2 * n_low_per_run + off, 0)

        def high_map(p):
            run, off = p // n_low_per_run, p % n_low_per_run
            return (run * 2 * n_low_per_run + off + j_t, 0)

        low, high = pl.pallas_call(
            functools.partial(_cross_tile_kernel, k=k, tile=tile, j=j,
                              num_keys=num_keys,
                              n_low_per_run=n_low_per_run),
            grid=(n_low,),
            in_specs=[pl.BlockSpec((tile, W), low_map),
                      pl.BlockSpec((tile, W), high_map)],
            out_specs=[pl.BlockSpec((tile, W), low_map),
                       pl.BlockSpec((tile, W), high_map)],
            out_shape=[jax.ShapeDtypeStruct((n, W), jnp.int32)] * 2,
            interpret=interpret,
        )(rows, rows)
        # low/high outputs each hold their half; merge by position parity
        idx = jnp.arange(n) // tile
        is_low = (idx % (2 * j_t)) < j_t
        return jnp.where(is_low[:, None], low, high)

    return pl.pallas_call(
        functools.partial(_in_tile_kernel, k=k, j=j, tile=tile,
                          num_keys=num_keys),
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile, W), lambda p: (p, 0))],
        out_specs=pl.BlockSpec((tile, W), lambda p: (p, 0)),
        out_shape=jax.ShapeDtypeStruct((n, W), jnp.int32),
        interpret=interpret,
    )(rows)


def bitonic_sort_pallas(rows: jnp.ndarray, *, num_keys: int | None = None,
                        tile: int = 256, interpret: bool = True):
    """Full sort via repeated stages (tests/bench; the production sort fuses
    stages in repro.core.bitonic — this kernel is the per-stage hot loop)."""
    n = rows.shape[0]
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            rows = bitonic_stage_pallas(rows, k, j, tile=tile,
                                        num_keys=num_keys,
                                        interpret=interpret)
            j //= 2
        k *= 2
    return rows
