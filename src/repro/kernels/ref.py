"""Pure-jnp oracles for every Pallas kernel (the assert_allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def radix_histogram_ref(digits: jnp.ndarray, n_bins: int, block: int):
    n = digits.shape[0]
    d = digits.reshape(n // block, block)
    return jnp.sum(
        d[:, :, None] == jnp.arange(n_bins, dtype=digits.dtype)[None, None, :],
        axis=1).astype(jnp.int32)


def _lex_lt_ref(a, b, num_keys):
    lt = jnp.zeros(a.shape[:-1], jnp.bool_)
    eq = jnp.ones(a.shape[:-1], jnp.bool_)
    for c in range(num_keys):
        lt = lt | (eq & (a[..., c] < b[..., c]))
        eq = eq & (a[..., c] == b[..., c])
    return lt


def bitonic_stage_ref(rows: jnp.ndarray, k: int, j: int,
                      num_keys: int | None = None):
    n, W = rows.shape
    num_keys = num_keys or W
    idx = jnp.arange(n)
    partner = idx ^ j
    other = rows[partner]
    up = (idx & k) == 0
    lower = idx < partner
    lt = _lex_lt_ref(rows, other, num_keys)
    keep = (lt == lower) == up
    return jnp.where(keep[:, None], rows, other)


def bitonic_sort_ref(rows: jnp.ndarray, num_keys: int | None = None):
    """Oracle: lexsort by the key columns (requires a strict total order —
    give rows a unique final key column)."""
    import numpy as np
    r = np.asarray(rows)
    num_keys = num_keys or r.shape[1]
    order = np.lexsort(tuple(r[:, c] for c in range(num_keys - 1, -1, -1)))
    return jnp.asarray(r[order])


def seg_boundary_ref(rows: jnp.ndarray, num_keys: int | None = None,
                     block: int = 512):
    n, W = rows.shape
    num_keys = num_keys or W
    prev = jnp.concatenate([rows[:1], rows[:-1]], axis=0)
    neq = jnp.zeros(n, jnp.bool_)
    for c in range(num_keys):
        neq = neq | (rows[:, c] != prev[:, c])
    nb = n // block
    neq = neq.reshape(nb, block)
    neq = neq.at[:, 0].set(True)        # block-local convention
    neq = neq.at[0, 0].set(True)
    flags = neq.reshape(-1).astype(jnp.int32)
    csum = jnp.cumsum(neq, axis=1).reshape(-1).astype(jnp.int32)
    totals = jnp.sum(neq, axis=1).astype(jnp.int32)
    return flags, csum, totals
