"""LCP array (Kasai's algorithm) — the bridge from the paper's suffix arrays
to the LM data pipeline (exact-substring dedup, n-gram stats)."""
from __future__ import annotations

import numpy as np


def lcp_kasai(x, sa) -> np.ndarray:
    """LCP[i] = longest common prefix of suffixes sa[i-1], sa[i]; LCP[0]=0.

    O(n) (Kasai et al. 2001)."""
    x = np.asarray(x)
    sa = np.asarray(sa)
    n = len(x)
    lcp = np.zeros(n, dtype=np.int64)
    rank = np.empty(n, dtype=np.int64)
    rank[sa] = np.arange(n)
    h = 0
    for i in range(n):
        r = rank[i]
        if r > 0:
            j = sa[r - 1]
            while i + h < n and j + h < n and x[i + h] == x[j + h]:
                h += 1
            lcp[r] = h
            if h > 0:
                h -= 1
        else:
            h = 0
    return lcp


def repeated_substring_spans(x, sa, lcp, min_len: int):
    """All positions covered by a substring of length ≥ min_len that occurs
    at least twice (the Lee et al. 2022 dedup criterion). Returns a sorted
    list of (start, end) half-open spans, merged."""
    n = len(sa)
    spans = []
    for r in range(1, n):
        l = int(lcp[r])
        if l >= min_len:
            for start in (int(sa[r]), int(sa[r - 1])):
                spans.append((start, start + l))
    if not spans:
        return []
    spans.sort()
    merged = [spans[0]]
    for s, e in spans[1:]:
        if s <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], e))
        else:
            merged.append((s, e))
    return merged


def ngram_counts(x, sa, lcp, k: int):
    """Number of distinct k-grams (via SA+LCP: Σ max(0, run starts))."""
    n = len(sa)
    distinct = 0
    for r in range(n):
        if int(sa[r]) + k <= n and int(lcp[r]) < k:
            distinct += 1
    return distinct
