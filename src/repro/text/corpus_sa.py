"""DEPRECATED shim — use `repro.api.SuffixArrayIndex` instead.

The multi-document sentinel-separator corpus layout and all queries now
live in `repro.api.index.SuffixArrayIndex` (`from_docs`, `count`, `locate`,
`cross_doc_duplicates`). This module keeps the old `CorpusSA` struct and
free functions working on top of the facade for existing callers; each
entry point emits a DeprecationWarning.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

from ..api import SAOptions, SuffixArrayIndex, encode_docs


def _deprecated(old: str, new: str) -> None:
    warnings.warn(f"repro.text.corpus_sa.{old} is deprecated; use {new}",
                  DeprecationWarning, stacklevel=3)


@dataclass
class CorpusSA:
    text: np.ndarray          # concatenated, separator-encoded corpus
    sa: np.ndarray            # suffix array over `text`
    doc_starts: np.ndarray    # start offset of each document in `text`
    n_docs: int
    sep_count: int            # separators (excluded from queries)

    def doc_of(self, pos):
        """Document index owning text position(s) `pos` (scalar or array)."""
        return self.as_index().doc_of(pos)

    def as_index(self) -> SuffixArrayIndex:
        """The `repro.api.SuffixArrayIndex` view of this struct."""
        return SuffixArrayIndex(self.text, self.sa,
                                doc_starts=self.doc_starts,
                                shift=self.n_docs)


def build_corpus_sa(docs: list, sa_builder=None,
                    options: SAOptions | None = None) -> CorpusSA:
    """DEPRECATED: use `SuffixArrayIndex.from_docs(docs, options)`.

    `sa_builder` (legacy) is honoured when given: it is called directly on
    the encoded text. Otherwise the facade picks the backend from `options`
    (default: auto → jax, or bsp when a mesh is set)."""
    _deprecated("build_corpus_sa", "repro.api.SuffixArrayIndex.from_docs")
    n_docs = len(docs)
    if n_docs == 0:
        return CorpusSA(np.zeros(0, np.int32), np.zeros(0, np.int32),
                        np.zeros(0, np.int64), 0, 0)
    if sa_builder is not None:
        text, starts, n_docs = encode_docs(docs)
        sa = np.asarray(sa_builder(text), np.int64)
        index = SuffixArrayIndex(text, sa, doc_starts=starts, shift=n_docs)
    else:
        index = SuffixArrayIndex.from_docs(docs, options)
    return CorpusSA(text=index.text.astype(np.int32),
                    sa=index.sa.astype(np.int32),
                    doc_starts=index.doc_starts,
                    n_docs=index.n_docs, sep_count=index.sep_count)


def count_occurrences(csa: CorpusSA, pattern) -> int:
    """DEPRECATED: use `SuffixArrayIndex.count(pattern)`.

    Keeps the *legacy* query semantics this module always had, which the
    facade has since tightened (see docs/api.md "Migrating from
    repro.text.corpus_sa"): an empty pattern counts 0 (the facade counts
    n — empty prefix of every suffix) and out-of-alphabet values count 0
    (the facade raises ValueError)."""
    _deprecated("count_occurrences", "repro.api.SuffixArrayIndex.count")
    idx = csa.as_index()
    pat = np.asarray(pattern, np.int64).ravel()
    if len(pat) == 0:
        return 0
    if idx.n and len(pat) and int(pat.max()) >= idx.sigma:
        return 0
    return idx.count(pattern)


def cross_doc_duplicates(csa: CorpusSA, min_len: int):
    """DEPRECATED: use `SuffixArrayIndex.cross_doc_duplicates(min_len)`."""
    _deprecated("cross_doc_duplicates",
                "repro.api.SuffixArrayIndex.cross_doc_duplicates")
    return csa.as_index().cross_doc_duplicates(min_len)
