"""Multi-document corpus suffix arrays: concatenate documents with unique
low sentinels so suffixes never compare across document boundaries, then
build ONE suffix array for the whole corpus (the layout used by Lee et al.
dedup across documents and by cross-document n-gram statistics).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.dcv_jax import suffix_array_jax


@dataclass
class CorpusSA:
    text: np.ndarray          # concatenated, separator-encoded corpus
    sa: np.ndarray            # suffix array over `text`
    doc_starts: np.ndarray    # start offset of each document in `text`
    n_docs: int
    sep_count: int            # separators (excluded from queries)

    def doc_of(self, pos: int) -> int:
        """Document index owning text position pos."""
        return int(np.searchsorted(self.doc_starts, pos, side="right") - 1)


def build_corpus_sa(docs: list, sa_builder=suffix_array_jax) -> CorpusSA:
    """docs: list of int arrays (values ≥ 0). Documents are joined with
    distinct ascending separators placed BELOW the data alphabet, so (a) no
    suffix comparison crosses a document boundary (the separator differs),
    and (b) separator suffixes cluster at the front of the SA where they are
    cheap to skip."""
    n_docs = len(docs)
    if n_docs == 0:
        return CorpusSA(np.zeros(0, np.int32), np.zeros(0, np.int32),
                        np.zeros(0, np.int64), 0, 0)
    # shift data up by n_docs; separator for doc i gets value i
    parts = []
    starts = []
    off = 0
    for i, d in enumerate(docs):
        d = np.asarray(d, np.int64) + n_docs
        starts.append(off)
        parts.append(d)
        parts.append(np.asarray([i], np.int64))
        off += len(d) + 1
    text = np.concatenate(parts)
    sa = np.asarray(sa_builder(text), np.int64)
    return CorpusSA(text=text.astype(np.int32), sa=sa.astype(np.int32),
                    doc_starts=np.asarray(starts, np.int64),
                    n_docs=n_docs, sep_count=n_docs)


def count_occurrences(csa: CorpusSA, pattern) -> int:
    """Number of occurrences of `pattern` across all documents, via binary
    search on the suffix array — O(|pattern| log n)."""
    pat = np.asarray(pattern, np.int64) + csa.n_docs
    text, sa = csa.text.astype(np.int64), csa.sa
    n, m = len(text), len(pat)

    def cmp_at(i):
        """-1/0/+1 of suffix i vs pattern (prefix compare)."""
        seg = text[i:i + m]
        if len(seg) < m:
            pad = np.full(m - len(seg), -1, np.int64)
            seg = np.concatenate([seg, pad])
        for a, b in zip(seg, pat):
            if a < b:
                return -1
            if a > b:
                return 1
        return 0

    lo, hi = 0, n
    while lo < hi:                       # first suffix ≥ pattern
        mid = (lo + hi) // 2
        if cmp_at(int(sa[mid])) < 0:
            lo = mid + 1
        else:
            hi = mid
    first = lo
    lo, hi = first, n
    while lo < hi:                       # first suffix > pattern
        mid = (lo + hi) // 2
        if cmp_at(int(sa[mid])) <= 0:
            lo = mid + 1
        else:
            hi = mid
    return lo - first


def cross_doc_duplicates(csa: CorpusSA, min_len: int):
    """(doc_i, doc_j, length) for maximal repeats ≥ min_len that span two
    DIFFERENT documents (contamination check)."""
    from .lcp import lcp_kasai
    lcp = lcp_kasai(csa.text, csa.sa)
    out = []
    for r in range(1, len(csa.sa)):
        l = int(lcp[r])
        if l >= min_len:
            a, b = int(csa.sa[r - 1]), int(csa.sa[r])
            da, db = csa.doc_of(a), csa.doc_of(b)
            if da != db:
                out.append((min(da, db), max(da, db), l))
    return out
