"""Exact-substring deduplication powered by the paper's suffix arrays
(Lee et al. 2022 "Deduplicating Training Data Makes Language Models Better"
uses suffix arrays for exactly this; our distributed builder makes the SA
step scale with the training mesh)."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.dcv_jax import suffix_array_jax
from .lcp import lcp_kasai, repeated_substring_spans


@dataclass
class DedupReport:
    n_chars: int
    dup_chars: int
    spans: list

    @property
    def dup_fraction(self) -> float:
        return self.dup_chars / max(self.n_chars, 1)


def find_duplicates(corpus: np.ndarray, min_len: int = 32,
                    sa_builder=suffix_array_jax) -> DedupReport:
    corpus = np.asarray(corpus)
    sa = sa_builder(corpus)
    lcp = lcp_kasai(corpus, sa)
    spans = repeated_substring_spans(corpus, sa, lcp, min_len)
    dup = sum(e - s for s, e in spans)
    return DedupReport(n_chars=len(corpus), dup_chars=int(dup), spans=spans)


def dedup_corpus(corpus: np.ndarray, min_len: int = 32,
                 sa_builder=suffix_array_jax, keep_first: bool = True
                 ) -> tuple[np.ndarray, DedupReport]:
    """Remove all-but-first occurrences of repeated substrings ≥ min_len.

    Conservative variant: drops later duplicate spans wholesale (the Lee et
    al. policy); returns (deduped_corpus, report)."""
    corpus = np.asarray(corpus)
    report = find_duplicates(corpus, min_len, sa_builder)
    if not report.spans:
        return corpus, report
    # keep the FIRST occurrence of each duplicated string: recompute spans
    # keyed by content start order — simple policy: sort spans, always keep
    # the first span of an overlap chain, drop the rest.
    drop = np.zeros(len(corpus), dtype=bool)
    seen_starts = set()
    sa = sa_builder(corpus)
    lcp = lcp_kasai(corpus, sa)
    for r in range(1, len(sa)):
        l = int(lcp[r])
        if l >= min_len:
            a, b = int(sa[r - 1]), int(sa[r])
            first, later = (a, b) if a < b else (b, a)
            if keep_first:
                drop[later:later + l] = True
            else:
                drop[first:first + l] = True
    out = corpus[~drop]
    return out, report
