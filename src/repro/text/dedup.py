"""Exact-substring deduplication powered by the paper's suffix arrays
(Lee et al. 2022 "Deduplicating Training Data Makes Language Models Better"
uses suffix arrays for exactly this; our distributed builder makes the SA
step scale with the training mesh).

Construction goes through the `repro.api` facade: pass an `SAOptions` to
pick the backend (`jax` by default, `bsp` when the plan carries a mesh).
The legacy `sa_builder=` kwarg still works but is deprecated.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

from ..api import SAOptions, SuffixArrayIndex


@dataclass
class DedupReport:
    n_chars: int
    dup_chars: int
    spans: list

    @property
    def dup_fraction(self) -> float:
        return self.dup_chars / max(self.n_chars, 1)


def _index_of(corpus: np.ndarray, sa_builder, options: SAOptions | None
              ) -> SuffixArrayIndex:
    if sa_builder is not None:
        warnings.warn("dedup(sa_builder=...) is deprecated; pass "
                      "options=SAOptions(backend=...) instead",
                      DeprecationWarning, stacklevel=3)
        return SuffixArrayIndex(corpus, np.asarray(sa_builder(corpus)))
    return SuffixArrayIndex.build(corpus, options)


def find_duplicates(corpus: np.ndarray, min_len: int = 32,
                    sa_builder=None, options: SAOptions | None = None
                    ) -> DedupReport:
    corpus = np.asarray(corpus)
    index = _index_of(corpus, sa_builder, options)
    return report_duplicates(index, min_len)


def report_duplicates(index: SuffixArrayIndex, min_len: int) -> DedupReport:
    """DedupReport from an already-built index (SA/LCP are reused)."""
    spans = index.duplicate_spans(min_len)
    dup = sum(e - s for s, e in spans)
    return DedupReport(n_chars=index.n, dup_chars=int(dup), spans=spans)


def dedup_corpus(corpus: np.ndarray, min_len: int = 32,
                 sa_builder=None, keep_first: bool = True,
                 options: SAOptions | None = None
                 ) -> tuple[np.ndarray, DedupReport]:
    """Remove all-but-first occurrences of repeated substrings ≥ min_len.

    Conservative variant: drops later duplicate spans wholesale (the Lee et
    al. policy); returns (deduped_corpus, report). The SA and LCP are built
    once and shared between the report and the drop mask."""
    corpus = np.asarray(corpus)
    index = _index_of(corpus, sa_builder, options)
    report = report_duplicates(index, min_len)
    if not report.spans:
        return corpus, report
    # keep the FIRST occurrence of each duplicated string: for every
    # SA-adjacent pair with lcp ≥ min_len, drop the later (greater-position)
    # copy. Vectorised interval painting: +1/-1 deltas, cumsum > 0.
    n = index.n
    sa, lcp = index.sa.astype(np.int64), index.lcp
    r = np.flatnonzero(lcp >= min_len)
    r = r[r >= 1]
    a, b = sa[r - 1], sa[r]
    target = np.maximum(a, b) if keep_first else np.minimum(a, b)
    delta = np.zeros(n + 1, np.int64)
    np.add.at(delta, target, 1)
    np.add.at(delta, np.minimum(target + lcp[r], n), -1)
    drop = np.cumsum(delta[:-1]) > 0
    out = corpus[~drop]
    return out, report
