"""Exact-substring deduplication powered by the paper's suffix arrays
(Lee et al. 2022 "Deduplicating Training Data Makes Language Models Better"
uses suffix arrays for exactly this; our distributed builder makes the SA
step scale with the training mesh).

The drop rule (shared by every path)
------------------------------------
A position ``p`` is **flagged** when the ``min_len``-gram starting at ``p``
also occurs at an *earlier* corpus position (``keep_first=True``; the
symmetric rule flags non-latest occurrences for ``keep_first=False``).
The drop mask is the union of ``[p, p + min_len)`` over flagged ``p``.

This is exactly the union of ``[p, p + LPF(p))`` over positions whose
longest previous factor reaches ``min_len``: if a match of length
``L ≥ min_len`` starts at ``p``, the shifted starts ``p+j`` (``j ≤ L -
min_len``) are all flagged too, so the fixed-width intervals tile the whole
``[p, p + L)`` span. Unlike the historical rule (paint the later suffix of
each SA-*adjacent* pair), the gram rule is

* **exact** — every non-leftmost occurrence of a repeat ≥ ``min_len`` is
  dropped, even when three or more occurrences interleave in SA order and
  adjacency skips one; and
* **prefix-stable** — whether ``p`` is dropped depends only on content at
  positions ``≤ p``, so a streaming pass over document shards
  (`repro.data.pipeline.StreamingDedup`) produces byte-identical output to
  a monolithic rebuild of the same corpus. That equality is pinned in
  `tests/train/test_data_plane.py`.

Construction goes through the `repro.api` facade: pass an `SAOptions` to
pick the backend (`jax` by default, `bsp` when the plan carries a mesh).
The legacy `sa_builder=` kwarg still works but is deprecated.

The default threshold is pinned once, here: ``DEDUP_MIN_LEN = 48`` is the
documented default for `dedup_corpus`, `dedup_docs`, and
`repro.data.pipeline.PipelineConfig.dedup_min_len` (they used to disagree,
48 vs 32).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

from ..api import SAOptions, SuffixArrayIndex

#: the one documented default for exact-substring dedup thresholds
#: (Lee et al. 2022 use 50 BPE tokens; 48 is our byte-level pin).
DEDUP_MIN_LEN = 48


@dataclass
class DedupReport:
    n_chars: int
    dup_chars: int            # chars inside repeated regions (incl. firsts)
    spans: list
    dropped_chars: int = 0    # chars actually removed by the drop rule

    @property
    def dup_fraction(self) -> float:
        return self.dup_chars / max(self.n_chars, 1)

    @property
    def dropped_fraction(self) -> float:
        return self.dropped_chars / max(self.n_chars, 1)


def _index_of(corpus: np.ndarray, sa_builder, options: SAOptions | None
              ) -> SuffixArrayIndex:
    if sa_builder is not None:
        warnings.warn("dedup(sa_builder=...) is deprecated; pass "
                      "options=SAOptions(backend=...) instead",
                      DeprecationWarning, stacklevel=3)
        return SuffixArrayIndex(corpus, np.asarray(sa_builder(corpus)))
    return SuffixArrayIndex.build(corpus, options)


def find_duplicates(corpus: np.ndarray, min_len: int = DEDUP_MIN_LEN,
                    sa_builder=None, options: SAOptions | None = None
                    ) -> DedupReport:
    corpus = np.asarray(corpus)
    index = _index_of(corpus, sa_builder, options)
    return report_duplicates(index, min_len)


def report_duplicates(index: SuffixArrayIndex, min_len: int) -> DedupReport:
    """DedupReport from an already-built index (SA/LCP are reused)."""
    spans = index.duplicate_spans(min_len)
    dup = sum(e - s for s, e in spans)
    return DedupReport(n_chars=index.n, dup_chars=int(dup), spans=spans)


def duplicate_gram_flags(index: SuffixArrayIndex, min_len: int,
                         keep_first: bool = True) -> np.ndarray:
    """bool[n] over *encoded* positions: True where the ``min_len``-gram
    starting there also occurs at an earlier (``keep_first=True``) or later
    (``keep_first=False``) encoded position.

    Fully vectorised over the SA + LCP: consecutive SA ranks whose
    pairwise LCP is ≥ ``min_len`` form a *run*, and a run is exactly the
    occurrence set of one ``min_len``-gram (suffixes shorter than the gram
    can never reach the LCP bar, and unique separators stop comparisons at
    document boundaries, so runs never cross documents). Within a run,
    every member except the extreme-position one is flagged. Singleton
    runs flag nothing.
    """
    n = index.n
    flags = np.zeros(n, bool)
    if n == 0 or min_len <= 0 or min_len > n:
        return flags
    sa = index.sa.astype(np.int64)
    lcp = index.lcp
    new_run = np.ones(n, bool)
    new_run[1:] = lcp[1:] < min_len
    run_id = np.cumsum(new_run) - 1
    n_runs = int(run_id[-1]) + 1
    if keep_first:
        extreme = np.full(n_runs, np.iinfo(np.int64).max)
        np.minimum.at(extreme, run_id, sa)
    else:
        extreme = np.full(n_runs, -1)
        np.maximum.at(extreme, run_id, sa)
    flags[sa[sa != extreme[run_id]]] = True
    return flags


def gram_drop_mask(flags: np.ndarray, min_len: int) -> np.ndarray:
    """Union of ``[p, p + min_len)`` over flagged positions, as bool[n].

    Vectorised interval painting: +1/−1 deltas, cumsum > 0. Flagged
    positions always carry ``min_len`` real characters (that is what got
    them flagged), so the painted interval never spills past a document
    separator or the end of the text.
    """
    n = len(flags)
    at = np.flatnonzero(flags)
    delta = np.zeros(n + 1, np.int64)
    np.add.at(delta, at, 1)
    np.add.at(delta, np.minimum(at + min_len, n), -1)
    return np.cumsum(delta[:n]) > 0


def dedup_corpus(corpus: np.ndarray, min_len: int = DEDUP_MIN_LEN,
                 sa_builder=None, keep_first: bool = True,
                 options: SAOptions | None = None
                 ) -> tuple[np.ndarray, DedupReport]:
    """Remove all-but-one occurrence of repeated substrings ≥ ``min_len``.

    ``keep_first=True`` (the Lee et al. policy) keeps the earliest copy of
    each repeat and drops every later one; ``keep_first=False`` keeps the
    latest. Returns ``(deduped_corpus, report)``; the report's ``spans``
    still describe every repeated region (including the kept copy), while
    ``dropped_chars`` counts what was actually removed. The SA and LCP are
    built once and shared between the report and the drop mask. An empty
    corpus round-trips to an empty corpus with an all-zero report.
    """
    corpus = np.asarray(corpus)
    index = _index_of(corpus, sa_builder, options)
    report = report_duplicates(index, min_len)
    if not report.spans:
        return corpus, report
    flags = duplicate_gram_flags(index, min_len, keep_first=keep_first)
    drop = gram_drop_mask(flags, min_len)
    report.dropped_chars = int(drop.sum())
    return corpus[~drop], report


def dedup_docs(docs, min_len: int = DEDUP_MIN_LEN, *,
               options: SAOptions | None = None, sigma: int | None = None,
               keep_first: bool = True
               ) -> tuple[list, DedupReport]:
    """Document-aware monolithic dedup: one suffix array over all ``docs``
    (sentinel-separator layout, so no repeat ever spans a document
    boundary), the gram drop rule applied in global document order.

    Returns ``(deduped_docs, report)`` where ``deduped_docs[i]`` is
    ``docs[i]`` with its dropped positions removed. This is the
    whole-corpus reference the streaming data plane
    (`repro.data.pipeline.StreamingDedup`) is byte-identical to.
    """
    index = SuffixArrayIndex.from_docs(docs, options, sigma=sigma)
    report = report_duplicates(index, min_len)
    report.n_chars = int(sum(len(np.asarray(d).ravel()) for d in docs))
    flags = duplicate_gram_flags(index, min_len, keep_first=keep_first)
    drop = gram_drop_mask(flags, min_len)
    report.dropped_chars = int(drop.sum())
    out = []
    ends = index._doc_ends
    for s, e in zip(index.doc_starts, ends):
        payload = index.text[s:e] - index.shift
        out.append(payload[~drop[s:e]])
    return out, report
