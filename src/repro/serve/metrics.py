"""Serving-tier metrics: latency histograms, batch shape distributions,
admission counters.

Every claim the serving tier makes is measured here, request by request:

* three per-request latency components, each its own `Histogram` —
  **queue wait** (arrival → batch dispatched to the device loop),
  **service** (dispatch → results resolved), and **total** (arrival →
  resolved; under open-loop load this starts at the request's *scheduled*
  arrival time, so submission-loop lateness counts against the server
  instead of being silently forgiven — the coordinated-omission guard);
* coalescing effectiveness — the distribution of coalesced batch sizes
  and of bucket occupancy (`n_queries / B_pad`, how full the padded
  pow2 bucket actually was);
* admission outcomes — monotone counters for submitted / accepted /
  rejected / shed / completed.

Percentiles of an empty histogram are ``None`` (absent), never 0.0 — the
same rule as `repro.api.QuerySession.latency_summary` — so aggregating a
quiet window cannot drag an SLO report toward fictitious zeros.
"""
from __future__ import annotations

import threading

import numpy as np


class Histogram:
    """Append-only sample store with percentile summaries.

    Raw float samples are kept (serving runs are bounded — minutes, not
    days — so exact percentiles beat bucketed approximations); `add` is
    thread-safe via one lock shared with the summary reader.
    """

    __slots__ = ("name", "_values", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._values: list[float] = []
        self._lock = threading.Lock()

    def add(self, value: float) -> None:
        with self._lock:
            self._values.append(float(value))

    def extend(self, values) -> None:
        with self._lock:
            self._values.extend(float(v) for v in values)

    @property
    def count(self) -> int:
        with self._lock:
            return len(self._values)

    def values(self) -> np.ndarray:
        with self._lock:
            return np.asarray(self._values, np.float64)

    def summary(self) -> dict:
        """count/mean/max + p50/p95/p99; absent (None) stats when empty."""
        v = self.values()
        if v.size == 0:
            return {"count": 0, "mean": None, "max": None,
                    "p50": None, "p95": None, "p99": None}
        p50, p95, p99 = np.percentile(v, [50, 95, 99])
        return {"count": int(v.size), "mean": float(v.mean()),
                "max": float(v.max()), "p50": float(p50),
                "p95": float(p95), "p99": float(p99)}


class ServeMetrics:
    """All serving-tier instrumentation for one `SAServer`."""

    #: admission/lifecycle counter names, in reporting order
    #: (gc_pauses: full collections observed while the serving loops ran —
    #: the GC-hygiene regime in `SAServer` keeps it near zero)
    COUNTERS = ("submitted", "accepted", "rejected", "shed", "completed",
                "gc_pauses")

    def __init__(self):
        self.queue_wait_us = Histogram("queue_wait_us")
        self.service_us = Histogram("service_us")
        self.total_us = Histogram("total_us")
        self.batch_size = Histogram("batch_size")
        self.bucket_occupancy = Histogram("bucket_occupancy")
        self._counters = {k: 0 for k in self.COUNTERS}
        self._lock = threading.Lock()

    def bump(self, counter: str, by: int = 1) -> None:
        with self._lock:
            self._counters[counter] += by

    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters[name]

    def counters(self) -> dict:
        with self._lock:
            return dict(self._counters)

    def record_batch(self, size: int, bucket_b: int) -> None:
        """One coalesced batch left for the device: its true size and how
        full the padded pow2 bucket was."""
        self.batch_size.add(size)
        self.bucket_occupancy.add(size / max(bucket_b, 1))

    def snapshot(self) -> dict:
        """One JSON-ready dict with every histogram summary + counters."""
        return {
            "counters": self.counters(),
            "queue_wait_us": self.queue_wait_us.summary(),
            "service_us": self.service_us.summary(),
            "total_us": self.total_us.summary(),
            "batch_size": self.batch_size.summary(),
            "bucket_occupancy": self.bucket_occupancy.summary(),
        }
