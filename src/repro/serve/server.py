"""`SAServer` — the asynchronous serving loop over one suffix-array index.

Data path (one request's life):

    submit(pattern)                      [caller thread]
      validate + encode (ValueError raised synchronously)
      AdmissionController.admit(queue depth, oldest age)
        reject → completed future, Response(status="rejected", retry_after)
        shed   → oldest pending request is evicted, new one admitted
        accept → PendingQuery into the inbox, coalesce thread woken
    coalesce loop                        [thread 1]
      inbox → Coalescer buckets; windows close on full-bucket or
      max-wait deadline → index.stage_encoded (host→device transfer
      STARTS here) → staging queue (depth 1)
    device loop                          [thread 2]
      staging queue → index.ranges_staged runs the kernel(s) on the
      staged buffers → block on results → resolve futures, record
      metrics

The index is either a monolithic `SuffixArrayIndex` (one `QueryBatch`,
one `_ranges_kernel` call) or a `SegmentedIndex` (one staged batch per
segment, counts merged) — the loops only speak the staging protocol.

The staging queue of depth 1 is the double buffer: while the device loop
blocks on batch k's kernel, the coalesce thread encodes and stages batch
k+1, whose host→device transfer rides under the in-flight compute. When
both slots are busy the coalesce thread itself blocks, arrivals pile up
in the inbox, the measured queue depth grows, and admission control sees
the overload — backpressure propagates end to end instead of vanishing
into an unbounded buffer.

Latency accounting is per request: queue wait (arrival → batch left for
the device), service (device pickup → results resolved), total. Under
open-loop load `submit(..., t_arrival=scheduled)` dates the request from
its *scheduled* arrival, so loadgen lateness counts against the server
(no coordinated omission).
"""
from __future__ import annotations

import collections
import gc
import itertools
import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..api.query import _MIN_LEN_BUCKET, pow2_bucket
from .admission import AdmissionController, POLICIES
from .coalescer import Coalescer, PendingQuery
from .metrics import ServeMetrics

__all__ = ["Response", "SAServer", "POLICIES"]

#: EMA weight for the per-request service-cost estimate (retry-after hints)
_EMA_ALPHA = 0.2

#: pinned GC thresholds while the serving loops run: gen-0/1 stay at the
#: CPython defaults, gen-2 is pushed out 1000× so full collections — the
#: pauses that walk the entire (index-sized) heap — can't fire mid-batch.
_SERVE_GC_THRESHOLDS = (700, 10, 10_000)


@dataclass(frozen=True)
class Response:
    """Terminal state of one submitted request.

    Over a monolithic `SuffixArrayIndex`, ``(lo, hi)`` is the SA-rank
    range of the matches. Over a `repro.api.SegmentedIndex`, per-segment
    ranks don't compose into global ranks, so ``(lo, hi)`` is the
    *virtual* merged range ``[0, count)`` — ``count`` is exact either
    way (docs/api.md, "Multi-segment semantics")."""

    req_id: int
    status: str                          # "ok" | "rejected" | "shed"
    count: Optional[int] = None          # occurrences (status "ok")
    lo: Optional[int] = None             # SA-rank range (status "ok")
    hi: Optional[int] = None
    retry_after_us: Optional[float] = None   # backoff hint ("rejected")
    queue_us: Optional[float] = None
    service_us: Optional[float] = None
    total_us: Optional[float] = None

    @property
    def ok(self) -> bool:
        return self.status == "ok"


class SAServer:
    """Coalescing, admission-controlled serving loop over one index.

    `index` is either a monolithic `repro.api.SuffixArrayIndex` or a
    `repro.api.SegmentedIndex` — both speak the `_encode_pattern` /
    `stage_encoded` / `ranges_staged` staging protocol the loops are
    written against, so incremental multi-segment corpora serve through
    the identical data path (per-segment kernels fan out inside
    `ranges_staged`).

    Parameters mirror `repro.configs.SAConfig` serving knobs:

    * `max_batch` — largest coalesced batch (rounded up to a power of
      two; the kernel-shape bucket batches are emitted at).
    * `coalesce_max_wait_us` — deadline for a non-full window; the extra
      latency a lone request can pay for the chance of sharing a kernel.
    * `queue_depth` / `overload_policy` / `max_queue_age_us` — admission
      control (`repro.serve.admission`).
    * `gc_hygiene` — latency hygiene for the (process-global) cyclic GC:
      while the loops run, gen-2 thresholds are pinned high
      (`_SERVE_GC_THRESHOLDS`) so full heap walks can't land mid-batch,
      and after `warmup()` the loaded index + compiled caches are
      `gc.freeze()`-d out of every future collection. Any full collection
      that still happens in-loop bumps the `gc_pauses` metric counter.
      `stop()` restores the previous thresholds and unfreezes.
    """

    def __init__(self, index, *, max_batch: int = 256,
                 coalesce_max_wait_us: float = 500.0,
                 queue_depth: int = 1024,
                 overload_policy: str = "reject",
                 max_queue_age_us: Optional[float] = None,
                 metrics: Optional[ServeMetrics] = None,
                 gc_hygiene: bool = True):
        self.index = index
        self.coalescer = Coalescer(max_batch=max_batch,
                                   max_wait_us=coalesce_max_wait_us)
        self.admission = AdmissionController(queue_depth=queue_depth,
                                             policy=overload_policy,
                                             max_age_us=max_queue_age_us)
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.warmed_shapes = 0
        self._ids = itertools.count()
        self._cond = threading.Condition()
        self._inbox: collections.deque = collections.deque()
        self._queued = 0                  # accepted, not yet on the device
        self._ema_us_per_req: Optional[float] = None
        self._stage_q: queue.Queue = queue.Queue(maxsize=1)
        self._running = False
        self._stopping = False
        self._threads: list[threading.Thread] = []
        self.gc_hygiene = gc_hygiene
        self._gc_saved_thresholds: Optional[tuple] = None
        self._gc_frozen = False

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "SAServer":
        if self._running:
            return self
        with self._cond:
            # `_stopping` is read by the coalesce loop; take the lock even
            # though the threads don't exist yet, so a racing stop()/start()
            # pair can't interleave the flag writes.
            self._running, self._stopping = True, False
        if self.gc_hygiene:
            self._gc_saved_thresholds = gc.get_threshold()
            gc.set_threshold(*_SERVE_GC_THRESHOLDS)
            gc.callbacks.append(self._on_gc)
        self._threads = [
            threading.Thread(target=self._coalesce_loop,
                             name="sa-serve-coalesce", daemon=True),
            threading.Thread(target=self._device_loop,
                             name="sa-serve-device", daemon=True),
        ]
        for t in self._threads:
            t.start()
        return self

    def stop(self, timeout: float = 30.0) -> None:
        """Drain every pending request, then stop both loops (and hand the
        process-global GC state back the way it was found)."""
        if not self._running:
            return
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        for t in self._threads:
            t.join(timeout)
        self._running = False
        if self._on_gc in gc.callbacks:
            gc.callbacks.remove(self._on_gc)
        if self._gc_frozen:
            gc.unfreeze()
            self._gc_frozen = False
        if self._gc_saved_thresholds is not None:
            gc.set_threshold(*self._gc_saved_thresholds)
            self._gc_saved_thresholds = None

    def _on_gc(self, phase: str, info: dict) -> None:
        """`gc.callbacks` hook: count full collections that land while the
        serving loops are live — each one is a stop-the-world heap walk the
        latency histograms would otherwise show as an anonymous p99 spike."""
        if (phase == "stop" and info.get("generation") == 2
                and self._running):
            self.metrics.bump("gc_pauses")

    def __enter__(self) -> "SAServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -------------------------------------------------------------- warmup
    def warmup(self, pattern_lens=(8,), batch_buckets=None) -> int:
        """Compile the kernel shapes live traffic will hit, off the clock.

        Coalesced batches can land on ANY pow2 batch bucket up to
        `max_batch`, and each distinct `(B_pad, L_pad)` is a separate XLA
        compile — tens of ms to seconds that would otherwise surface as
        arbitrary p99 spikes mid-run. Default warms every pow2 batch
        bucket × every length bucket in `pattern_lens`. Returns the number
        of shapes run (compiled-or-cached; re-warming is cheap)."""
        if self.index.n == 0 or self.index.sigma == 0:
            return 0
        if batch_buckets is None:
            b = self.coalescer.max_batch
            batch_buckets = [1 << k for k in range(b.bit_length())
                             if (1 << k) <= b]
        done = 0
        # a sparse index rejects patterns below its rate, and its real
        # traffic only ever lands on length buckets ≥ that rate — floor
        # the warmed shapes the same way
        floor = max(_MIN_LEN_BUCKET,
                    int(getattr(self.index, "min_pattern_len", 0)))
        for m in sorted({pow2_bucket(int(l), floor=floor)
                         for l in pattern_lens}):
            for b in batch_buckets:
                pats = [np.zeros(m, np.int64)] * int(b)
                self.index.count_batch(pats)
                done += 1
        self.warmed_shapes += done
        if self.gc_hygiene and done:
            # everything alive now — the index, its SA/LCP arrays, the
            # freshly-compiled query kernels — is long-lived state. One
            # deliberate full collection while off the clock (not counted
            # as an in-loop pause), then freeze it all out of every future
            # GC pass.
            observed = self._on_gc in gc.callbacks
            if observed:
                gc.callbacks.remove(self._on_gc)
            gc.collect()
            gc.freeze()
            if observed:
                gc.callbacks.append(self._on_gc)
            self._gc_frozen = True
        return done

    # -------------------------------------------------------------- submit
    def submit(self, pattern, *, t_arrival: Optional[float] = None) -> Future:
        """Submit one pattern; returns a Future resolving to a `Response`.

        Never blocks on the device. Validation errors (out-of-alphabet
        values) raise synchronously; admission rejections resolve the
        future immediately with `status="rejected"` and a
        `retry_after_us` hint."""
        if not self._running or self._stopping:
            raise RuntimeError("SAServer is not running (call start())")
        enc = self.index._encode_pattern(pattern)   # raises on bad alphabet
        now = time.perf_counter()
        t_arrival = now if t_arrival is None else float(t_arrival)
        fut: Future = Future()
        req = PendingQuery(req_id=next(self._ids), pattern=enc,
                           t_arrival=t_arrival, future=fut)
        self.metrics.bump("submitted")
        with self._cond:
            decision = self.admission.admit(
                self._queued, self._oldest_age_us(now), self._ema_us_per_req)
            if decision.action == "reject":
                self.metrics.bump("rejected")
                fut.set_result(Response(
                    req_id=req.req_id, status="rejected",
                    retry_after_us=decision.retry_after_us,
                    total_us=(time.perf_counter() - t_arrival) * 1e6))
                return fut
            if decision.action == "shed":
                victim = self._shed_locked()
                if victim is not None:
                    self.metrics.bump("shed")
                    victim.future.set_result(Response(
                        req_id=victim.req_id, status="shed",
                        total_us=(now - victim.t_arrival) * 1e6))
            self.metrics.bump("accepted")
            self._inbox.append(req)
            self._queued += 1
            self._cond.notify_all()
        return fut

    def _oldest_age_us(self, now: float) -> float:
        """Oldest queued age across inbox + coalescer (caller holds lock)."""
        age = self.coalescer.oldest_age_us(now)
        if self._inbox:
            age = max(age, (now - self._inbox[0].t_arrival) * 1e6)
        return age

    def _shed_locked(self):
        """Evict the oldest queued request (caller holds the lock)."""
        victim = None
        if self._inbox and (self.coalescer.pending_count() == 0):
            victim = self._inbox.popleft()
        else:
            victim = self.coalescer.shed_oldest()
            if victim is None and self._inbox:
                victim = self._inbox.popleft()
        if victim is not None:
            self._queued -= 1
        return victim

    # ------------------------------------------------------ coalesce thread
    def _coalesce_loop(self) -> None:
        while True:
            with self._cond:
                while (not self._inbox and not self._stopping
                       and self.coalescer.next_deadline() is None):
                    self._cond.wait()
                while self._inbox:
                    self.coalescer.add(self._inbox.popleft())
                stopping = self._stopping and not self._inbox
                now = time.perf_counter()
                batches = self.coalescer.pop_ready(now, flush=stopping)
                if not batches and not stopping:
                    deadline = self.coalescer.next_deadline()
                    if deadline is not None:
                        self._cond.wait(timeout=max(deadline - now, 0.0))
                        continue
            for reqs in batches:
                self._stage_and_enqueue(reqs)
            if stopping:
                self._stage_q.put(None)     # device-loop shutdown sentinel
                return

    def _stage_and_enqueue(self, reqs) -> None:
        """Encode + begin host→device transfer, then hand to the device
        loop. Runs OUTSIDE the lock: staging overlaps both new arrivals
        and the in-flight kernel. Blocks when the staging slot is full —
        that is the backpressure edge."""
        work = self.index.stage_encoded([r.pattern for r in reqs])
        t_dispatch = time.perf_counter()
        self.metrics.record_batch(len(reqs), pow2_bucket(len(reqs)))
        self._stage_q.put((work, reqs, t_dispatch))

    # -------------------------------------------------------- device thread
    def _device_loop(self) -> None:
        while True:
            item = self._stage_q.get()
            if item is None:
                return
            work, reqs, t_dispatch = item
            with self._cond:
                self._queued -= len(reqs)
            try:
                lo, hi = self.index.ranges_staged(work)
            except Exception as e:                 # pragma: no cover
                for r in reqs:
                    r.future.set_exception(e)
                continue
            t_done = time.perf_counter()
            service_us = (t_done - t_dispatch) * 1e6
            per_req = service_us / max(len(reqs), 1)
            with self._cond:
                # submit() reads the EMA under the lock for retry-after
                # hints; an unlocked read-modify-write here could publish a
                # torn/stale estimate to the admission controller.
                self._ema_us_per_req = (
                    per_req if self._ema_us_per_req is None else
                    _EMA_ALPHA * per_req +
                    (1 - _EMA_ALPHA) * self._ema_us_per_req)
            self.metrics.service_us.add(service_us)
            for r, l, h in zip(reqs, lo, hi):
                queue_us = (t_dispatch - r.t_arrival) * 1e6
                total_us = (t_done - r.t_arrival) * 1e6
                self.metrics.queue_wait_us.add(queue_us)
                self.metrics.total_us.add(total_us)
                self.metrics.bump("completed")
                r.future.set_result(Response(
                    req_id=r.req_id, status="ok", count=int(h - l),
                    lo=int(l), hi=int(h), queue_us=queue_us,
                    service_us=service_us, total_us=total_us))

    # --------------------------------------------------------------- intro
    def __repr__(self) -> str:
        c = self.metrics.counters()
        return (f"SAServer(n={self.index.n}, "
                f"max_batch={self.coalescer.max_batch}, "
                f"policy={self.admission.policy!r}, "
                f"running={self._running}, completed={c['completed']})")
