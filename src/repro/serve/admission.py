"""Admission control: bounded queue + overload policy.

An open-loop arrival process does not slow down when the server falls
behind — past saturation the queue grows without bound and every
latency percentile diverges with the length of the run. Admission
control trades a little goodput for a bounded queue: the wait for any
*accepted* request is at most `queue_depth / service_rate`, so accepted
p99 stays flat past the saturation point while a no-admission baseline's
p99 climbs forever (`benchmarks/serve_slo.py` measures exactly this
pair of curves).

Three policies:

* ``"none"``   — accept everything; the unbounded baseline.
* ``"reject"`` — refuse new requests while the queue is at `queue_depth`
  (or the oldest queued request is older than `max_age_us`, when set).
  Refusals carry a `retry_after_us` hint: the estimated time to drain
  the current backlog at the server's measured per-request service rate
  — a cooperative client that waits that long will usually be admitted.
* ``"shed"``   — admit the new request but evict the *oldest* queued one
  (its waiting time is already the worst in the room; under overload it
  is the request most likely to be useless by the time it is served).

The controller is pure decision logic — no clocks, no locks, no queue of
its own. `SAServer` feeds it the observed queue state and applies the
decision; that keeps it unit-testable with plain numbers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

#: valid overload_policy spellings, in docs order
POLICIES = ("none", "reject", "shed")


@dataclass(frozen=True)
class AdmissionDecision:
    """What to do with one arriving request."""

    action: str                            # "accept" | "reject" | "shed"
    retry_after_us: Optional[float] = None  # set on "reject" only

    @property
    def accepted(self) -> bool:
        return self.action in ("accept", "shed")


class AdmissionController:
    """Apply one overload policy to a stream of (queue state) observations."""

    def __init__(self, *, queue_depth: int = 1024, policy: str = "reject",
                 max_age_us: Optional[float] = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown overload policy {policy!r} "
                             f"(choose from {POLICIES})")
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be ≥ 1, got {queue_depth}")
        self.queue_depth = int(queue_depth)
        self.policy = policy
        self.max_age_us = max_age_us

    def admit(self, queued: int, oldest_age_us: float,
              est_us_per_req: Optional[float] = None) -> AdmissionDecision:
        """Decide for one arrival given the queue's depth and oldest age.

        `est_us_per_req` is the server's measured per-request service cost
        (EMA); it prices the retry-after hint. Before any batch has
        completed there is no estimate and the hint falls back to the
        backlog count (1 µs/request floor) — deliberately optimistic, a
        cold server would rather see the retry early than late.
        """
        overloaded = queued >= self.queue_depth or (
            self.max_age_us is not None and oldest_age_us > self.max_age_us)
        if self.policy == "none" or not overloaded:
            return AdmissionDecision("accept")
        if self.policy == "shed":
            return AdmissionDecision("shed")
        per_req = est_us_per_req if est_us_per_req else 1.0
        return AdmissionDecision(
            "reject", retry_after_us=max(queued * per_req, 1.0))
