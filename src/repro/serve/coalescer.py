"""Request coalescing: individual queries → pow2 `(batch, length)` buckets.

The query kernel (`repro.api.query._ranges_kernel`) amortises its
dispatch overhead over a whole batch, but concurrent clients submit one
pattern at a time. The `Coalescer` is the piece in between: it holds
pending requests in per-length-bucket queues (the same
`pow2_bucket(len, floor=8)` grid `QueryBatch` pads to, so every batch it
emits lands on an already-compiled kernel shape) and closes a batch
window on the first of two triggers:

* **full bucket** — a length bucket reaches `max_batch` requests; the
  full chunk is emitted immediately (a burst larger than the biggest
  bucket simply emits several full chunks and leaves the remainder
  pending);
* **deadline** — the *oldest* request in a bucket reaches `max_wait_us`;
  the whole bucket is flushed (younger requests ride along — a lone
  straggler is never stranded longer than the max wait).

The class is intentionally free of threads and wall clocks: every method
takes `now` (seconds, `time.perf_counter` timebase) from the caller, so
the adversarial-arrival tests in `tests/serve/test_coalescer.py` drive
it with a purely virtual clock. `SAServer` owns the real clock and the
locking discipline (all coalescer calls happen under the server's
condition lock).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from ..api.query import _MIN_LEN_BUCKET, pow2_bucket


@dataclass
class PendingQuery:
    """One accepted, not-yet-served request."""

    req_id: int
    pattern: np.ndarray          # already through index._encode_pattern
    t_arrival: float             # seconds; scheduled arrival under open loop
    future: object = None        # concurrent.futures.Future[Response]
    len_bucket: int = field(init=False)

    def __post_init__(self):
        self.len_bucket = pow2_bucket(len(self.pattern),
                                      floor=_MIN_LEN_BUCKET)


class Coalescer:
    """Per-length-bucket pending queues with full/deadline batch windows."""

    def __init__(self, *, max_batch: int = 256, max_wait_us: float = 500.0):
        if max_batch < 1:
            raise ValueError(f"max_batch must be ≥ 1, got {max_batch}")
        if max_wait_us < 0:
            raise ValueError(f"max_wait_us must be ≥ 0, got {max_wait_us}")
        #: batches are emitted at the pow2 bucket the kernel compiles for
        self.max_batch = pow2_bucket(max_batch)
        self.max_wait_s = max_wait_us * 1e-6
        self._buckets: dict[int, collections.deque] = {}
        self._pending = 0

    # ------------------------------------------------------------- state
    def pending_count(self) -> int:
        return self._pending

    def oldest_age_us(self, now: float) -> float:
        """Age of the oldest pending request, 0.0 when empty."""
        oldest = self._oldest_arrival()
        return 0.0 if oldest is None else max(now - oldest, 0.0) * 1e6

    def _oldest_arrival(self) -> Optional[float]:
        arrivals = [q[0].t_arrival for q in self._buckets.values() if q]
        return min(arrivals) if arrivals else None

    def next_deadline(self) -> Optional[float]:
        """Absolute time the earliest pending window must close, or None."""
        oldest = self._oldest_arrival()
        return None if oldest is None else oldest + self.max_wait_s

    # ------------------------------------------------------------ intake
    def add(self, req: PendingQuery) -> None:
        self._buckets.setdefault(req.len_bucket, collections.deque()) \
            .append(req)
        self._pending += 1

    def shed_oldest(self) -> Optional[PendingQuery]:
        """Remove and return the single oldest pending request (the
        overload_policy="shed" victim), or None when empty."""
        best_key, best_t = None, None
        for key, q in self._buckets.items():
            if q and (best_t is None or q[0].t_arrival < best_t):
                best_key, best_t = key, q[0].t_arrival
        if best_key is None:
            return None
        self._pending -= 1
        return self._buckets[best_key].popleft()

    # ----------------------------------------------------------- windows
    def pop_ready(self, now: float, *, flush: bool = False) -> list:
        """Batches whose window closed by `now` — list of PendingQuery
        lists, each a single (length-bucket, ≤ max_batch) batch in arrival
        order. `flush=True` closes every window regardless of age (server
        shutdown)."""
        out = []
        for key in sorted(self._buckets):
            q = self._buckets[key]
            while len(q) >= self.max_batch:           # full windows first
                out.append([q.popleft() for _ in range(self.max_batch)])
            if q and (flush or
                      now - q[0].t_arrival >= self.max_wait_s):
                out.append(list(q))
                q.clear()
        self._pending -= sum(len(b) for b in out)
        return out
