"""Open-loop load generation: seeded arrival processes + the submit loop.

Closed-loop benchmarks (send a batch, wait, send the next) can never
observe queueing collapse: the client slows down exactly when the server
does. SLO claims need **open-loop** load — arrivals are scheduled by the
process, not by the server's progress, so offered load past saturation
actually piles up. Three arrival processes, all driven by one seeded
`numpy` Generator (never wall-clock-seeded: the same seed must produce
the same arrival schedule on every machine, which is what lets the CI
smoke slice of `benchmarks/serve_slo.py` pin its schema and counts):

* ``"uniform"`` — evenly spaced, deterministic; the degenerate baseline
  and the unit-test workhorse.
* ``"poisson"`` — i.i.d. exponential inter-arrivals at `qps`; the
  classic memoryless open-loop model.
* ``"onoff"``  — bursty Markov-modulated traffic: a Poisson process at
  peak rate `qps · (on+off)/on` thinned to ON windows of `on_ms` every
  `on_ms + off_ms`, so the *mean* rate is `qps` but the server sees
  alternating silence and `1/duty`-times-overload bursts.

`run_open_loop` replays a schedule against an `SAServer`: submissions
are never gated on completions, each request is dated from its
*scheduled* arrival (lateness of the submit loop is charged to measured
latency — no coordinated omission), and the collected `Response`
objects are folded into one summary dict by `summarize`.
"""
from __future__ import annotations

import time

import numpy as np

#: valid arrival-process spellings
ARRIVALS = ("uniform", "poisson", "onoff")


def make_arrivals(process: str, qps: float, duration_s: float, *,
                  seed: int = 0, on_ms: float = 50.0,
                  off_ms: float = 150.0) -> np.ndarray:
    """Sorted arrival offsets (seconds, float64) in [0, duration_s).

    Deterministic in (process, qps, duration_s, seed, on_ms, off_ms).
    """
    if process not in ARRIVALS:
        raise ValueError(f"unknown arrival process {process!r} "
                         f"(choose from {ARRIVALS})")
    if qps <= 0 or duration_s <= 0:
        raise ValueError("qps and duration_s must be > 0")
    rng = np.random.default_rng(seed)
    if process == "uniform":
        return np.arange(0.0, duration_s, 1.0 / qps)
    if process == "poisson":
        # draw in one vector slightly past the horizon, then trim
        est = int(qps * duration_s * 1.5) + 16
        t = np.cumsum(rng.exponential(1.0 / qps, size=est))
        while t.size and t[-1] < duration_s:
            t = np.concatenate(
                [t, t[-1] + np.cumsum(rng.exponential(1.0 / qps, size=est))])
        return t[t < duration_s]
    # onoff: homogeneous Poisson at the ON-window peak rate, thinned to ON
    on_s, off_s = on_ms * 1e-3, off_ms * 1e-3
    period = on_s + off_s
    duty = on_s / period
    peak = qps / duty
    t = make_arrivals("poisson", peak, duration_s, seed=seed)
    return t[(t % period) < on_s]


def run_open_loop(server, patterns, arrivals, *, result_timeout_s: float = 60.0,
                  tick_s: float = 0.002) -> list:
    """Replay `arrivals` against `server`, cycling through `patterns`.

    Open loop: the submit loop sleeps until the next scheduled arrival and
    NEVER waits for a response; requests due in the past are submitted
    immediately with their scheduled time as `t_arrival`. Returns the list
    of `repro.serve.Response` objects (one per arrival, in schedule
    order) after every future resolves."""
    if len(patterns) == 0:
        raise ValueError("need at least one pattern")
    arrivals = np.asarray(arrivals, np.float64)
    futs = []
    t0 = time.perf_counter()
    i, n = 0, len(arrivals)
    while i < n:
        now = time.perf_counter() - t0
        if arrivals[i] <= now:
            futs.append(server.submit(patterns[i % len(patterns)],
                                      t_arrival=t0 + arrivals[i]))
            i += 1
        else:
            time.sleep(min(arrivals[i] - now, tick_s))
    deadline = time.perf_counter() + result_timeout_s
    return [f.result(timeout=max(deadline - time.perf_counter(), 0.001))
            for f in futs]


def summarize(responses, duration_s: float) -> dict:
    """Fold one open-loop run into a JSON-ready record.

    Latency percentiles cover *accepted-and-served* ("ok") requests only
    — that is the population the SLO is promised to; rejected requests
    are counted, not averaged in (their retry cost is the client's,
    bounded by the retry-after hint). Percentiles are None when nothing
    completed (absent, never a fake 0)."""
    statuses = [r.status for r in responses]
    ok_total = np.asarray([r.total_us for r in responses if r.ok], np.float64)
    ok_queue = np.asarray([r.queue_us for r in responses if r.ok], np.float64)
    out = {
        "offered": len(responses),
        "ok": statuses.count("ok"),
        "rejected": statuses.count("rejected"),
        "shed": statuses.count("shed"),
        "goodput_qps": statuses.count("ok") / max(duration_s, 1e-9),
    }
    if ok_total.size:
        p = np.percentile(ok_total, [50, 95, 99])
        out.update(p50_ms=float(p[0]) * 1e-3, p95_ms=float(p[1]) * 1e-3,
                   p99_ms=float(p[2]) * 1e-3,
                   queue_p99_ms=float(np.percentile(ok_queue, 99)) * 1e-3,
                   max_ms=float(ok_total.max()) * 1e-3)
    else:
        out.update(p50_ms=None, p95_ms=None, p99_ms=None,
                   queue_p99_ms=None, max_ms=None)
    return out
