"""repro.serve — the asynchronous serving tier over the query engine.

`repro.api.QuerySession` answers closed-loop batches: the caller already
*has* a batch and waits for it. Production traffic is the opposite —
many independent clients, one small request each, arriving whenever they
like. This package is the subsystem in between the two:

* `Coalescer` merges individual requests into the pow2 (batch, length)
  buckets the jitted query kernel compiles for, closing each window on
  full-bucket or a max-wait deadline (`repro.serve.coalescer`);
* `AdmissionController` bounds the queue and applies an overload policy
  — reject-with-retry-after or shed-oldest — so accepted-request p99
  stays flat past saturation instead of diverging
  (`repro.serve.admission`);
* `SAServer` runs the loop: non-blocking `submit()` → coalesce →
  double-buffered host→device staging against the in-flight kernel →
  futures resolved with per-request latency breakdowns
  (`repro.serve.server`);
* `ServeMetrics` measures everything — queue-wait/service/total
  histograms, batch-size and bucket-occupancy distributions, admission
  counters (`repro.serve.metrics`);
* `make_arrivals` / `run_open_loop` / `summarize` generate seeded
  Poisson / bursty ON-OFF open-loop load and fold the responses into
  SLO records (`repro.serve.loadgen`) — what `benchmarks/serve_slo.py`
  sweeps into `BENCH_serve_slo.json`.

Quickstart (tiny, CPU-safe)
---------------------------
>>> import numpy as np
>>> from repro.api import SuffixArrayIndex
>>> from repro.serve import SAServer
>>> idx = SuffixArrayIndex.build(np.array([0, 2, 1, 0, 0, 2, 1, 0]),
...                              sigma=4)
>>> with SAServer(idx, max_batch=4, coalesce_max_wait_us=200.0) as srv:
...     futs = [srv.submit([0, 2]), srv.submit([1, 0]), srv.submit([3])]
...     counts = [f.result().count for f in futs]
>>> counts
[2, 2, 0]
"""
from .admission import AdmissionController, AdmissionDecision, POLICIES
from .coalescer import Coalescer, PendingQuery
from .loadgen import ARRIVALS, make_arrivals, run_open_loop, summarize
from .metrics import Histogram, ServeMetrics
from .server import Response, SAServer

__all__ = [
    "ARRIVALS",
    "AdmissionController",
    "AdmissionDecision",
    "Coalescer",
    "Histogram",
    "POLICIES",
    "PendingQuery",
    "Response",
    "SAServer",
    "ServeMetrics",
    "make_arrivals",
    "run_open_loop",
    "summarize",
]
