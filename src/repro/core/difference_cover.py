"""Difference covers of Z_v and the Lemma-1 offset tables.

A set D ⊆ Z_v is a *difference cover* of Z_v if every z ∈ Z_v can be written as
z ≡ a - b (mod v) with a, b ∈ D.  The paper (Pace & Tiskin 2013, §2) requires
|D| < v and 0 ∉ D (so that the last super-character of each X_k block ends with
a -1 sentinel, see §3 Step 1).

Constructions
-------------
* exact optimal covers for small v (from the literature / brute force),
* the O(√v) "run ∪ stride" construction for arbitrary v:
      D0 = [0:r) ∪ {0, r, 2r, ...}  with r = ceil(sqrt(v))
  which is a difference cover because any z ∈ Z_v decomposes as z = q·r - s with
  q·r < v + r and s ∈ [0:r); |D0| ≤ 2√v + 2 = O(√v), matching the paper's
  asymptotics (the Colbourn–Ling series achieves ≈ √(1.5 v) but is only defined
  at specific moduli; EXPERIMENTS C2 compares the sizes).
* a greedy pruning pass that removes redundant elements while preserving the
  cover property (keeps sizes close to CL's in practice).

0 ∉ D is enforced by the shift trick from the paper: for any fixed z,
D' = {(d - z) mod v | d ∈ D} is still a difference cover.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

# Known-good small covers (0-free where possible; shifted later anyway).
# v: cover. Optimal sizes: v=3:2, v=4:3, v=5:3 (paper: |D|>=... table2 says 4
# for 5..13 via CL; the true optimum for v=5 is 3: {1,2,4} ... differences:
# 1-2=-1=4? {1,2,4}: pairwise diffs mod 5: {0,1,2,3,4} yes (4-1=3, 1-4=-3=2,
# 2-1=1, 1-2=4, 4-2=2...). We verify everything at construction time.
_EXACT_COVERS = {
    3: [1, 2],
    4: [1, 2, 3],
    5: [1, 2, 4],
    7: [1, 2, 4],
    9: [1, 2, 4, 7],
    13: [1, 2, 4, 10],
    21: [1, 2, 5, 15, 17],
    31: [1, 2, 4, 9, 13, 19],
    32: [1, 2, 4, 9, 13, 19],  # cover of 31 works? verified at import below.
    64: [1, 2, 4, 9, 13, 19, 24, 31, 52],
}


def is_difference_cover(D, v: int) -> bool:
    """Check that D covers Z_v: ∀z∈[0,v) ∃a,b∈D: z ≡ a-b (mod v)."""
    D = np.asarray(sorted(set(int(d) % v for d in D)), dtype=np.int64)
    if len(D) == 0:
        return False
    diffs = (D[:, None] - D[None, :]) % v
    return len(np.unique(diffs)) == v


def _run_stride_cover(v: int) -> list[int]:
    """O(√v) construction: [0:r) ∪ {0, r, 2r, ...}, r = ceil(sqrt(v))."""
    r = int(np.ceil(np.sqrt(v)))
    D = set(range(r)) | set(range(0, v, r))
    return sorted(D)


def _greedy_prune(D: list[int], v: int) -> list[int]:
    """Remove elements while the set remains a difference cover (stable)."""
    D = list(D)
    # Try removing largest-first; keeps the small run elements that carry
    # most coverage.
    for d in sorted(D, reverse=True):
        trial = [x for x in D if x != d]
        if len(trial) >= 2 and is_difference_cover(trial, v):
            D = trial
    return D


def _shift_zero_free(D: list[int], v: int) -> list[int]:
    """Shift D so that 0 ∉ D (paper §2: D' = {(d-z) mod v} is still a cover)."""
    if 0 not in D:
        return sorted(D)
    for z in range(1, v):
        shifted = sorted((d - z) % v for d in D)
        if 0 not in shifted:
            return shifted
    raise ValueError(f"no zero-free shift exists for D={D}, v={v}")  # |D|=v only


@functools.lru_cache(maxsize=None)
def difference_cover(v: int) -> tuple[int, ...]:
    """Return a 0-free difference cover of Z_v with |D| = O(√v), |D| < v.

    Requires v >= 3 (paper §2).
    """
    if v < 3:
        raise ValueError(f"difference cover requires v >= 3, got {v}")
    if v in _EXACT_COVERS and is_difference_cover(_EXACT_COVERS[v], v):
        D = list(_EXACT_COVERS[v])
    else:
        D = _run_stride_cover(v)
        if v <= 4096:  # pruning is O(v·|D|²)-ish; cheap at these sizes
            D = _greedy_prune(D, v)
    D = _shift_zero_free(D, v)
    assert is_difference_cover(D, v), (v, D)
    assert 0 not in D and len(D) < v
    return tuple(int(d) for d in D)


def cover_size_lower_bound(v: int) -> float:
    """|D| ≥ (1+√(4v−3))/2 (paper §2: |D|(|D|−1)+1 ≥ v)."""
    return (1.0 + np.sqrt(4.0 * v - 3.0)) / 2.0


@dataclass(frozen=True)
class CoverTables:
    """Precomputed lookup tables for one (v, D) pair.

    Attributes
    ----------
    v : modulus
    D : the difference cover (sorted, 0-free)
    in_D : bool[v], in_D[k] = k ∈ D
    shifts : int32[v, |D|]; shifts[k] = sorted {l ∈ [0:v) : (k+l) mod v ∈ D}.
        For every class k there are exactly |D| such offsets.
    lam : int32[v, v]; lam[k1, k2] = min l such that (k1+l) mod v ∈ D and
        (k2+l) mod v ∈ D  — the Lemma-1 offset. Always < v.
    lam_idx1 / lam_idx2 : int32[v, v]; position of lam[k1,k2] within
        shifts[k1] / shifts[k2] — lets a payload that carries
        rank[i + shifts[k][j]] for j ∈ [0:|D|) look up the Lemma-1 rank by
        *local index* instead of by offset.
    """

    v: int
    D: tuple[int, ...]
    in_D: np.ndarray
    shifts: np.ndarray
    lam: np.ndarray
    lam_idx1: np.ndarray
    lam_idx2: np.ndarray


@functools.lru_cache(maxsize=None)
def cover_tables(v: int) -> CoverTables:
    D = difference_cover(v)
    dsize = len(D)
    in_D = np.zeros(v, dtype=bool)
    in_D[list(D)] = True

    # shifts[k] = all l with (k+l) mod v ∈ D
    shifts = np.zeros((v, dsize), dtype=np.int32)
    for k in range(v):
        ls = [l for l in range(v) if in_D[(k + l) % v]]
        assert len(ls) == dsize
        shifts[k] = ls

    # Lemma 1: for any k1,k2 there is l with both (k1+l),(k2+l) ∈ D.
    lam = np.full((v, v), -1, dtype=np.int32)
    lam_idx1 = np.full((v, v), -1, dtype=np.int32)
    lam_idx2 = np.full((v, v), -1, dtype=np.int32)
    shift_sets = [set(int(x) for x in shifts[k]) for k in range(v)]
    for k1 in range(v):
        for k2 in range(v):
            common = shift_sets[k1] & shift_sets[k2]
            assert common, f"Lemma 1 violated for v={v}, D={D}, k=({k1},{k2})"
            l = min(common)
            lam[k1, k2] = l
            lam_idx1[k1, k2] = int(np.where(shifts[k1] == l)[0][0])
            lam_idx2[k1, k2] = int(np.where(shifts[k2] == l)[0][0])

    return CoverTables(
        v=v, D=D, in_D=in_D, shifts=shifts, lam=lam,
        lam_idx1=lam_idx1, lam_idx2=lam_idx2,
    )


# Verify the tabulated exact covers once at import (cheap) so a bad entry can
# never be silently used — invalid entries fall through to run∪stride.
for _v, _D in list(_EXACT_COVERS.items()):
    if not is_difference_cover(_D, _v):
        del _EXACT_COVERS[_v]
