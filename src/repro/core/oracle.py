"""Naive suffix-array oracles used to validate every other implementation."""
from __future__ import annotations

import numpy as np


def suffix_array_naive(x) -> np.ndarray:
    """O(n² log n) reference: sort suffixes directly. Test-sized inputs only."""
    x = np.asarray(x, dtype=np.int64)
    n = len(x)
    suffixes = [tuple(x[i:]) for i in range(n)]
    order = sorted(range(n), key=lambda i: suffixes[i])
    return np.asarray(order, dtype=np.int64)


def suffix_array_doubling(x) -> np.ndarray:
    """O(n log² n) prefix-doubling oracle (numpy), for larger benchmark inputs.

    Classic Manber–Myers by repeated lexsort on (rank[i], rank[i+h]).
    """
    x = np.asarray(x, dtype=np.int64)
    n = len(x)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    # initial ranks from single characters
    rank = np.unique(x, return_inverse=True)[1].astype(np.int64)
    h = 1
    idx = np.arange(n)
    while True:
        key2 = np.where(idx + h < n, np.concatenate([rank[h:], np.full(min(h, n), -1)])[:n], -1)
        order = np.lexsort((key2, rank))
        # recompute dense ranks
        r_o, k_o = rank[order], key2[order]
        new_rank = np.zeros(n, dtype=np.int64)
        boundary = np.ones(n, dtype=bool)
        boundary[1:] = (r_o[1:] != r_o[:-1]) | (k_o[1:] != k_o[:-1])
        new_rank[order] = np.cumsum(boundary) - 1
        rank = new_rank
        if rank.max() == n - 1:
            return order.astype(np.int64)
        h *= 2
        if h >= 2 * n:  # pragma: no cover - safety
            return order.astype(np.int64)


def rank_of_suffixes(sa: np.ndarray) -> np.ndarray:
    """Inverse permutation: rank[i] = position of suffix i in the SA."""
    sa = np.asarray(sa)
    inv = np.empty_like(sa)
    inv[sa] = np.arange(len(sa))
    return inv
