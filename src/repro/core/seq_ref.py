"""Paper-faithful sequential DC-v suffix array construction (Algorithm 1).

This is the *executable specification* of Pace & Tiskin 2013, Section 3 — the
steps are kept literal (Step 0 sample construction, Step 1 recursive sample
sort, Step 2 per-class non-sample sort, Step 3 v-character sort, Step 4
Lemma-1 v-way merge). numpy is used for the radix/counting sorts (lexsort is
key-based, i.e. radix semantics); clarity is preferred over speed — the
optimised paths live in `dcv_jax.py` and `repro.bsp`.

Canonical padding
-----------------
The paper's block/terminator structure (§3 Step 1: "the last super-character of
X_k ends with one or more -1 elements") is guaranteed only when n ≡ 0 (mod v)
and 0 ∉ D. We therefore pad the index domain to n_v = v·ceil(n/v) with
sentinel (-1) characters and treat pad positions as genuine suffixes. Pad
suffixes start with -1 < every real character, so they never disturb the
relative order of real suffixes, and they are dropped from the returned SA.
This matches the classic DC3 "append zeros / include the empty suffix" trick,
generalised to arbitrary v.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .difference_cover import cover_tables
from .oracle import suffix_array_doubling


@dataclass
class SeqStats:
    """Instrumentation: one entry per recursion round (EXPERIMENTS C3)."""

    rounds: list = field(default_factory=list)  # dicts: v, |D|, n, work

    def add(self, *, v: int, dsize: int, n: int, work: int) -> None:
        self.rounds.append({"v": v, "D": dsize, "n": n, "work": work})


def accelerated_next_v(v: int, dsize: int, m: int) -> int:
    """v' = min(v^{5/4}, v²/|D| − 1, m), clamped to ≥ 3 (paper §5, Step 1)."""
    if m < 3:
        return 3
    # paper §1.1: real numbers are *rounded up*; bound v' < v²/|D| keeps the
    # total work linear (§3 Step 1).
    cap_work = max(3, int(np.ceil(v * v / max(dsize, 1))) - 1)
    accel = max(3, int(np.ceil(float(v) ** 1.25)))
    return int(min(accel, cap_work, m))


def fixed_next_v(v: int, dsize: int, m: int) -> int:
    """Non-accelerated baseline: constant v (the Kärkkäinen et al. regime)."""
    return int(min(v, max(m, 3)))


def _pad_to_multiple(x: np.ndarray, v: int) -> np.ndarray:
    n = len(x)
    n_v = v * int(np.ceil(n / v)) if n else v
    out = np.full(n_v + 2 * v, -1, dtype=np.int64)  # +2v char lookahead buffer
    out[:n] = x
    return out


def _windows(xp: np.ndarray, positions: np.ndarray, v: int) -> np.ndarray:
    """Windows x[i:i+v) for each i in positions → int64[len(positions), v]."""
    return xp[positions[:, None] + np.arange(v)[None, :]]


def _lexsort_rows(rows: np.ndarray, tiebreak: np.ndarray | None = None):
    """Sort rows lexicographically (radix over columns); returns order."""
    keys = [rows[:, c] for c in range(rows.shape[1] - 1, -1, -1)]
    if tiebreak is not None:
        keys = [tiebreak] + keys
    return np.lexsort(keys)


def _dense_ranks(sorted_rows: np.ndarray) -> tuple[np.ndarray, bool]:
    """Dense ranks of sorted rows + all-distinct flag."""
    m = len(sorted_rows)
    boundary = np.ones(m, dtype=bool)
    if m > 1:
        boundary[1:] = np.any(sorted_rows[1:] != sorted_rows[:-1], axis=1)
    ranks = np.cumsum(boundary) - 1
    return ranks, bool(boundary.all())


def suffix_array_dcv(
    x,
    v: int = 3,
    schedule=accelerated_next_v,
    base_threshold: int = 32,
    stats: SeqStats | None = None,
    _depth: int = 0,
) -> np.ndarray:
    """Suffix array of x (ints ≥ 0) by the paper's DC-v algorithm.

    Parameters mirror Algorithm 1: `v` is the difference-cover modulus for
    this round; `schedule(v, |D|, m)` picks v' for the recursive call
    (accelerated_next_v reproduces the paper's v^{5/4} regime; fixed_next_v is
    the constant-v baseline).
    """
    x = np.asarray(x, dtype=np.int64)
    n = len(x)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    if n <= max(base_threshold, v):  # paper: sequential base once tiny
        if stats is not None:
            stats.add(v=v, dsize=0, n=n, work=n)
        return suffix_array_doubling(x)

    # ---- Recursion base check: all characters distinct → SA = argsort ----
    order0 = np.argsort(x, kind="stable")
    if len(np.unique(x)) == n:
        if stats is not None:
            stats.add(v=v, dsize=0, n=n, work=n)
        return order0.astype(np.int64)

    v = int(min(max(v, 3), n))
    tabs = cover_tables(v)
    D = np.asarray(tabs.D, dtype=np.int64)
    dsize = len(D)
    if stats is not None:
        stats.add(v=v, dsize=dsize, n=n, work=v * n)

    # ---- Step 0: sample construction ----
    xp = _pad_to_multiple(x, v)
    n_v = len(xp) - 2 * v
    per_block = n_v // v
    # B_k = {i : i mod v = k}; C = ∪_{k∈D} B_k  (block-major order as in X)
    sample_pos = (D[:, None] * 0 + np.arange(per_block)[None, :] * v + D[:, None]).reshape(-1)
    m = dsize * per_block
    rank = np.full(n_v + v, -1, dtype=np.int64)

    # ---- Step 1: sort sample suffixes (recurse on super-character string) --
    W = _windows(xp, sample_pos, v)                 # super-characters
    order = _lexsort_rows(W)
    ranks_sorted, distinct = _dense_ranks(W[order])
    Xp = np.empty(m, dtype=np.int64)                # X' over Σ' = [0:m)
    Xp[order] = ranks_sorted
    if distinct:
        # all super-characters distinct → SA_{X'} is just the sort order
        sa_rank = np.empty(m, dtype=np.int64)
        sa_rank[order] = np.arange(m)
    else:
        v_next = schedule(v, dsize, m)
        sa_sub = suffix_array_dcv(
            Xp, v=v_next, schedule=schedule, base_threshold=base_threshold,
            stats=stats, _depth=_depth + 1,
        )
        sa_rank = np.empty(m, dtype=np.int64)
        sa_rank[sa_sub] = np.arange(m)
    rank[sample_pos] = sa_rank

    # ---- Step 2: order non-sample suffixes within each class S_k, k ∉ D ----
    # Within-class key: (x[i..i+l_k-1], rank[i+l_k]) with (k+l_k) mod v ∈ D.
    within_rank = np.full(n_v, -1, dtype=np.int64)  # order within S_k
    for k in range(v):
        pos_k = np.arange(k, n_v, v)
        if tabs.in_D[k]:
            # within-class order of sample classes = restriction of sa_rank
            o = np.argsort(rank[pos_k], kind="stable")
        else:
            l_k = int(tabs.shifts[k][0])            # min l ≥ 1 with (k+l)∈D
            chars = _windows(xp, pos_k, l_k) if l_k > 0 else np.zeros((len(pos_k), 0), np.int64)
            tup = np.concatenate([chars, rank[pos_k + l_k][:, None]], axis=1)
            o = _lexsort_rows(tup)
        within_rank[pos_k[o]] = np.arange(len(pos_k))

    # ---- Step 3: sort all suffixes by their first v characters ----
    all_pos = np.arange(n_v)
    Wall = _windows(xp, all_pos, v)
    order3 = _lexsort_rows(Wall, tiebreak=all_pos)
    group_ranks, _ = _dense_ranks(Wall[order3])
    group_of = np.empty(n_v, dtype=np.int64)
    group_of[order3] = group_ranks

    # ---- Step 4: v-way merge inside each group S^α via Lemma 1 ----
    lam = tabs.lam
    sa_full = np.empty(n_v, dtype=np.int64)
    out = 0
    sorted_pos = all_pos[order3]
    bounds = np.flatnonzero(np.r_[True, group_ranks[1:] != group_ranks[:-1], True])
    for gi in range(len(bounds) - 1):
        members = sorted_pos[bounds[gi]:bounds[gi + 1]]
        if len(members) == 1:
            sa_full[out] = members[0]
            out += 1
            continue
        # per-class sorted sub-lists (classes already ordered by steps 1-2)
        heads: dict[int, list] = {}
        for i in members:
            heads.setdefault(int(i % v), []).append(int(i))
        for k in heads:
            heads[k].sort(key=lambda i: within_rank[i])
        lists = [heads[k] for k in sorted(heads)]
        ptrs = [0] * len(lists)
        # comparison-based v-way merge: compare heads via rank[i+l], l = Λ
        remaining = len(members)
        while remaining:
            best = -1
            for a in range(len(lists)):
                if ptrs[a] >= len(lists[a]):
                    continue
                if best == -1:
                    best = a
                    continue
                i, j = lists[best][ptrs[best]], lists[a][ptrs[a]]
                l = lam[i % v, j % v]
                if rank[j + l] < rank[i + l]:
                    best = a
            sa_full[out] = lists[best][ptrs[best]]
            ptrs[best] += 1
            out += 1
            remaining -= 1

    sa = sa_full[sa_full < n]
    return sa.astype(np.int64)
