"""Version and platform compatibility shims for the host jax.

Two concerns live here:

1. **API layout.** `jax.shard_map` was promoted out of
   `jax.experimental.shard_map` only in newer jax releases; the baked-in
   toolchain may predate that. Import `shard_map` from here instead of from
   jax directly so both layouts work. `check_rep` is disabled on the
   experimental fallback: the BSP layer's collective patterns (ppermute
   halos + capacity-bounded all-to-all) are not expressible under its
   replication checker.

2. **Primitive selection.** The suffix-array hot path is sort-bound, and
   the best sort primitive differs per platform: XLA's `lax.sort` is a
   single-threaded comparison sort on CPU (~50× slower than the host
   radix/introsort at n=200k on this container) but is the native fast path
   on TPU/GPU, and the Mosaic Pallas kernels in `repro.kernels` only
   compile on TPU (elsewhere they run in the slow `interpret=True` mode).
   `default_sort_impl()` / `pallas_available()` encode that decision tree
   once so `repro.core.dcv_jax` and the `repro.api` registry never
   hard-code a platform assumption (see docs/architecture.md).
"""
from __future__ import annotations

import functools

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - exercised only on older jax
    from jax.experimental.shard_map import shard_map as _shard_map_experimental

    @functools.wraps(_shard_map_experimental)
    def shard_map(f=None, *, mesh, in_specs, out_specs, **kw):
        kw.setdefault("check_rep", False)
        if f is None:
            return functools.partial(_shard_map_experimental, mesh=mesh,
                                     in_specs=in_specs, out_specs=out_specs,
                                     **kw)
        return _shard_map_experimental(f, mesh=mesh, in_specs=in_specs,
                                       out_specs=out_specs, **kw)


@functools.lru_cache(maxsize=None)
def backend_platform() -> str:
    """The default jax backend platform: "cpu", "tpu", or "gpu"."""
    try:
        return jax.default_backend()
    except Exception:  # pragma: no cover - jax failed to init a backend
        return "cpu"


def pallas_available() -> bool:
    """True when the Pallas kernels in `repro.kernels` can run *compiled*
    (Mosaic on TPU). Elsewhere they only run under `interpret=True`, which
    executes kernel bodies in Python and is strictly slower than the lax /
    host fallbacks — callers should treat that as "unavailable" for
    performance selection (it stays usable for correctness testing).
    """
    return backend_platform() == "tpu"


def default_sort_impl() -> str:
    """Resolve `sort_impl="auto"` for the current platform.

    ==========  ==========================================================
    "radix"     CPU — packed-key host sorts (numpy introsort / LSD radix
                passes); XLA's CPU `lax.sort` is a single-threaded
                comparison sort and loses by ~50× at n=200k.
    "lax"       TPU/GPU — XLA's native variadic `lax.sort`, one fused
                multi-key sort per round, no host round-trips.
    ==========  ==========================================================

    The Pallas row-sort path is *not* auto-selected yet even on TPU (the
    fused `lax.sort` is at least as good for these payload widths); request
    it explicitly with ``sort_impl="pallas"``.
    """
    return "lax" if backend_platform() in ("tpu", "gpu") else "radix"
