"""Version compatibility shims for the host jax.

`jax.shard_map` was promoted out of `jax.experimental.shard_map` only in
newer jax releases; the baked-in toolchain may predate that. Import
`shard_map` from here instead of from jax directly so both layouts work.
`check_rep` is disabled on the experimental fallback: the BSP layer's
collective patterns (ppermute halos + capacity-bounded all-to-all) are not
expressible under its replication checker.
"""
from __future__ import annotations

import functools

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - exercised only on older jax
    from jax.experimental.shard_map import shard_map as _shard_map_experimental

    @functools.wraps(_shard_map_experimental)
    def shard_map(f=None, *, mesh, in_specs, out_specs, **kw):
        kw.setdefault("check_rep", False)
        if f is None:
            return functools.partial(_shard_map_experimental, mesh=mesh,
                                     in_specs=in_specs, out_specs=out_specs,
                                     **kw)
        return _shard_map_experimental(f, mesh=mesh, in_specs=in_specs,
                                       out_specs=out_specs, **kw)
