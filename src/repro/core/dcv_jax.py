"""Vectorised single-device JAX DC-v suffix array construction.

Same mathematics as `seq_ref` (difference-cover sampling + Lemma-1
comparisons), reorganised for the TPU execution model (DESIGN.md §3):

* window encoding + ranking via variadic `lax.sort` (XLA's native sort),
* the paper's Steps 2–4 fused into ONE comparator-bitonic sort over
  self-contained payloads
  `P(i) = (x[i:i+v), rank[i+l] for l ∈ shifts(i mod v), i mod v, i)`,
  where `shifts(k) = {l : (k+l) mod v ∈ D}`. For any pair, the Lemma-1
  offset `Λ[k_i][k_j]` lies in both shift sets, so the true suffix order is a
  strict total order computable from the payloads alone — no remote lookups.

The recursion driver stays in Python (shapes are data-independent functions of
the schedule), each round body is jitted per-shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .bitonic import bitonic_sort, lex_lt_int, next_pow2, sort_rows_with_index
from .difference_cover import cover_tables
from .seq_ref import accelerated_next_v

INT32_MAX = np.int32(np.iinfo(np.int32).max)


@functools.partial(jax.jit, static_argnames=("n", "steps"))
def suffix_array_doubling_jax(x: jnp.ndarray, n: int, steps: int) -> jnp.ndarray:
    """Prefix-doubling base case (Manber–Myers), log n rounds of lax.sort."""
    idx = jnp.arange(n, dtype=jnp.int32)
    x = x.astype(jnp.int32)

    def dense_rank(k1, k2):
        _, _, perm = jax.lax.sort((k1, k2, idx), num_keys=3)
        s1, s2 = k1[perm], k2[perm]
        boundary = jnp.ones(n, dtype=jnp.int32)
        if n > 1:
            neq = (s1[1:] != s1[:-1]) | (s2[1:] != s2[:-1])
            boundary = boundary.at[1:].set(neq.astype(jnp.int32))
        ranks_sorted = jnp.cumsum(boundary) - 1
        rank = jnp.zeros(n, dtype=jnp.int32).at[perm].set(ranks_sorted)
        return rank, perm

    rank, perm = dense_rank(x, jnp.zeros_like(x))
    for s in range(steps):
        h = 1 << s
        shifted = jnp.concatenate([rank[h:], jnp.full((min(h, n),), -1, jnp.int32)])[:n]
        rank, perm = dense_rank(rank, shifted)
    return perm


def _np_sample_positions(n_v: int, v: int, D) -> np.ndarray:
    per_block = n_v // v
    return (np.asarray(D, np.int64)[:, None] + np.arange(per_block)[None, :] * v).reshape(-1)


@functools.partial(jax.jit, static_argnames=("v", "m"))
def _encode_sample(xp: jnp.ndarray, sample_pos: jnp.ndarray, v: int, m: int):
    """Step 1 (first half): rank super-characters; X' + distinct flag."""
    W = xp[sample_pos[:, None] + jnp.arange(v, dtype=jnp.int32)[None, :]]
    perm = sort_rows_with_index(W, v)
    Ws = W[perm]
    boundary = jnp.ones(m, dtype=jnp.int32)
    if m > 1:
        boundary = boundary.at[1:].set(
            jnp.any(Ws[1:] != Ws[:-1], axis=1).astype(jnp.int32))
    ranks_sorted = jnp.cumsum(boundary) - 1
    Xp = jnp.zeros(m, dtype=jnp.int32).at[perm].set(ranks_sorted)
    sa_rank_direct = jnp.zeros(m, dtype=jnp.int32).at[perm].set(
        jnp.arange(m, dtype=jnp.int32))
    distinct = jnp.all(boundary == 1)
    return Xp, distinct, sa_rank_direct


@functools.partial(jax.jit, static_argnames=("v", "n_v"))
def _fused_final_sort(
    xp: jnp.ndarray,
    sample_pos: jnp.ndarray,
    sa_rank: jnp.ndarray,
    shifts_tab: jnp.ndarray,     # int32[v, |D|]
    lam_i1: jnp.ndarray,         # int32[v, v]
    lam_i2: jnp.ndarray,         # int32[v, v]
    v: int,
    n_v: int,
) -> jnp.ndarray:
    """Fused Steps 2–4: one comparator-bitonic sort of all n_v suffixes."""
    dsize = shifts_tab.shape[1]
    rank = jnp.full(n_v + v, -1, dtype=jnp.int32).at[sample_pos].set(sa_rank)

    pos = jnp.arange(n_v, dtype=jnp.int32)
    chars = xp[pos[:, None] + jnp.arange(v, dtype=jnp.int32)[None, :]]
    klass = pos % v
    rvals = rank[pos[:, None] + shifts_tab[klass]]          # [n_v, |D|]

    n2 = next_pow2(n_v)
    pad = n2 - n_v
    payload = {
        "chars": jnp.concatenate(
            [chars, jnp.full((pad, v), INT32_MAX, jnp.int32)], axis=0),
        "ranks": jnp.concatenate(
            [rvals, jnp.zeros((pad, dsize), jnp.int32)], axis=0),
        "klass": jnp.concatenate(
            [klass, jnp.zeros((pad,), jnp.int32)], axis=0),
        "idx": jnp.concatenate(
            [pos, n_v + jnp.arange(pad, dtype=jnp.int32)], axis=0),
    }

    def lt_fn(a, b):
        char_lt, char_eq = lex_lt_int(a["chars"], b["chars"])
        ka, kb = a["klass"], b["klass"]
        ra = jnp.take_along_axis(a["ranks"], lam_i1[ka, kb][:, None], axis=1)[:, 0]
        rb = jnp.take_along_axis(b["ranks"], lam_i2[ka, kb][:, None], axis=1)[:, 0]
        rank_decides = char_eq & (ra != rb)
        return jnp.where(
            rank_decides, ra < rb,
            jnp.where(char_eq, a["idx"] < b["idx"], char_lt))

    out = bitonic_sort(payload, lt_fn)
    return out["idx"][:n_v]   # pads carry INT32_MAX chars → sorted last


@functools.partial(jax.jit, static_argnames=("m",))
def _inverse_perm(sa: jnp.ndarray, m: int) -> jnp.ndarray:
    return jnp.zeros(m, dtype=jnp.int32).at[sa].set(jnp.arange(m, dtype=jnp.int32))


def suffix_array_jax(
    x,
    v: int = 3,
    schedule=accelerated_next_v,
    base_threshold: int = 256,
) -> np.ndarray:
    """Suffix array of x (ints ≥ 0) — vectorised JAX DC-v. Returns np.int32[n]."""
    x = np.asarray(x)
    n = int(len(x))
    if n == 0:
        return np.zeros(0, dtype=np.int32)
    if n == 1:
        return np.zeros(1, dtype=np.int32)

    def rec(x_np: np.ndarray, v: int) -> np.ndarray:
        n = len(x_np)
        if n <= max(base_threshold, v, 4):
            steps = max(1, int(np.ceil(np.log2(max(n, 2)))) + 1)
            return np.asarray(
                suffix_array_doubling_jax(jnp.asarray(x_np, jnp.int32), n, steps))
        v = int(min(max(v, 3), n))
        tabs = cover_tables(v)
        n_v = v * int(np.ceil(n / v))
        xp_np = np.full(n_v + 2 * v, -1, dtype=np.int32)
        xp_np[:n] = x_np
        xp = jnp.asarray(xp_np)
        sample_pos_np = _np_sample_positions(n_v, v, tabs.D)
        sample_pos = jnp.asarray(sample_pos_np, jnp.int32)
        m = len(sample_pos_np)

        Xp, distinct, sa_rank_direct = _encode_sample(xp, sample_pos, v, m)
        if bool(distinct):
            sa_rank = sa_rank_direct
        else:
            v_next = schedule(v, len(tabs.D), m)
            sa_sub = rec(np.asarray(Xp), v_next)
            sa_rank = _inverse_perm(jnp.asarray(sa_sub, jnp.int32), m)

        sa_full = _fused_final_sort(
            xp, sample_pos, sa_rank,
            jnp.asarray(tabs.shifts, jnp.int32),
            jnp.asarray(tabs.lam_idx1, jnp.int32),
            jnp.asarray(tabs.lam_idx2, jnp.int32),
            v, n_v,
        )
        sa_full = np.asarray(sa_full)
        return sa_full[sa_full < n]

    return rec(x.astype(np.int32), v).astype(np.int32)
