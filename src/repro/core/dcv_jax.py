"""Vectorised single-device JAX DC-v suffix array construction.

Same mathematics as `seq_ref` (difference-cover sampling + Lemma-1
comparisons), reorganised so that each recursion level is dominated by ONE
multi-key sort instead of an O(n log² n) comparator network:

* the v-character windows of ALL n_v positions are sorted once per level;
  the sample super-character ranks of Step 1 fall out of that order by
  filtering it to sample positions (a stable subsequence of a sorted
  sequence is sorted), and the same order is the Steps 2–4 candidate;
* suffix pairs sharing their full v-prefix form *tie groups*; only those
  are resolved with the paper's Lemma-1 comparator
  `rank[i + Λ[k_i][k_j]]`, evaluated on a compacted payload. For realistic
  alphabets the tie set is tiny (expected O(n²/σᵛ) positions), so the
  comparator now touches thousands of rows, not all n — see
  docs/architecture.md for the measured effect. Adversarial inputs
  (periodic / tiny alphabets) are first shrunk by stride-doubling
  refinement rounds so the comparator never sees a large payload.

The sort primitive itself is pluggable (`sort_impl`), because the fastest
correct choice is platform-dependent (see `repro.core.compat`):

==========  =============================================================
"auto"      `compat.default_sort_impl()`: "radix" on CPU, "lax" on TPU/GPU.
"radix"     host-side packed-key sorts: window columns are packed into as
            few 64-bit words as their bit-width allows (streamed off the
            text — the [n, v] window matrix is never materialised), then
            sorted with numpy's introsort (single word) or stable LSD
            passes (multi-word).
"lax"       XLA's native variadic `lax.sort` (multi-key, same trick the
            prefix-doubling base case uses) — the accelerator fast path.
"bitonic"   the legacy fully-fused comparator-bitonic network over all n_v
            payload rows (O(n log² n) compare-exchanges). Kept as an
            executable reference and for `benchmarks/sa_throughput.py`
            regression records.
"pallas"    the Mosaic kernels in `repro.kernels` (row bitonic sort +
            `dense_rank_sorted`); compiled on TPU, `interpret=True`
            elsewhere (correct but slow — CI exercises it at small n).
==========  =============================================================

Shapes are quantised to a geometric bucket grid (`pad_bucket`) when
`bucket=True` so repeated builds of nearby lengths reuse every jitted
computation; `TRACE_COUNTS` records one event per actual jax trace, which
the cache tests in `tests/api/test_sort_impl.py` assert against. The
recursion driver stays in Python (shapes are data-independent functions of
the schedule).
"""
from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .bitonic import bitonic_sort, lex_lt_int, next_pow2, sort_rows_with_index
from .compat import default_sort_impl, pallas_available
from .difference_cover import cover_tables
from .oracle import suffix_array_doubling
from .seq_ref import accelerated_next_v

INT32_MAX = np.int32(np.iinfo(np.int32).max)

#: accepted `sort_impl` values ("auto" resolves via `compat.default_sort_impl`).
SORT_IMPLS = ("auto", "radix", "lax", "bitonic", "pallas")

#: jitted-piece trace counter: name -> number of times jax *traced* (not ran)
#: that piece. A second build of the same bucketed shape must not add events;
#: `tests/api/test_sort_impl.py` enforces it.
TRACE_COUNTS: collections.Counter = collections.Counter()


def trace_events() -> int:
    """Total number of jax traces performed by this module so far."""
    return sum(TRACE_COUNTS.values())


def resolve_sort_impl(sort_impl: str) -> str:
    """Validate `sort_impl` and resolve "auto" for the current platform."""
    if sort_impl not in SORT_IMPLS:
        raise ValueError(f"unknown sort_impl {sort_impl!r}; "
                         f"expected one of {SORT_IMPLS}")
    return default_sort_impl() if sort_impl == "auto" else sort_impl


# --------------------------------------------------------------------------
# shape bucketing — the compiled-builder cache's padding rule
# --------------------------------------------------------------------------
#: lengths below this are never bucketed (trace cost is negligible there).
_BUCKET_MIN = 512


def pad_bucket(n: int) -> int:
    """Smallest grid length ≥ n, grid = {2^k · q/4 : q ∈ {4,5,6,7}}.

    Quantising every level's length to this geometric grid (ratio ≤ 1.25,
    so ≤ 25% padding overhead) collapses the open-ended family of input
    lengths onto O(log n) distinct shapes, so jax's jit cache — and the
    builder cache in `repro.api.build` — get hits instead of re-traces when
    serving many nearby lengths.
    """
    if n <= _BUCKET_MIN:
        return n
    base = 1 << (n - 1).bit_length() - 1          # largest power of two < n
    for q in (4, 5, 6, 7):
        cand = base * q // 4
        if cand >= n:
            return cand
    return base * 2


# --------------------------------------------------------------------------
# per-level constants (shared across builds; part of the builder-cache
# contract in repro.api.build)
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=256)
def _level_constants(n_v: int, v: int):
    """Host- and device-side constants for one (n_v, v) level shape.

    Returns (sample_pos int64[m], inv_sample int64[n_v], in_D bool[v],
    shifts int64[v,|D|], lam1/lam2 np int64[v,v], lam1/lam2 jnp int32[v,v]).
    lru-cached so repeated bucketed builds skip both the table construction
    and the host→device copies.
    """
    tabs = cover_tables(v)
    per_block = n_v // v
    sample_pos = (np.asarray(tabs.D, np.int64)[:, None]
                  + np.arange(per_block, dtype=np.int64)[None, :] * v
                  ).reshape(-1)
    inv_sample = np.full(n_v, -1, dtype=np.int64)
    inv_sample[sample_pos] = np.arange(len(sample_pos), dtype=np.int64)
    return (
        sample_pos,
        inv_sample,
        np.asarray(tabs.in_D, bool),
        np.asarray(tabs.shifts, np.int64),
        np.asarray(tabs.lam_idx1, np.int64),
        np.asarray(tabs.lam_idx2, np.int64),
        jnp.asarray(tabs.lam_idx1, jnp.int32),
        jnp.asarray(tabs.lam_idx2, jnp.int32),
    )


# --------------------------------------------------------------------------
# pluggable window-sort primitives
# --------------------------------------------------------------------------
def _window_words(xp: np.ndarray, n_v: int, v: int, lo: int, hi: int):
    """Pack the v-character windows at positions [0, n_v) into uint64 words.

    Values (in [lo, hi]; lo < 0 covers the distinct pad sentinels) are
    shifted to non-negative and packed most-significant-column-first,
    `64 // bits` columns per word, so that comparing the word list
    lexicographically equals comparing windows lexicographically. The words
    are built by strided reads straight off the padded text — the [n_v, v]
    window matrix is never materialised.
    """
    bits = max(1, int(hi - lo).bit_length())
    per_word = max(1, 64 // bits)
    shift = np.uint64(bits)
    words = []
    for start in range(0, v, per_word):
        w = np.zeros(n_v, dtype=np.uint64)
        for c in range(start, min(start + per_word, v)):
            w = (w << shift) | (xp[c:c + n_v] - lo).astype(np.uint64)
        words.append(w)
    return words


def _order_from_words(words):
    """Lexicographic argsort of packed word lists, MSD with compaction.

    One introsort on the most-significant word orders almost everything for
    high-entropy alphabets; later words only re-sort the (compacted) runs
    that are still tied — far cheaper than LSD's full-length stable passes.
    Returns (perm int64[N], is_start bool[N]): `is_start` marks the row-
    equality run boundaries along perm, which callers reuse as the tie-group
    seed (ties may land in any order inside a run).
    """
    perm = np.argsort(words[0]).astype(np.int64)
    n = len(perm)
    is_start = np.ones(n, dtype=bool)
    sw = words[0][perm]
    if n > 1:
        is_start[1:] = sw[1:] != sw[:-1]
    for w in words[1:]:
        start_slot = np.flatnonzero(is_start)
        run_id = np.cumsum(is_start) - 1
        sizes = np.diff(start_slot, append=n)
        sl = np.flatnonzero(sizes[run_id] > 1)
        if len(sl) == 0:
            break
        p = perm[sl]
        rid = run_id[sl]
        local = np.lexsort((w[p], rid))
        perm[sl] = p[local]
        wv = w[perm[sl]]
        if len(sl) > 1:
            is_start[sl[1:]] = (rid[1:] != rid[:-1]) | (wv[1:] != wv[:-1])
    return perm, is_start


@jax.jit
def _argsort_cols_lax(cols):
    """Variadic lax.sort over window columns + index → permutation."""
    # saca-lint: allow[TRACE001] deliberate: trace-time retrace counter, mutated only while tracing, read by tests via total_traces()
    TRACE_COUNTS["argsort_cols_lax"] += 1
    n = cols[0].shape[0]
    operands = tuple(cols) + (jnp.arange(n, dtype=jnp.int32),)
    return jax.lax.sort(operands, num_keys=len(cols) + 1)[-1]


def _argsort_rows_pallas(rows: np.ndarray) -> np.ndarray:
    """Row sort on the Pallas bitonic kernel: append an index column (total
    order), pad to a power of two with +inf rows, sort, read the index."""
    from ..kernels.ops import bitonic_sort as kernel_bitonic_sort
    n, w = rows.shape
    n2 = next_pow2(n)
    body = np.concatenate(
        [rows.astype(np.int32), np.arange(n, dtype=np.int32)[:, None]],
        axis=1)
    if n2 > n:
        pad = np.full((n2 - n, w + 1), INT32_MAX, dtype=np.int32)
        body = np.concatenate([body, pad], axis=0)
    out = kernel_bitonic_sort(jnp.asarray(body), num_keys=w + 1,
                              interpret=not pallas_available())
    perm = np.asarray(out)[:, -1]
    return perm[perm < n].astype(np.int64)


def _window_order(xp: np.ndarray, n_v: int, v: int, lo: int, hi: int,
                  impl: str):
    """Sort all n_v window rows with the chosen impl.

    Returns (order int64[n_v], rep, is_start bool[n_v]): `rep` is a list of
    position-indexed arrays whose element-wise equality equals full-row
    equality — packed words for "radix", the raw shifted columns otherwise;
    `is_start` marks the row-equality run boundaries along `order`.
    """
    if impl == "radix":
        words = _window_words(xp, n_v, v, lo, hi)
        order, is_start = _order_from_words(words)
        return order, words, is_start
    cols = [np.ascontiguousarray(xp[c:c + n_v]) for c in range(v)]
    if impl == "pallas":
        order = _argsort_rows_pallas(np.stack(cols, axis=1))
    else:
        order = np.asarray(_argsort_cols_lax(
            tuple(jnp.asarray(c, jnp.int32) for c in cols))).astype(np.int64)
    is_start = np.ones(n_v, dtype=bool)
    if n_v > 1:
        is_start[1:] = _rows_neq(cols, order[1:], order[:-1])
    return order, cols, is_start


def _rows_neq(rep, pa: np.ndarray, pb: np.ndarray) -> np.ndarray:
    """Element-wise "window at pa differs from window at pb" via `rep`."""
    neq = rep[0][pa] != rep[0][pb]
    for w in rep[1:]:
        neq |= w[pa] != w[pb]
    return neq


# --------------------------------------------------------------------------
# prefix-doubling base case (also the "oracle" spine) — kept jitted for the
# lax/pallas paths; the radix path uses the host doubling reference.
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n", "steps"))
def suffix_array_doubling_jax(x: jnp.ndarray, n: int, steps: int) -> jnp.ndarray:
    """Prefix-doubling base case (Manber–Myers), log n rounds of lax.sort."""
    # saca-lint: allow[TRACE001] deliberate: trace-time retrace counter, mutated only while tracing, read by tests via total_traces()
    TRACE_COUNTS["doubling_jax"] += 1
    idx = jnp.arange(n, dtype=jnp.int32)
    x = x.astype(jnp.int32)

    def dense_rank(k1, k2):
        _, _, perm = jax.lax.sort((k1, k2, idx), num_keys=3)
        s1, s2 = k1[perm], k2[perm]
        boundary = jnp.ones(n, dtype=jnp.int32)
        if n > 1:
            neq = (s1[1:] != s1[:-1]) | (s2[1:] != s2[:-1])
            boundary = boundary.at[1:].set(neq.astype(jnp.int32))
        ranks_sorted = jnp.cumsum(boundary) - 1
        rank = jnp.zeros(n, dtype=jnp.int32).at[perm].set(ranks_sorted)
        return rank, perm

    rank, perm = dense_rank(x, jnp.zeros_like(x))
    for s in range(steps):
        h = 1 << s
        shifted = jnp.concatenate([rank[h:], jnp.full((min(h, n),), -1, jnp.int32)])[:n]
        rank, perm = dense_rank(rank, shifted)
    return perm


def _suffix_array_base(x_np: np.ndarray, impl: str) -> np.ndarray:
    """Recursion cutoff: sort a short text directly by prefix doubling."""
    n = len(x_np)
    if impl == "radix":
        return suffix_array_doubling(x_np.astype(np.int64)).astype(np.int32)
    steps = max(1, int(np.ceil(np.log2(max(n, 2)))) + 1)
    return np.asarray(
        suffix_array_doubling_jax(jnp.asarray(x_np, jnp.int32), n, steps))


# --------------------------------------------------------------------------
# legacy fully-fused bitonic path (sort_impl="bitonic")
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("v", "m"))
def _encode_sample(xp: jnp.ndarray, sample_pos: jnp.ndarray, v: int, m: int):
    """Step 1 (first half): rank super-characters; X' + distinct flag."""
    # saca-lint: allow[TRACE001] deliberate: trace-time retrace counter, mutated only while tracing, read by tests via total_traces()
    TRACE_COUNTS["encode_sample_lax"] += 1
    W = xp[sample_pos[:, None] + jnp.arange(v, dtype=jnp.int32)[None, :]]
    perm = sort_rows_with_index(W, v)
    Ws = W[perm]
    boundary = jnp.ones(m, dtype=jnp.int32)
    if m > 1:
        boundary = boundary.at[1:].set(
            jnp.any(Ws[1:] != Ws[:-1], axis=1).astype(jnp.int32))
    ranks_sorted = jnp.cumsum(boundary) - 1
    Xp = jnp.zeros(m, dtype=jnp.int32).at[perm].set(ranks_sorted)
    sa_rank_direct = jnp.zeros(m, dtype=jnp.int32).at[perm].set(
        jnp.arange(m, dtype=jnp.int32))
    distinct = jnp.all(boundary == 1)
    return Xp, distinct, sa_rank_direct


@functools.partial(jax.jit, static_argnames=("v", "n_v"))
def _fused_final_sort(
    xp: jnp.ndarray,
    sample_pos: jnp.ndarray,
    sa_rank: jnp.ndarray,
    shifts_tab: jnp.ndarray,     # int32[v, |D|]
    lam_i1: jnp.ndarray,         # int32[v, v]
    lam_i2: jnp.ndarray,         # int32[v, v]
    v: int,
    n_v: int,
) -> jnp.ndarray:
    """Fused Steps 2–4: one comparator-bitonic sort of all n_v suffixes.

    O(n log² n) compare-exchanges over the full payload — kept as the
    executable reference the keyed paths are tested against, and as the
    `sort_impl="bitonic"` regression row in BENCH_sa_throughput.json.
    """
    # saca-lint: allow[TRACE001] deliberate: trace-time retrace counter, mutated only while tracing, read by tests via total_traces()
    TRACE_COUNTS["fused_final_sort_bitonic"] += 1
    dsize = shifts_tab.shape[1]
    rank = jnp.full(n_v + v, -1, dtype=jnp.int32).at[sample_pos].set(sa_rank)

    pos = jnp.arange(n_v, dtype=jnp.int32)
    chars = xp[pos[:, None] + jnp.arange(v, dtype=jnp.int32)[None, :]]
    klass = pos % v
    rvals = rank[pos[:, None] + shifts_tab[klass]]          # [n_v, |D|]

    n2 = next_pow2(n_v)
    pad = n2 - n_v
    payload = {
        "chars": jnp.concatenate(
            [chars, jnp.full((pad, v), INT32_MAX, jnp.int32)], axis=0),
        "ranks": jnp.concatenate(
            [rvals, jnp.zeros((pad, dsize), jnp.int32)], axis=0),
        "klass": jnp.concatenate(
            [klass, jnp.zeros((pad,), jnp.int32)], axis=0),
        "idx": jnp.concatenate(
            [pos, n_v + jnp.arange(pad, dtype=jnp.int32)], axis=0),
    }

    def lt_fn(a, b):
        char_lt, char_eq = lex_lt_int(a["chars"], b["chars"])
        ka, kb = a["klass"], b["klass"]
        ra = jnp.take_along_axis(a["ranks"], lam_i1[ka, kb][:, None], axis=1)[:, 0]
        rb = jnp.take_along_axis(b["ranks"], lam_i2[ka, kb][:, None], axis=1)[:, 0]
        rank_decides = char_eq & (ra != rb)
        return jnp.where(
            rank_decides, ra < rb,
            jnp.where(char_eq, a["idx"] < b["idx"], char_lt))

    out = bitonic_sort(payload, lt_fn)
    return out["idx"][:n_v]   # pads carry INT32_MAX chars → sorted last


# --------------------------------------------------------------------------
# Lemma-1 tie resolution for the keyed paths
# --------------------------------------------------------------------------
@jax.jit
def _lambda_tiebreak_jit(seg, rvals, klass, pos, lam_i1, lam_i2):
    """Sort the compacted tie payload by (tie group, Lemma-1 rank, index).

    All rows inside one `seg` group share their full v-character prefix, so
    the paper's Lemma-1 comparison degenerates to a pure rank lookup:
    `rank[i + Λ[k_i][k_j]]` via the per-class local index tables. Pad rows
    carry seg=INT32_MAX and sort to the back. Callers pad to powers of two,
    so the jit cache holds at most log₂(n) entries.
    """
    # saca-lint: allow[TRACE001] deliberate: trace-time retrace counter, mutated only while tracing, read by tests via total_traces()
    TRACE_COUNTS["lambda_tiebreak"] += 1
    payload = {"seg": seg, "ranks": rvals, "klass": klass, "idx": pos}

    def lt_fn(a, b):
        seg_lt = a["seg"] < b["seg"]
        seg_eq = a["seg"] == b["seg"]
        ka, kb = a["klass"], b["klass"]
        ra = jnp.take_along_axis(a["ranks"], lam_i1[ka, kb][:, None], axis=1)[:, 0]
        rb = jnp.take_along_axis(b["ranks"], lam_i2[ka, kb][:, None], axis=1)[:, 0]
        rank_decides = seg_eq & (ra != rb)
        return jnp.where(rank_decides, ra < rb,
                         jnp.where(seg_eq, a["idx"] < b["idx"], seg_lt))

    return bitonic_sort(payload, lt_fn)["idx"]


#: tie groups wider than this run on the jitted device network; narrower
#: ones (the overwhelmingly common case) run the same bitonic schedule
#: lane-parallel in numpy, skipping the device round-trip entirely.
_HOST_LANE_MAX = 16


def _lambda_tiebreak_host(p, lane, row_of, n_rows, g2, rvals, klass,
                          lam1_np, lam2_np) -> np.ndarray:
    """Lane-parallel bitonic over [n_rows, g2] tie groups, vectorised in
    numpy: one compare-exchange stage = one vectorised Lemma-1 comparator
    evaluation across every group at once. Pads (-1) act as +inf."""
    mat = np.full((n_rows, g2), -1, dtype=np.int64)
    mat[row_of, lane] = np.arange(len(p), dtype=np.int64)
    idxv = p

    def lt(a, b):
        ac = np.clip(a, 0, None)
        bc = np.clip(b, 0, None)
        ka, kb = klass[ac], klass[bc]
        ra = rvals[ac, lam1_np[ka, kb]]
        rb = rvals[bc, lam2_np[ka, kb]]
        res = np.where(ra != rb, ra < rb, idxv[ac] < idxv[bc])
        return np.where(a < 0, False, np.where(b < 0, True, res))

    lanes = np.arange(g2)
    k = 2
    while k <= g2:
        j = k // 2
        while j >= 1:
            partner = lanes ^ j
            other = mat[:, partner]
            up = (lanes & k) == 0
            lower = lanes < partner
            keep = (lt(mat, other) == lower[None, :]) == up[None, :]
            mat = np.where(keep, mat, other)
            j //= 2
        k *= 2
    return p[mat[mat >= 0]]          # row-major: groups in slot order


# --------------------------------------------------------------------------
# keyed final phase (sort_impl = "radix" / "lax" / "pallas")
# --------------------------------------------------------------------------
#: tie sets larger than max(this, n_v/8) are first shrunk by stride-doubling
#: refinement rounds before any comparator runs — keeps adversarial inputs
#: (tiny alphabets, periodic texts) off the O(U log² U) network.
_TIEBREAK_COMPACT_MAX = 1024


def _resolve_ties(order, is_start, rank, shifts_np, lam1_np, lam2_np,
                  lam1_jnp, lam2_jnp, v: int, n_v: int) -> np.ndarray:
    """Steps 2–4 second half: refine the window-sorted candidate order.

    `order` sorts all n_v suffixes by their v-character window; `is_start`
    marks tie-group boundaries along it. While the tie set is large
    (adversarial inputs), stride-doubling refinement rounds shrink it using
    the group ranks themselves as keys (classic Manber–Myers, seeded at
    resolution v); the residue is resolved by the Lemma-1 comparator on a
    compacted payload — lane-parallel in numpy for narrow groups, the
    jitted bitonic network for wide ones.
    """
    def run_state(is_start):
        start_slot = np.flatnonzero(is_start)
        run_id = np.cumsum(is_start) - 1                  # per slot
        r_sorted = start_slot[run_id]                     # rank-with-ties
        sizes = np.diff(start_slot, append=n_v)
        return start_slot, run_id, r_sorted, sizes

    start_slot, run_id, r_sorted, sizes = run_state(is_start)
    r_pos = np.empty(n_v, dtype=np.int64)
    r_pos[order] = r_sorted
    unresolved = sizes[run_id] > 1
    U = int(unresolved.sum())
    if U == 0:
        return order

    # Refinement: slots in one run share their first `stride` characters,
    # so (r_pos[i], r_pos[i+stride]) is a valid refinement key.
    stride = v
    cap = max(_TIEBREAK_COMPACT_MAX, n_v >> 3)
    while U > cap and stride < n_v:
        sl = np.flatnonzero(unresolved)
        p = order[sl]
        nxt = p + stride
        key = np.where(nxt < n_v, r_pos[np.minimum(nxt, n_v - 1)], -1)
        packed = (r_pos[p] << 32) | (key + 1)             # both < 2^31
        local = np.argsort(packed, kind="stable")
        order[sl] = p[local]
        pk = packed[local]
        if len(sl) > 1:
            # run starts re-emerge via the high bits; interiors refine.
            is_start[sl[1:]] = pk[1:] != pk[:-1]
        start_slot, run_id, r_sorted, sizes = run_state(is_start)
        r_pos[order] = r_sorted
        unresolved = sizes[run_id] > 1
        U = int(unresolved.sum())
        stride *= 2
    if U == 0:
        return order

    # Lemma-1 comparator on the compacted ties only.
    sl = np.flatnonzero(unresolved)
    p = order[sl]
    klass = p % v
    rvals = rank[p[:, None] + shifts_np[klass]]
    lane = sl - start_slot[run_id[sl]]
    g2 = next_pow2(int(lane.max()) + 1)
    if g2 <= _HOST_LANE_MAX:
        rows, row_of = np.unique(run_id[sl], return_inverse=True)
        order[sl] = _lambda_tiebreak_host(
            p, lane, row_of, len(rows), g2, rvals, klass, lam1_np, lam2_np)
        return order

    n2 = next_pow2(U)
    seg_p = np.full(n2, INT32_MAX, dtype=np.int32)
    rv_p = np.zeros((n2, shifts_np.shape[1]), dtype=np.int32)
    kl_p = np.zeros(n2, dtype=np.int32)
    pos_p = np.full(n2, INT32_MAX, dtype=np.int32)
    seg_p[:U] = r_pos[p]
    rv_p[:U] = rvals
    kl_p[:U] = klass
    pos_p[:U] = p
    out = np.asarray(_lambda_tiebreak_jit(
        jnp.asarray(seg_p), jnp.asarray(rv_p), jnp.asarray(kl_p),
        jnp.asarray(pos_p), lam1_jnp, lam2_jnp))
    order[sl] = out[:U]
    return order


# --------------------------------------------------------------------------
# recursion driver
# --------------------------------------------------------------------------
def suffix_array_jax(
    x,
    v: int = 3,
    schedule=accelerated_next_v,
    base_threshold: int | None = None,
    sort_impl: str = "auto",
    bucket: bool = False,
) -> np.ndarray:
    """Suffix array of x (ints ≥ 0, < 2³¹) — vectorised JAX DC-v.

    Parameters
    ----------
    x : 1-D integer sequence (tokens / bytes).
    v : initial difference-cover modulus (paper Algorithm 1).
    schedule : ``(v, |D|, m) -> v'`` — the paper's accelerated v-schedule
        by default.
    base_threshold : recursion cutoff; below it a prefix-doubling sort runs
        directly. ``None`` picks the impl's tuned default (radix: 1024 —
        the host doubling base beats 2-3 more tiny DC levels; others: 256).
    sort_impl : one of `SORT_IMPLS`; see the module docstring.
    bucket : pad every level's length up to the `pad_bucket` grid so
        repeated builds of nearby lengths reuse all jitted computations
        (`repro.api.build` enables this for its builder cache).

    Returns np.int32[n], a permutation of range(n).
    """
    impl = resolve_sort_impl(sort_impl)
    if base_threshold is None:
        base_threshold = 1024 if impl == "radix" else 256
    x = np.asarray(x)
    n = int(len(x))
    if n == 0:
        return np.zeros(0, dtype=np.int32)
    if n == 1:
        return np.zeros(1, dtype=np.int32)

    def rec(x_np: np.ndarray, v: int) -> np.ndarray:
        n = len(x_np)
        if n <= max(base_threshold, v, 4):
            return _suffix_array_base(x_np, impl)
        n_b = pad_bucket(n) if bucket else n
        v = int(min(max(v, 3), n_b))
        tabs = cover_tables(v)
        n_v = v * int(np.ceil(n_b / v))
        # Pad with *distinct, decreasing* negative sentinels. Distinctness
        # matters: equal sentinels would form giant tie groups and defeat
        # the `distinct` recursion short-circuit once bucketing makes the
        # pad region large. Correctness needs only "below the alphabet":
        # the first differing window column between two real suffixes is
        # never pad-vs-pad (pad values are position-unique), so the
        # sentinels' relative order never decides a real comparison.
        xp_np = np.empty(n_v + 2 * v, dtype=np.int64)
        xp_np[:n] = x_np
        npad = n_v + 2 * v - n
        xp_np[n:] = -1 - np.arange(npad, dtype=np.int64)
        (sample_pos, inv_sample, in_D, shifts_np,
         lam1_np, lam2_np, lam1_jnp, lam2_jnp) = _level_constants(n_v, v)
        m = len(sample_pos)
        lo, hi = -npad, int(x_np.max(initial=0))

        if impl == "bitonic":
            xp = jnp.asarray(xp_np, jnp.int32)
            sp_dev = jnp.asarray(sample_pos, jnp.int32)
            Xp_dev, distinct_dev, sa_rank_dev = _encode_sample(
                xp, sp_dev, v, m)
            Xp = np.asarray(Xp_dev).astype(np.int64)
            distinct = bool(distinct_dev)
            sa_rank = np.asarray(sa_rank_dev).astype(np.int64)
            if not distinct:
                v_next = schedule(v, len(tabs.D), m)
                sa_sub = rec(Xp, v_next)
                sa_rank = np.zeros(m, dtype=np.int64)
                sa_rank[sa_sub] = np.arange(m, dtype=np.int64)
            sa_full = np.asarray(_fused_final_sort(
                xp, sp_dev, jnp.asarray(sa_rank, jnp.int32),
                jnp.asarray(tabs.shifts, jnp.int32),
                lam1_jnp, lam2_jnp, v, n_v))
            return sa_full[sa_full < n]

        # --- keyed paths: ONE window sort feeds Step 1 AND Steps 2–4 ---
        order, rep, is_start = _window_order(xp_np, n_v, v, lo, hi, impl)

        # Step 1: sample ranks = the window order filtered to sample
        # positions (a stable subsequence of a sorted sequence is sorted).
        s_slots = np.flatnonzero(in_D[order % v])
        sp = order[s_slots]                       # sample pos, window-sorted
        si = inv_sample[sp]
        if impl == "pallas" and m > 1:
            from ..kernels.ops import dense_rank_sorted
            rows_s = np.stack([c[sp] for c in rep], axis=1)
            ranks_dev, _ = dense_rank_sorted(
                jnp.asarray(rows_s, jnp.int32),
                interpret=not pallas_available())
            ranks_sorted = np.asarray(ranks_dev).astype(np.int64)
            distinct = bool(ranks_sorted[-1] == m - 1)
        else:
            sb = np.ones(m, dtype=bool)
            if m > 1:
                sb[1:] = _rows_neq(rep, sp[1:], sp[:-1])
            ranks_sorted = np.cumsum(sb) - 1
            distinct = bool(ranks_sorted[-1] == m - 1)
        sa_rank = np.empty(m, dtype=np.int64)
        if distinct:
            sa_rank[si] = np.arange(m, dtype=np.int64)
        else:
            Xp = np.empty(m, dtype=np.int64)
            Xp[si] = ranks_sorted
            v_next = schedule(v, len(tabs.D), m)
            sa_sub = rec(Xp, v_next)
            sa_rank[sa_sub] = np.arange(m, dtype=np.int64)

        # Steps 2–4: refine the shared window order with Lemma-1 ranks.
        rank = np.full(n_v + v, -1, dtype=np.int64)
        rank[sample_pos] = sa_rank
        sa_full = _resolve_ties(order, is_start, rank, shifts_np,
                                lam1_np, lam2_np, lam1_jnp, lam2_jnp, v, n_v)
        return sa_full[sa_full < n]

    return rec(x.astype(np.int64), v).astype(np.int32)
