"""Comparator-based bitonic sort in JAX.

XLA exposes only key-based sorts (`lax.sort`), but the paper's Step-4 merge
compares suffixes through the Lemma-1 offset `Λ[k_i][k_j]`, i.e. with a
*pairwise* comparator that cannot be expressed as a lexicographic key. A
bitonic network with a branchless compare-exchange is the TPU-idiomatic
answer: oblivious data movement, O(log² N) stages, every stage a vectorised
gather + select that runs at VPU rate (DESIGN.md §3.2/§3.3).

The comparator must be a *strict total order* (break ties by a unique index
column) so both elements of a pair agree on the exchange direction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _stage_schedule(n_pow2: int) -> np.ndarray:
    """All (k, j) bitonic stages for size n_pow2, as an int32[S, 2] array."""
    stages = []
    k = 2
    while k <= n_pow2:
        j = k // 2
        while j >= 1:
            stages.append((k, j))
            j //= 2
        k *= 2
    return np.asarray(stages, dtype=np.int32).reshape(-1, 2)


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def bitonic_sort(payload: dict, lt_fn, *, unroll: bool = False) -> dict:
    """Sort `payload` (dict of arrays sharing leading dim N, a power of two)
    ascending by the strict total order `lt_fn(a, b) -> bool[N]`.

    `lt_fn` receives two payload dicts (self, partner) and must return
    element-wise "self strictly precedes partner". Ties must be impossible
    (give every element a unique tiebreak column).
    """
    leaves = jax.tree_util.tree_leaves(payload)
    n = leaves[0].shape[0]
    assert n & (n - 1) == 0, f"bitonic_sort needs power-of-two length, got {n}"
    if n <= 1:
        return payload
    schedule = jnp.asarray(_stage_schedule(n))
    idx = jnp.arange(n, dtype=jnp.int32)

    def one_stage(payload, kj):
        k, j = kj[0], kj[1]
        partner = idx ^ j
        up = (idx & k) == 0
        other = jax.tree_util.tree_map(lambda t: t[partner], payload)
        lt = lt_fn(payload, other)
        lower = idx < partner
        # pair (low, high): low ends up with min iff ascending. Element keeps
        # its own value iff  (lt(self,partner) == lower) == up.
        keep = ((lt == lower) == up)
        return jax.tree_util.tree_map(
            lambda a, b: jnp.where(
                keep.reshape((-1,) + (1,) * (a.ndim - 1)), a, b
            ),
            payload, other,
        )

    if unroll:
        for s in np.asarray(schedule):
            payload = one_stage(payload, jnp.asarray(s))
        return payload

    def body(s, payload):
        return one_stage(payload, schedule[s])

    return jax.lax.fori_loop(0, schedule.shape[0], body, payload)


def lex_lt_int(a_cols: jnp.ndarray, b_cols: jnp.ndarray):
    """Vectorised lexicographic (lt, all_eq) over trailing axis of int cols.

    a_cols, b_cols: int[N, W]. Returns (lt: bool[N], eq: bool[N]) without
    unrolling over W (argmax-of-first-difference trick).
    """
    neq = a_cols != b_cols
    any_neq = jnp.any(neq, axis=-1)
    first = jnp.argmax(neq, axis=-1)  # 0 when all equal (masked by any_neq)
    a_star = jnp.take_along_axis(a_cols, first[:, None], axis=-1)[:, 0]
    b_star = jnp.take_along_axis(b_cols, first[:, None], axis=-1)[:, 0]
    lt = jnp.where(any_neq, a_star < b_star, False)
    return lt, ~any_neq


@functools.partial(jax.jit, static_argnames=("num_cols",))
def sort_rows_with_index(cols: jnp.ndarray, num_cols: int):
    """Key-based row sort via variadic lax.sort: returns permutation.

    cols: int32[N, W] with W == num_cols. Final tiebreak = row index, making
    the sort stable and the permutation unique.
    """
    n = cols.shape[0]
    operands = tuple(cols[:, c] for c in range(num_cols)) + (
        jnp.arange(n, dtype=jnp.int32),
    )
    out = jax.lax.sort(operands, num_keys=num_cols + 1)
    return out[-1]
