"""Feed-forward layers: SwiGLU dense MLP and sort-free capacity-based MoE
with true expert parallelism (single symmetric all_to_all pair over the
`model` mesh axis, DeepSeek/Switch-style capacity-factor semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..bsp.primitives import within_group_index
from ..core.compat import shard_map
from .layers import COMPUTE_DTYPE, activation


# --------------------------------------------------------------------------
# dense SwiGLU
# --------------------------------------------------------------------------
def init_mlp(col, prefix: str, cfg):
    col.param(f"{prefix}.wg", (cfg.d_model, cfg.d_ff), ("embed_fsdp", "mlp"))
    col.param(f"{prefix}.wu", (cfg.d_model, cfg.d_ff), ("embed_fsdp", "mlp"))
    col.param(f"{prefix}.wd", (cfg.d_ff, cfg.d_model),
              ("mlp", "embed_fsdp"),
              scale=0.02 / np.sqrt(2 * cfg.n_layers))


def _pin(t, mesh, spec_builder):
    if mesh is None:
        return t
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = spec_builder(mesh)
    if spec is None:
        return t
    return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))


def _ffn_spec(t_shape, ff: int):
    """[B, S, ff] → (dp, None, model-if-divisible): forbids partial-sum
    outputs, so XLA resolves the FSDP contraction by all-gathering the
    (small) weight shard instead of all-reducing the (huge) activation
    (§Perf iteration 8)."""
    def build(mesh):
        from jax.sharding import PartitionSpec as P
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        msz = mesh.shape.get("model", 1)
        dpsz = 1
        for a in dp:
            dpsz *= mesh.shape[a]
        b_ok = dp and t_shape[0] % dpsz == 0
        f_ok = "model" in mesh.axis_names and ff % msz == 0
        if not (b_ok or f_ok):
            return None
        return P(dp if b_ok else None, None, "model" if f_ok else None)
    return build


def mlp_layer(p, cfg, x, mesh=None):
    act = activation(cfg.act)
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype),
                   preferred_element_type=COMPUTE_DTYPE)
    g = _pin(g, mesh, _ffn_spec(g.shape, cfg.d_ff))
    u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(x.dtype),
                   preferred_element_type=COMPUTE_DTYPE)
    u = _pin(u, mesh, _ffn_spec(u.shape, cfg.d_ff))
    h = (act(g) * u).astype(COMPUTE_DTYPE)
    return jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(h.dtype),
                      preferred_element_type=COMPUTE_DTYPE)


# --------------------------------------------------------------------------
# MoE (expert parallel)
# --------------------------------------------------------------------------
def init_moe(col, prefix: str, cfg):
    E = cfg.n_experts
    col.param(f"{prefix}.router", (cfg.d_model, E), ("embed", None))
    col.param(f"{prefix}.wg", (E, cfg.d_model, cfg.d_ff),
              ("experts", "embed_fsdp", "expert_mlp"))
    col.param(f"{prefix}.wu", (E, cfg.d_model, cfg.d_ff),
              ("experts", "embed_fsdp", "expert_mlp"))
    col.param(f"{prefix}.wd", (E, cfg.d_ff, cfg.d_model),
              ("experts", "expert_mlp", "embed_fsdp"),
              scale=0.02 / np.sqrt(2 * cfg.n_layers))


def _moe_local(x, wr, wg, wu, wd, *, cfg, tp: int, axis: str | None):
    """Local-shard MoE body. x [T, d]; wg/wu/wd [E_loc, d, ff]/[E_loc, ff, d].

    When axis is None (single shard / smoke) tp == 1 and no collectives run.
    Returns ([T, d], aux_loss)."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // tp
    act = activation(cfg.act)

    logits = jnp.einsum("td,de->te", x, wr.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)                    # [T, k]
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch): E · Σ_e f_e · P_e
    me_frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, E, dtype=jnp.float32), axis=1), axis=0)
    pr_frac = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(me_frac * pr_frac)

    ids_f = ids.reshape(-1)                                # [T*k]
    gate_f = gate.reshape(-1)
    src_f = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    owner = ids_f // E_loc
    valid = jnp.ones_like(ids_f, dtype=bool)
    cap = int(cfg.capacity_factor * T * k / tp) + 8
    slot = within_group_index(owner, valid)
    keep = slot < cap

    tok_buf = jnp.zeros((tp, cap, d), COMPUTE_DTYPE)
    meta_buf = jnp.full((tp, cap, 1), -1, jnp.int32)
    ow = jnp.where(keep, owner, tp)
    tok_buf = tok_buf.at[ow, slot].set(
        x.astype(COMPUTE_DTYPE)[src_f], mode="drop")
    meta_buf = meta_buf.at[ow, slot, 0].set(ids_f % E_loc, mode="drop")

    if axis is not None:
        tok_buf = jax.lax.all_to_all(tok_buf, axis, 0, 0, tiled=False)
        meta_buf = jax.lax.all_to_all(meta_buf, axis, 0, 0, tiled=False)

    R = tp * cap
    toks = tok_buf.reshape(R, d)
    eid = meta_buf.reshape(R)
    ev = eid >= 0
    cap_e = int(cfg.capacity_factor * T * k * tp / E) + 8
    eslot = within_group_index(eid, ev)
    ekeep = ev & (eslot < cap_e)
    e_ix = jnp.where(ekeep, eid, E_loc)
    ebuf = jnp.zeros((E_loc, cap_e, d), COMPUTE_DTYPE)
    ebuf = ebuf.at[e_ix, eslot].set(toks, mode="drop")
    rmap = jnp.full((E_loc, cap_e), -1, jnp.int32)
    rmap = rmap.at[e_ix, eslot].set(jnp.arange(R, dtype=jnp.int32),
                                    mode="drop")

    g = jnp.einsum("ecd,edf->ecf", ebuf, wg.astype(ebuf.dtype),
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", ebuf, wu.astype(ebuf.dtype),
                   preferred_element_type=jnp.float32)
    h = (act(g) * u).astype(COMPUTE_DTYPE)
    y = jnp.einsum("ecf,efd->ecd", h, wd.astype(h.dtype),
                   preferred_element_type=jnp.float32).astype(COMPUTE_DTYPE)

    # symmetric return path: place results back in arrival slots, a2a back
    y_flat = jnp.zeros((R, d), COMPUTE_DTYPE)
    rix = jnp.where(rmap >= 0, rmap, R).reshape(-1)
    y_flat = y_flat.at[rix].set(y.reshape(-1, d), mode="drop")
    y_buf = y_flat.reshape(tp, cap, d)
    if axis is not None:
        y_buf = jax.lax.all_to_all(y_buf, axis, 0, 0, tiled=False)

    got = y_buf[ow.clip(0, tp - 1), slot]                  # [T*k, d]
    got = jnp.where((keep & valid)[:, None], got, 0)
    out = jnp.zeros((T, d), jnp.float32).at[src_f].add(
        got.astype(jnp.float32) * gate_f[:, None])
    return out.astype(COMPUTE_DTYPE), aux


def _moe_decode_local(x, wr, wg, wu, wd, *, cfg, tp: int, axis: str | None):
    """Replicated-token, expert-sliced MoE for small-S decode: every shard
    routes all T tokens, computes only its local experts' contributions, and
    the partial outputs are psum'd over the expert axis. No all_to_all."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // tp
    act = activation(cfg.act)
    me = jax.lax.axis_index(axis) if axis is not None else 0

    logits = jnp.einsum("td,de->te", x, wr.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    ids_f = ids.reshape(-1)
    gate_f = gate.reshape(-1)
    src_f = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    mine = (ids_f // E_loc) == me
    eid = jnp.where(mine, ids_f % E_loc, E_loc)
    cap_e = max(8, int(cfg.capacity_factor * T * k / max(E_loc, 1)) + 8)
    eslot = within_group_index(eid, mine)
    keep = mine & (eslot < cap_e)
    e_ix = jnp.where(keep, eid, E_loc)
    ebuf = jnp.zeros((E_loc, cap_e, d), COMPUTE_DTYPE)
    ebuf = ebuf.at[e_ix, eslot].set(x.astype(COMPUTE_DTYPE)[src_f],
                                    mode="drop")
    g = jnp.einsum("ecd,edf->ecf", ebuf, wg.astype(ebuf.dtype),
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", ebuf, wu.astype(ebuf.dtype),
                   preferred_element_type=jnp.float32)
    h = (act(g) * u).astype(COMPUTE_DTYPE)
    y = jnp.einsum("ecf,efd->ecd", h, wd.astype(h.dtype),
                   preferred_element_type=jnp.float32)

    got = y[e_ix.clip(0, E_loc - 1), eslot]
    got = jnp.where(keep[:, None], got, 0)
    out = jnp.zeros((T, d), jnp.float32).at[src_f].add(
        got.astype(jnp.float32) * gate_f[:, None])
    if axis is not None:
        out = jax.lax.psum(out, axis)
    return out.astype(COMPUTE_DTYPE), jnp.float32(0.0)


def moe_layer(p, cfg, x, *, mesh=None, dp_axes=("pod", "data"),
              tp_axis: str = "model"):
    """x [B, S, d] (global). Uses shard_map EP when a mesh with tp_axis of
    size > 1 is provided; otherwise runs the single-shard body."""
    B, S, d = x.shape
    if mesh is None or tp_axis not in mesh.axis_names or \
            mesh.shape[tp_axis] == 1:
        out, aux = _moe_local(
            x.reshape(B * S, d), p["router"], p["wg"], p["wu"], p["wd"],
            cfg=cfg, tp=1, axis=None)
        return out.reshape(B, S, d), aux

    tp = mesh.shape[tp_axis]
    dp = tuple(a for a in dp_axes if a in mesh.axis_names)
    dp_spec = dp if dp else None
    decode_path = (S % tp) != 0            # S too small to sequence-shard

    def body(x_blk, wr, wg, wu, wd):
        Bl, Sl, _ = x_blk.shape
        fn = _moe_decode_local if decode_path else _moe_local
        out, aux = fn(x_blk.reshape(Bl * Sl, d), wr, wg, wu, wd,
                      cfg=cfg, tp=tp, axis=tp_axis)
        # aux is per-shard; average over the whole mesh
        aux = jax.lax.pmean(aux, tp_axis)
        for a in dp:
            aux = jax.lax.pmean(aux, a)
        return out.reshape(Bl, Sl, d), aux[None]

    x_seq_spec = None if decode_path else tp_axis
    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_spec, x_seq_spec, None), P(), P(tp_axis, None, None),
                  P(tp_axis, None, None), P(tp_axis, None, None)),
        out_specs=(P(dp_spec, x_seq_spec, None), P(None)),
    )(x, p["router"], p["wg"], p["wu"], p["wd"])
    return out, aux[0]
