"""Logical-axis sharding: params carry logical axis names, a rule table maps
them to mesh axes (MaxText-style). Rules are per-arch configurable — they are
the main §Perf hillclimb lever.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default logical → mesh axis rules. `None` = replicate.
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),      # data parallel (pods are extra DP)
    "seq": None,                   # sequence usually unsharded
    "seq_sp": "model",             # sequence-parallel regions (MoE dispatch)
    "vocab": "model",
    "embed": None,                 # d_model
    "embed_fsdp": ("data", "pod"),  # FSDP over ALL pure-DP axes (ZeRO)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",                # d_ff
    "experts": "model",            # EP
    "expert_mlp": None,
    "layers": None,                # scan dim
    "conv": None,
    "state": None,
}


@dataclass(frozen=True)
class ShardingRules:
    rules: dict = field(default_factory=lambda: dict(DEFAULT_RULES))

    def spec(self, logical_axes: tuple) -> P:
        parts = []
        used = set()
        for ax in logical_axes:
            m = self.rules.get(ax) if ax is not None else None
            # never map two tensor dims to the same mesh axis
            flat = tuple(m) if isinstance(m, tuple) else ((m,) if m else ())
            if any(f in used for f in flat):
                m = None
            for f in flat:
                used.add(f)
            parts.append(m)
        return P(*parts)

    def with_overrides(self, **kv) -> "ShardingRules":
        r = dict(self.rules)
        r.update(kv)
        return ShardingRules(rules=r)


def logical_to_sharding(tree_axes, mesh: Mesh, rules: ShardingRules,
                        tree_abs=None):
    """Map a pytree of logical-axis tuples to NamedShardings.

    With `tree_abs` (matching pytree of ShapeDtypeStructs/arrays), mesh axes
    that do not evenly divide the tensor dim are dropped (e.g. whisper's 12
    heads on a 16-way model axis fall back to replication)."""

    def one(axes, leaf=None):
        spec = rules.spec(axes)

        def filt(e, dim_size=None):
            if e is None:
                return None
            axs = e if isinstance(e, tuple) else (e,)
            kept = tuple(a for a in axs if a in mesh.axis_names)
            if dim_size is not None:
                total = 1
                ok = []
                for a in kept:
                    if dim_size % (total * mesh.shape[a]) == 0:
                        ok.append(a)
                        total *= mesh.shape[a]
                kept = tuple(ok)
            if not kept:
                return None
            return kept if len(kept) > 1 else kept[0]

        dims = (list(leaf.shape) if leaf is not None
                else [None] * len(spec))
        spec = P(*[filt(e, d) for e, d in zip(spec, dims)])
        return NamedSharding(mesh, spec)

    if tree_abs is None:
        return jax.tree_util.tree_map(
            one, tree_axes, is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree_util.tree_map(
        one, tree_axes, tree_abs, is_leaf=lambda x: isinstance(x, tuple))


def constrain(x, mesh: Mesh, rules: ShardingRules, logical_axes: tuple):
    """with_sharding_constraint by logical axes (no-op outside jit/mesh)."""
    try:
        sh = logical_to_sharding(logical_axes, mesh, rules)
        return jax.lax.with_sharding_constraint(x, sh)
    except Exception:
        return x


class ParamCollector:
    """Collects (shape, logical_axes, init) during model init."""

    def __init__(self, key):
        self.key = key
        self.params: dict = {}
        self.axes: dict = {}

    def _split(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def param(self, path: str, shape, axes, *, scale: float = 0.02,
              dtype=jnp.float32, init: str = "normal"):
        assert len(shape) == len(axes), (path, shape, axes)
        d = self.params
        a = self.axes
        keys = path.split(".")
        for k in keys[:-1]:
            d = d.setdefault(k, {})
            a = a.setdefault(k, {})
        if init == "normal":
            val = jax.random.normal(self._split(), shape, dtype) * scale
        elif init == "zeros":
            val = jnp.zeros(shape, dtype)
        elif init == "ones":
            val = jnp.ones(shape, dtype)
        else:
            raise ValueError(init)
        d[keys[-1]] = val
        a[keys[-1]] = tuple(axes)
        return val

    def abstract_param(self, path: str, shape, axes, dtype=jnp.float32):
        """ShapeDtypeStruct variant for allocation-free dry-runs."""
        d = self.params
        a = self.axes
        keys = path.split(".")
        for k in keys[:-1]:
            d = d.setdefault(k, {})
            a = a.setdefault(k, {})
        d[keys[-1]] = jax.ShapeDtypeStruct(tuple(shape), dtype)
        a[keys[-1]] = tuple(axes)


def param_count(params) -> int:
    return int(sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params)))
