"""RWKV6 "Finch" time-mix + channel-mix (arXiv:2404.05892), attention-free.

Time-mix recurrence per head (hd = head dim, state S ∈ R^{hd×hd}):
    y_t = r_t · (S_{t-1} + (u ⊙ k_t) v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ           (data-dependent decay w_t)
Training uses lax.scan over time (the recurrence is inherently sequential;
a chunked parallel form is a recorded §Perf candidate); decode is O(1)/token
carrying (x_prev, S) — which is why rwkv6 runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import COMPUTE_DTYPE, rms_norm


def init_rwkv_time_mix(col, prefix: str, cfg):
    d = cfg.d_model
    H, hd = cfg.n_heads, cfg.hd
    for nm in ("r", "k", "v", "g", "w"):
        col.param(f"{prefix}.mu_{nm}", (d,), ("embed",), init="zeros")
    col.param(f"{prefix}.w_r", (d, H * hd), ("embed_fsdp", "heads"))
    col.param(f"{prefix}.w_k", (d, H * hd), ("embed_fsdp", "heads"))
    col.param(f"{prefix}.w_v", (d, H * hd), ("embed_fsdp", "heads"))
    col.param(f"{prefix}.w_g", (d, H * hd), ("embed_fsdp", "heads"))
    col.param(f"{prefix}.w_w", (d, H * hd), ("embed_fsdp", "heads"),
              scale=0.001)
    col.param(f"{prefix}.w0", (H * hd,), ("heads",), init="zeros")
    col.param(f"{prefix}.u", (H, hd), ("heads", "head_dim"), scale=0.1)
    col.param(f"{prefix}.ln_x", (H * hd,), ("heads",), init="zeros")
    col.param(f"{prefix}.w_out", (H * hd, d), ("heads", "embed_fsdp"),
              scale=0.02 / np.sqrt(2 * cfg.n_layers))


def _token_shift(x, mu, x_prev):
    """lerp(x_{t-1}, x_t, μ). x [B,S,d]; x_prev [B,1,d] (decode carry)."""
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    mu = mu.astype(x.dtype)
    return x * (1 + mu) - shifted * mu  # x + μ(x − x_{t−1}) form


def rwkv_time_mix(p, cfg, x, *, state=None):
    """x [B, S, d] → (out, new_state). state = {"x_prev": [B,1,d],
    "S": [B,H,hd,hd]} for decode / chunk continuation."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    x_prev = (jnp.zeros((B, 1, d), x.dtype) if state is None
              else state["x_prev"].astype(x.dtype))

    def proj(nm):
        xs = _token_shift(x, p[f"mu_{nm}"], x_prev)
        return jnp.einsum("bsd,de->bse", xs, p[f"w_{nm}"].astype(x.dtype),
                          preferred_element_type=jnp.float32)

    r = proj("r").reshape(B, S, H, hd)
    k = proj("k").reshape(B, S, H, hd)
    v = proj("v").reshape(B, S, H, hd)
    g = proj("g")
    w = jnp.exp(-jnp.exp(
        (p["w0"].astype(jnp.float32) + proj("w")).clip(-20, 10)
    )).reshape(B, S, H, hd)                               # decay ∈ (0,1)
    u = p["u"].astype(jnp.float32)

    S0 = (jnp.zeros((B, H, hd, hd), jnp.float32) if state is None
          else state["S"])

    def step(Sm, inp):
        r_t, k_t, v_t, w_t = inp                          # [B,H,hd]
        kv = k_t[..., :, None] * v_t[..., None, :]        # [B,H,hd,hd]
        y = jnp.einsum("bhk,bhkv->bhv", r_t,
                       Sm + u[None, :, :, None] * kv)
        S_new = w_t[..., :, None] * Sm + kv
        return S_new, y

    xs = tuple(a.swapaxes(0, 1).astype(jnp.float32) for a in (r, k, v, w))
    S_fin, ys = jax.lax.scan(step, S0, xs)
    y = ys.swapaxes(0, 1).reshape(B, S, H * hd)           # [B,S,H*hd]

    y = rms_norm(y.astype(COMPUTE_DTYPE), p["ln_x"], cfg.norm_eps)
    y = y * jax.nn.silu(g).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(y.dtype),
                     preferred_element_type=jnp.float32)
    new_state = {"x_prev": x[:, -1:].astype(COMPUTE_DTYPE), "S": S_fin}
    return out.astype(COMPUTE_DTYPE), new_state


def init_rwkv_channel_mix(col, prefix: str, cfg):
    d, ff = cfg.d_model, cfg.d_ff
    col.param(f"{prefix}.mu_k", (d,), ("embed",), init="zeros")
    col.param(f"{prefix}.mu_r", (d,), ("embed",), init="zeros")
    col.param(f"{prefix}.w_k", (d, ff), ("embed_fsdp", "mlp"))
    col.param(f"{prefix}.w_r", (d, d), ("embed_fsdp", None))
    col.param(f"{prefix}.w_v", (ff, d), ("mlp", "embed_fsdp"),
              scale=0.02 / np.sqrt(2 * cfg.n_layers))


def rwkv_channel_mix(p, cfg, x, *, state=None):
    B, S, d = x.shape
    x_prev = (jnp.zeros((B, 1, d), x.dtype) if state is None
              else state["x_prev"].astype(x.dtype))
    xk = _token_shift(x, p["mu_k"], x_prev)
    xr = _token_shift(x, p["mu_r"], x_prev)
    k = jnp.einsum("bsd,df->bsf", xk, p["w_k"].astype(x.dtype),
                   preferred_element_type=jnp.float32)
    k = jnp.square(jax.nn.relu(k)).astype(COMPUTE_DTYPE)
    kv = jnp.einsum("bsf,fd->bsd", k, p["w_v"].astype(k.dtype),
                    preferred_element_type=jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr,
                                  p["w_r"].astype(x.dtype),
                                  preferred_element_type=jnp.float32))
    out = (r * kv).astype(COMPUTE_DTYPE)
    return out, {"x_prev": x[:, -1:].astype(COMPUTE_DTYPE)}


def init_rwkv_state(cfg, B: int):
    H, hd, d = cfg.n_heads, cfg.hd, cfg.d_model
    return {
        "tm": {"x_prev": jnp.zeros((B, 1, d), COMPUTE_DTYPE),
               "S": jnp.zeros((B, H, hd, hd), jnp.float32)},
        "cm": {"x_prev": jnp.zeros((B, 1, d), COMPUTE_DTYPE)},
    }
