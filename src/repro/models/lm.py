"""LM assembly: pattern-period scan-over-layers decoder (+ optional encoder),
covering all 10 assigned architectures through ModelConfig.pattern:

  "g" global attention · "l" sliding-window attention · "r" RG-LRU block ·
  "w" RWKV6 time-mix (+ channel-mix MLP) · encoder layers are bidirectional.

Layers are grouped into repeating periods (e.g. gemma3: l,l,l,l,l,g) and
scanned over ⌊L/P⌋ periods with stacked params — HLO size is ~depth-
independent, which keeps the 70-compile dry-run tractable (DESIGN §7).
Remainder layers (L mod P) get unstacked "tail" params.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .attention import attention_layer, init_attention
from .config import ModelConfig
from .ffn import init_mlp, init_moe, mlp_layer, moe_layer
from .layers import (COMPUTE_DTYPE, chunked_softmax_xent, embed,
                     logits_from_embedding, rms_norm, softcap)
from .rglru import init_rglru, init_rglru_state, rglru_layer
from .rwkv6 import (init_rwkv_channel_mix, init_rwkv_state,
                    init_rwkv_time_mix, rwkv_channel_mix, rwkv_time_mix)
from .sharding import ParamCollector


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
class _Stacked:
    """Collector proxy that prepends a layer-stack dim to every param."""

    def __init__(self, col: ParamCollector, n: int, abstract: bool):
        self.col, self.n, self.abstract = col, n, abstract

    def param(self, path, shape, axes, **kw):
        shape = (self.n,) + tuple(shape)
        axes = ("layers",) + tuple(axes)
        if self.abstract:
            self.col.abstract_param(path, shape, axes,
                                    dtype=kw.get("dtype", jnp.float32))
        else:
            self.col.param(path, shape, axes, **kw)


class _Plain:
    def __init__(self, col: ParamCollector, abstract: bool):
        self.col, self.abstract = col, abstract

    def param(self, path, shape, axes, **kw):
        if self.abstract:
            self.col.abstract_param(path, shape, axes,
                                    dtype=kw.get("dtype", jnp.float32))
        else:
            self.col.param(path, shape, axes, **kw)


def _init_block(col, prefix: str, cfg: ModelConfig, kind: str,
                cross: bool = False):
    col.param(f"{prefix}.norm1", (cfg.d_model,), ("embed",), init="zeros")
    col.param(f"{prefix}.norm2", (cfg.d_model,), ("embed",), init="zeros")
    if cfg.sandwich_norm:
        col.param(f"{prefix}.post1", (cfg.d_model,), ("embed",), init="zeros")
        col.param(f"{prefix}.post2", (cfg.d_model,), ("embed",), init="zeros")
    if kind in ("g", "l", "b"):
        init_attention(col, f"{prefix}.attn", cfg)
    elif kind == "r":
        init_rglru(col, f"{prefix}.rnn", cfg)
    elif kind == "w":
        init_rwkv_time_mix(col, f"{prefix}.tmix", cfg)
    else:
        raise ValueError(kind)
    if cross:
        col.param(f"{prefix}.norm_x", (cfg.d_model,), ("embed",), init="zeros")
        init_attention(col, f"{prefix}.xattn", cfg)
    if kind == "w":
        init_rwkv_channel_mix(col, f"{prefix}.cmix", cfg)
    elif cfg.is_moe:
        init_moe(col, f"{prefix}.moe", cfg)
    else:
        init_mlp(col, f"{prefix}.mlp", cfg)


def lm_init(key, cfg: ModelConfig, abstract: bool = False):
    """Returns (params, logical_axes) pytrees."""
    col = ParamCollector(key)
    plain = _Plain(col, abstract)
    P = len(cfg.pattern)
    n_full, rem = cfg.n_layers // P, cfg.n_layers % P
    dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32

    plain.param("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                scale=cfg.d_model ** -0.5, dtype=dtype)
    stk = _Stacked(col, n_full, abstract)
    for j, kind in enumerate(cfg.pattern):
        _init_block(stk, f"blocks.l{j}", cfg, kind, cross=cfg.is_encdec)
    for j in range(rem):
        _init_block(plain, f"tail.l{j}", cfg, cfg.pattern[j],
                    cross=cfg.is_encdec)
    plain.param("final_norm", (cfg.d_model,), ("embed",), init="zeros")

    if cfg.is_encdec:
        enc_stk = _Stacked(col, cfg.encoder_layers, abstract)
        _init_block(enc_stk, "enc.l0", cfg, "b", cross=False)
        plain.param("enc_norm", (cfg.d_model,), ("embed",), init="zeros")
    return col.params, col.axes


# --------------------------------------------------------------------------
# one block
# --------------------------------------------------------------------------
def constrain_act(x, mesh, *, shard_batch=True):
    """Pin activation sharding [B, S, d] → (dp axes, None, None) so SPMD
    propagation through remat+scan never falls back to replication
    (§Perf iteration 4: minicpm attention ran at full global batch per
    device without this)."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not dp or not shard_batch:
        return x
    total = 1
    for a in dp:
        total *= mesh.shape[a]
    if x.shape[0] % total:
        return x
    spec = P(dp, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _temporal(bp, cfg, kind, x, *, state, cur_pos, causal, mesh):
    if kind in ("g", "l", "b"):
        out, new_cache = attention_layer(
            bp["attn"], cfg, x, is_local=(kind == "l"),
            cache=None if state is None else state,
            cur_pos=cur_pos, causal=(kind != "b") and causal, mesh=mesh)
        return out, new_cache
    if kind == "r":
        return rglru_layer(bp["rnn"], cfg, x, state=state)
    if kind == "w":
        return rwkv_time_mix(bp["tmix"], cfg, x, state=state)
    raise ValueError(kind)


def block_apply(bp, cfg: ModelConfig, kind: str, x, *, state=None,
                cur_pos=None, enc_out=None, mesh=None):
    """Pre-norm (optionally sandwich) block. Returns (x, new_state, aux)."""
    aux = jnp.float32(0.0)
    x = constrain_act(x, mesh)
    h = rms_norm(x, bp["norm1"], cfg.norm_eps)
    tstate = None if state is None else state.get("t")
    out, new_t = _temporal(bp, cfg, kind, h, state=tstate, cur_pos=cur_pos,
                           causal=True, mesh=mesh)
    if cfg.sandwich_norm:
        out = rms_norm(out, bp["post1"], cfg.norm_eps)
    x = x + out

    if enc_out is not None and "xattn" in bp:
        h = rms_norm(x, bp["norm_x"], cfg.norm_eps)
        kv = _cross_kv(bp["xattn"], enc_out)
        out, _ = attention_layer(bp["xattn"], cfg, h, is_local=False,
                                 kv_override=kv, causal=False)
        x = x + out

    h = rms_norm(x, bp["norm2"], cfg.norm_eps)
    mstate = None if state is None else state.get("m")
    new_m = None
    if kind == "w":
        out, new_m = rwkv_channel_mix(bp["cmix"], cfg, h, state=mstate)
    elif cfg.is_moe:
        out, aux = moe_layer(bp["moe"], cfg, h, mesh=mesh)
    else:
        out = mlp_layer(bp["mlp"], cfg, h, mesh=mesh)
    if cfg.sandwich_norm:
        out = rms_norm(out, bp["post2"], cfg.norm_eps)
    x = x + out
    new_state = None
    if state is not None:
        new_state = {"t": new_t, "m": new_m} if new_m is not None else \
            {"t": new_t}
    return x, new_state, aux


def _cross_kv(p, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype),
                   preferred_element_type=jnp.float32).astype(COMPUTE_DTYPE)
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype),
                   preferred_element_type=jnp.float32).astype(COMPUTE_DTYPE)
    return k, v


# --------------------------------------------------------------------------
# stacks
# --------------------------------------------------------------------------
def _sinusoid(S, d):
    pos = np.arange(S)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * i / d)
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), COMPUTE_DTYPE)


def _sinusoid_at(positions, d):
    """Sinusoidal embeddings at traced positions [S] → [S, d]."""
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = positions.astype(jnp.float32)[:, None] / jnp.power(
        10_000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                           axis=-1).astype(COMPUTE_DTYPE)


def encode(params, cfg: ModelConfig, enc_embeds, *, mesh=None):
    """Whisper-style encoder over precomputed frame embeddings [B, T, d]."""
    x = enc_embeds.astype(COMPUTE_DTYPE) + _sinusoid(
        enc_embeds.shape[1], cfg.d_model)[None]

    def body(x, bp):
        x, _, _ = block_apply(bp, cfg, "b", x, mesh=mesh)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc"]["l0"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward_hidden(params, cfg: ModelConfig, tokens=None, embeds=None, *,
                   states=None, cur_pos=None, enc_out=None, mesh=None):
    """Decoder trunk → hidden [B, S, d]. Returns (hidden, new_states, aux)."""
    if embeds is None:
        x = embed(tokens, params["embed"])
    else:
        x = embeds.astype(COMPUTE_DTYPE)
    if cfg.is_encdec:
        S = x.shape[1]
        start = jnp.int32(0) if cur_pos is None else jnp.asarray(cur_pos)
        positions = start + jnp.arange(S)
        x = x + _sinusoid_at(positions, cfg.d_model)[None]

    P = len(cfg.pattern)
    n_full, rem = cfg.n_layers // P, cfg.n_layers % P
    aux_total = jnp.float32(0.0)

    def period(x, bparams, bstates):
        new_states = {}
        aux = jnp.float32(0.0)
        for j, kind in enumerate(cfg.pattern):
            st = None if bstates is None else bstates[f"l{j}"]
            x, ns, a = block_apply(
                bparams[f"l{j}"], cfg, kind, x, state=st, cur_pos=cur_pos,
                enc_out=enc_out, mesh=mesh)
            aux = aux + a
            if ns is not None:
                new_states[f"l{j}"] = ns
        return x, (new_states if new_states else None), aux

    if states is None:
        def period_fwd(x, bparams):
            x, _, a = period(x, bparams, None)
            return x, a

        if cfg.remat == "full":
            # per-layer-period remat: backward recomputes the block, so the
            # scan saves only [B, S, d] per period instead of every
            # intermediate (§Perf iteration 6).
            period_fwd = jax.checkpoint(period_fwd, prevent_cse=False)

        def body(carry, bparams):
            x, aux = carry
            x, a = period_fwd(x, bparams)
            return (x, aux + a), None
        (x, aux_total), _ = jax.lax.scan(
            body, (x, aux_total), params["blocks"])
        new_blk_states = None
    else:
        def body(carry, xs):
            x, aux = carry
            bparams, bstates = xs
            x, ns, a = period(x, bparams, bstates)
            return (x, aux + a), ns
        (x, aux_total), new_blk_states = jax.lax.scan(
            body, (x, aux_total), (params["blocks"], states["blocks"]))

    new_tail_states = {}
    for j in range(rem):
        st = None if states is None else states["tail"][f"l{j}"]
        x, ns, a = block_apply(
            params["tail"][f"l{j}"], cfg, cfg.pattern[j], x, state=st,
            cur_pos=cur_pos, enc_out=enc_out, mesh=mesh)
        aux_total = aux_total + a
        if ns is not None:
            new_tail_states[f"l{j}"] = ns

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    new_states = None
    if states is not None:
        new_states = {"blocks": new_blk_states, "tail": new_tail_states}
    return x, new_states, aux_total


# --------------------------------------------------------------------------
# losses / serving entry points
# --------------------------------------------------------------------------
def lm_loss(params, cfg: ModelConfig, batch: dict, *, mesh=None):
    """batch: {"tokens": [B, S+1] int32} (+ "enc_embeds" for enc-dec,
    "embeds" for stub frontends, "loss_mask" [B, S] to drop targets —
    the contamination gate's mask policy). Returns (loss, metrics)."""
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["enc_embeds"], mesh=mesh)
    embeds = batch.get("embeds")
    hidden, _, aux = forward_hidden(
        params, cfg, tokens=None if embeds is not None else inputs,
        embeds=embeds, enc_out=enc_out, mesh=mesh)
    loss, wt = chunked_softmax_xent(
        hidden, params["embed"], targets, mask=batch.get("loss_mask"),
        cap=cfg.logit_softcap)
    total = loss + 0.01 * aux
    return total, {"xent": loss, "aux": aux, "tokens": wt}


def init_decode_states(cfg: ModelConfig, B: int, cache_len: int):
    """Per-layer decode state pytree matching the scan structure."""
    P = len(cfg.pattern)
    n_full, rem = cfg.n_layers // P, cfg.n_layers % P

    def one(kind):
        if kind in ("g", "b"):
            C = cache_len
        elif kind == "l":
            C = min(cfg.window, cache_len)
        if kind in ("g", "l", "b"):
            return {"t": {
                "k": jnp.zeros((B, C, cfg.n_kv_heads, cfg.hd), COMPUTE_DTYPE),
                "v": jnp.zeros((B, C, cfg.n_kv_heads, cfg.hd), COMPUTE_DTYPE),
            }}
        if kind == "r":
            return {"t": init_rglru_state(cfg, B)}
        if kind == "w":
            s = init_rwkv_state(cfg, B)
            return {"t": s["tm"], "m": s["cm"]}
        raise ValueError(kind)

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (n_full,) + l.shape), tree)

    blocks = {f"l{j}": stack(one(k)) for j, k in enumerate(cfg.pattern)}
    tail = {f"l{j}": one(cfg.pattern[j]) for j in range(rem)}
    return {"blocks": blocks, "tail": tail}


def decode_step(params, cfg: ModelConfig, token, states, cur_pos, *,
                enc_out=None, mesh=None):
    """token [B, 1] int32; cur_pos int32[] — absolute position.
    Returns (logits [B, 1, V], new_states)."""
    hidden, new_states, _ = forward_hidden(
        params, cfg, tokens=token, states=states, cur_pos=cur_pos,
        enc_out=enc_out, mesh=mesh)
    logits = logits_from_embedding(hidden, params["embed"],
                                   cap=cfg.logit_softcap)
    return logits, new_states
