"""Core layers: norms, activations, RoPE, embeddings, chunked cross-entropy."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

COMPUTE_DTYPE = jnp.bfloat16


def rms_norm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def softcap(x, cap: float | None):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, base: float):
    return base ** (-np.arange(0, head_dim, 2, dtype=np.float32) / head_dim)


def apply_rope(x, positions, base: float):
    """x [..., S, H, hd]; positions [..., S] int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, base))          # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Embedding / unembedding / loss
# --------------------------------------------------------------------------
def embed(tokens, table, scale_by_dim: bool = True):
    out = table[tokens]
    if scale_by_dim:
        out = out * jnp.asarray(np.sqrt(table.shape[-1]), out.dtype)
    return out.astype(COMPUTE_DTYPE)


def logits_from_embedding(x, table, cap: float | None = None):
    out = jnp.einsum("...sd,vd->...sv", x, table.astype(x.dtype),
                     preferred_element_type=jnp.float32)
    return softcap(out, cap)


def chunked_softmax_xent(x, table, targets, mask=None, *, chunk: int = 512,
                         cap: float | None = None):
    """Cross-entropy without materialising [B, S, V] for the full sequence.

    Scans over S in chunks; each chunk computes logits, log-sum-exp, and the
    target logit. Returns (mean_loss, total_weight)."""
    B, S, D = x.shape
    chunk = min(chunk, S)
    n_chunks = S // chunk
    rem = S - n_chunks * chunk
    if mask is None:
        mask = jnp.ones((B, S), dtype=jnp.float32)

    def chunk_loss(xc, tc, mc):
        logits = logits_from_embedding(xc, table, cap)      # [B, c, V] f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        # target logit via embedding-ROW gather (cheap [B,c,D] gather) — a
        # take_along_axis over the vocab-sharded logits axis would force
        # XLA to all-gather full-vocab logits per device (§Perf iter. 3:
        # that was 6.6e15 of 6.7e15 per-device FLOPs on minicpm train_4k).
        tgt_emb = table[tc]                                 # [B, c, D]
        tgt = jnp.einsum("bcd,bcd->bc", xc.astype(jnp.float32),
                         tgt_emb.astype(jnp.float32))
        tgt = softcap(tgt, cap)
        return jnp.sum((lse - tgt) * mc), jnp.sum(mc)

    def body(carry, args):
        tot, wt = carry
        xc, tc, mc = args
        l, w = chunk_loss(xc, tc, mc)
        return (tot + l, wt + w), None

    xs = (x[:, :n_chunks * chunk].reshape(B, n_chunks, chunk, D).swapaxes(0, 1),
          targets[:, :n_chunks * chunk].reshape(B, n_chunks, chunk).swapaxes(0, 1),
          mask[:, :n_chunks * chunk].reshape(B, n_chunks, chunk).swapaxes(0, 1))
    (tot, wt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), xs)
    if rem:
        l, w = chunk_loss(x[:, -rem:], targets[:, -rem:], mask[:, -rem:])
        tot, wt = tot + l, wt + w
    return tot / jnp.maximum(wt, 1.0), wt
