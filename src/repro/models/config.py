"""Model configuration dataclass shared by all 10 assigned architectures."""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None      # default d_model // n_heads

    # --- layer pattern: one entry per layer within a repeating period ---
    #   "g" global attention, "l" local (sliding window) attention,
    #   "r" RG-LRU recurrent block, "w" RWKV6 time-mix block
    pattern: tuple = ("g",)
    window: int = 4096               # sliding window for "l" layers
    logit_softcap: float | None = None
    attn_softcap: float | None = None
    qk_norm: bool = False
    sandwich_norm: bool = False      # gemma2/3 pre+post block norms

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.3

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    enc_seq: int = 1500              # fixed encoder grid (audio frames)

    # --- frontend stub: None | "audio" | "vision" ---
    frontend: str | None = None

    # --- rope / misc ---
    rope_base: float = 10_000.0
    rope_base_local: float | None = None
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"

    # --- conv/recurrence hyper-params (hybrid/ssm) ---
    conv_width: int = 4
    lru_dim: int | None = None       # RG-LRU width (default d_model)

    # --- training defaults ---
    lr_schedule: str = "cosine"      # "wsd" for minicpm
    optimizer: str = "adamw"         # "adafactor" for 1T-scale
    param_dtype: str = "float32"     # "bfloat16" for 1T-scale
    remat: str = "none"              # none | full | save_dots

    # sub-quadratic? (drives long_500k applicability, DESIGN §5)
    @property
    def subquadratic(self) -> bool:
        return any(k in ("l", "r", "w") for k in self.pattern)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def replace(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=max(2, len(self.pattern)),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, 4 * self.n_kv_heads // max(self.n_heads, 1)),
            head_dim=16,
            d_ff=128,
            vocab_size=257,
            window=16,
            enc_seq=24,
            conv_width=4,
            lru_dim=64,
        )
        if self.is_moe:
            kw.update(n_experts=4, top_k=2)
        if self.is_encdec:
            kw.update(encoder_layers=2)
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
