"""GQA attention: flash-style blocked softmax (train/prefill), ring-buffer KV
caches (decode), sliding-window local layers, gemma-style softcaps, qk-norm.

The blocked implementation never materialises the [S, T] score matrix: it
scans query chunks and, per query chunk, only the causally/window reachable
KV chunks — this is what makes prefill_32k memory-sane and local layers at
long context O(S·window).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .layers import COMPUTE_DTYPE, apply_rope, rms_norm, softcap

NEG_INF = -1e30


def _chunk(x, n):  # [B, S, ...] -> [B, nchunks, n, ...]
    B, S = x.shape[:2]
    return x.reshape((B, S // n, n) + x.shape[2:])


def flash_attention(
    q,                      # [B, S, H, hd]
    k,                      # [B, T, Hk, hd]
    v,                      # [B, T, Hk, hd]
    *,
    causal: bool = True,
    window: int | None = None,
    attn_softcap: float | None = None,
    q_offset: int = 0,      # absolute position of q[0] (prefill continuation)
    q_chunk: int = 512,
    kv_chunk: int = 512,
):
    B, S, H, hd = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    qc = min(q_chunk, S)
    kc = min(kv_chunk, T)
    S_true, T_true = S, T
    # pad to chunk multiples; padded kv is masked out, padded q is dropped
    if S % qc:
        pad = qc - S % qc
        q = jnp.concatenate([q, jnp.zeros((B, pad, H, hd), q.dtype)], axis=1)
        S += pad
    if T % kc:
        pad = kc - T % kc
        k = jnp.concatenate([k, jnp.zeros((B, pad, Hk, hd), k.dtype)], axis=1)
        v = jnp.concatenate([v, jnp.zeros((B, pad, Hk, hd), v.dtype)], axis=1)
        T += pad
    nq, nk = S // qc, T // kc
    scale = 1.0 / math.sqrt(hd)

    qg = _chunk(q, qc).reshape(B, nq, qc, Hk, G, hd)
    kg = _chunk(k, kc)                                  # [B, nk, kc, Hk, hd]
    vg = _chunk(v, kc)

    # static chunk window: how many kv chunks back a q chunk can see
    if window is not None:
        back = int(math.ceil(window / kc)) + 1
    else:
        back = nk

    banded = window is not None and back < nk

    def _score_block(qblk, kblk, vblk, q_pos, kv_pos, extra_ok=None):
        """qblk [B,qc,Hk,G,hd]; kblk/vblk [B,C,Hk,hd] → (s, ok)."""
        s = jnp.einsum("bqkgd,bckd->bqkgc", qblk.astype(COMPUTE_DTYPE),
                       kblk.astype(COMPUTE_DTYPE),
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, attn_softcap)
        ok = jnp.broadcast_to((kv_pos < T_true)[None, :],
                              (qc, kv_pos.shape[0])).copy() if T != T_true \
            else jnp.ones((qc, kv_pos.shape[0]), bool)
        if causal:
            ok &= q_pos[:, None] >= kv_pos[None, :]
        if window is not None:
            ok &= (q_pos[:, None] - kv_pos[None, :]) < window
        if extra_ok is not None:
            ok &= extra_ok[None, :]
        return jnp.where(ok[None, :, None, None, :], s, NEG_INF)

    def q_body(_, qi):
        qblk = qg[:, qi]                                # [B, qc, Hk, G, hd]
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        if banded:
            # sliding window: gather the `back` reachable kv chunks and do a
            # single softmax over the band — zero wasted FLOPs, fully
            # differentiable (no dynamic control flow).
            rel = qi - (back - 1) + jnp.arange(back)    # chunk ids [b]
            relc = jnp.clip(rel, 0, nk - 1)
            kb = kg[:, relc].reshape(B, back * kc, Hk, hd)
            vb = vg[:, relc].reshape(B, back * kc, Hk, hd)
            kv_pos = (rel[:, None] * kc + jnp.arange(kc)[None, :]).reshape(-1)
            in_range = (rel >= 0).repeat(kc)
            s = _score_block(qblk, kb, vb, q_pos, kv_pos, in_range)
            p = jax.nn.softmax(s, axis=-1)
            out = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(COMPUTE_DTYPE),
                             vb.astype(COMPUTE_DTYPE),
                             preferred_element_type=jnp.float32)
            return None, out.reshape(B, qc, H, hd).astype(q.dtype)

        # causal global: online softmax over kv chunks; irrelevant chunks are
        # skipped with lax.cond (runtime-skipped AND reverse-differentiable).
        m0 = jnp.full((B, qc, Hk, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qc, Hk, G), jnp.float32)
        a0 = jnp.zeros((B, qc, Hk, G, hd), jnp.float32)
        hi_pos = q_offset + (qi + 1) * qc if causal else T
        hi = jnp.minimum((hi_pos + kc - 1) // kc, nk) if causal else nk

        def kv_body(carry, ki):
            def active(c):
                m, l, acc = c
                kblk = jax.lax.dynamic_index_in_dim(kg, ki, 1, keepdims=False)
                vblk = jax.lax.dynamic_index_in_dim(vg, ki, 1, keepdims=False)
                kv_pos = ki * kc + jnp.arange(kc)
                s = _score_block(qblk, kblk, vblk, q_pos, kv_pos)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                acc = acc * corr[..., None] + jnp.einsum(
                    "bqkgc,bckd->bqkgd", p.astype(COMPUTE_DTYPE),
                    vblk.astype(COMPUTE_DTYPE),
                    preferred_element_type=jnp.float32)
                return m_new, l_new, acc
            relevant = ki < hi
            return jax.lax.cond(relevant, active, lambda c: c, carry), None

        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.reshape(B, qc, H, hd).astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, jnp.arange(nq))
    out = outs.swapaxes(0, 1).reshape(B, S, H, hd)
    return out[:, :S_true]                              # [B, S, H, hd]


def decode_attention(
    q,                      # [B, 1, H, hd]
    cache_k,                # [B, C, Hk, hd]
    cache_v,
    cur_pos,                # int32[] — absolute position of the new token
    *,
    window: int | None = None,
    attn_softcap: float | None = None,
):
    B, _, H, hd = q.shape
    C, Hk = cache_k.shape[1], cache_k.shape[2]
    G = H // Hk
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Hk, G, hd)
    s = jnp.einsum("bkgd,bckd->bkgc", qg.astype(COMPUTE_DTYPE),
                   cache_k.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, attn_softcap)
    # ring buffer: slot c holds position cur - ((cur - c) mod C)
    slots = jnp.arange(C)
    pos_of_slot = cur_pos - ((cur_pos - slots) % C)
    ok = (pos_of_slot >= 0) & (pos_of_slot <= cur_pos)
    if window is not None:
        ok &= (cur_pos - pos_of_slot) < window
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p.astype(COMPUTE_DTYPE),
                     cache_v.astype(COMPUTE_DTYPE),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def update_cache(cache_k, cache_v, k_new, v_new, cur_pos):
    """Ring-buffer write of one position. k_new [B, 1, Hk, hd]."""
    C = cache_k.shape[1]
    slot = cur_pos % C
    cache_k = jax.lax.dynamic_update_index_in_dim(cache_k, k_new[:, 0], slot, 1)
    cache_v = jax.lax.dynamic_update_index_in_dim(cache_v, v_new[:, 0], slot, 1)
    return cache_k, cache_v


# --------------------------------------------------------------------------
# full attention layer (projections + rope + flash/decode)
# --------------------------------------------------------------------------
def init_attention(col, prefix: str, cfg):
    hd = cfg.hd
    col.param(f"{prefix}.wq", (cfg.d_model, cfg.n_heads, hd),
              ("embed_fsdp", "heads", "head_dim"),
              scale=0.02)
    col.param(f"{prefix}.wk", (cfg.d_model, cfg.n_kv_heads, hd),
              ("embed_fsdp", "kv_heads", "head_dim"), scale=0.02)
    col.param(f"{prefix}.wv", (cfg.d_model, cfg.n_kv_heads, hd),
              ("embed_fsdp", "kv_heads", "head_dim"), scale=0.02)
    col.param(f"{prefix}.wo", (cfg.n_heads, hd, cfg.d_model),
              ("heads", "head_dim", "embed_fsdp"),
              scale=0.02 / np.sqrt(2 * cfg.n_layers))
    if cfg.qk_norm:
        col.param(f"{prefix}.q_norm", (hd,), ("head_dim",), init="zeros")
        col.param(f"{prefix}.k_norm", (hd,), ("head_dim",), init="zeros")


def attention_layer(
    p, cfg, x, *, is_local: bool, positions=None, cache=None, cur_pos=None,
    kv_override=None, causal: bool = True, mesh=None,
):
    """x [B, S, d]. Returns (out [B, S, d], new_cache).

    cache: None (training/prefill) or dict(k, v) ring buffers (decode, S=1).
    kv_override: (k, v) for cross-attention (encoder outputs).
    """
    B, S, _ = x.shape
    hd = cfg.hd
    window = cfg.window if is_local else None
    rope_base = (cfg.rope_base_local if (is_local and cfg.rope_base_local)
                 else cfg.rope_base)

    from .lm import constrain_act

    def qkv_spec(t):
        """Attention sharding strategy (§Perf iteration 5): prefer heads
        over `model` (zero reshard traffic); when the head count doesn't
        divide (minicpm 36H, whisper 12H), shard batch over dp×model so the
        model axis isn't doing redundant attention; else dp only."""
        if mesh is None:
            return t
        from jax.sharding import NamedSharding, PartitionSpec as P
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        mdl = "model" if "model" in mesh.axis_names else None
        if not dp and not mdl:
            return t
        msz = mesh.shape[mdl] if mdl else 1
        dpsz = 1
        for a in dp:
            dpsz *= mesh.shape[a]
        B, H = t.shape[0], t.shape[2]
        heads_ok = (cfg.n_heads % msz == 0 and cfg.n_kv_heads % msz == 0) \
            if mdl else False
        if heads_ok and H % msz == 0:
            spec = P(dp or None, None, mdl, None)
        elif mdl and B % (dpsz * msz) == 0:
            spec = P(tuple(dp) + (mdl,), None, None, None)
        elif dp and B % dpsz == 0:
            spec = P(dp, None, None, None)
        else:
            return t
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype),
                   preferred_element_type=COMPUTE_DTYPE)
    q = qkv_spec(q)
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype),
                       preferred_element_type=COMPUTE_DTYPE)
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype),
                       preferred_element_type=COMPUTE_DTYPE)
        k = qkv_spec(k)
        v = qkv_spec(v)
    else:
        k, v = kv_override

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if positions is None:
        positions = jnp.arange(S)[None, :] if cur_pos is None \
            else jnp.full((B, S), cur_pos)
    if kv_override is None and rope_base:
        q = apply_rope(q, positions, rope_base)
        k = apply_rope(k, positions, rope_base)

    new_cache = None
    if cache is not None:                      # decode: S == 1
        ck, cv = update_cache(cache["k"], cache["v"], k, v, cur_pos)
        new_cache = {"k": ck, "v": cv}
        out = decode_attention(q, ck, cv, cur_pos, window=window,
                               attn_softcap=cfg.attn_softcap)
    elif kv_override is not None:
        flash = jax.checkpoint(functools.partial(
            flash_attention, causal=False, window=None,
            attn_softcap=cfg.attn_softcap))
        out = flash(q, k, v)
    else:
        # remat the streaming softmax: backward recomputes score blocks
        # instead of saving O(S²) intermediates (flash-attention semantics;
        # §Perf iteration 1 — before: ~99 TB/device activations on
        # minicpm train_4k, after: O(S·d)).
        flash = jax.checkpoint(functools.partial(
            flash_attention, causal=causal, window=window,
            attn_softcap=cfg.attn_softcap))
        out = flash(q, k, v)

    # bf16 output => the TP all-reduce of the partial sums runs in bf16
    # (half the collective bytes; §Perf iteration 7)
    proj = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype),
                      preferred_element_type=COMPUTE_DTYPE)
    proj = constrain_act(proj, mesh)
    return proj, new_cache
