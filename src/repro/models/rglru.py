"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t),
a_t = exp(−c · softplus(Λ) · r_t),  r_t, i_t input-dependent sigmoid gates.

The linear recurrence is evaluated with jax.lax.associative_scan (parallel
prefix — the TPU-friendly O(log T) depth form); decode carries (h, conv)
state for O(1) per-token cost (long_500k applicability, DESIGN §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import COMPUTE_DTYPE

_C = 8.0


def init_rglru(col, prefix: str, cfg):
    D = cfg.lru_dim or cfg.d_model
    d = cfg.d_model
    col.param(f"{prefix}.w_x", (d, D), ("embed_fsdp", "mlp"))
    col.param(f"{prefix}.w_gate", (d, D), ("embed_fsdp", "mlp"))
    col.param(f"{prefix}.conv", (cfg.conv_width, D), ("conv", "mlp"))
    col.param(f"{prefix}.w_rg", (D, D), ("mlp", None))
    col.param(f"{prefix}.w_ig", (D, D), ("mlp", None))
    col.param(f"{prefix}.lam", (D,), ("mlp",), init="ones")
    col.param(f"{prefix}.w_out", (D, d), ("mlp", "embed_fsdp"),
              scale=0.02 / np.sqrt(2 * cfg.n_layers))


def _causal_conv(x, kernel, state=None):
    """x [B, S, D]; kernel [W, D] depthwise causal. state [B, W-1, D]."""
    W = kernel.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * kernel[i].astype(x.dtype)
              for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else None
    return out, new_state


def _gates(p, u):
    r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", u, p["w_rg"].astype(u.dtype),
                                  preferred_element_type=jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("...d,de->...e", u, p["w_ig"].astype(u.dtype),
                                  preferred_element_type=jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12))
    return a, mult * i


def rglru_layer(p, cfg, x, *, state=None):
    """x [B, S, d] → ([B, S, d], new_state). state = {h, conv} for decode."""
    u = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(COMPUTE_DTYPE)
    gate = jnp.einsum("bsd,de->bse", x, p["w_gate"].astype(x.dtype),
                      preferred_element_type=jnp.float32)
    u, conv_state = _causal_conv(
        u, p["conv"], None if state is None else state["conv"])

    a, b_scale = _gates(p, u)
    b = (b_scale * u.astype(jnp.float32))

    if state is None:
        # parallel prefix over S:  h_t = a_t h_{t-1} + b_t
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        new_state = None if conv_state is None else {
            "h": h[:, -1], "conv": conv_state}
    else:
        h = (a * state["h"][:, None].astype(jnp.float32) + b)
        new_state = {"h": h[:, -1], "conv": conv_state}

    out = h.astype(COMPUTE_DTYPE) * jax.nn.gelu(gate).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bse,ed->bsd", out, p["w_out"].astype(out.dtype),
                     preferred_element_type=jnp.float32)
    return out.astype(COMPUTE_DTYPE), new_state


def init_rglru_state(cfg, B: int):
    D = cfg.lru_dim or cfg.d_model
    return {"h": jnp.zeros((B, D), jnp.float32),
            "conv": jnp.zeros((B, cfg.conv_width - 1, D), COMPUTE_DTYPE)}
