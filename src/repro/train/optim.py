"""Optimizers built from scratch: AdamW, Adafactor (factored second moment —
the 1T-param memory play), SGD+momentum; global-norm clipping; int8
error-feedback gradient compression for the DP all-reduce.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"          # adamw | adafactor | sgdm
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    momentum: float = 0.9
    compress: bool = False       # int8 error-feedback DP compression


# --------------------------------------------------------------------------
# gradient clipping
# --------------------------------------------------------------------------
def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


# --------------------------------------------------------------------------
# int8 error-feedback compression (gradient compression, DESIGN §6)
# --------------------------------------------------------------------------
def compress_int8(g: jnp.ndarray):
    """Symmetric per-tensor int8 quantisation. Returns (q, scale)."""
    a = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(a, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_grads_with_feedback(grads, errors):
    """Quantise grads + carry the quantisation error into the next step
    (error feedback keeps convergence; unit-tested)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = compress_int8(g32)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), (g32 - deq)
    flat = jax.tree_util.tree_map(one, grads, errors)
    new_g = jax.tree_util.tree_map(lambda t: t[0], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------
def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}

def adamw_update(params, grads, state, cfg: OptConfig, lr):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_ = cfg.b1 * m + (1 - cfg.b1) * g32
        v_ = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mh, vh = m_ / c1, v_ / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_, v_

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), {"m": pick(1), "v": pick(2), "step": step}


# --------------------------------------------------------------------------
# Adafactor (Shazeer & Stern) — factored second moment, no first moment
# --------------------------------------------------------------------------
def adafactor_init(params):
    def one(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"f": jax.tree_util.tree_map(
        one, params, is_leaf=lambda x: not isinstance(x, dict)),
        "step": jnp.zeros((), jnp.int32)}

def adafactor_update(params, grads, state, cfg: OptConfig, lr):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** -0.8
    eps = 1e-30

    def upd(p, g, s):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        if p.ndim >= 2:
            vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            vhat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
            upd_ = g32 / jnp.sqrt(vhat + eps)
            new_s = {"vr": vr, "vc": vc}
        else:
            v = beta2 * s["v"] + (1 - beta2) * g2
            upd_ = g32 / jnp.sqrt(v + eps)
            new_s = {"v": v}
        # update clipping (RMS ≤ 1) as in the paper
        rms = jnp.sqrt(jnp.mean(jnp.square(upd_)) + eps)
        upd_ = upd_ / jnp.maximum(1.0, rms)
        new_p = (p.astype(jnp.float32) * (1 - lr * cfg.weight_decay)
                 - lr * upd_).astype(p.dtype)
        return new_p, new_s

    # tree_map walks `params`' structure; the matching state["f"] subtree at
    # each param leaf is the {"vr","vc"}/{"v"} dict, passed whole to `upd`.
    out = jax.tree_util.tree_map(upd, params, grads, state["f"])
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), {"f": pick(1), "step": step}


# --------------------------------------------------------------------------
# SGD + momentum
# --------------------------------------------------------------------------
def sgdm_init(params):
    return {"m": jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32)}

def sgdm_update(params, grads, state, cfg: OptConfig, lr):
    def upd(p, g, m):
        m_ = cfg.momentum * m + g.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * m_).astype(p.dtype), m_
    out = jax.tree_util.tree_map(upd, params, grads, state["m"])
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), {"m": pick(1), "step": state["step"] + 1}


OPTIMIZERS = {
    "adamw": (adamw_init, adamw_update),
    "adafactor": (adafactor_init, adafactor_update),
    "sgdm": (sgdm_init, sgdm_update),
}


def make_optimizer(cfg: OptConfig):
    init, update = OPTIMIZERS[cfg.name]
    return init, functools.partial(update, cfg=cfg)
