"""LR schedules: cosine and WSD (warmup–stable–decay, MiniCPM arXiv:2404.06395)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    t = step.astype(jnp.float32)
    warm = t / jnp.maximum(warmup, 1)
    prog = jnp.clip((t - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(t < warmup, warm, cos)


def wsd_schedule(step, *, base_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, min_ratio: float = 0.01):
    """Warmup → stable plateau → fast exponential-ish (linear here) decay in
    the final `decay_frac` of training."""
    t = step.astype(jnp.float32)
    decay_start = total * (1.0 - decay_frac)
    warm = t / jnp.maximum(warmup, 1)
    dec = 1.0 - (1.0 - min_ratio) * jnp.clip(
        (t - decay_start) / jnp.maximum(total - decay_start, 1), 0.0, 1.0)
    return base_lr * jnp.where(
        t < warmup, warm, jnp.where(t < decay_start, 1.0, dec))


def make_schedule(name: str, **kw):
    fn = {"cosine": cosine_schedule, "wsd": wsd_schedule}[name]
    return lambda step: fn(step, **kw)
