"""Train step builder: loss → grads (with remat policy) → clip → (optional
int8 error-feedback compression) → optimizer → new state. Supports microbatch
gradient accumulation via lax.scan, which also lets XLA overlap the DP grad
all-reduce of microbatch t with the backward compute of t+1 (DESIGN §6).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..models.lm import lm_loss
from .optim import (OptConfig, clip_by_global_norm,
                    compressed_grads_with_feedback, make_optimizer)
from .schedule import make_schedule


@dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    schedule: str = "cosine"
    warmup: int = 100
    total_steps: int = 10_000
    microbatches: int = 1        # grad accumulation
    remat: str = "none"          # none | full | save_dots


def _remat_policy(name: str):
    if name == "full":
        return None                                  # recompute everything
    if name == "save_dots":
        return jax.checkpoint_policies.checkpoint_dots
    return None


def make_loss_fn(cfg, mesh=None, remat: str = "none"):
    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch, mesh=mesh)
    if remat != "none":
        loss_fn = jax.checkpoint(
            loss_fn, policy=_remat_policy(remat),
            prevent_cse=False)
    return loss_fn


def make_train_state(params, tcfg: TrainConfig):
    init, _ = make_optimizer(tcfg.opt)
    state = {"opt": init(params), "params": params}
    if tcfg.opt.compress:
        state["ef_error"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def make_train_step(cfg, tcfg: TrainConfig, mesh=None):
    """Returns train_step(state, batch) -> (state, metrics).

    batch leaves have leading dims [microbatches, per_mb_batch, ...] when
    tcfg.microbatches > 1, else [batch, ...]. An optional "loss_mask"
    leaf ([..., S] float32, 1 = count the target) flows through to
    `lm_loss` and surfaces as a ``masked_frac`` metric.
    """
    _, opt_update = make_optimizer(tcfg.opt)
    sched = make_schedule(
        tcfg.schedule, base_lr=tcfg.opt.lr, warmup=tcfg.warmup,
        total=tcfg.total_steps)
    loss_fn = make_loss_fn(cfg, mesh=mesh, remat=tcfg.remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tcfg.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def mb_body(carry, mb):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, loss_acc + loss), metrics

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gacc, loss_sum), metrics = jax.lax.scan(
            mb_body, (zeros, jnp.float32(0)), batch)
        inv = 1.0 / tcfg.microbatches
        grads = jax.tree_util.tree_map(lambda g: g * inv, gacc)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return loss_sum * inv, metrics, grads

    def train_step(state, batch):
        params = state["params"]
        loss, metrics, grads = compute_grads(params, batch)
        if "loss_mask" in batch:
            # fraction of targets zeroed by the contamination gate's mask
            # policy (repro.data.pipeline.ContaminationGate)
            mask = batch["loss_mask"]
            metrics = dict(metrics, masked_frac=1.0 - jnp.mean(mask))
        grads, gnorm = clip_by_global_norm(grads, tcfg.opt.clip_norm)
        if tcfg.opt.compress:
            grads, new_err = compressed_grads_with_feedback(
                grads, state["ef_error"])
        lr = sched(state["opt"]["step"])
        new_params, new_opt = opt_update(params, grads, state["opt"], lr=lr)
        new_state = {"opt": new_opt, "params": new_params}
        if tcfg.opt.compress:
            new_state["ef_error"] = new_err
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return new_state, metrics

    return train_step
