"""Data pipeline: corpus synthesis, byte tokenizer, SA-dedup stage,
deterministic shard-aware batching with skip-ahead resume (fault tolerance:
restoring step k replays exactly the batches ≥ k)."""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..text.dedup import dedup_corpus


def synthetic_corpus(n_chars: int, vocab: int = 256, *, dup_fraction:
                     float = 0.0, seed: int = 0) -> np.ndarray:
    """Zipf-ish random byte corpus; optionally inject duplicate blocks so the
    dedup stage has real work to do."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = (1.0 / ranks) / np.sum(1.0 / ranks)
    x = rng.choice(vocab, size=n_chars, p=probs).astype(np.int32)
    if dup_fraction > 0:
        blk = max(64, n_chars // 50)
        n_dup = int(dup_fraction * n_chars / blk)
        for _ in range(n_dup):
            src = int(rng.integers(0, max(n_chars - blk, 1)))
            dst = int(rng.integers(0, max(n_chars - blk, 1)))
            x[dst:dst + blk] = x[src:src + blk]
    return x


@dataclass
class PipelineConfig:
    seq_len: int = 512
    global_batch: int = 8
    dedup: bool = False
    dedup_min_len: int = 48
    seed: int = 0


class TokenPipeline:
    """Packs a token corpus into [global_batch, seq_len + 1] LM batches.

    Deterministic given (seed, step): `batch_at(step)` is a pure function —
    resume after failure = start calling from the restored step."""

    def __init__(self, corpus: np.ndarray, cfg: PipelineConfig):
        self.cfg = cfg
        if cfg.dedup:
            corpus, self.dedup_report = dedup_corpus(
                corpus, min_len=cfg.dedup_min_len)
        else:
            self.dedup_report = None
        self.corpus = np.asarray(corpus, dtype=np.int32)
        self.n = len(self.corpus)
        self.window = cfg.seq_len + 1
        self.n_windows = max(1, self.n - self.window)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step]))
        starts = rng.integers(0, self.n_windows,
                              size=self.cfg.global_batch)
        toks = np.stack([self.corpus[s:s + self.window] for s in starts])
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
