"""SA-backed training data plane: staged streaming shard dedup, train/eval
contamination gate, memorization probe, deterministic batching.

The monolithic `TokenPipeline` used to take one flat corpus and pay a
whole-corpus `dedup_corpus` rebuild up front. This module refactors it
into a **streaming data plane** whose filters are backed by the suffix
array index (the repo's flagship workload — ROADMAP "close the loop with
the model stack"):

    shards ──▶ StreamingDedup ──▶ packed corpus ──▶ batch_at(step)
                  │    │                                 │
                  │    └─ ingest: ONE segment build      ├─ ContaminationGate
                  ▼       per shard (SegmentedIndex)     │  (eval index,
            training index ◀── MemorizationProbe ◀───────┘   reject | mask)
                               (decoded samples)

* **StreamingDedup** — each document shard is ingested into a
  `repro.api.SegmentedIndex` as exactly ONE new segment (builder-cache
  deltas asserted in tests); the shard's own segment SA answers
  "earlier occurrence *within* this shard" and a batched containment
  query against the accumulated index answers "occurs in any *prior*
  shard". Because the gram drop rule is prefix-stable
  (`repro.text.dedup`), the streamed output is **byte-identical** to the
  monolithic `dedup_docs` of the same corpus.
* **ContaminationGate** — a held-out eval set gets its own index; every
  candidate training window's ``gate_min_len``-grams go through ONE
  `count_batch` call, and windows whose hit count exceeds the threshold
  are rejected (deterministically resampled) or loss-masked.
* **MemorizationProbe** — samples decoded from the training model are
  scored for their longest verbatim copy out of the *training* index
  (`longest_match`), logged into the step report by `repro.launch.train`.

Batching stays deterministic and shard-aware: `batch_at(step)` is a pure
function of (seed, step) given the plane's corpus and eval set — restoring
step k replays exactly the batches ≥ k, gate decisions included."""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..api import (SAOptions, SegmentedIndex, SuffixArrayIndex,
                   builder_cache_stats)
from ..text.dedup import (DEDUP_MIN_LEN, duplicate_gram_flags,
                          gram_drop_mask)

GATE_POLICIES = ("reject", "mask")


def synthetic_corpus(n_chars: int, vocab: int = 256, *, dup_fraction:
                     float = 0.0, seed: int = 0) -> np.ndarray:
    """Zipf-ish random byte corpus; optionally inject duplicate blocks so the
    dedup stage has real work to do."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = (1.0 / ranks) / np.sum(1.0 / ranks)
    x = rng.choice(vocab, size=n_chars, p=probs).astype(np.int32)
    if dup_fraction > 0:
        blk = max(64, n_chars // 50)
        n_dup = int(dup_fraction * n_chars / blk)
        for _ in range(n_dup):
            src = int(rng.integers(0, max(n_chars - blk, 1)))
            dst = int(rng.integers(0, max(n_chars - blk, 1)))
            x[dst:dst + blk] = x[src:src + blk]
    return x


def synthetic_doc_shards(n_chars: int, vocab: int = 256, *,
                         shard_docs: int = 8, doc_len: int = 2048,
                         dup_fraction: float = 0.0, seed: int = 0) -> list:
    """The streaming twin of `synthetic_corpus`: the same corpus chopped
    into documents of `doc_len` chars, grouped `shard_docs` documents per
    shard — the arrival unit of the data plane."""
    corpus = synthetic_corpus(n_chars, vocab, dup_fraction=dup_fraction,
                              seed=seed)
    docs = [corpus[at:at + doc_len] for at in range(0, len(corpus), doc_len)]
    return [docs[at:at + shard_docs]
            for at in range(0, len(docs), shard_docs)]


@dataclass
class PipelineConfig:
    """Knobs for the training data plane (and the legacy `TokenPipeline`).

    ``dedup_min_len`` defaults to the one pinned threshold
    (`repro.text.dedup.DEDUP_MIN_LEN`); it used to disagree with
    `dedup_corpus`'s default (48 vs 32)."""

    seq_len: int = 512
    global_batch: int = 8
    dedup: bool = False
    dedup_min_len: int = DEDUP_MIN_LEN
    seed: int = 0
    # ---- data-plane stages ----
    options: SAOptions | None = None   # SA construction plan (None → auto)
    vocab: int | None = None           # declared alphabet for every index
    build_index: bool | None = None    # None → auto (dedup implies index)
    compact_every: int = 0             # compact() every k shards (0 = never;
                                       # merges add builder traffic on top of
                                       # the one-build-per-shard ingest)
    # ---- contamination gate (active when the plane gets eval docs) ----
    gate_min_len: int = DEDUP_MIN_LEN
    gate_policy: str = "reject"        # "reject" | "mask" (GATE_POLICIES)
    gate_max_hits: int = 0             # contaminated gram starts tolerated
    gate_max_resample: int = 8         # reject-policy redraw rounds before
                                       # falling back to masking the window
    # ---- memorization probe ----
    probe_min_len: int = DEDUP_MIN_LEN

    def __post_init__(self):
        if self.gate_policy not in GATE_POLICIES:
            raise ValueError(f"unknown gate_policy {self.gate_policy!r}; "
                             f"expected one of {GATE_POLICIES}")
        rate = self.options.sample_rate if self.options is not None else 1
        if rate > 1:
            # a sparse index answers exactly only for patterns ≥ its rate;
            # every gram the plane queries must clear that bar, so the
            # incompatibility is rejected at construction, not mid-stream
            if rate > self.dedup_min_len:
                raise ValueError(
                    f"options.sample_rate={rate} > dedup_min_len="
                    f"{self.dedup_min_len}: the sparse training index "
                    f"cannot answer the dedup stage's {self.dedup_min_len}-"
                    f"grams — lower sample_rate or raise dedup_min_len")
            if rate > self.gate_min_len:
                raise ValueError(
                    f"options.sample_rate={rate} > gate_min_len="
                    f"{self.gate_min_len}: the sparse eval index cannot "
                    f"answer the contamination gate's {self.gate_min_len}-"
                    f"grams — lower sample_rate or raise gate_min_len")

    @property
    def wants_index(self) -> bool:
        return self.dedup if self.build_index is None else self.build_index


@dataclass
class ShardStats:
    """What one shard cost as it moved through the plane."""

    docs: int = 0
    chars: int = 0
    kept_chars: int = 0
    dropped_chars: int = 0
    prior_hits: int = 0        # gram starts matched in earlier shards
    within_hits: int = 0       # gram starts matched earlier in this shard
    unique_grams: int = 0
    builds: int = 0            # builder-cache delta (ingest = exactly 1)


@dataclass
class PlaneReport:
    """Aggregate over every shard the plane has ingested. `dup_chars` /
    `dup_fraction` mirror the legacy `DedupReport` spelling (they count
    *dropped* chars — what the launcher prints as "removed")."""

    shards: int = 0
    docs: int = 0
    n_chars: int = 0
    kept_chars: int = 0
    dropped_chars: int = 0
    builds: int = 0

    @property
    def dup_chars(self) -> int:
        return self.dropped_chars

    @property
    def dup_fraction(self) -> float:
        return self.dropped_chars / max(self.n_chars, 1)

    def absorb(self, st: ShardStats) -> None:
        self.shards += 1
        self.docs += st.docs
        self.n_chars += st.chars
        self.kept_chars += st.kept_chars
        self.dropped_chars += st.dropped_chars
        self.builds += st.builds


def _builds() -> int:
    s = builder_cache_stats()
    return s["hits"] + s["misses"]


def _doc_grams(doc: np.ndarray, g: int) -> np.ndarray:
    """[n_pos, g] sliding windows (empty when the doc is shorter than g)."""
    if len(doc) < g:
        return np.zeros((0, g), np.int64)
    return np.lib.stride_tricks.sliding_window_view(doc, g)


class StreamingDedup:
    """Per-shard exact-substring dedup against everything seen so far.

    Shares the drop rule with the monolithic `repro.text.dedup.dedup_docs`
    — position p of a new document is flagged when its ``min_len``-gram
    occurred at any earlier global position. "Earlier" splits along the
    shard boundary:

    * **prior shards** — one batched containment query (`contains_batch`,
      chunked) against the accumulated `SegmentedIndex`, on the shard's
      *deduplicated set* of grams;
    * **within this shard** — the gram-run rule over the shard's own
      fresh segment SA (`duplicate_gram_flags`), which also covers
      earlier documents of the same shard.

    Ingest is exactly ONE segment build (`add_docs(compact=False)`); the
    raw (pre-drop) documents are what enters the index, because that is
    what the monolithic reference matches against.
    """

    def __init__(self, index: SegmentedIndex, min_len: int = DEDUP_MIN_LEN,
                 *, chunk: int = 2048):
        if min_len < 1:
            raise ValueError(f"min_len must be ≥ 1, got {min_len}")
        if index.options.sample_rate > min_len:
            raise ValueError(
                f"StreamingDedup over a sparse index needs min_len ≥ "
                f"sample_rate (exact containment of every {min_len}-gram); "
                f"got sample_rate={index.options.sample_rate}")
        self.index = index
        self.min_len = int(min_len)
        self.chunk = int(chunk)

    def _prior_flags(self, docs: list) -> list:
        """Per-doc bool[n_pos]: gram occurs in a previously-ingested shard."""
        g = self.min_len
        n_pos = [max(len(d) - g + 1, 0) for d in docs]
        flags = [np.zeros(k, bool) for k in n_pos]
        rows = [_doc_grams(d, g) for d in docs if len(d) >= g]
        if not rows or self.index.n == 0:
            return flags
        uniq, inv = np.unique(np.concatenate(rows), axis=0,
                              return_inverse=True)
        hit = np.zeros(len(uniq), bool)
        sigma = self.index.sigma
        # grams with symbols the prior corpus never used can't occur there
        askable = np.flatnonzero(uniq.max(axis=1) < sigma)
        for at in range(0, len(askable), self.chunk):
            sel = askable[at:at + self.chunk]
            hit[sel] = self.index.contains_batch(list(uniq[sel]))
        flat = hit[inv]
        at = 0
        for j, k in enumerate(n_pos):
            flags[j] = flat[at:at + k]
            at += k
        return flags

    def process_shard(self, docs: list) -> tuple[list, ShardStats]:
        """Dedup + ingest one shard; returns (kept_docs, stats)."""
        g = self.min_len
        st = ShardStats(docs=len(docs), chars=int(sum(len(d) for d in docs)))
        prior = self._prior_flags(docs)
        self.index.add_docs(docs, compact=False)      # the ONE build
        seg = self.index.segments[-1]
        flat = seg.index
        if getattr(flat, "sample_rate", 1) > 1:
            # the within-shard gram-run rule needs the rank of EVERY shard
            # position (dense SA + LCP) — build a transient dense index of
            # just this shard. Sparse segment construction bypasses the
            # builder cache entirely, so this dense build is still THE one
            # builder-cache build per shard (same layout: encode_docs of
            # the same docs ⇒ identical text/doc_starts).
            flat = SuffixArrayIndex.from_docs(
                docs, self.index.options.replace(sample_rate=1),
                sigma=self.index._sigma)
        within = duplicate_gram_flags(flat, g, keep_first=True)
        ends = flat._doc_ends
        kept = []
        for j, d in enumerate(docs):
            flags = within[flat.doc_starts[j]:ends[j]].copy()
            st.within_hits += int(flags.sum())
            st.prior_hits += int(prior[j].sum())
            flags[:len(prior[j])] |= prior[j]
            drop = gram_drop_mask(flags, g)
            st.dropped_chars += int(drop.sum())
            kept.append(d[~drop])
        st.kept_chars = st.chars - st.dropped_chars
        st.unique_grams = int(sum(len(p) for p in prior))
        return kept, st


class ContaminationGate:
    """Train/eval firewall: exact-substring overlap of training windows
    against a held-out eval set, measured gram-by-gram.

    A window is *flagged* when more than ``max_hits`` of its
    ``min_len``-grams occur in the eval index; all grams of a whole batch
    of windows resolve in one (chunked) `count_batch` call on the
    deduplicated gram set. `check` is pure; the policy (reject vs mask)
    is applied by the data plane's `batch_at`."""

    def __init__(self, eval_docs, *, min_len: int = DEDUP_MIN_LEN,
                 options: SAOptions | None = None, sigma: int | None = None,
                 max_hits: int = 0, chunk: int = 4096):
        docs = [np.asarray(d, np.int64).ravel() for d in eval_docs]
        self.index = SuffixArrayIndex.from_docs(docs, options, sigma=sigma)
        if int(min_len) < self.index.min_pattern_len:
            raise ValueError(
                f"gate min_len={min_len} is below the eval index's minimum "
                f"answerable pattern length "
                f"({self.index.min_pattern_len} = its sample_rate) — the "
                f"gate's grams could not be checked exactly")
        self.min_len = int(min_len)
        self.max_hits = int(max_hits)
        self.chunk = int(chunk)
        self.stats = {"checked_windows": 0, "flagged_windows": 0,
                      "rejected_windows": 0, "masked_windows": 0,
                      "grams_queried": 0}

    def check(self, windows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(hits int64[W], contaminated bool[W, L]) for a [W, L] batch.

        ``hits[w]`` counts gram starts of window w present in the eval
        set; ``contaminated[w]`` paints the union of their ``[p, p +
        min_len)`` intervals (the char positions a mask policy zeroes
        out)."""
        windows = np.asarray(windows, np.int64)
        W, L = windows.shape
        g = self.min_len
        hits = np.zeros(W, np.int64)
        contaminated = np.zeros((W, L), bool)
        self.stats["checked_windows"] += W
        if L < g or self.index.n == 0 or W == 0:
            return hits, contaminated
        grams = np.lib.stride_tricks.sliding_window_view(windows, g, axis=1)
        P = grams.shape[1]
        uniq, inv = np.unique(grams.reshape(-1, g), axis=0,
                              return_inverse=True)
        hit = np.zeros(len(uniq), bool)
        sigma = self.index.sigma
        askable = np.flatnonzero((uniq.min(axis=1) >= 0)
                                 & (uniq.max(axis=1) < sigma))
        for at in range(0, len(askable), self.chunk):
            sel = askable[at:at + self.chunk]
            hit[sel] = self.index.count_batch(list(uniq[sel])) > 0
        self.stats["grams_queried"] += len(askable)
        flags = hit[inv].reshape(W, P)
        hits = flags.sum(axis=1)
        rows, cols = np.nonzero(flags)
        delta = np.zeros((W, L + 1), np.int64)
        np.add.at(delta, (rows, cols), 1)
        np.add.at(delta, (rows, np.minimum(cols + g, L)), -1)
        contaminated = np.cumsum(delta[:, :L], axis=1) > 0
        self.stats["flagged_windows"] += int((hits > self.max_hits).sum())
        return hits, contaminated


class MemorizationProbe:
    """Longest-verbatim-copy metrics for generated samples vs an index.

    `run` scores each sample by `longest_match` against the (streaming)
    training index — the length of the longest substring the model emitted
    verbatim from its training data — and summarises max/mean plus the
    fraction at or above ``min_len`` (the same bar the dedup stage uses:
    a copy that long would itself have been a dedup candidate)."""

    def __init__(self, index, *, min_len: int = DEDUP_MIN_LEN):
        self.index = index
        self.min_len = int(min_len)

    def run(self, samples) -> dict:
        lens = [int(self.index.longest_match(np.asarray(s).ravel()))
                for s in samples]
        if not lens:
            return {"samples": 0, "longest_copy_max": 0,
                    "longest_copy_mean": 0.0, "frac_memorized": 0.0,
                    "min_len": self.min_len}
        arr = np.asarray(lens, np.int64)
        return {"samples": len(lens),
                "longest_copy_max": int(arr.max()),
                "longest_copy_mean": float(arr.mean()),
                "frac_memorized": float((arr >= self.min_len).mean()),
                "min_len": self.min_len}


class TrainingDataPlane:
    """The staged data plane: shards in, gated deterministic batches out.

    Construction wires the stages from one `PipelineConfig`:

    * ``cfg.dedup`` → a `StreamingDedup` over a fresh `SegmentedIndex`
      (also reachable as ``plane.index`` for the probe);
    * ``eval_docs`` → a `ContaminationGate` applied inside `batch_at`;
    * `probe(samples)` → `MemorizationProbe` over the training index.

    `batch_at(step)` is a pure function of ``(cfg.seed, step)`` given the
    ingested corpus and eval set — gate rejections resample from the same
    deterministic stream, so restore-and-replay reproduces batches
    exactly. When a gate is attached, batches always carry a
    ``loss_mask`` key ([B, seq_len] float32, 1 = count the target) so the
    train-step pytree structure never changes between steps."""

    def __init__(self, cfg: PipelineConfig, *, eval_docs=None, shards=None):
        self.cfg = cfg
        self.options = cfg.options if cfg.options is not None else SAOptions()
        self.index = (SegmentedIndex(options=self.options, sigma=cfg.vocab)
                      if cfg.wants_index else None)
        self.dedup = (StreamingDedup(self.index, cfg.dedup_min_len)
                      if cfg.dedup else None)
        self.gate = (ContaminationGate(
            eval_docs, min_len=cfg.gate_min_len, options=self.options,
            sigma=cfg.vocab, max_hits=cfg.gate_max_hits)
            if eval_docs is not None else None)
        self.report = PlaneReport()
        self.shard_stats: list[ShardStats] = []
        self._kept: list[np.ndarray] = []
        self._corpus: np.ndarray | None = None
        for shard in (shards if shards is not None else []):
            self.ingest_shard(shard)

    # -------------------------------------------------------------- ingest
    def ingest_shard(self, docs) -> ShardStats:
        """Push one shard (a list of documents) through dedup + indexing.
        Exactly one segment build when an index is attached (asserted via
        builder-cache deltas in tests); `compact_every` adds merge builds
        on top, every that-many shards."""
        docs = [np.asarray(d, np.int64).ravel() for d in docs]
        if not docs:
            return ShardStats()
        before = _builds()
        if self.dedup is not None:
            kept, st = self.dedup.process_shard(docs)
        else:
            if self.index is not None:
                self.index.add_docs(docs, compact=False)
            kept = docs
            st = ShardStats(docs=len(docs),
                            chars=int(sum(len(d) for d in docs)),
                            kept_chars=int(sum(len(d) for d in docs)))
        if (self.index is not None and self.cfg.compact_every
                and (self.report.shards + 1) % self.cfg.compact_every == 0):
            self.index.compact()
        st.builds = _builds() - before
        self.report.absorb(st)
        self.shard_stats.append(st)
        self._kept.extend(kept)
        self._corpus = None
        return st

    # ------------------------------------------------------------ batching
    @property
    def corpus(self) -> np.ndarray:
        """Every kept (post-dedup) document, packed flat for batching."""
        if self._corpus is None:
            self._corpus = (np.concatenate(self._kept).astype(np.int32)
                            if self._kept else np.zeros(0, np.int32))
        return self._corpus

    @property
    def n(self) -> int:
        return len(self.corpus)

    @property
    def window(self) -> int:
        return self.cfg.seq_len + 1

    @property
    def n_windows(self) -> int:
        return max(1, self.n - self.window)

    def _windows(self, starts) -> np.ndarray:
        corpus = self.corpus
        return np.stack([corpus[s:s + self.window] for s in starts])

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        toks = self._windows(rng.integers(0, self.n_windows,
                                          size=cfg.global_batch))
        if self.gate is None:
            return {"tokens": toks.astype(np.int32)}
        hits, contaminated = self.gate.check(toks)
        bad = hits > cfg.gate_max_hits
        if cfg.gate_policy == "reject":
            rounds = 0
            while bad.any() and rounds < cfg.gate_max_resample:
                idx = np.flatnonzero(bad)
                self.gate.stats["rejected_windows"] += len(idx)
                toks[idx] = self._windows(
                    rng.integers(0, self.n_windows, size=len(idx)))
                hits[idx], contaminated[idx] = self.gate.check(toks[idx])
                bad = np.zeros_like(bad)
                bad[idx] = hits[idx] > cfg.gate_max_hits
                rounds += 1
        # windows still over threshold (mask policy, or reject ran out of
        # redraws) train with their contaminated targets masked out
        self.gate.stats["masked_windows"] += int(bad.sum())
        keep = ~(contaminated & bad[:, None])
        loss_mask = keep[:, 1:].astype(np.float32)   # target t = token t+1
        return {"tokens": toks.astype(np.int32), "loss_mask": loss_mask}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    # --------------------------------------------------------------- probe
    def probe(self, samples, *, min_len: int | None = None) -> dict:
        """Memorization metrics for decoded `samples` against the training
        index (requires the plane to have one — dedup or build_index)."""
        if self.index is None:
            raise RuntimeError(
                "the plane has no training index (enable cfg.dedup or "
                "cfg.build_index) — nothing to probe against")
        probe = MemorizationProbe(
            self.index, min_len=(self.cfg.probe_min_len
                                 if min_len is None else min_len))
        return probe.run(samples)

    def gate_stats(self) -> dict:
        return dict(self.gate.stats) if self.gate is not None else {}

    def __repr__(self) -> str:
        return (f"TrainingDataPlane(shards={self.report.shards}, "
                f"docs={self.report.docs}, n={self.n}, "
                f"dedup={self.dedup is not None}, "
                f"gate={self.gate is not None})")


class TokenPipeline:
    """Legacy facade: one flat corpus through the plane as a single shard.

    Packs a token corpus into [global_batch, seq_len + 1] LM batches.
    Deterministic given (seed, step): `batch_at(step)` is a pure function —
    resume after failure = start calling from the restored step. With
    ``cfg.dedup`` the corpus goes through the streaming dedup stage (a
    single-shard stream is byte-identical to the monolithic path)."""

    def __init__(self, corpus: np.ndarray, cfg: PipelineConfig):
        self.cfg = cfg
        self._plane = TrainingDataPlane(cfg)
        self._plane.ingest_shard([np.asarray(corpus).ravel()])
        self.dedup_report = self._plane.report if cfg.dedup else None
        self.corpus = self._plane.corpus
        self.n = self._plane.n
        self.window = self._plane.window
        self.n_windows = self._plane.n_windows

    def batch_at(self, step: int) -> dict:
        return self._plane.batch_at(step)

    def __iter__(self):
        return iter(self._plane)
