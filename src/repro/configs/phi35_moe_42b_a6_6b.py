"""Phi-3.5-MoE 42B-A6.6B [hf:microsoft/Phi-3.5-MoE-instruct; hf] —
16 experts top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064. Pure full attention
→ long_500k skipped."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=6_400, vocab_size=32_064,
    pattern=("g",), n_experts=16, top_k=2,
)
