"""The paper's own workload: distributed suffix-array construction configs
(corpus size, backend, v schedule) for benchmarks and the SA dry-run.

`SAConfig` is a thin, frozen launch-config wrapper; the executable plan is
the `repro.api.SAOptions` it produces via `to_options()`.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class SAConfig:
    name: str = "suffix-array"
    n: int = 1 << 20            # corpus length (characters)
    backend: str = "auto"       # registry key, or "auto" (mesh → bsp)
    v0: int = 3
    schedule: str = "accelerated"   # or "fixed"
    base_threshold: int = 4096
    sort_impl: str = "auto"     # sort primitive: jax hot path AND the BSP
                                # shard-local sorts (see SAOptions.sort_impl)
    cache: bool = True          # compiled-builder cache + bucketed padding
    pack_keys: bool = True
    sample_rate: int = 1        # >1: sparse sampled-position indexing
                                # (repro.sparse) — index memory scales n/s,
                                # patterns shorter than this raise
                                # PatternTooShortError; must stay ≤ the
                                # dedup/gate gram lengths below (validated
                                # by PipelineConfig)
    axis: str = "bsp"
    store_dir: str = ""         # IndexStore root for serving ("" = build
                                # in-process, never persist)
    query_batch: int = 64       # patterns per batched query tick
                                # (repro.api.QuerySession batch_size)
    # ---- async serving tier (repro.serve.SAServer) ----
    coalesce_max_wait_us: float = 500.0   # batch-window deadline: extra
                                # latency a lone request may pay to share
                                # a kernel with later arrivals
    queue_depth: int = 1024     # admission bound on queued requests
    overload_policy: str = "reject"  # "none" | "reject" | "shed"
                                # (repro.serve.admission.POLICIES)
    arrival: str = "poisson"    # open-loop arrival process for serving/
                                # loadgen ("uniform"|"poisson"|"onoff")
    offered_qps: float = 2000.0  # open-loop offered load for launch/serve
    # ---- segmented incremental serving (repro.api.SegmentedIndex) ----
    segments: int = 0           # >0: serve a SegmentedIndex with this many
                                # segments (docs chunked evenly); 0 = the
                                # monolithic single-index path
    ingest: int = 0             # docs ingested through add_docs AFTER the
                                # initial build (exercises the incremental
                                # one-segment-per-ingest path in launch/serve)
    compact_fanin: int = 4      # size-tiered compaction trigger
                                # (SAOptions.compact_fanin)
    gc_hygiene: bool = True     # SAServer GC regime: pin gen-2 thresholds
                                # + freeze the index after warmup
    # ---- training data plane (repro.data.pipeline) ----
    dedup_min_len: int = 48     # exact-substring dedup bar
                                # (= repro.text.dedup.DEDUP_MIN_LEN)
    gate_min_len: int = 48      # train/eval contamination-gate gram length
    gate_policy: str = "reject"  # "reject" | "mask"
                                # (repro.data.pipeline.GATE_POLICIES)
    shard_docs: int = 8         # documents per streamed ingest shard

    def to_pipeline(self, *, seq_len: int = 512, global_batch: int = 8,
                    dedup: bool = True, vocab=None, seed: int = 0):
        """A `repro.data.pipeline.PipelineConfig` carrying this config's
        data-plane knobs (the SA plan rides along via `to_options`)."""
        from ..data.pipeline import PipelineConfig
        return PipelineConfig(
            seq_len=seq_len, global_batch=global_batch, dedup=dedup,
            dedup_min_len=self.dedup_min_len, seed=seed,
            options=self.to_options(), vocab=vocab,
            gate_min_len=self.gate_min_len, gate_policy=self.gate_policy)

    def to_options(self, *, mesh=None, counters=None, stats=None):
        """The `repro.api.SAOptions` plan this config describes. Runtime
        objects (mesh, instrumentation sinks) are supplied here — they do
        not belong in a frozen launch config."""
        from ..api import SAOptions
        return SAOptions(backend=self.backend, v0=self.v0,
                         schedule=self.schedule,
                         base_threshold=self.base_threshold,
                         sort_impl=self.sort_impl, cache=self.cache,
                         mesh=mesh, axis=self.axis,
                         pack_keys=self.pack_keys,
                         counters=counters, stats=stats,
                         compact_fanin=self.compact_fanin,
                         sample_rate=self.sample_rate)


CONFIG = SAConfig()
