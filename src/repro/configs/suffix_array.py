"""The paper's own workload: distributed suffix-array construction configs
(corpus size, v schedule) for benchmarks and the SA dry-run."""
from dataclasses import dataclass


@dataclass(frozen=True)
class SAConfig:
    name: str = "suffix-array"
    n: int = 1 << 20            # corpus length (characters)
    v0: int = 3
    schedule: str = "accelerated"   # or "fixed"
    base_threshold: int = 4096


CONFIG = SAConfig()
