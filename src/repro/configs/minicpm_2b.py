"""MiniCPM-2B [arXiv:2404.06395; hf] — dense llama-like, WSD schedule.

40L d_model=2304 36H (GQA kv=36 == MHA) d_ff=5760 vocab=122753.
Pure full attention → long_500k cell skipped (DESIGN §5).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, head_dim=64,
    d_ff=5760, vocab_size=122_753,
    pattern=("g",), rope_base=10_000.0,
    lr_schedule="wsd",
)
