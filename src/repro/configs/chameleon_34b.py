"""Chameleon-34B [arXiv:2405.09818; unverified] — early-fusion VLM: VQ image
tokens share the text vocab (65536), so the backbone is a dense decoder with
qk-norm; the VQ-GAN tokenizer frontend is a STUB (input_specs provides token
ids / precomputed patch embeddings).

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. Pure full attention
→ long_500k skipped."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22_016, vocab_size=65_536,
    pattern=("g",), qk_norm=True, frontend="vision",
)
