"""Gemma-3 1B [hf:google/gemma-3-1b-pt; unverified] — 5:1 local:global, MQA.

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144 head_dim=256.
26 = 4 periods of 6 + tail of 2."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6_912, vocab_size=262_144,
    pattern=("l", "l", "l", "l", "l", "g"), window=512,
    rope_base=1_000_000.0, rope_base_local=10_000.0,
    sandwich_norm=True, qk_norm=True, act="gelu",
)
