"""RWKV6 "Finch" 1.6B [arXiv:2404.05892; unverified] — attention-free SSM
with data-dependent decay; O(1)/token decode → long_500k runs.

24L d_model=2048 d_ff=7168 vocab=65536; WKV heads = d/64 = 32."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=7_168, vocab_size=65_536,
    pattern=("w",), rope_base=0.0,
)
