"""Assigned-architecture registry: --arch <id> resolves here."""
from __future__ import annotations

from importlib import import_module

ARCH_IDS = [
    "minicpm_2b",
    "gemma2_27b",
    "gemma3_27b",
    "gemma3_1b",
    "recurrentgemma_2b",
    "kimi_k2_1t_a32b",
    "phi35_moe_42b_a6_6b",
    "rwkv6_1_6b",
    "chameleon_34b",
    "whisper_small",
    "suffix_array",          # the paper's own workload config
]

_ALIASES = {
    "minicpm-2b": "minicpm_2b",
    "gemma2-27b": "gemma2_27b",
    "gemma3-27b": "gemma3_27b",
    "gemma3-1b": "gemma3_1b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b_a6_6b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "chameleon-34b": "chameleon_34b",
    "whisper-small": "whisper_small",
}


def get_config(arch: str):
    arch = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def model_archs():
    return [a for a in ARCH_IDS if a != "suffix_array"]
