"""Gemma-3 27B [hf:google/gemma-3-*-pt; unverified] — 5:1 local:global,
128k context, window 1024, dual rope bases (local 10k / global 1M).

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144 head_dim=128.
62 = 10 full periods of 6 + tail of 2 (l, l)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21_504, vocab_size=262_144,
    pattern=("l", "l", "l", "l", "l", "g"), window=1024,
    rope_base=1_000_000.0, rope_base_local=10_000.0,
    sandwich_norm=True, qk_norm=True, act="gelu",
)
