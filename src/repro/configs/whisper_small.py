"""Whisper-small [arXiv:2212.04356; unverified] — encoder-decoder; the conv
mel frontend is a STUB (input_specs provides precomputed frame embeddings,
enc_seq=1500). Decoder self-attn is causal full attention + cross-attention
to the encoder. long_500k skipped (30 s audio; full attention).

12L(dec) + 12L(enc) d_model=768 12H (kv=12) d_ff=3072 vocab=51865."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3_072, vocab_size=51_865,
    pattern=("g",), encoder_layers=12, enc_seq=1500,
    rope_base=0.0, frontend="audio", act="gelu",
)
