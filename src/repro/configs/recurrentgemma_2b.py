"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf] — RG-LRU + local
attention 2:1, window 2048.

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000 lru_dim=2560.
26 = 8 periods of (r, r, l) + tail of 2 (r, r)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7_680, vocab_size=256_000,
    pattern=("r", "r", "l"), window=2048, lru_dim=2560,
    act="gelu",
)
