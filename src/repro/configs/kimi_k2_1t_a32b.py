"""Kimi K2 1T-A32B [arXiv:2501.kimi2; unverified, paper-table] — trillion-
parameter MoE: 384 experts, top-8, fine-grained d_ff=2048 per expert.

61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840. Pure full attention
→ long_500k skipped. bf16 params + Adafactor (1T-scale memory, DESIGN §6)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2_048, vocab_size=163_840,
    pattern=("g",), n_experts=384, top_k=8,
    param_dtype="bfloat16", optimizer="adafactor", remat="full",
)
