"""Gemma-2 27B [arXiv:2408.00118; hf] — 1:1 local:global alternation,
logit softcap 30 / attention softcap 50, sandwich norms, window 4096.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000 head_dim=128.
Hybrid local/global → long_500k runs (local layers bound KV; global layers
decode-linear)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36_864, vocab_size=256_000,
    pattern=("l", "g"), window=4096,
    logit_softcap=30.0, attn_softcap=50.0, sandwich_norm=True,
    act="gelu",
)
