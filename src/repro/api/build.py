"""`build_suffix_array` — the one entry point for suffix-array construction.

Validation, dtype normalisation, and trivial-input fast paths live here so
every backend sees the same contract (int64 1-D text, values ≥ 0, n ≥ 2) and
every caller gets the same result type (np.int32[n], a permutation of
range(n)).

This module also owns the **builder cache**, the keying/observability layer
over the compiled state a build reuses. Suffix-array builds are dominated
by per-shape compiled state (jitted XLA computations, packed
difference-cover tables, device-resident Λ lookup tables), and a serving
process sees an open-ended stream of input lengths. What prevents
unbounded re-tracing is *shape quantisation*: plans with
``options.cache=True`` run the jax backend with bucketed padding
(`repro.core.dcv_jax.pad_bucket`, geometric grid of ratio ≤ 1.25), so all
lengths inside one bucket reach the same shapes and jax's jit cache plus
the lru-cached level tables in `dcv_jax` serve every later build without
tracing (`TRACE_COUNTS` stays flat — `tests/api/test_sort_impl.py`
asserts it; note recursion depth is data-dependent via the `distinct`
short-circuit, so the first build of *new data* may still trace deeper
levels).

The cache here names each compiled configuration — one entry per
``(resolved plan, bucketed length)``, where "resolved" means backend and
sort_impl are concrete (``"auto"`` and its platform resolution share an
entry) — and memoises that resolution. Its hit/miss counters are the
serving-path metric for "did this build land on a warm configuration".
`builder_cache_stats()` / `clear_builder_cache()` expose it to tests,
benchmarks, and `repro.launch.serve`.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from .options import SAOptions
from .registry import get_backend

#: (backend, v0, schedule, base_threshold, resolved sort_impl, n_bucket)
#: → (builder fn, resolved sort_impl).
_BUILDER_CACHE: dict[tuple, tuple[Callable, str]] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def builder_cache_stats() -> dict:
    """Snapshot of the builder cache: entries / hits / misses."""
    return {"entries": len(_BUILDER_CACHE), **_CACHE_STATS}


def clear_builder_cache() -> None:
    """Drop all builder-cache entries and reset the hit/miss counters.

    Does not drop jax's own jit cache — entries re-created after a clear
    still reuse compiled computations when shapes match.
    """
    _BUILDER_CACHE.clear()
    _CACHE_STATS["hits"] = 0
    _CACHE_STATS["misses"] = 0


def _resolved_impl(opts: SAOptions, backend: str) -> str:
    """Concrete sort_impl for this plan ("auto" → the backend's choice).

    jax resolves per platform (`repro.core.compat.default_sort_impl`); bsp
    per `repro.bsp.psort.resolve_bsp_sort_impl` — imported lazily so only
    plans that actually target the bsp backend load the BSP stack (which
    they are about to build with anyway)."""
    if opts.sort_impl != "auto":
        return opts.sort_impl
    if backend == "jax":
        from ..core.compat import default_sort_impl
        return default_sort_impl()
    if backend == "bsp":
        from ..bsp.psort import resolve_bsp_sort_impl
        return resolve_bsp_sort_impl(opts.sort_impl, opts.pack_keys)
    return opts.sort_impl


def _cached_builder(opts: SAOptions, n: int) -> tuple[Callable, SAOptions]:
    """(builder, fully-resolved plan) for this plan + bucketed length.

    The key uses the *resolved* backend and sort_impl, so plans that differ
    only in spelling ("auto" vs its resolution) share one entry, and the
    resolution work itself is memoised.
    """
    from ..core.dcv_jax import pad_bucket
    backend = opts.resolve_backend()
    impl = _resolved_impl(opts, backend)
    sched = (opts.schedule if isinstance(opts.schedule, str)
             else id(opts.schedule))
    key = (backend, opts.v0, sched, opts.base_threshold, impl,
           pad_bucket(n))
    entry = _BUILDER_CACHE.get(key)
    if entry is None:
        _CACHE_STATS["misses"] += 1
        entry = (get_backend(backend), impl)
        _BUILDER_CACHE[key] = entry
    else:
        _CACHE_STATS["hits"] += 1
    builder, impl = entry
    if impl != opts.sort_impl:
        opts = opts.replace(sort_impl=impl)
    return builder, opts


def build_suffix_array(x, options: SAOptions | None = None,
                       **overrides) -> np.ndarray:
    """Suffix array of `x` under the plan `options`. Returns np.int32[n].

    `x` is a 1-D sequence of non-negative integers (tokens/bytes).
    Keyword overrides are applied on top of `options`, e.g.
    ``build_suffix_array(x, backend="seq")`` or
    ``build_suffix_array(x, opts, mesh=my_mesh)``.

    With ``options.cache`` (the default) the build goes through the
    compiled-builder cache: input lengths are padded up to a geometric
    bucket grid inside the jax backend, so repeated builds of nearby
    lengths — `SuffixArrayIndex` rebuilds, the serve path, benchmark
    sweeps — reuse every jitted computation instead of re-tracing. Pass
    ``cache=False`` to build at the exact input shape.
    """
    opts = options if options is not None else SAOptions()
    if overrides:
        opts = opts.replace(**overrides)
    if opts.sample_rate > 1:
        raise ValueError(
            f"build_suffix_array builds the DENSE full-length suffix array "
            f"(every registry backend's contract); sample_rate="
            f"{opts.sample_rate} plans go through the facade — "
            f"SuffixArrayIndex.build / .from_docs dispatch to "
            f"repro.sparse.SparseSuffixArrayIndex, or call "
            f"repro.sparse.build_sparse_suffix_array directly")

    x = np.asarray(x)
    if x.ndim != 1:
        raise ValueError(f"text must be 1-D, got shape {x.shape}")
    if x.dtype.kind not in "iub":
        raise TypeError(f"text must be integer-valued, got dtype {x.dtype}")
    n = int(len(x))
    x = x.astype(np.int64, copy=False)
    if n and opts.validate and int(x.min()) < 0:
        raise ValueError("text values must be ≥ 0 (negative values are "
                         "reserved for pad/separator sentinels)")
    if n == 0:
        return np.zeros(0, dtype=np.int32)
    if n == 1:
        return np.zeros(1, dtype=np.int32)

    if opts.cache:
        builder, opts = _cached_builder(opts, n)
    else:
        builder = get_backend(opts.resolve_backend())
    sa = np.asarray(builder(x, opts))
    sa = sa.astype(np.int32, copy=False)
    if opts.validate and sa.shape != (n,):
        raise RuntimeError(
            f"backend {opts.resolve_backend()!r} returned shape {sa.shape}, "
            f"expected ({n},)")
    return sa
