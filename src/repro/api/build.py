"""`build_suffix_array` — the one entry point for suffix-array construction.

Validation, dtype normalisation, and trivial-input fast paths live here so
every backend sees the same contract (int64 1-D text, values ≥ 0, n ≥ 2) and
every caller gets the same result type (np.int32[n], a permutation of
range(n)).
"""
from __future__ import annotations

import numpy as np

from .options import SAOptions
from .registry import get_backend


def build_suffix_array(x, options: SAOptions | None = None,
                       **overrides) -> np.ndarray:
    """Suffix array of `x` under the plan `options`. Returns np.int32[n].

    `x` is a 1-D sequence of non-negative integers (tokens/bytes).
    Keyword overrides are applied on top of `options`, e.g.
    ``build_suffix_array(x, backend="seq")`` or
    ``build_suffix_array(x, opts, mesh=my_mesh)``.
    """
    opts = options if options is not None else SAOptions()
    if overrides:
        opts = opts.replace(**overrides)

    x = np.asarray(x)
    if x.ndim != 1:
        raise ValueError(f"text must be 1-D, got shape {x.shape}")
    if x.dtype.kind not in "iub":
        raise TypeError(f"text must be integer-valued, got dtype {x.dtype}")
    n = int(len(x))
    x = x.astype(np.int64, copy=False)
    if n and opts.validate and int(x.min()) < 0:
        raise ValueError("text values must be ≥ 0 (negative values are "
                         "reserved for pad/separator sentinels)")
    if n == 0:
        return np.zeros(0, dtype=np.int32)
    if n == 1:
        return np.zeros(1, dtype=np.int32)

    sa = np.asarray(get_backend(opts.resolve_backend())(x, opts))
    sa = sa.astype(np.int32, copy=False)
    if opts.validate and sa.shape != (n,):
        raise RuntimeError(
            f"backend {opts.resolve_backend()!r} returned shape {sa.shape}, "
            f"expected ({n},)")
    return sa
