"""`IndexStore` — persist built suffix-array indexes; restore, don't rebuild.

Construction cost is the whole point of the paper; paying it once and
amortising it across query workloads is the whole point of an index
service. This module turns a built `SuffixArrayIndex` into a durable,
versioned artifact on disk so a serving process restarts into a warm
index instead of re-running the builder.

Format — one directory per named entry, written through the committed
checkpoint machinery in `repro.ckpt.checkpoint` (atomic rename + a
`COMMITTED` marker, so a crashed writer never leaves a half-visible
index)::

    <root>/<name>/step_00000000/
        arrays.npz       — text, sa, doc_starts (+ lcp when it was cached)
        manifest.json    — leaf shapes/dtypes + the index manifest extras
        COMMITTED

The manifest extras carry everything needed to trust a restore:

* ``format`` — `FORMAT_VERSION`; bumped on layout changes, old entries
  load as stale rather than as garbage;
* ``options_fingerprint`` — `SAOptions.fingerprint()` of the plan that
  built the index (construction fields only; see that docstring);
* ``corpus_sha256`` — content hash of the encoded text, so a store entry
  built from yesterday's corpus never silently serves today's queries;
* ``shift`` / ``sigma`` / ``n`` / ``n_docs`` / ``has_lcp`` — the index
  structure, restored without recomputation (the lazy LCP stays lazy if
  it was never computed before saving).

Staleness is an *error type*, not a boolean: `load_index` raises
`StaleIndexError` describing exactly which check failed, and
`IndexStore.get_or_build` catches it (and `FileNotFoundError`) to fall
back to a fresh build + save, reporting ``"hit" | "miss" | "stale"`` the
way `repro.api.build.builder_cache_stats` reports builder-cache traffic.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from typing import Callable

import numpy as np

from ..ckpt.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from .index import SuffixArrayIndex
from .options import SAOptions

#: bump when the on-disk layout or manifest fields change incompatibly.
FORMAT_VERSION = 1

_KIND = "suffix-array-index"


class StaleIndexError(RuntimeError):
    """A persisted index exists but no longer matches what was asked for
    (format version, construction plan, or corpus content)."""


def corpus_fingerprint(text) -> str:
    """Content hash of an encoded text buffer (dtype-normalised sha256).

    This is the store's corpus identity: computing it costs one linear
    pass, vastly cheaper than the build it may save. `encode_docs` output
    and `SuffixArrayIndex.text` hash identically for the same corpus.
    """
    arr = np.ascontiguousarray(np.asarray(text, np.int64))
    h = hashlib.sha256()
    h.update(str(arr.shape).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


def _index_tree(index: SuffixArrayIndex) -> dict:
    tree = {"text": index.text, "sa": index.sa,
            "doc_starts": index.doc_starts}
    if index._lcp is not None:
        tree["lcp"] = index._lcp
    return tree


def save_index(path: str, index: SuffixArrayIndex) -> str:
    """Persist `index` under `path` (one committed step_00000000 entry).

    Returns `path`. The LCP array is included only if it was already
    computed — saving never forces the Kasai pass.
    """
    opts = index.options
    extras = {
        "format": FORMAT_VERSION,
        "kind": _KIND,
        "n": index.n,
        "n_docs": index.n_docs,
        "shift": index.shift,
        "sigma": index.sigma,
        "has_lcp": index._lcp is not None,
        "options_fingerprint": opts.fingerprint(),
        # the plan fields themselves, so load_index can reconstruct the
        # SAOptions and a restored index re-saves with the SAME
        # fingerprint (callable schedules don't round-trip: None here)
        "plan": {
            "backend": opts.backend,
            "v0": opts.v0,
            "schedule": (opts.schedule if isinstance(opts.schedule, str)
                         else None),
            "base_threshold": opts.base_threshold,
            "sort_impl": opts.sort_impl,
            "pack_keys": opts.pack_keys,
        },
        "corpus_sha256": corpus_fingerprint(index.text),
        "created_unix": time.time(),
    }
    save_checkpoint(path, 0, _index_tree(index), extras=extras)
    return path


def _read_manifest(path: str, step: int) -> dict:
    mpath = os.path.join(path, f"step_{step:08d}", "manifest.json")
    try:
        with open(mpath) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise StaleIndexError(f"unreadable index manifest at {mpath}: {e}")


def load_index(path: str, *, options: SAOptions | None = None,
               expect_corpus_sha: str | None = None) -> SuffixArrayIndex:
    """Restore a `SuffixArrayIndex` persisted by `save_index`.

    Raises `FileNotFoundError` when no committed entry exists, and
    `StaleIndexError` when one exists but fails a staleness check:
    unknown format version, `options.fingerprint()` mismatch (pass
    ``options`` to enforce the plan), or `expect_corpus_sha` mismatch
    (pass the current corpus hash to enforce content identity). Leaf
    shapes/dtypes are validated by `repro.ckpt.checkpoint
    .restore_checkpoint` against the manifest, so a truncated or
    hand-edited `arrays.npz` raises instead of restoring garbage.
    """
    step = latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no committed index entry under {path!r}")
    manifest = _read_manifest(path, step)
    extras = manifest.get("extras", {})
    if extras.get("kind") != _KIND:
        raise StaleIndexError(
            f"{path!r} is not a suffix-array index artifact "
            f"(kind={extras.get('kind')!r})")
    if extras.get("format") != FORMAT_VERSION:
        raise StaleIndexError(
            f"index at {path!r} has format {extras.get('format')!r}, "
            f"this code reads {FORMAT_VERSION} — rebuild it")
    if options is not None:
        want, got = options.fingerprint(), extras.get("options_fingerprint")
        if want != got:
            raise StaleIndexError(
                f"index at {path!r} was built with plan {got!r}, "
                f"requested {want!r}")
    if expect_corpus_sha is not None and \
            extras.get("corpus_sha256") != expect_corpus_sha:
        raise StaleIndexError(
            f"index at {path!r} was built from a different corpus "
            f"(stored sha {extras.get('corpus_sha256')!r:.24} != expected "
            f"{expect_corpus_sha!r:.24})")

    # like_tree reconstructed from the manifest itself; flatten order of a
    # dict is sorted keys, matching the order shapes/dtypes were recorded.
    keys = ["doc_starts", "sa", "text"] + (["lcp"] if extras.get("has_lcp")
                                           else [])
    keys = sorted(keys)
    shapes, dtypes = manifest.get("shapes", []), manifest.get("dtypes", [])
    if len(shapes) != len(keys) or len(dtypes) != len(keys):
        raise StaleIndexError(
            f"index manifest at {path!r} records {len(shapes)} leaves, "
            f"expected {len(keys)} ({keys})")
    like = {k: np.zeros(tuple(s), np.dtype(d))
            for k, s, d in zip(keys, shapes, dtypes)}
    tree, extras = restore_checkpoint(path, step, like)
    # re-attach the construction plan so the restored index re-saves with
    # the same fingerprint: the caller's options when given (fingerprint
    # already verified above), else the persisted plan fields
    if options is not None:
        opts = options
    else:
        plan = dict(extras.get("plan") or {})
        if plan.get("schedule") is None:
            # a callable schedule doesn't round-trip: keep every other
            # plan field (backend/v0/sort_impl/... provenance) and let the
            # schedule fall back to the default — the SA itself is
            # schedule-invariant, only the fingerprint's schedule
            # component is lost
            plan.pop("schedule", None)
        opts = SAOptions(**plan) if plan else None
    return SuffixArrayIndex(
        tree["text"], tree["sa"], doc_starts=tree["doc_starts"],
        shift=int(extras["shift"]), sigma=int(extras["sigma"]),
        options=opts, lcp=tree.get("lcp"))


class IndexStore:
    """Named persistent indexes under one root directory, with traffic
    stats — the serving-side analogue of the compiled-builder cache.

    >>> store = IndexStore(root)                          # doctest: +SKIP
    >>> index, status = store.get_or_build(
    ...     "corpus", lambda: SuffixArrayIndex.from_docs(docs, opts),
    ...     options=opts)                                 # doctest: +SKIP

    `status` is ``"hit"`` (restored — the build was skipped entirely),
    ``"miss"`` (no entry yet) or ``"stale"`` (entry failed a staleness
    check); both non-hits build via `build_fn` and persist the result.
    """

    def __init__(self, root: str):
        self.root = str(root)
        self._stats = {"hits": 0, "misses": 0, "stale": 0}

    def path(self, name: str) -> str:
        if not name or os.sep in name or name.startswith("."):
            raise ValueError(f"invalid index entry name {name!r}")
        return os.path.join(self.root, name)

    def entries(self) -> list[str]:
        """Names with a committed entry, sorted."""
        if not os.path.isdir(self.root):
            return []
        return sorted(d for d in os.listdir(self.root)
                      if latest_step(os.path.join(self.root, d)) is not None)

    def save(self, name: str, index: SuffixArrayIndex) -> str:
        return save_index(self.path(name), index)

    def load(self, name: str, *, options: SAOptions | None = None,
             expect_corpus_sha: str | None = None) -> SuffixArrayIndex:
        return load_index(self.path(name), options=options,
                          expect_corpus_sha=expect_corpus_sha)

    def manifest_age(self, name: str) -> float | None:
        """Seconds since the entry's manifest was written, or None."""
        step = latest_step(self.path(name))
        if step is None:
            return None
        mpath = os.path.join(self.path(name), f"step_{step:08d}",
                             "manifest.json")
        try:
            return max(time.time() - os.path.getmtime(mpath), 0.0)
        except OSError:
            return None

    def get_or_build(self, name: str,
                     build_fn: Callable[[], SuffixArrayIndex], *,
                     options: SAOptions | None = None,
                     corpus_sha: str | None = None,
                     ) -> tuple[SuffixArrayIndex, str]:
        """Restore `name` if fresh, else build, persist, and return.

        Returns ``(index, status)`` with status in {"hit", "miss",
        "stale"}. On a hit the builder never runs —
        `repro.api.build.builder_cache_stats` stays at zero builds, which
        is exactly what the warm-restart test asserts.
        """
        try:
            index = self.load(name, options=options,
                              expect_corpus_sha=corpus_sha)
            self._stats["hits"] += 1
            return index, "hit"
        except FileNotFoundError:
            status = "miss"
            self._stats["misses"] += 1
        except StaleIndexError:
            status = "stale"
            self._stats["stale"] += 1
        index = build_fn()
        self.save(name, index)
        return index, status

    def stats(self) -> dict:
        """Traffic snapshot: entries on disk + hits/misses/stale so far."""
        return {"entries": len(self.entries()), **self._stats}

    def __repr__(self) -> str:
        return f"IndexStore(root={self.root!r}, stats={self.stats()})"
