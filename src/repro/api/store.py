"""`IndexStore` — persist built suffix-array indexes; restore, don't rebuild.

Construction cost is the whole point of the paper; paying it once and
amortising it across query workloads is the whole point of an index
service. This module turns a built `SuffixArrayIndex` into a durable,
versioned artifact on disk so a serving process restarts into a warm
index instead of re-running the builder.

Format — one directory per named entry, written through the committed
checkpoint machinery in `repro.ckpt.checkpoint` (atomic rename + a
`COMMITTED` marker, so a crashed writer never leaves a half-visible
index)::

    <root>/<name>/step_00000000/
        arrays.npz       — text, sa, doc_starts (+ lcp when it was cached)
        manifest.json    — leaf shapes/dtypes + the index manifest extras
        COMMITTED

The manifest extras carry everything needed to trust a restore:

* ``format`` — `FORMAT_VERSION`; bumped on layout changes, old entries
  load as stale rather than as garbage;
* ``options_fingerprint`` — `SAOptions.fingerprint()` of the plan that
  built the index (construction fields only; see that docstring);
* ``corpus_sha256`` — content hash of the encoded text, so a store entry
  built from yesterday's corpus never silently serves today's queries;
* ``shift`` / ``sigma`` / ``n`` / ``n_docs`` / ``has_lcp`` — the index
  structure, restored without recomputation (the lazy LCP stays lazy if
  it was never computed before saving).

Staleness is an *error type*, not a boolean: `load_index` raises
`StaleIndexError` describing exactly which check failed, and
`IndexStore.get_or_build` catches it (and `FileNotFoundError`) to fall
back to a fresh build + save, reporting ``"hit" | "miss" | "stale"`` the
way `repro.api.build.builder_cache_stats` reports builder-cache traffic.

`SegmentedIndexStore` lifts the same contract to multi-segment corpora
(`repro.api.SegmentedIndex`): one versioned checkpoint per segment plus
an atomically-replaced corpus-level manifest, with **incremental** sync —
an ingest persists exactly the one new segment.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
import time
from typing import Callable

import numpy as np

from ..ckpt.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from .index import SuffixArrayIndex
from .options import SAOptions
from .segments import Segment, SegmentedIndex

#: bump when the on-disk layout or manifest fields change incompatibly.
FORMAT_VERSION = 1

#: corpus-level manifest version for segmented entries (independent of the
#: per-segment checkpoint format above).
SEG_FORMAT_VERSION = 1

_KIND = "suffix-array-index"
_SPARSE_KIND = "sparse-suffix-array-index"
_SEG_KIND = "segmented-suffix-array-index"


class StaleIndexError(RuntimeError):
    """A persisted index exists but no longer matches what was asked for
    (format version, construction plan, or corpus content)."""


def corpus_fingerprint(text) -> str:
    """Content hash of an encoded text buffer (dtype-normalised sha256).

    This is the store's corpus identity: computing it costs one linear
    pass, vastly cheaper than the build it may save. `encode_docs` output
    and `SuffixArrayIndex.text` hash identically for the same corpus.
    """
    arr = np.ascontiguousarray(np.asarray(text, np.int64))
    h = hashlib.sha256()
    h.update(str(arr.shape).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


def _index_tree(index: SuffixArrayIndex) -> dict:
    tree = {"text": index.text, "sa": index.sa,
            "doc_starts": index.doc_starts}
    if index._lcp is not None:
        tree["lcp"] = index._lcp
    return tree


def save_index(path: str, index: SuffixArrayIndex, *, step: int = 0) -> str:
    """Persist `index` under `path` (one committed step_<step> entry).

    Returns `path`. The LCP array is included only if it was already
    computed — saving never forces the Kasai pass. `step` versions the
    checkpoint: `load_index` restores the latest committed step, and
    `SegmentedIndexStore` bumps it on every re-save so a rolled-back
    segment is detectable against the corpus manifest.
    """
    opts = index.options
    rate = int(getattr(index, "sample_rate", 1))
    extras = {
        "format": FORMAT_VERSION,
        # a sparse index persists under its own kind: its `sa` leaf covers
        # only every rate-th position, so a dense reader must refuse it
        # (and vice versa) even before the fingerprint check
        "kind": _SPARSE_KIND if rate > 1 else _KIND,
        "n": index.n,
        "n_docs": index.n_docs,
        "shift": index.shift,
        "sigma": index.sigma,
        "sample_rate": rate,
        "has_lcp": index._lcp is not None,
        "options_fingerprint": opts.fingerprint(),
        # the plan fields themselves, so load_index can reconstruct the
        # SAOptions and a restored index re-saves with the SAME
        # fingerprint (callable schedules don't round-trip: None here)
        "plan": {
            "backend": opts.backend,
            "v0": opts.v0,
            "schedule": (opts.schedule if isinstance(opts.schedule, str)
                         else None),
            "base_threshold": opts.base_threshold,
            "sort_impl": opts.sort_impl,
            "pack_keys": opts.pack_keys,
            "sample_rate": opts.sample_rate,
        },
        "corpus_sha256": corpus_fingerprint(index.text),
        "created_unix": time.time(),
    }
    save_checkpoint(path, int(step), _index_tree(index), extras=extras)
    return path


def _read_manifest(path: str, step: int) -> dict:
    mpath = os.path.join(path, f"step_{step:08d}", "manifest.json")
    try:
        with open(mpath) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise StaleIndexError(f"unreadable index manifest at {mpath}: {e}")


def load_index(path: str, *, options: SAOptions | None = None,
               expect_corpus_sha: str | None = None,
               expect_step: int | None = None) -> SuffixArrayIndex:
    """Restore a `SuffixArrayIndex` persisted by `save_index`.

    Raises `FileNotFoundError` when no committed entry exists, and
    `StaleIndexError` when one exists but fails a staleness check:
    unknown format version, `options.fingerprint()` mismatch (pass
    ``options`` to enforce the plan), `expect_corpus_sha` mismatch
    (pass the current corpus hash to enforce content identity), or a
    latest committed step other than `expect_step` (how the segmented
    store detects a rolled-back or partially-synced segment). Leaf
    shapes/dtypes are validated by `repro.ckpt.checkpoint
    .restore_checkpoint` against the manifest, so a truncated or
    hand-edited `arrays.npz` raises instead of restoring garbage.
    """
    step = latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no committed index entry under {path!r}")
    if expect_step is not None and step != expect_step:
        raise StaleIndexError(
            f"index at {path!r} is at step {step}, expected {expect_step} "
            f"— rolled back or partially synced")
    manifest = _read_manifest(path, step)
    extras = manifest.get("extras", {})
    if extras.get("kind") not in (_KIND, _SPARSE_KIND):
        raise StaleIndexError(
            f"{path!r} is not a suffix-array index artifact "
            f"(kind={extras.get('kind')!r})")
    rate = int(extras.get("sample_rate", 1))
    if (extras.get("kind") == _SPARSE_KIND) != (rate > 1):
        raise StaleIndexError(
            f"index at {path!r} records kind={extras.get('kind')!r} but "
            f"sample_rate={rate} — manifest tampered or half-written")
    if extras.get("format") != FORMAT_VERSION:
        raise StaleIndexError(
            f"index at {path!r} has format {extras.get('format')!r}, "
            f"this code reads {FORMAT_VERSION} — rebuild it")
    if options is not None:
        want, got = options.fingerprint(), extras.get("options_fingerprint")
        if want != got:
            raise StaleIndexError(
                f"index at {path!r} was built with plan {got!r}, "
                f"requested {want!r}")
    if expect_corpus_sha is not None and \
            extras.get("corpus_sha256") != expect_corpus_sha:
        raise StaleIndexError(
            f"index at {path!r} was built from a different corpus "
            f"(stored sha {extras.get('corpus_sha256')!r:.24} != expected "
            f"{expect_corpus_sha!r:.24})")

    # like_tree reconstructed from the manifest itself; flatten order of a
    # dict is sorted keys, matching the order shapes/dtypes were recorded.
    keys = ["doc_starts", "sa", "text"] + (["lcp"] if extras.get("has_lcp")
                                           else [])
    keys = sorted(keys)
    shapes, dtypes = manifest.get("shapes", []), manifest.get("dtypes", [])
    if len(shapes) != len(keys) or len(dtypes) != len(keys):
        raise StaleIndexError(
            f"index manifest at {path!r} records {len(shapes)} leaves, "
            f"expected {len(keys)} ({keys})")
    like = {k: np.zeros(tuple(s), np.dtype(d))
            for k, s, d in zip(keys, shapes, dtypes)}
    tree, extras = restore_checkpoint(path, step, like)
    # re-attach the construction plan so the restored index re-saves with
    # the same fingerprint: the caller's options when given (fingerprint
    # already verified above), else the persisted plan fields
    if options is not None:
        opts = options
    else:
        plan = dict(extras.get("plan") or {})
        if plan.get("schedule") is None:
            # a callable schedule doesn't round-trip: keep every other
            # plan field (backend/v0/sort_impl/... provenance) and let the
            # schedule fall back to the default — the SA itself is
            # schedule-invariant, only the fingerprint's schedule
            # component is lost
            plan.pop("schedule", None)
        opts = SAOptions(**plan) if plan else None
    if rate > 1:
        from ..sparse import SparseSuffixArrayIndex
        return SparseSuffixArrayIndex(
            tree["text"], tree["sa"], sample_rate=rate,
            doc_starts=tree["doc_starts"], shift=int(extras["shift"]),
            sigma=int(extras["sigma"]), options=opts, lcp=tree.get("lcp"))
    return SuffixArrayIndex(
        tree["text"], tree["sa"], doc_starts=tree["doc_starts"],
        shift=int(extras["shift"]), sigma=int(extras["sigma"]),
        options=opts, lcp=tree.get("lcp"))


class IndexStore:
    """Named persistent indexes under one root directory, with traffic
    stats — the serving-side analogue of the compiled-builder cache.

    >>> store = IndexStore(root)                          # doctest: +SKIP
    >>> index, status = store.get_or_build(
    ...     "corpus", lambda: SuffixArrayIndex.from_docs(docs, opts),
    ...     options=opts)                                 # doctest: +SKIP

    `status` is ``"hit"`` (restored — the build was skipped entirely),
    ``"miss"`` (no entry yet) or ``"stale"`` (entry failed a staleness
    check); both non-hits build via `build_fn` and persist the result.
    """

    #: get_or_build status → stats counter key
    _STATUS_KEY = {"hit": "hits", "miss": "misses", "stale": "stale"}

    def __init__(self, root: str):
        self.root = str(root)
        self._stats = {"hits": 0, "misses": 0, "stale": 0}
        self._stats_lock = threading.Lock()

    def _record(self, status: str) -> None:
        """Count one *completed* get_or_build outcome.

        Called only when the (index, status) pair is actually being
        returned, under a lock: a build_fn that raises must not leave a
        phantom miss/stale behind, and concurrent sessions must not lose
        increments — `stats()` is the serving-side "did the restart skip
        the build" metric, so it has to be exact."""
        with self._stats_lock:
            self._stats[self._STATUS_KEY[status]] += 1

    def path(self, name: str) -> str:
        if not name or os.sep in name or name.startswith("."):
            raise ValueError(f"invalid index entry name {name!r}")
        return os.path.join(self.root, name)

    def entries(self) -> list[str]:
        """Names with a committed entry, sorted."""
        if not os.path.isdir(self.root):
            return []
        return sorted(d for d in os.listdir(self.root)
                      if latest_step(os.path.join(self.root, d)) is not None)

    def save(self, name: str, index: SuffixArrayIndex) -> str:
        return save_index(self.path(name), index)

    def load(self, name: str, *, options: SAOptions | None = None,
             expect_corpus_sha: str | None = None) -> SuffixArrayIndex:
        return load_index(self.path(name), options=options,
                          expect_corpus_sha=expect_corpus_sha)

    def manifest_age(self, name: str) -> float | None:
        """Seconds since the entry's manifest was written, or None."""
        step = latest_step(self.path(name))
        if step is None:
            return None
        mpath = os.path.join(self.path(name), f"step_{step:08d}",
                             "manifest.json")
        try:
            return max(time.time() - os.path.getmtime(mpath), 0.0)
        except OSError:
            return None

    def get_or_build(self, name: str,
                     build_fn: Callable[[], SuffixArrayIndex], *,
                     options: SAOptions | None = None,
                     corpus_sha: str | None = None,
                     ) -> tuple[SuffixArrayIndex, str]:
        """Restore `name` if fresh, else build, persist, and return.

        Returns ``(index, status)`` with status in {"hit", "miss",
        "stale"}. On a hit the builder never runs —
        `repro.api.build.builder_cache_stats` stays at zero builds, which
        is exactly what the warm-restart test asserts.

        Stats are updated atomically with the returned index (under a
        lock, only once the non-hit path has actually built AND
        persisted): a `build_fn` that raises on the stale-then-rebuild
        path propagates the exception and leaves `stats()` untouched,
        instead of recording a rebuild that never happened
        (`tests/api/test_store.py::test_get_or_build_stats_are_atomic`).
        """
        try:
            index = self.load(name, options=options,
                              expect_corpus_sha=corpus_sha)
            status = "hit"
        except FileNotFoundError:
            index, status = None, "miss"
        except StaleIndexError:
            index, status = None, "stale"
        if index is None:
            index = build_fn()
            self.save(name, index)
        self._record(status)
        return index, status

    def stats(self) -> dict:
        """Traffic snapshot: entries on disk + hits/misses/stale so far."""
        with self._stats_lock:
            counts = dict(self._stats)
        return {"entries": len(self.entries()), **counts}

    def __repr__(self) -> str:
        return f"IndexStore(root={self.root!r}, stats={self.stats()})"


# ---------------------------------------------------------------------------
# segmented persistence
# ---------------------------------------------------------------------------
_SEG_ID_RE = re.compile(r"^seg-\d{6,}$")


class SegmentedIndexStore:
    """Persist a `repro.api.SegmentedIndex`: one versioned checkpoint per
    segment plus a corpus-level manifest — ingest persists one small
    segment, never the corpus.

    Layout (one directory per named entry)::

        <root>/<name>/
            corpus.json              — corpus-level manifest (atomic write)
            segments/<seg_id>/       — one `save_index` checkpoint each
                step_<version>/{arrays.npz, manifest.json, COMMITTED}

    ``corpus.json`` pins the corpus: the segment list with each segment's
    global doc ids, checkpoint step, encoded length, and corpus sha. A
    segment whose latest committed step, content hash, or length disagrees
    with the manifest loads as `StaleIndexError` (rolled back, tampered,
    or half-synced), never as silently wrong query results.

    `save` is **incremental**: only segments marked dirty on the
    `SegmentedIndex` (new since the last sync) are written, and segments
    dropped by delete/compaction are garbage-collected — the returned
    traffic dict is what `tests/api/test_segments.py` uses to prove a
    single-doc ingest persists exactly one segment.
    """

    _STATUS_KEY = IndexStore._STATUS_KEY

    def __init__(self, root: str):
        self.root = str(root)
        self._stats = {"hits": 0, "misses": 0, "stale": 0,
                       "segments_written": 0, "segments_deleted": 0,
                       "segments_loaded": 0}
        self._stats_lock = threading.Lock()

    def path(self, name: str) -> str:
        if not name or os.sep in name or name.startswith("."):
            raise ValueError(f"invalid index entry name {name!r}")
        return os.path.join(self.root, name)

    def _manifest_path(self, name: str) -> str:
        return os.path.join(self.path(name), "corpus.json")

    def _segment_path(self, name: str, seg_id: str) -> str:
        if not _SEG_ID_RE.match(seg_id):
            raise StaleIndexError(f"invalid segment id {seg_id!r} in "
                                  f"entry {name!r}")
        return os.path.join(self.path(name), "segments", seg_id)

    def entries(self) -> list[str]:
        """Names with a corpus manifest, sorted."""
        if not os.path.isdir(self.root):
            return []
        return sorted(d for d in os.listdir(self.root)
                      if os.path.exists(self._manifest_path(d)))

    # ------------------------------------------------------------- persist
    def save(self, name: str, sidx: SegmentedIndex) -> dict:
        """Sync `sidx` to disk incrementally; returns the traffic dict
        ``{"segments_written": w, "segments_deleted": d}``.

        Dirty segments are checkpointed (at the next step when the
        directory already exists — a versioned re-save, not an
        overwrite), dropped segments' directories are removed, and the
        corpus manifest is atomically replaced LAST, so a crash mid-sync
        leaves the previous manifest pointing at fully-committed
        segments."""
        written = deleted = 0
        for seg in sidx.segments:
            spath = self._segment_path(name, seg.seg_id)
            if seg.seg_id in sidx.dirty or latest_step(spath) is None:
                prev = latest_step(spath)
                seg.version = 0 if prev is None else prev + 1
                save_index(spath, seg.index, step=seg.version)
                written += 1
        for seg_id in sorted(sidx.dropped):
            spath = self._segment_path(name, seg_id)
            if os.path.isdir(spath):
                shutil.rmtree(spath)
                deleted += 1
        manifest = {
            "format": SEG_FORMAT_VERSION,
            "kind": _SEG_KIND,
            "options_fingerprint": sidx.options.fingerprint(),
            "sigma": sidx._sigma,
            "next_doc_id": sidx._next_doc_id,
            "next_seg": sidx._next_seg,
            "segments": [{
                "seg_id": seg.seg_id,
                "doc_ids": np.asarray(seg.doc_ids, np.int64).tolist(),
                "step": seg.version,
                "n": seg.n,
                "corpus_sha256": corpus_fingerprint(seg.index.text),
            } for seg in sidx.segments],
            "created_unix": time.time(),
        }
        os.makedirs(self.path(name), exist_ok=True)
        tmp = self._manifest_path(name) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, self._manifest_path(name))
        sidx.dirty.clear()
        sidx.dropped.clear()
        with self._stats_lock:
            self._stats["segments_written"] += written
            self._stats["segments_deleted"] += deleted
        return {"segments_written": written, "segments_deleted": deleted}

    # ------------------------------------------------------------- restore
    def load(self, name: str, *,
             options: SAOptions | None = None) -> SegmentedIndex:
        """Restore a segmented entry; zero builder traffic.

        Raises `FileNotFoundError` with no manifest, `StaleIndexError`
        when the manifest is unreadable/wrong-kind/wrong-format, when
        ``options.fingerprint()`` disagrees, or when any referenced
        segment is missing, rolled back to a different step, or fails its
        own content checks."""
        mpath = self._manifest_path(name)
        if not os.path.exists(mpath):
            raise FileNotFoundError(
                f"no segmented index entry under {self.path(name)!r}")
        try:
            with open(mpath) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise StaleIndexError(f"unreadable corpus manifest {mpath}: {e}")
        if manifest.get("kind") != _SEG_KIND:
            raise StaleIndexError(
                f"{mpath} is not a segmented index manifest "
                f"(kind={manifest.get('kind')!r})")
        if manifest.get("format") != SEG_FORMAT_VERSION:
            raise StaleIndexError(
                f"segmented entry {name!r} has format "
                f"{manifest.get('format')!r}, this code reads "
                f"{SEG_FORMAT_VERSION} — rebuild it")
        if options is not None:
            want, got = options.fingerprint(), \
                manifest.get("options_fingerprint")
            if want != got:
                raise StaleIndexError(
                    f"segmented entry {name!r} was built with plan {got!r}, "
                    f"requested {want!r}")
        segments = []
        for ent in manifest.get("segments", []):
            spath = self._segment_path(name, str(ent.get("seg_id", "")))
            try:
                index = load_index(
                    spath, options=options,
                    expect_corpus_sha=ent.get("corpus_sha256"),
                    expect_step=int(ent.get("step", 0)))
            except FileNotFoundError as e:
                raise StaleIndexError(
                    f"segmented entry {name!r} references missing segment "
                    f"{ent.get('seg_id')!r}: {e}")
            if index.n != int(ent.get("n", -1)):
                raise StaleIndexError(
                    f"segment {ent.get('seg_id')!r} of entry {name!r} holds "
                    f"{index.n} chars, manifest records {ent.get('n')}")
            segments.append(Segment(
                seg_id=str(ent["seg_id"]),
                doc_ids=np.asarray(ent.get("doc_ids", []), np.int64),
                index=index, version=int(ent.get("step", 0))))
        opts = options
        if opts is None:
            opts = (segments[0].index.options if segments
                    else SAOptions())
        sidx = SegmentedIndex(
            segments, options=opts,
            sigma=manifest.get("sigma"),
            next_doc_id=int(manifest.get("next_doc_id", 0)),
            next_seg=int(manifest.get("next_seg", len(segments))))
        sidx.dirty.clear()          # just loaded: everything is in sync
        with self._stats_lock:
            self._stats["segments_loaded"] += len(segments)
        return sidx

    def get_or_build(self, name: str,
                     build_fn: Callable[[], SegmentedIndex], *,
                     options: SAOptions | None = None,
                     ) -> tuple[SegmentedIndex, str]:
        """Restore `name` if fresh, else build + persist. Returns
        ``(segmented_index, status)``, status in {"hit", "miss",
        "stale"}; stats update atomically with the successful return,
        same contract as `IndexStore.get_or_build`."""
        try:
            sidx = self.load(name, options=options)
            status = "hit"
        except FileNotFoundError:
            sidx, status = None, "miss"
        except StaleIndexError:
            sidx, status = None, "stale"
        if sidx is None:
            sidx = build_fn()
            self.save(name, sidx)
        with self._stats_lock:
            self._stats[self._STATUS_KEY[status]] += 1
        return sidx, status

    def stats(self) -> dict:
        """Traffic snapshot: entries on disk + hit/miss/stale counts +
        per-segment write/delete/load traffic since construction."""
        with self._stats_lock:
            counts = dict(self._stats)
        return {"entries": len(self.entries()), **counts}

    def __repr__(self) -> str:
        return f"SegmentedIndexStore(root={self.root!r}, stats={self.stats()})"
