"""`SuffixArrayIndex` — text + suffix array + lazy LCP, with queries.

One object subsumes the previous loose functions (`corpus_sa.CorpusSA`,
`count_occurrences`, `cross_doc_duplicates`, `lcp.ngram_counts`,
`repeated_substring_spans`) behind a single facade:

* `SuffixArrayIndex.build(text, options)` — one document;
* `SuffixArrayIndex.from_docs(docs, options)` — multi-document corpus with
  the sentinel-separator layout (doc i is terminated by a unique separator
  of value i placed BELOW the shifted data alphabet, so no suffix comparison
  ever crosses a document boundary);
* `count_batch` / `locate_batch` / `contains_batch` — the query engine:
  many patterns padded into one device buffer, all SA ranges resolved by
  a single jitted vectorised binary search (`repro.api.query`);
* `count` / `locate` — scalar conveniences, thin shims over a batch of
  one (the legacy numpy bisection loop survives as `_sa_range`, the
  reference/regression path);
* `ngram_stats(k)` — total and distinct k-grams fully inside documents;
* `duplicate_spans(min_len)` — merged repeated-substring spans (the Lee et
  al. 2022 dedup criterion);
* `cross_doc_duplicates(min_len)` — vectorised contamination check;
* `save` / `load` — persistence through `repro.api.store` (an
  `IndexStore` adds naming, staleness checks, and get-or-build on top).

Pattern semantics are explicit: values must lie in ``[0, sigma)`` (the
index's data alphabet — inferred from the text or declared via
``sigma=``); out-of-alphabet values raise `ValueError` instead of
silently never matching. The empty pattern is a prefix of every suffix,
so ``count([]) == n``; `locate([])` raises `ValueError` (n positions is
a result you enumerate with `numpy.arange`, not a locate call).

The LCP array is computed lazily on first use and cached.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..text.lcp import lcp_kasai, repeated_substring_spans
from .build import build_suffix_array
from .options import SAOptions
from .query import QueryBatch, batch_ranges, stage_batch


def longest_match_len(index, seq) -> int:
    """Length of the longest substring of ``seq`` that occurs in ``index``.

    Works against anything with ``contains_batch`` (monolithic
    `SuffixArrayIndex` or `repro.api.SegmentedIndex`). Feasibility is
    monotone in the length (a substring's prefixes occur wherever it
    does), so a binary search over lengths resolves the answer with
    O(log |seq|) batched containment queries — each one jitted call
    testing *every* window of the probed length at once. This is the
    overlap primitive behind the memorization probe and contamination
    reporting in `repro.data.pipeline`.

    Out-of-alphabet values in ``seq`` can never match, so they are masked
    out up front (windows containing them are skipped, not errors) —
    generated samples may legally contain tokens the corpus never used.

    Against an index with a minimum answerable pattern length (a sparse
    index's ``min_pattern_len == sample_rate``), the search floors at
    that length: matches shorter than the floor report 0 (the index
    cannot certify them), matches ≥ the floor are exact and identical to
    the dense answer — monotonicity makes the floored binary search
    sound.
    """
    seq = np.asarray(seq, np.int64).ravel()
    if len(seq) == 0 or index.n == 0:
        return 0
    ok = (seq >= 0) & (seq < max(index.sigma, 1))

    def feasible(m: int) -> bool:
        wins = np.lib.stride_tricks.sliding_window_view(seq, m)
        valid = np.flatnonzero(
            np.lib.stride_tricks.sliding_window_view(ok, m).all(axis=1))
        if not len(valid):
            return False
        return bool(np.any(index.contains_batch(list(wins[valid]))))

    floor = int(getattr(index, "min_pattern_len", 0))
    lo, hi = 0, len(seq)            # longest feasible is in [lo, hi]
    if floor > 1:
        if len(seq) < floor or not feasible(floor):
            return 0                # any true match is below the floor
        lo = floor
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if feasible(mid):
            lo = mid
        else:
            hi = mid - 1
    return lo


def encode_docs(docs) -> tuple[np.ndarray, np.ndarray, int]:
    """Sentinel-separator corpus layout: data values are shifted up by
    n_docs and doc i is terminated by separator value i. Separators are
    (a) unique, so no suffix comparison crosses a document boundary, and
    (b) below the data alphabet, so separator suffixes cluster at the front
    of the SA where they are cheap to skip.

    Returns (text int64[N], doc_starts int64[n_docs], n_docs).
    """
    n_docs = len(docs)
    if n_docs == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64), 0
    parts, starts, off = [], [], 0
    for i, d in enumerate(docs):
        d = np.asarray(d, np.int64)
        if d.ndim != 1:
            raise ValueError(f"doc {i} must be 1-D, got shape {d.shape}")
        if len(d) and int(d.min()) < 0:
            raise ValueError(f"doc {i} has negative values")
        starts.append(off)
        parts.append(d + n_docs)
        parts.append(np.asarray([i], np.int64))
        off += len(d) + 1
    return (np.concatenate(parts), np.asarray(starts, np.int64), n_docs)


@dataclass(frozen=True)
class NgramStats:
    """k-gram statistics over the indexed corpus (separator-free windows)."""

    k: int
    total: int        # number of k-gram positions fully inside one document
    distinct: int     # number of distinct k-gram strings among those


class SuffixArrayIndex:
    """Queryable suffix-array index over one document or a corpus.

    Positions returned by `locate` / `duplicate_spans` are offsets into the
    *encoded* text (`self.text`); for a single-document index these equal
    raw text offsets. Use `doc_of` / `doc_offset` to map a position into
    (document, in-document offset) for multi-document indexes.
    """

    def __init__(self, text, sa, *, doc_starts=None, shift: int = 0,
                 options: SAOptions | None = None, lcp=None,
                 sigma: int | None = None):
        self.text = np.asarray(text, np.int64)
        self.sa = np.asarray(sa, np.int32)
        self._check_shapes()
        n = len(self.text)
        self.doc_starts = (np.asarray(doc_starts, np.int64)
                           if doc_starts is not None
                           else np.zeros(1 if n else 0, np.int64))
        self.shift = int(shift)
        self.options = options if options is not None else SAOptions()
        self._lcp = None if lcp is None else np.asarray(lcp, np.int64)
        self._sigma = None if sigma is None else int(sigma)
        self._device = None        # lazy (text, sa) device buffers

    def _check_shapes(self) -> None:
        """Text-vs-SA shape contract; `repro.sparse` relaxes it to n/s."""
        if self.sa.shape != self.text.shape:
            raise ValueError(f"sa shape {self.sa.shape} != text shape "
                             f"{self.text.shape}")

    #: shortest pattern this index answers exactly; 0 = no restriction.
    #: `repro.sparse.SparseSuffixArrayIndex` overrides with its rate, and
    #: `longest_match_len` / serving warmups floor their probes at it.
    min_pattern_len = 0

    # ----------------------------------------------------------- construct
    @classmethod
    def build(cls, text, options: SAOptions | None = None, *,
              sigma: int | None = None, **overrides) -> "SuffixArrayIndex":
        """Index a single document (no separators, positions = raw offsets).

        Construction goes through `build_suffix_array`, so it benefits from
        the compiled-builder cache: indexing many similar-length documents
        under one plan reuses all jitted computations (see docs/api.md).
        Pass ``sigma=`` to declare the alphabet size explicitly (pattern
        validation otherwise infers it from the text's maximum value)."""
        opts = options if options is not None else SAOptions()
        if overrides:
            opts = opts.replace(**overrides)
        if opts.sample_rate > 1 and cls is SuffixArrayIndex:
            # facade dispatch: a sampled plan builds the sparse subclass
            from ..sparse import SparseSuffixArrayIndex
            return SparseSuffixArrayIndex.build(text, opts, sigma=sigma)
        text = np.asarray(text, np.int64)
        sa = build_suffix_array(text, opts)
        return cls(text, sa, shift=0, options=opts, sigma=sigma)

    @classmethod
    def from_docs(cls, docs, options: SAOptions | None = None, *,
                  sigma: int | None = None, **overrides) -> "SuffixArrayIndex":
        """Index a list of documents with the sentinel-separator layout."""
        opts = options if options is not None else SAOptions()
        if overrides:
            opts = opts.replace(**overrides)
        if opts.sample_rate > 1 and cls is SuffixArrayIndex:
            from ..sparse import SparseSuffixArrayIndex
            return SparseSuffixArrayIndex.from_docs(docs, opts, sigma=sigma)
        text, starts, n_docs = encode_docs(docs)
        sa = build_suffix_array(text, opts)
        return cls(text, sa, doc_starts=starts, shift=n_docs, options=opts,
                   sigma=sigma)

    # --------------------------------------------------------- persistence
    def save(self, path: str) -> str:
        """Persist this index at `path` (`repro.api.store.save_index`)."""
        from .store import save_index
        return save_index(path, self)

    @classmethod
    def load(cls, path: str, *, options: SAOptions | None = None
             ) -> "SuffixArrayIndex":
        """Restore an index saved by `save` — no rebuild, no LCP recompute.

        Pass ``options`` to reject an artifact whose construction plan
        fingerprint differs (`repro.api.store.StaleIndexError`)."""
        from .store import load_index
        return load_index(path, options=options)

    # ----------------------------------------------------------- structure
    @property
    def n(self) -> int:
        return len(self.text)

    @property
    def n_docs(self) -> int:
        return len(self.doc_starts)

    @property
    def sep_count(self) -> int:
        return self.shift          # one separator per document when encoded

    @property
    def sigma(self) -> int:
        """Data-alphabet size: patterns must use values in [0, sigma).

        Inferred as ``max data value + 1`` unless declared at construction
        (``sigma=``); 0 for an index with no data characters."""
        if self._sigma is None:
            data_max = int(self.text.max()) - self.shift if self.n else -1
            self._sigma = max(data_max + 1, 0)
        return self._sigma

    @property
    def lcp(self) -> np.ndarray:
        """LCP array (Kasai), computed on first access and cached."""
        if self._lcp is None:
            self._lcp = lcp_kasai(self.text, self.sa)
        return self._lcp

    @property
    def _doc_ends(self) -> np.ndarray:
        """End (exclusive, separator position) of each document's payload."""
        if self.shift == 0:
            return np.full(self.n_docs, self.n, np.int64)
        return np.flatnonzero(self.text < self.shift).astype(np.int64)

    def doc_of(self, pos):
        """Document index owning encoded position(s) `pos` (scalar or array).

        Positions must lie in [0, n); out-of-range values raise IndexError
        (they used to wrap around silently — on an empty index
        `doc_offset(0)` crashed on `doc_starts[-1]`, on a non-empty one a
        negative position was attributed to the last document). An empty
        position *array* is always valid and maps to an empty result."""
        pos_arr = np.asarray(pos)
        if pos_arr.size and (np.any(pos_arr < 0) or np.any(pos_arr >= self.n)):
            raise IndexError(
                f"position(s) out of range for index of length {self.n}")
        idx = np.searchsorted(self.doc_starts, pos_arr, side="right") - 1
        if np.isscalar(pos) or np.ndim(pos) == 0:
            return int(idx)
        return idx.astype(np.int64)

    def doc_offset(self, pos):
        """(doc, in-document offset) for encoded position(s) `pos`."""
        doc = self.doc_of(pos)
        return doc, np.asarray(pos) - self.doc_starts[doc]

    # ------------------------------------------------------------- queries
    def _encode_pattern(self, pattern) -> np.ndarray:
        """Validate + shift a raw pattern into the encoded alphabet.

        Values must lie in ``[0, sigma)``: negatives always raise, and
        values ≥ sigma raise too (they can never occur in the data, so a
        silent 0-count would hide caller bugs — and before this check an
        out-of-range token could alias a separator after the shift). The
        alphabet check is skipped on an empty index (sigma is vacuously 0
        there; every count is 0 anyway).
        """
        pat = np.asarray(pattern, np.int64).ravel()
        if len(pat):
            if int(pat.min()) < 0:
                raise ValueError("pattern values must be ≥ 0")
            if self.n and int(pat.max()) >= self.sigma:
                raise ValueError(
                    f"pattern value {int(pat.max())} outside the index "
                    f"alphabet [0, {self.sigma}) — out-of-alphabet queries "
                    f"are rejected rather than silently counted as 0")
        return pat + self.shift

    def _device_state(self):
        """Device-resident (text, sa) buffers for the batched query kernel,
        created on first use and cached for the life of the index."""
        if self._device is None:
            import jax.numpy as jnp
            if self.n and int(self.text.max()) >= np.iinfo(np.int32).max:
                raise NotImplementedError(
                    "batched queries need int32-representable symbols "
                    f"(max encoded value {int(self.text.max())})")
            self._device = (jnp.asarray(self.text.astype(np.int32)),
                            jnp.asarray(self.sa))
        return self._device

    def _suffix_cmp(self, starts: np.ndarray, pat: np.ndarray) -> np.ndarray:
        """Vectorised 3-way prefix compare of suffixes at `starts` vs `pat`:
        -1 suffix < pat, 0 pat is a prefix of suffix, +1 suffix > pat.
        One numpy gather + compare per call — no Python character loop."""
        starts = np.asarray(starts, np.int64).ravel()
        m, n = len(pat), self.n
        if m == 0 or n == 0:
            # empty pattern is a prefix of everything; on an empty index
            # every probe is past-the-end, i.e. "suffix < pat". Guarded
            # here so n-1 == -1 can never wrap the gather below.
            return np.full(len(starts), -1 if (n == 0 and m) else 0, np.int8)
        idx = starts[:, None] + np.arange(m, dtype=np.int64)[None, :]
        in_range = idx < n
        seg = np.where(in_range, self.text[np.minimum(idx, n - 1)],
                       np.int64(-1))       # past-the-end < every real char
        diff = seg != pat[None, :]
        any_diff = diff.any(axis=1)
        first = np.where(any_diff, diff.argmax(axis=1), 0)
        rows = np.arange(len(starts))
        out = np.zeros(len(starts), np.int8)
        s_at, p_at = seg[rows, first], pat[first]
        out[any_diff & (s_at < p_at)] = -1
        out[any_diff & (s_at > p_at)] = 1
        return out

    def _sa_range(self, pat: np.ndarray) -> tuple[int, int]:
        """[lo, hi) block of SA ranks whose suffixes start with `pat`.

        The *scalar reference* search: a Python binary-search loop where
        every probe is one vectorised `_suffix_cmp` call → O(|pat| log n)
        numpy work per pattern. Serving traffic goes through the batched
        jitted path instead (`sa_ranges_batch`); this loop is kept as the
        equivalence oracle for `tests/api/test_query.py` and the
        regression row of `benchmarks/query_throughput.py`."""
        n = len(self.sa)
        if len(pat) == 0:
            return 0, n
        lo = np.zeros(2, np.int64)
        hi = np.full(2, n, np.int64)
        while True:
            active = lo < hi
            if not active.any():
                break
            mid = (lo + hi) // 2
            c = self._suffix_cmp(self.sa[np.where(active, mid, 0)], pat)
            # bound 0 = first suffix ≥ pat, bound 1 = first suffix > pat
            before = np.array([c[0] < 0, c[1] <= 0])
            lo = np.where(active & before, mid + 1, lo)
            hi = np.where(active & ~before, mid, hi)
        return int(lo[0]), int(lo[1])

    # ------------------------------------------------------ batched queries
    def _as_batch(self, patterns) -> QueryBatch:
        return (patterns if isinstance(patterns, QueryBatch)
                else QueryBatch.encode(self, patterns))

    def sa_ranges_batch(self, patterns) -> tuple[np.ndarray, np.ndarray]:
        """`[lo, hi)` SA-rank ranges for many patterns in ONE device call.

        `patterns` is a sequence of int sequences (mixed lengths fine) or
        a pre-encoded `QueryBatch` for reuse. Returns two int64 arrays of
        length `len(patterns)`. Empty patterns resolve to (0, n); patterns
        longer than the text to an empty range."""
        return batch_ranges(self, self._as_batch(patterns))

    def count_batch(self, patterns) -> np.ndarray:
        """Occurrence counts for many patterns — int64[len(patterns)],
        resolved by one jitted vectorised binary search. The empty pattern
        is a prefix of every suffix, so it counts n."""
        lo, hi = self.sa_ranges_batch(patterns)
        return hi - lo

    def contains_batch(self, patterns) -> np.ndarray:
        """Presence flags for many patterns — bool[len(patterns)]."""
        return self.count_batch(patterns) > 0

    def locate_batch(self, patterns) -> list:
        """Sorted encoded start positions per pattern — a list of int64
        arrays. Raises `ValueError` on an empty pattern (its result is
        "every position"; enumerate that with `numpy.arange(n)`)."""
        qb = self._as_batch(patterns)
        if self.n and np.any(qb.lens[:qb.n_queries] == 0):
            raise ValueError("locate of an empty pattern is every position "
                             "in the index; use numpy.arange(n) instead")
        lo, hi = batch_ranges(self, qb)
        return [np.sort(self.sa[l:h].astype(np.int64))
                for l, h in zip(lo, hi)]

    def locate_docs_batch(self, patterns) -> list:
        """Occurrences in **document coordinates**: one int64[k, 2] array
        of (doc, in-doc offset) rows per pattern, sorted
        lexicographically. This is the representation shared with
        `repro.api.SegmentedIndex.locate_batch` — the segment-merge
        property tests compare the two byte-for-byte (encoded positions
        are ascending exactly when (doc, offset) rows are lex-sorted,
        since doc_starts is increasing)."""
        out = []
        for pos in self.locate_batch(patterns):
            doc, off = self.doc_offset(pos)
            out.append(np.stack([np.asarray(doc, np.int64).ravel(),
                                 np.asarray(off, np.int64).ravel()], axis=1)
                       if len(pos) else np.zeros((0, 2), np.int64))
        return out

    # --------------------------------------------------- encoded fan-in API
    def _counts_encoded(self, enc) -> np.ndarray:
        """Counts for already-encoded patterns (`_encode_pattern` output).

        The uniform per-segment primitive `repro.api.SegmentedIndex` fans
        out over — encoded once globally, shift-adjusted per segment —
        implemented by every index flavour (the sparse subclass resolves
        it through its two-level plan instead of SA ranges)."""
        lo, hi = batch_ranges(self, QueryBatch.from_encoded(self, enc))
        return hi - lo

    def _positions_encoded(self, enc) -> list:
        """Sorted encoded positions per already-encoded pattern — the
        locate-side companion of `_counts_encoded`."""
        lo, hi = batch_ranges(self, QueryBatch.from_encoded(self, enc))
        return [np.sort(self.sa[l:h].astype(np.int64))
                for l, h in zip(lo, hi)]

    # ------------------------------------------------- serving-tier protocol
    def stage_encoded(self, enc):
        """Package already-encoded patterns (`_encode_pattern` output) for
        the serving tier and begin their host→device transfer. Returns an
        opaque work item for `ranges_staged` — `repro.serve.SAServer`
        double-buffers the pair, and `SegmentedIndex` implements the same
        two methods with a per-segment fan-out inside."""
        batch = QueryBatch.from_encoded(self, enc)
        return (batch, stage_batch(self, batch) if self.n else None)

    def ranges_staged(self, work) -> tuple[np.ndarray, np.ndarray]:
        """Resolve a `stage_encoded` work item to its (lo, hi) SA ranges."""
        batch, staged = work
        return batch_ranges(self, batch, staged=staged)

    # ----------------------------------------------------- scalar shims
    def count(self, pattern) -> int:
        """Occurrences of `pattern` across the corpus.

        Thin shim over a batch of one (`count_batch`); `count([]) == n`
        by the empty-prefix rule."""
        return int(self.count_batch([pattern])[0])

    def locate(self, pattern) -> np.ndarray:
        """Sorted encoded start positions of every occurrence of `pattern`.
        Thin shim over a batch of one (`locate_batch`)."""
        return self.locate_batch([pattern])[0]

    def locate_docs(self, pattern) -> np.ndarray:
        """Occurrences as an int64[k, 2] array of (doc, in-doc offset)."""
        pos = self.locate(pattern)
        doc, off = self.doc_offset(pos)
        return np.stack([np.asarray(doc, np.int64), off], axis=1)

    def longest_match(self, seq) -> int:
        """Longest substring of ``seq`` occurring anywhere in the index
        (`longest_match_len`) — the memorization-probe primitive."""
        return longest_match_len(self, seq)

    # ---------------------------------------------------------- statistics
    def ngram_stats(self, k: int) -> NgramStats:
        """Total / distinct k-grams, counting only windows that lie fully
        inside one document (never spanning a separator)."""
        if k <= 0 or self.n == 0:
            return NgramStats(k=k, total=0, distinct=0)
        pos = self.sa.astype(np.int64)
        if self.shift == 0:
            valid = pos + k <= self.n
        else:
            ends = self._doc_ends
            owner = np.searchsorted(self.doc_starts, pos, side="right") - 1
            valid = pos + k <= ends[owner]
        distinct = int(np.sum(valid & (self.lcp < k)))
        return NgramStats(k=k, total=int(np.sum(valid)), distinct=distinct)

    def duplicate_spans(self, min_len: int) -> list:
        """Merged (start, end) spans covered by a substring of length ≥
        min_len occurring at least twice (Lee et al. dedup criterion).
        Separator uniqueness guarantees spans never cross documents."""
        return repeated_substring_spans(self.text, self.sa, self.lcp, min_len)

    def cross_doc_duplicates(self, min_len: int) -> list:
        """(doc_i, doc_j, length) for SA-adjacent repeats ≥ min_len spanning
        two DIFFERENT documents — fully vectorised (mask over lcp ≥ min_len
        + batched searchsorted doc lookup)."""
        lcp = self.lcp
        r = np.flatnonzero(lcp >= min_len)
        r = r[r >= 1]
        if len(r) == 0:
            return []
        a = self.sa[r - 1].astype(np.int64)
        b = self.sa[r].astype(np.int64)
        da = np.searchsorted(self.doc_starts, a, side="right") - 1
        db = np.searchsorted(self.doc_starts, b, side="right") - 1
        hit = da != db
        lo = np.minimum(da, db)[hit]
        hi = np.maximum(da, db)[hit]
        ln = lcp[r][hit]
        return [(int(i), int(j), int(l)) for i, j, l in zip(lo, hi, ln)]

    def __repr__(self) -> str:
        return (f"SuffixArrayIndex(n={self.n}, n_docs={self.n_docs}, "
                f"backend={self.options.resolve_backend()!r}, "
                f"lcp={'cached' if self._lcp is not None else 'lazy'})")
