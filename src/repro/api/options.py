"""`SAOptions` — the single plan object for suffix-array construction.

Every knob that used to be scattered across `suffix_array_dcv` /
`suffix_array_jax` / `suffix_array_bsp` call sites (initial modulus `v0`,
the v-schedule, recursion base threshold, the BSP mesh/axis, key packing,
the sort-primitive implementation, instrumentation sinks) lives here.
Consumers construct one `SAOptions` and hand it to
`repro.api.build_suffix_array`; backends read only the fields they
understand. The dataclass is frozen, so the builder cache in
`repro.api.build` can key compiled configurations by its fields.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Union

from ..core.seq_ref import accelerated_next_v, fixed_next_v

#: name → schedule fn; `SAOptions.schedule` accepts either the name or a raw
#: ``(v, |D|, m) -> v'`` callable.
SCHEDULES: dict[str, Callable[[int, int, int], int]] = {
    "accelerated": accelerated_next_v,
    "fixed": fixed_next_v,
}

AUTO = "auto"

#: accepted `sort_impl` values; mirrors `repro.core.dcv_jax.SORT_IMPLS`.
#: Kept as a literal here so constructing an SAOptions never imports jax.
SORT_IMPLS = ("auto", "radix", "lax", "bitonic", "pallas")


@dataclass(frozen=True)
class SAOptions:
    """Construction plan for one suffix-array build.

    Fields
    ------
    backend:        registry key (``"oracle" | "seq" | "jax" | "bsp"``) or
                    ``"auto"``: pick ``"bsp"`` when `mesh` is set, else
                    ``"jax"``.
    v0:             initial difference-cover modulus (paper Algorithm 1).
    schedule:       ``"accelerated"`` (v' ~ v^{5/4}, the paper's headline),
                    ``"fixed"`` (constant v baseline), or a callable
                    ``(v, |D|, m) -> v'``.
    base_threshold: recursion cutoff; ``None`` keeps each backend's native
                    default (seq: 32, jax: per sort_impl, bsp:
                    max(1024, n/p)).
    sort_impl:      which sort primitive the hot path uses. For the jax
                    backend, ``"auto"`` resolves per platform via
                    `repro.core.compat.default_sort_impl` ("radix" on CPU
                    hosts, "lax" on TPU/GPU); ``"radix"`` packed-key host
                    sorts; ``"lax"`` XLA's variadic `lax.sort`;
                    ``"bitonic"`` the legacy fused comparator network;
                    ``"pallas"`` the Mosaic row-sort kernels. For the bsp
                    backend the same names select the *shard-local* sort
                    inside both Algorithm-2 psorts: ``"auto"`` → packed-key
                    ``"radix"`` (or ``"lax"`` when `pack_keys` is False),
                    ``"lax"`` unpacked multi-key `lax.sort`, ``"bitonic"``
                    the legacy comparator network kept as the benchmark
                    regression row; ``"pallas"`` is rejected
                    (`repro.bsp.psort.resolve_bsp_sort_impl`). See
                    docs/architecture.md for the decision tree.
    cache:          enable the compiled-builder cache and bucketed shape
                    padding in `repro.api.build` — repeated builds of
                    nearby lengths reuse jitted computations instead of
                    re-tracing. Disable for exact-shape benchmarking.
    mesh:           a 1-D ``jax.sharding.Mesh`` for the BSP backend. Setting
                    it makes ``backend="auto"`` resolve to ``"bsp"``.
    axis:           mesh axis name the BSP pipeline shards over.
    pack_keys:      BSP radix key packing (§Perf SA-iteration A). Only
                    consulted when ``sort_impl="auto"`` (False → the
                    unpacked "lax" local sort) or ``"bitonic"`` (legacy
                    SM1 window packing); explicit "radix"/"lax" already
                    state the packing choice.
    counters:       ``repro.bsp.counters.BSPCounters`` sink (BSP backend).
    stats:          ``repro.core.seq_ref.SeqStats`` sink (seq backend).
    validate:       check input values are non-negative ints before building.
    segment_docs:   default documents-per-segment for
                    `repro.api.SegmentedIndex.from_docs` (``None`` = one
                    segment, the monolithic layout). A *serving-layer*
                    knob: it shapes how the corpus is sliced, never the
                    per-segment suffix arrays themselves, so it is
                    excluded from `fingerprint()` — persisted segments
                    stay valid however future ingests are chunked.
    compact_fanin:  size-tiered compaction trigger for `SegmentedIndex`:
                    merge whenever this many segments share a size tier
                    (sizes within one power of the fanin). Also excluded
                    from `fingerprint()` for the same reason.
    sample_rate:    sampled-position indexing stride (Ayad et al.,
                    arXiv:2310.09023). ``1`` (default) keeps the dense
                    suffix array over every position; ``s > 1`` makes
                    `repro.sparse.SparseSuffixArrayIndex` store the SA
                    over positions ``{0, s, 2s, ...}`` only — index
                    memory scales n/s, and queries are exact for every
                    pattern of length ≥ s (shorter patterns raise
                    `repro.sparse.PatternTooShortError`). Unlike the
                    serving-layer knobs above this DOES change the
                    persisted index structure, so it is part of
                    `fingerprint()`: a dense checkpoint never warm-loads
                    as sparse, nor across different rates.
    """

    backend: str = AUTO
    v0: int = 3
    schedule: Union[str, Callable[[int, int, int], int]] = "accelerated"
    base_threshold: int | None = None
    sort_impl: str = AUTO
    cache: bool = True
    mesh: Any = None
    axis: str = "bsp"
    pack_keys: bool = True
    counters: Any = None
    stats: Any = None
    validate: bool = True
    segment_docs: int | None = None
    compact_fanin: int = 4
    sample_rate: int = 1

    def __post_init__(self):
        if isinstance(self.schedule, str) and self.schedule not in SCHEDULES:
            raise ValueError(
                f"unknown schedule {self.schedule!r}; "
                f"expected one of {sorted(SCHEDULES)} or a callable")
        if self.v0 < 3:
            raise ValueError(f"v0 must be ≥ 3 (difference covers), got {self.v0}")
        if self.sort_impl not in SORT_IMPLS:
            raise ValueError(f"unknown sort_impl {self.sort_impl!r}; "
                             f"expected one of {SORT_IMPLS}")
        if self.segment_docs is not None and self.segment_docs < 1:
            raise ValueError(
                f"segment_docs must be ≥ 1, got {self.segment_docs}")
        if self.compact_fanin < 2:
            raise ValueError(
                f"compact_fanin must be ≥ 2, got {self.compact_fanin}")
        if self.sample_rate < 1:
            raise ValueError(
                f"sample_rate must be ≥ 1, got {self.sample_rate}")

    @property
    def schedule_fn(self) -> Callable[[int, int, int], int]:
        if callable(self.schedule):
            return self.schedule
        return SCHEDULES[self.schedule]

    def resolve_backend(self) -> str:
        """Concrete registry key for this plan (applies the auto rule)."""
        if self.backend != AUTO:
            return self.backend
        return "bsp" if self.mesh is not None else "jax"

    def fingerprint(self) -> str:
        """Stable identity of the construction plan, for staleness checks.

        Covers the fields that *describe* the build (backend spelling, v0,
        schedule, base_threshold, sort_impl, pack_keys, sample_rate —
        the last one changes the persisted index *structure*, dense vs
        sampled, so dense and sparse checkpoints can never be confused)
        and deliberately
        excludes runtime objects (mesh, counters/stats sinks),
        execution-only knobs (cache, validate), and serving-layer
        segmentation knobs (segment_docs, compact_fanin — they shape how
        a corpus is sliced into segments, never the per-segment suffix
        array): every correct backend
        produces the identical suffix array, so a persisted index
        (`repro.api.store.IndexStore`) stays valid across process
        restarts, device counts, and instrumentation changes — but is
        conservatively rebuilt when the plan itself changes. Callable
        schedules fingerprint by name: two differently-named callables
        never match, same-named ones are trusted to agree.
        """
        sched = (self.schedule if isinstance(self.schedule, str)
                 else f"callable:{getattr(self.schedule, '__name__', 'anon')}")
        return (f"plan-v2|backend={self.backend}|v0={self.v0}"
                f"|schedule={sched}|base={self.base_threshold}"
                f"|sort={self.sort_impl}|pack={int(self.pack_keys)}"
                f"|rate={self.sample_rate}")

    def replace(self, **changes) -> "SAOptions":
        return dataclasses.replace(self, **changes)
