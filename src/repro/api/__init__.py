"""repro.api — the unified suffix-array facade.

This package is the single entry point for every suffix-array workload in
the repo (dedup, corpus statistics, serving, benchmarks). It decouples
*what* to build (a suffix array over a text or a multi-document corpus)
from *how* it is built (which of the paper's construction algorithms runs,
on which substrate).

Three layers
------------
1. **Backend registry** (`registry`): string-keyed
   `SuffixArrayBuilder` implementations. Built-ins::

       "oracle"  O(n² log n) direct sort      — ground truth for tests
       "seq"     paper Algorithm 1 (DC-v)     — executable specification
       "jax"     vectorised DC-v on XLA       — single-device fast path
       "bsp"     paper Algorithm 3 (shard_map) — distributed fast path

   `register_backend(name, fn)` adds future substrates (Pallas kernels,
   multi-host) without touching any consumer.

2. **Plan** (`SAOptions` + `build_suffix_array`): one frozen dataclass
   carrying every construction knob (`v0`, schedule, `base_threshold`,
   mesh/axis, key packing, counters/stats sinks). Backend selection rules:

   * ``backend="<name>"`` uses that registry entry, always.
   * ``backend="auto"`` (default) resolves to ``"bsp"`` when
     ``options.mesh`` is set, and to ``"jax"`` otherwise — so the same
     call site scales from a laptop to a pod by passing a mesh.
   * ``backend="bsp"`` with no mesh builds a 1-D mesh over all local
     devices (`repro.launch.mesh.make_sa_mesh`).

   All backends see identical normalised input (1-D int64, values ≥ 0) and
   return identical results (np.int32[n]); the equivalence suite in
   `tests/api/test_api.py` enforces agreement with the oracle.

   The jax backend's sort primitive is itself pluggable
   (``SAOptions.sort_impl``: "auto"/"radix"/"lax"/"bitonic"/"pallas" — see
   docs/architecture.md for the decision tree), and plans with
   ``cache=True`` (default) go through the compiled-builder cache in
   `build`: input lengths are padded to a geometric bucket grid so
   repeated builds of nearby lengths reuse every jitted computation
   (`builder_cache_stats` / `clear_builder_cache` expose it).

3. **Index** (`SuffixArrayIndex`): text + SA + lazily-computed LCP with
   queries — `count_batch` / `locate_batch` / `contains_batch` (the
   batched jitted query engine, `repro.api.query`), scalar `count` /
   `locate` shims, `ngram_stats(k)`, `duplicate_spans(min_len)`,
   `cross_doc_duplicates(min_len)`. `SuffixArrayIndex.from_docs` keeps the
   sentinel-separator corpus layout previously hand-rolled in
   `repro.text.corpus_sa` (now a deprecation shim over this class).

4. **Query engine + store** (`query`, `store`): `QueryBatch` pads and
   bucketizes many patterns into one device buffer and a single jitted
   vectorised binary search resolves every `(lo, hi)` SA range in one
   XLA call (`query_cache_stats` mirrors `builder_cache_stats` on the
   query side); `QuerySession` serves batched ticks with p50/p95/p99
   latency accounting; `IndexStore` persists built indexes through the
   committed-checkpoint machinery (`repro.ckpt.checkpoint`) with
   staleness detection, so a serving process restores in milliseconds
   instead of rebuilding (`SuffixArrayIndex.save` / `.load` are the
   single-artifact conveniences).

5. **Sparse indexing** (`repro.sparse`): any plan with
   ``SAOptions(sample_rate=s)``, s > 1, makes `SuffixArrayIndex.build` /
   `.from_docs` (and therefore segments, stores, the serving tier, and
   the data plane) construct a `repro.sparse.SparseSuffixArrayIndex` —
   the suffix array over every s-th position only, ~s× less index
   memory, exact answers for every pattern of length ≥ s and a typed
   `repro.sparse.PatternTooShortError` below that. `sample_rate` is part
   of `SAOptions.fingerprint()`, so persisted dense and sparse artifacts
   can never be confused.

6. **Segmented serving** (`segments` + `SegmentedIndexStore`): a
   `SegmentedIndex` splits the corpus into independently-built segments
   so ingesting or deleting a document rebuilds ONE small segment instead
   of the corpus; queries fan a batch across segments through the same
   jitted range kernel and merge counts/locations back to global document
   coordinates, and size-tiered compaction bounds the fan-out.
   `SegmentedIndexStore` persists each segment under its own versioned
   checkpoint plus an atomically-replaced corpus manifest — an ingest
   syncs exactly one segment to disk.

Quickstart
----------
>>> import numpy as np
>>> from repro.api import SAOptions, SuffixArrayIndex, build_suffix_array
>>> x = np.array([0, 2, 1, 0, 0, 2, 4, 3, 1, 1, 4, 0])
>>> build_suffix_array(x, backend="seq").tolist()
[11, 3, 0, 4, 2, 8, 9, 1, 5, 7, 10, 6]
>>> idx = SuffixArrayIndex.from_docs([[0, 1, 0], [1, 0, 1]])
>>> idx.count([0, 1]), idx.count([1, 0])
(2, 2)
"""
from .build import (build_suffix_array, builder_cache_stats,
                    clear_builder_cache)
from .index import (NgramStats, SuffixArrayIndex, encode_docs,
                    longest_match_len)
from .options import SAOptions, SCHEDULES, SORT_IMPLS
from .query import (QueryBatch, QuerySession, clear_query_cache,
                    query_cache_stats)
from .registry import (SuffixArrayBuilder, get_backend, register_backend,
                       registered_backends)
from .segments import Segment, SegmentedIndex
from .store import (IndexStore, SegmentedIndexStore, StaleIndexError,
                    corpus_fingerprint, load_index, save_index)

__all__ = [
    "SAOptions",
    "SCHEDULES",
    "SORT_IMPLS",
    "Segment",
    "SegmentedIndex",
    "SegmentedIndexStore",
    "SuffixArrayBuilder",
    "SuffixArrayIndex",
    "NgramStats",
    "IndexStore",
    "QueryBatch",
    "QuerySession",
    "StaleIndexError",
    "build_suffix_array",
    "builder_cache_stats",
    "clear_builder_cache",
    "clear_query_cache",
    "corpus_fingerprint",
    "encode_docs",
    "get_backend",
    "load_index",
    "longest_match_len",
    "query_cache_stats",
    "register_backend",
    "registered_backends",
    "save_index",
]
