"""String-keyed registry of suffix-array construction backends.

A backend is any callable ``(x: np.int64[n], options: SAOptions) ->
integer[n]`` mapping a normalised non-negative text to its suffix array.
Normalisation (dtype coercion, dimension/value checks, empty and length-1
fast paths, output dtype) happens once in `repro.api.build.build_suffix_array`
— backends only implement the algorithm.

Built-ins registered on import:

==========  ===============================================================
``oracle``  O(n² log n) direct suffix sort (`repro.core.oracle`) — the
            ground truth the equivalence suite compares everything against.
``seq``     paper-faithful sequential DC-v, Algorithm 1
            (`repro.core.seq_ref.suffix_array_dcv`).
``jax``     vectorised single-device DC-v
            (`repro.core.dcv_jax.suffix_array_jax`) — the fastest
            single-device path. Honours ``options.sort_impl`` (platform-
            adaptive sort primitive, see docs/architecture.md) and
            ``options.cache`` (bucketed shape padding for the compiled-
            builder cache in `repro.api.build`).
``bsp``     Algorithm 3 on a 1-D shard_map mesh
            (`repro.bsp.suffix_array.suffix_array_bsp`); builds a mesh over
            all local devices when `options.mesh` is None. Honours
            ``options.sort_impl`` for the shard-local sorts inside both
            Algorithm-2 psorts ("auto" → packed-key "radix"; "bitonic" is
            the legacy comparator network; "pallas" is rejected — see
            `repro.bsp.psort.resolve_bsp_sort_impl`) and
            ``options.counters`` for BSP superstep accounting.
==========  ===============================================================

`register_backend` exists so future substrates (Pallas kernels, multi-host)
plug in without touching consumers.
"""
from __future__ import annotations

from typing import Callable, Protocol

import numpy as np

from .options import SAOptions


class SuffixArrayBuilder(Protocol):
    """Backend contract: normalised text + plan → suffix array."""

    def __call__(self, x: np.ndarray, options: SAOptions) -> np.ndarray: ...


_REGISTRY: dict[str, SuffixArrayBuilder] = {}


def register_backend(name: str, builder: SuffixArrayBuilder, *,
                     overwrite: bool = False) -> SuffixArrayBuilder:
    """Register `builder` under `name`. Returns the builder (decorator-safe)."""
    if not overwrite and name in _REGISTRY:
        raise ValueError(f"backend {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[name] = builder
    return builder


def get_backend(name: str) -> SuffixArrayBuilder:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown suffix-array backend {name!r}; "
                       f"registered: {registered_backends()}") from None


def registered_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# --------------------------------------------------------------------------
# built-in backends
# --------------------------------------------------------------------------
#: above this length the oracle switches from the O(n² log n) direct sort to
#: the O(n log² n) prefix-doubling oracle (both are reference implementations;
#: the direct sort materialises every suffix as a Python tuple).
_ORACLE_NAIVE_MAX = 2048


def _oracle_backend(x: np.ndarray, options: SAOptions) -> np.ndarray:
    from ..core.oracle import suffix_array_doubling, suffix_array_naive
    if len(x) <= _ORACLE_NAIVE_MAX:
        return suffix_array_naive(x)
    return suffix_array_doubling(x)


def _seq_backend(x: np.ndarray, options: SAOptions) -> np.ndarray:
    from ..core.seq_ref import suffix_array_dcv
    kw = {"v": options.v0, "schedule": options.schedule_fn,
          "stats": options.stats}
    if options.base_threshold is not None:
        kw["base_threshold"] = options.base_threshold
    return suffix_array_dcv(x, **kw)


def _jax_backend(x: np.ndarray, options: SAOptions) -> np.ndarray:
    from ..core.dcv_jax import suffix_array_jax
    kw = {"v": options.v0, "schedule": options.schedule_fn,
          "sort_impl": options.sort_impl, "bucket": options.cache}
    if options.base_threshold is not None:
        kw["base_threshold"] = options.base_threshold
    return suffix_array_jax(x, **kw)


def _bsp_backend(x: np.ndarray, options: SAOptions) -> np.ndarray:
    from ..bsp.counters import NULL_COUNTERS
    from ..bsp.suffix_array import suffix_array_bsp
    mesh = options.mesh
    if mesh is None:
        from ..launch.mesh import make_sa_mesh
        mesh = make_sa_mesh(axis=options.axis)
    return suffix_array_bsp(
        x, mesh, axis=options.axis, v=options.v0,
        schedule=options.schedule_fn, base_threshold=options.base_threshold,
        counters=options.counters or NULL_COUNTERS,
        pack_keys=options.pack_keys, sort_impl=options.sort_impl)


register_backend("oracle", _oracle_backend)
register_backend("seq", _seq_backend)
register_backend("jax", _jax_backend)
register_backend("bsp", _bsp_backend)
