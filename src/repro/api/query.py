"""Batched query execution — many patterns, one XLA call.

The scalar query path (`SuffixArrayIndex._sa_range`) answers one pattern
at a time with a Python binary-search loop: O(log n) numpy probes per
pattern, each a host gather + compare. That is fine for a notebook and
hopeless for a serving process. This module is the batched replacement:

* `QueryBatch` encodes many patterns into ONE padded device buffer
  (`int[B_pad, L_pad]` + per-row lengths), with both axes quantised onto a
  power-of-two bucket grid so repeated batch shapes reuse the same jitted
  computation — the same shape-quantisation idea as the compiled-builder
  cache in `repro.api.build` (`pad_bucket`), applied to the query side.
* `batch_ranges` runs a single jitted **vectorised double binary search**
  (`_ranges_kernel`): all B patterns advance their (lower, upper) SA
  bounds in lock-step; every step is one `[B, 2, L]` gather of text
  windows and one masked prefix comparison. All `(lo, hi)` SA ranges
  resolve in one XLA call — O(B · L · log n) device work, zero Python
  per-probe overhead.
* `QuerySession` is the serving facade: it chops an incoming pattern
  stream into fixed-size ticks, runs each tick through the batched path,
  and keeps per-tick latency records (`latency_summary()` reports
  p50/p95/p99 and qps) — what `repro.launch.serve` prints.

Observability mirrors `repro.core.dcv_jax`: `TRACE_COUNTS` records one
event per actual kernel trace (the no-retrace tests in
`tests/api/test_query.py` assert it stays flat for re-used buckets), and
`query_cache_stats()` counts bucket hits/misses the way
`builder_cache_stats()` does for builds.
"""
from __future__ import annotations

import collections
import time
import weakref

import jax
import jax.numpy as jnp
import numpy as np

#: one event per actual jax trace of the query kernel (keyed by shape).
TRACE_COUNTS: collections.Counter = collections.Counter()

#: (n, B_pad, L_pad, text dtype) buckets seen so far + hit/miss counters.
_SEEN_BUCKETS: set[tuple] = set()
_CACHE_STATS = {"hits": 0, "misses": 0}

#: pattern-length buckets never go below this (tiny patterns share shapes).
_MIN_LEN_BUCKET = 8


def trace_events() -> int:
    """Total number of jax traces performed by the query kernel so far."""
    return sum(TRACE_COUNTS.values())


def query_cache_stats() -> dict:
    """Snapshot of the query-plan cache: buckets / hits / misses.

    A "bucket" is one compiled kernel shape `(n, B_pad, L_pad, dtype)`;
    a hit means the batch landed on a shape that was already compiled.
    """
    return {"buckets": len(_SEEN_BUCKETS), **_CACHE_STATS}


def clear_query_cache() -> None:
    """Reset the bucket bookkeeping and hit/miss counters.

    Does not drop jax's jit cache — batches re-run after a clear still
    reuse compiled kernels when shapes match (exactly like
    `repro.api.build.clear_builder_cache`).
    """
    _SEEN_BUCKETS.clear()
    _CACHE_STATS["hits"] = 0
    _CACHE_STATS["misses"] = 0


def pow2_bucket(m: int, floor: int = 1) -> int:
    """Smallest power of two ≥ max(m, floor).

    The shape-quantisation rule shared by the query engine and the serving
    tier: batch sizes and pattern lengths land on this grid so an
    open-ended stream of shapes maps onto O(log) compiled kernels."""
    m = max(int(m), floor, 1)
    return 1 << (m - 1).bit_length()


#: kept as an alias — pre-existing internal callers use the underscored name.
_pow2_bucket = pow2_bucket


class QueryBatch:
    """Many encoded patterns in one padded, bucketed device-ready buffer.

    Rows are patterns *after* `SuffixArrayIndex._encode_pattern` (shift
    applied, alphabet validated); `lens[i]` is the true length of row i and
    columns past it are padding (masked inside the kernel, value
    irrelevant). Both axes are padded up to power-of-two buckets
    (`L` has a floor of 8) so nearby batch shapes share one compiled
    kernel; padded rows have length 0 and are sliced off the results.

    A `QueryBatch` is **bound to the index that encoded it** (the
    shift/sigma are baked into the values) and that binding is enforced:
    running it against any other index raises `ValueError` instead of
    silently searching mis-encoded values. Within its index it is
    reusable — passing the same batch to `count_batch`/`locate_batch`
    repeatedly skips re-encoding.
    """

    __slots__ = ("pats", "lens", "n_queries", "_index_ref")

    def __init__(self, pats: np.ndarray, lens: np.ndarray, n_queries: int,
                 index=None):
        self.pats = pats            # int[B_pad, L_pad], encoded + padded
        self.lens = lens            # int32[B_pad], 0 for padding rows
        self.n_queries = int(n_queries)
        self._index_ref = (weakref.ref(index) if index is not None
                           else lambda: None)

    def check_bound_to(self, index) -> None:
        """Raise unless this batch was encoded by `index` (the encoding
        shift/sigma are index-specific — a foreign batch would return
        wrong counts, not an error, without this check)."""
        if self._index_ref() is not index:
            raise ValueError(
                "QueryBatch was encoded against a different index (or one "
                "that no longer exists) — re-encode with "
                "QueryBatch.encode(index, patterns)")

    @classmethod
    def encode(cls, index, patterns, dtype=np.int32) -> "QueryBatch":
        """Encode `patterns` (a sequence of int sequences) against `index`."""
        return cls.from_encoded(index, [index._encode_pattern(p)
                                        for p in patterns], dtype)

    @classmethod
    def from_encoded(cls, index, enc, dtype=np.int32) -> "QueryBatch":
        """Build a batch from patterns already passed through
        `index._encode_pattern` (the serving tier validates/encodes each
        request at submit time, so coalesced batches must not pay — or
        double-apply — the shift again)."""
        B = len(enc)
        max_len = max((len(p) for p in enc), default=0)
        b_pad = _pow2_bucket(B)
        l_pad = _pow2_bucket(max_len, floor=_MIN_LEN_BUCKET)
        pats = np.zeros((b_pad, l_pad), dtype)
        lens = np.zeros(b_pad, np.int32)
        cap = np.iinfo(dtype).max
        for i, p in enumerate(enc):
            if len(p) and int(p.max()) >= cap:
                # a declared sigma may admit values past int32; every text
                # symbol is < cap (enforced by _device_state), so clamping
                # to cap preserves every text-vs-pattern comparison exactly
                # instead of wrapping to a false match.
                p = np.minimum(p, cap)
            pats[i, :len(p)] = p
            lens[i] = len(p)
        return cls(pats, lens, B, index=index)

    @property
    def bucket(self) -> tuple[int, int]:
        """(B_pad, L_pad) — the compiled shape this batch runs at."""
        return tuple(self.pats.shape)

    def __len__(self) -> int:
        return self.n_queries

    def __repr__(self) -> str:
        return (f"QueryBatch(n_queries={self.n_queries}, "
                f"bucket={self.bucket})")


@jax.jit
def _ranges_kernel(text, sa, pats, lens):
    """Vectorised double binary search: all patterns, both bounds, at once.

    For each pattern row the kernel maintains two binary-search states over
    SA ranks — bound 0 converges to the first suffix ≥ pattern, bound 1 to
    the first suffix > pattern (prefix-match counts as equal), so
    `[lo, hi)` is exactly the block of suffixes starting with the pattern.
    Every iteration probes both bounds of every pattern with one gather of
    `[B, 2, L]` text windows and one masked 3-way prefix comparison
    (past-the-end reads as -1, below every real character; columns ≥ the
    pattern's true length are masked out). Rows with length 0 (empty or
    padding) resolve to (0, n). The iteration count is ceil(log2(n + 1)),
    a shape-derived Python int, so the whole search is one fori_loop in
    one XLA computation.
    """
    # saca-lint: allow[TRACE001] deliberate: trace-time retrace counter, mutated only while tracing, read by tests via total_traces()
    TRACE_COUNTS["ranges_kernel"] += 1
    n = text.shape[0]
    B, L = pats.shape
    steps = max(int(n).bit_length(), 1) + 1
    col = jnp.arange(L, dtype=jnp.int32)
    past_end = jnp.array(-1, text.dtype)   # below every real character

    def body(_, state):
        lo, hi = state
        active = lo < hi                                    # [B, 2]
        mid = lo + (hi - lo) // 2    # lo+hi could wrap int32 for n > 2^30
        start = sa[jnp.where(active, mid, 0)]               # [B, 2]
        idx = start[..., None] + col[None, None, :]         # [B, 2, L]
        chars = jnp.where(idx < n, text[jnp.minimum(idx, n - 1)], past_end)
        pat = jnp.broadcast_to(pats[:, None, :], chars.shape)
        valid = col[None, None, :] < lens[:, None, None]
        diff = (chars != pat) & valid
        any_diff = diff.any(axis=-1)
        first = jnp.argmax(diff, axis=-1)[..., None]
        s_at = jnp.take_along_axis(chars, first, axis=-1)[..., 0]
        p_at = jnp.take_along_axis(pat, first, axis=-1)[..., 0]
        less = any_diff & (s_at < p_at)       # suffix < pattern
        greater = any_diff & (s_at > p_at)    # suffix > pattern
        # bound 0 moves right while suffix < pat; bound 1 while suffix ≤ pat
        before = jnp.stack([less[:, 0], ~greater[:, 1]], axis=1)
        lo = jnp.where(active & before, mid + 1, lo)
        hi = jnp.where(active & ~before, mid, hi)
        return lo, hi

    lo0 = jnp.zeros((B, 2), jnp.int32)
    hi0 = jnp.full((B, 2), n, jnp.int32)
    lo, hi = jax.lax.fori_loop(0, steps, body, (lo0, hi0))
    return lo[:, 0], lo[:, 1]


def stage_batch(index, batch: QueryBatch):
    """Begin the host→device transfer of a batch's buffers.

    Returns opaque staged device arrays for `batch_ranges(..., staged=)`.
    `jax.device_put` dispatches asynchronously, so the serving tier calls
    this for the *next* coalesced batch while the previous one's kernel is
    still in flight — the transfer rides under the in-flight compute
    (double-buffering). Harmless but pointless on an empty index."""
    batch.check_bound_to(index)
    return (jax.device_put(batch.pats), jax.device_put(batch.lens))


def batch_ranges(index, batch: QueryBatch, *,
                 staged=None) -> tuple[np.ndarray, np.ndarray]:
    """Resolve every pattern in `batch` to its `[lo, hi)` SA-rank range.

    One jitted call for the whole batch; returns two int64[n_queries]
    arrays (padding rows already sliced off). An empty index maps every
    pattern to the empty range (0, 0). Pass `staged=stage_batch(...)` to
    run against buffers whose transfer was already started (the serving
    tier's double-buffer path); without it the transfer happens here.
    """
    batch.check_bound_to(index)
    k = batch.n_queries
    if index.n == 0 or k == 0:
        z = np.zeros(k, np.int64)
        return z, z.copy()
    text_d, sa_d = index._device_state()
    key = (index.n, *batch.bucket, np.dtype(batch.pats.dtype).str)
    if key in _SEEN_BUCKETS:
        _CACHE_STATS["hits"] += 1
    else:
        _CACHE_STATS["misses"] += 1
        _SEEN_BUCKETS.add(key)
    pats_d, lens_d = (staged if staged is not None
                      else (jnp.asarray(batch.pats), jnp.asarray(batch.lens)))
    lo, hi = _ranges_kernel(text_d, sa_d, pats_d, lens_d)
    return (np.asarray(lo)[:k].astype(np.int64),
            np.asarray(hi)[:k].astype(np.int64))


class QuerySession:
    """Serving facade: batched query ticks + latency accounting.

    Wraps one `SuffixArrayIndex` (built locally or restored from an
    `IndexStore`) — or a `repro.api.SegmentedIndex`, whose `count_batch`
    fans each tick across segments and merges (locate then yields global
    (doc, offset) rows) — and exposes the batch API in serving shape: an
    incoming
    sequence of patterns is chopped into ticks of at most `batch_size`,
    each tick runs through the jitted batched path as one device call, and
    the wall time of every tick is recorded. `latency_summary()` reports
    per-query p50/p95/p99 latency (a query's latency is its tick's wall
    time — queries in one tick complete together) plus aggregate qps.
    """

    def __init__(self, index, *, batch_size: int = 64):
        if batch_size < 1:
            raise ValueError(f"batch_size must be ≥ 1, got {batch_size}")
        self.index = index
        self.batch_size = int(batch_size)
        self._tick_us: list[float] = []     # wall µs per tick
        self._tick_sizes: list[int] = []    # queries per tick
        self._warmup_ticks = 0
        self._server = None                 # lazy repro.serve.SAServer

    # ------------------------------------------------------------ serving
    def _ticks(self, patterns):
        pats = list(patterns)
        for at in range(0, len(pats), self.batch_size):
            yield pats[at:at + self.batch_size]

    def _timed(self, fn, tick):
        t0 = time.perf_counter()
        out = fn(tick)
        self._tick_us.append(1e6 * (time.perf_counter() - t0))
        self._tick_sizes.append(len(tick))
        return out

    def warmup(self, pattern_lens=(8,)) -> int:
        """Run one un-recorded tick per pattern-length bucket.

        The first tick at a new `(B_pad, L_pad)` shape pays the jax trace +
        XLA compile — tens of ms to seconds on CPU, orders of magnitude
        above steady state. Serving percentiles must describe steady state,
        so callers warm the buckets they expect *before* timed traffic;
        warmed ticks are counted (`latency_summary()["warmup_ticks"]`) but
        never enter the percentile pool. Returns the tick count run."""
        done = 0
        for m in pattern_lens:
            # floor by the index's minimum answerable length (a sparse
            # index rejects shorter patterns instead of compiling them)
            m = max(int(m), 1,
                    int(getattr(self.index, "min_pattern_len", 0)))
            if self.index.n == 0 or self.index.sigma == 0:
                continue        # nothing to compile against / no alphabet
            # value 0 is always in-alphabet when sigma ≥ 1
            self.index.count_batch([np.zeros(m, np.int64)] * self.batch_size)
            self._warmup_ticks += 1
            done += 1
        return done

    def count(self, patterns) -> np.ndarray:
        """Occurrence counts for a stream of patterns — int64[len]."""
        outs = [self._timed(self.index.count_batch, t)
                for t in self._ticks(patterns)]
        return (np.concatenate(outs) if outs else np.zeros(0, np.int64))

    def contains(self, patterns) -> np.ndarray:
        """Presence flags for a stream of patterns — bool[len]."""
        return self.count(patterns) > 0

    def locate(self, patterns) -> list:
        """Sorted occurrence positions per pattern — list of int64 arrays."""
        outs: list = []
        for t in self._ticks(patterns):
            outs.extend(self._timed(self.index.locate_batch, t))
        return outs

    # --------------------------------------------------------- accounting
    @property
    def queries_served(self) -> int:
        return int(sum(self._tick_sizes))

    def latency_summary(self) -> dict:
        """Aggregate latency stats over every *recorded* tick served so far.

        Warmup ticks are excluded (only their count is reported). With no
        recorded ticks the percentiles and qps are ``None`` — *absent*, not
        zero — so an idle session aggregated into an SLO report contributes
        nothing instead of dragging p99 toward a fictitious 0µs.
        """
        if not self._tick_us:
            return {"ticks": 0, "queries": 0,
                    "warmup_ticks": self._warmup_ticks,
                    "p50_us": None, "p95_us": None, "p99_us": None,
                    "qps": None}
        per_query = np.repeat(np.asarray(self._tick_us),
                              np.asarray(self._tick_sizes))
        p50, p95, p99 = np.percentile(per_query, [50, 95, 99])
        total_s = float(np.sum(self._tick_us)) * 1e-6
        return {
            "ticks": len(self._tick_us),
            "queries": self.queries_served,
            "warmup_ticks": self._warmup_ticks,
            "p50_us": float(p50),
            "p95_us": float(p95),
            "p99_us": float(p99),
            "qps": self.queries_served / max(total_s, 1e-9),
        }

    def reset_latency(self) -> None:
        self._tick_us.clear()
        self._tick_sizes.clear()
        self._warmup_ticks = 0

    # ------------------------------------------------- non-blocking submit
    def submit(self, pattern, **server_knobs):
        """Submit ONE pattern without blocking; returns a future.

        First call lazily starts a `repro.serve.SAServer` over this
        session's index (`max_batch=batch_size`; pass coalescing/admission
        knobs as keyword arguments on that first call — see
        `repro.serve.SAServer`). The future resolves to a
        `repro.serve.Response` whose `.count` is the occurrence count.
        Async traffic is accounted in `server.metrics`, not in this
        session's closed-loop tick stats. Call `close()` (or use the
        session as a context manager) to drain and stop the loop.
        """
        if self._server is None:
            from ..serve import SAServer
            self._server = SAServer(self.index, max_batch=self.batch_size,
                                    **server_knobs)
            self._server.start()
        elif server_knobs:
            raise ValueError("server knobs only apply to the first submit "
                             "(the serving loop is already running)")
        return self._server.submit(pattern)

    @property
    def server(self):
        """The lazily-started `repro.serve.SAServer`, or None."""
        return self._server

    def close(self) -> None:
        """Drain and stop the async serving loop (no-op if never started)."""
        if self._server is not None:
            self._server.stop()
            self._server = None

    def __enter__(self) -> "QuerySession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        return (f"QuerySession(index=n{self.index.n}, "
                f"batch_size={self.batch_size}, "
                f"served={self.queries_served})")
