"""`SegmentedIndex` — incremental multi-segment serving over many docs.

The monolithic `SuffixArrayIndex.from_docs` pays an O(n log n) rebuild of
the *whole* corpus for every document change. That caps corpus sizes well
below the ROADMAP's "millions of users" target: a serving fleet ingesting
a stream of documents cannot re-sort terabytes per ingest. The classic
amortization (the shift argued for distributed SACA by Haag/Kurpicz/
Sanders/Schimek, arXiv:2412.10160, and by every LSM-shaped index since
Lucene) is **segment/merge**:

* the corpus is a set of **segments**, each an independent
  `SuffixArrayIndex` over a slice of the documents (its own
  sentinel-separator encoding, its own suffix array);
* **ingest** builds one small segment over just the new documents —
  builder traffic is O(new docs), not O(corpus);
* **delete** rebuilds only the segment that owned the document;
* **queries** fan a pattern batch across segments through the existing
  jitted `repro.api.query._ranges_kernel` (one call per segment) and
  merge: counts add, located positions map through each segment's doc
  table back to *global* document coordinates;
* a **size-tiered compaction** policy merges segments whose sizes share a
  tier once `compact_fanin` of them pile up, so per-query fan-out stays
  O(log_fanin(corpus / ingest)) instead of O(ingests).

Coordinate semantics (documented in docs/api.md): a segmented index has
no global *encoded text*, so `locate_batch` returns **(doc, offset)**
rows (int64[k, 2], sorted lexicographically) rather than encoded
positions. `SuffixArrayIndex.locate_docs_batch` produces the identical
representation for a monolithic index — the differential property tests
in `tests/api/test_segments.py` pin merged results byte-identical to a
monolithic rebuild of the same documents.

Persistence lives in `repro.api.store.SegmentedIndexStore`: one
versioned checkpoint per segment plus a corpus-level manifest, so an
ingest persists one small segment, never the corpus.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .index import SuffixArrayIndex, longest_match_len
from .options import SAOptions

__all__ = ["Segment", "SegmentedIndex"]


@dataclass
class Segment:
    """One independently-built slice of the corpus.

    `doc_ids[j]` is the *global* document id of the segment's local
    document j — the only state needed to merge per-segment query results
    back into corpus coordinates.
    """

    seg_id: str
    doc_ids: np.ndarray                  # int64[local n_docs], global ids
    index: SuffixArrayIndex
    version: int = 0                     # checkpoint step on disk

    def __post_init__(self):
        self.doc_ids = np.asarray(self.doc_ids, np.int64)
        if len(self.doc_ids) != self.index.n_docs:
            raise ValueError(
                f"segment {self.seg_id!r} maps {len(self.doc_ids)} doc ids "
                f"onto an index of {self.index.n_docs} docs")

    @property
    def n(self) -> int:
        return self.index.n

    def payloads(self) -> list[np.ndarray]:
        """Decode the segment's raw documents back out of the encoded text
        (payload = chars between doc start and separator, unshifted).
        Exact inverse of `encode_docs` — rebuilds and merges never need
        the original inputs kept around."""
        idx = self.index
        starts, ends = idx.doc_starts, idx._doc_ends
        return [idx.text[s:e] - idx.shift for s, e in zip(starts, ends)]

    def __repr__(self) -> str:
        return (f"Segment(id={self.seg_id!r}, docs={len(self.doc_ids)}, "
                f"n={self.n}, v{self.version})")


def _tier_of(n: int, fanin: int) -> int:
    """Size tier of a segment with n encoded chars: segments land in the
    same tier iff their sizes are within one power of `fanin`."""
    t = 0
    n = max(int(n), 1)
    while n >= fanin:
        n //= fanin
        t += 1
    return t


class SegmentedIndex:
    """Multi-segment corpus index with incremental ingest/delete.

    Query surface mirrors `SuffixArrayIndex` where the semantics carry
    over (`count` / `count_batch` / `contains_batch` / empty pattern
    counts `n`), and diverges where a global encoded text does not exist:
    `locate_batch` / `locate` return (doc, offset) rows — see the module
    docstring. The serving tier (`repro.serve.SAServer`,
    `repro.api.QuerySession`) accepts either index kind through the
    shared `_encode_pattern` / `stage_encoded` / `ranges_staged`
    protocol.
    """

    def __init__(self, segments=(), *, options: SAOptions | None = None,
                 sigma: int | None = None, next_doc_id: int | None = None,
                 next_seg: int = 0, compact_fanin: int | None = None):
        self._segments: list[Segment] = list(segments)
        self.options = options if options is not None else SAOptions()
        self._sigma = None if sigma is None else int(sigma)
        fanin = (compact_fanin if compact_fanin is not None
                 else self.options.compact_fanin)
        if fanin < 2:
            raise ValueError(f"compact_fanin must be ≥ 2, got {fanin}")
        self.compact_fanin = int(fanin)
        top = max((int(s.doc_ids.max()) + 1 for s in self._segments
                   if len(s.doc_ids)), default=0)
        self._next_doc_id = (int(next_doc_id) if next_doc_id is not None
                             else top)
        if self._next_doc_id < top:
            raise ValueError(f"next_doc_id {next_doc_id} collides with "
                             f"existing doc id {top - 1}")
        self._next_seg = int(next_seg)
        # seg ids written since the last store sync / dropped and not yet
        # garbage-collected on disk (repro.api.store.SegmentedIndexStore)
        self.dirty: set[str] = {s.seg_id for s in self._segments}
        self.dropped: set[str] = set()

    # ----------------------------------------------------------- construct
    @classmethod
    def from_docs(cls, docs, options: SAOptions | None = None, *,
                  sigma: int | None = None, segment_docs: int | None = None,
                  **overrides) -> "SegmentedIndex":
        """Build a segmented index over `docs`, `segment_docs` documents
        per segment (default `options.segment_docs`, else one segment —
        the monolithic layout, still servable through the same surface).
        Document i gets global doc id i, exactly like the monolithic
        `SuffixArrayIndex.from_docs` numbering.

        The requested layout is produced EXACTLY — no compaction runs
        here, so tests can pin per-segment structure. Compaction kicks in
        on `add_docs` / `delete_doc`, or call `compact()` yourself."""
        opts = options if options is not None else SAOptions()
        if overrides:
            opts = opts.replace(**overrides)
        per = segment_docs if segment_docs is not None else opts.segment_docs
        if per is not None and int(per) < 1:
            raise ValueError(f"segment_docs must be ≥ 1, got {per}")
        per = int(per) if per else max(len(docs), 1)
        self = cls(options=opts, sigma=sigma)
        for at in range(0, len(docs), per):
            self._new_segment(list(docs[at:at + per]),
                              np.arange(at, min(at + per, len(docs)),
                                        dtype=np.int64))
        self._next_doc_id = len(docs)
        return self

    def _new_segment(self, payloads, doc_ids) -> Segment:
        """Build ONE segment over `payloads` — this is the only place
        segment construction happens, so builder-cache traffic counts
        segment builds exactly (the ingest-amortization metric). The
        facade dispatch in `SuffixArrayIndex.from_docs` makes segments
        sparse automatically when `options.sample_rate > 1`."""
        index = SuffixArrayIndex.from_docs(payloads, self.options,
                                           sigma=self._sigma)
        seg = Segment(seg_id=f"seg-{self._next_seg:06d}",
                      doc_ids=np.asarray(doc_ids, np.int64), index=index)
        self._next_seg += 1
        self._segments.append(seg)
        self.dirty.add(seg.seg_id)
        return seg

    def _drop_segment(self, seg: Segment) -> None:
        self._segments.remove(seg)
        self.dirty.discard(seg.seg_id)
        self.dropped.add(seg.seg_id)

    # ----------------------------------------------------------- structure
    @property
    def segments(self) -> tuple[Segment, ...]:
        return tuple(self._segments)

    @property
    def n_segments(self) -> int:
        return len(self._segments)

    @property
    def n(self) -> int:
        """Total encoded chars across segments (equals the monolithic n —
        one separator per document either way)."""
        return sum(s.n for s in self._segments)

    @property
    def n_docs(self) -> int:
        return sum(len(s.doc_ids) for s in self._segments)

    @property
    def doc_ids(self) -> np.ndarray:
        """Every live global doc id, sorted."""
        parts = [s.doc_ids for s in self._segments]
        return (np.sort(np.concatenate(parts)) if parts
                else np.zeros(0, np.int64))

    @property
    def sigma(self) -> int:
        """Global data alphabet: declared, else the max over segments."""
        if self._sigma is not None:
            return self._sigma
        return max((s.index.sigma for s in self._segments), default=0)

    def doc(self, doc_id: int) -> np.ndarray:
        """Raw payload of global document `doc_id` (decoded, unshifted)."""
        seg, local = self._find_doc(doc_id)
        return seg.payloads()[local]

    def _find_doc(self, doc_id: int) -> tuple[Segment, int]:
        for seg in self._segments:
            hit = np.flatnonzero(seg.doc_ids == int(doc_id))
            if len(hit):
                return seg, int(hit[0])
        raise KeyError(f"no document with id {doc_id}")

    # ------------------------------------------------------------- queries
    @property
    def min_pattern_len(self) -> int:
        """Shortest pattern this corpus answers exactly — the per-segment
        sparse rate when `options.sample_rate > 1`, else 0 (no floor)."""
        return self.options.sample_rate if self.options.sample_rate > 1 else 0

    def _encode_pattern(self, pattern) -> np.ndarray:
        """Validate a raw pattern against the *global* alphabet.

        Unlike `SuffixArrayIndex._encode_pattern` the result is NOT
        shifted — each segment has its own separator shift, applied at
        fan-out time. Same strictness rules: values must lie in
        [0, sigma), checked only when the corpus is non-empty; in sparse
        mode (`options.sample_rate > 1`) patterns shorter than the rate
        raise `repro.sparse.PatternTooShortError` here, before any
        segment fan-out."""
        pat = np.asarray(pattern, np.int64).ravel()
        if len(pat):
            if int(pat.min()) < 0:
                raise ValueError("pattern values must be ≥ 0")
            if self.n and int(pat.max()) >= self.sigma:
                raise ValueError(
                    f"pattern value {int(pat.max())} outside the corpus "
                    f"alphabet [0, {self.sigma}) — out-of-alphabet queries "
                    f"are rejected rather than silently counted as 0")
        if len(pat) < self.min_pattern_len:
            from ..sparse import PatternTooShortError
            raise PatternTooShortError(len(pat), self.options.sample_rate)
        return pat

    def _fan_encoded(self, enc) -> list[tuple[Segment, list]]:
        """Per-segment shift application for a list of *raw* (unshifted)
        validated patterns; empty segments are skipped. Pattern values
        past a segment's own data maximum simply never match — the
        separator band is below `seg.index.shift`, so a shifted pattern
        can never alias it."""
        return [(seg, [np.asarray(e, np.int64) + seg.index.shift
                       for e in enc])
                for seg in self._segments if seg.index.n]

    def count_batch(self, patterns) -> np.ndarray:
        """Merged occurrence counts — each segment resolves the batch
        through its own engine (`_counts_encoded`: SA range widths dense,
        the two-level verified plan sparse) and counts add;
        int64[len(patterns)]. The empty pattern counts the total encoded
        length `n`, exactly as monolithic (dense mode only — sparse mode
        rejects it as too short)."""
        enc = [self._encode_pattern(p) for p in patterns]
        counts = np.zeros(len(enc), np.int64)
        for seg, shifted in self._fan_encoded(enc):
            counts += seg.index._counts_encoded(shifted)
        return counts

    def contains_batch(self, patterns) -> np.ndarray:
        return self.count_batch(patterns) > 0

    def locate_batch(self, patterns) -> list:
        """Occurrences in **global document coordinates**: one
        int64[k, 2] array of (doc_id, in-doc offset) rows per pattern,
        sorted lexicographically. A segmented corpus has no global
        encoded text, so there is no encoded-position result to return —
        compare against `SuffixArrayIndex.locate_docs_batch`, which is
        byte-identical for the same documents. Raises `ValueError` on an
        empty pattern (same rule as monolithic locate)."""
        enc = [self._encode_pattern(p) for p in patterns]
        if self.n and any(len(e) == 0 for e in enc):
            raise ValueError("locate of an empty pattern is every position "
                             "in the corpus; enumerate documents instead")
        per: list[list] = [[] for _ in enc]
        for seg, shifted in self._fan_encoded(enc):
            for qi, pos in enumerate(seg.index._positions_encoded(shifted)):
                if len(pos):
                    local, off = seg.index.doc_offset(pos)
                    per[qi].append(np.stack(
                        [seg.doc_ids[local], off], axis=1))
        out = []
        for rows in per:
            if not rows:
                out.append(np.zeros((0, 2), np.int64))
                continue
            allrows = np.concatenate(rows)
            order = np.lexsort((allrows[:, 1], allrows[:, 0]))
            out.append(allrows[order])
        return out

    locate_docs_batch = locate_batch   # monolithic-compatible spelling

    def count(self, pattern) -> int:
        return int(self.count_batch([pattern])[0])

    def contains(self, pattern) -> bool:
        return bool(self.contains_batch([pattern])[0])

    def locate(self, pattern) -> np.ndarray:
        """(doc_id, offset) rows for one pattern — see `locate_batch`."""
        return self.locate_batch([pattern])[0]

    locate_docs = locate               # monolithic-compatible spelling

    def longest_match(self, seq) -> int:
        """Longest substring of ``seq`` occurring anywhere in the corpus —
        same semantics as `SuffixArrayIndex.longest_match`, resolved
        through the per-segment fan-out (each containment probe is one
        merged `contains_batch`). The memorization probe in
        `repro.data.pipeline` runs this against the streaming training
        index."""
        return longest_match_len(self, seq)

    # ------------------------------------------------- serving-tier protocol
    def stage_encoded(self, enc):
        """Serving-tier staging (`repro.serve.SAServer`): begin host→device
        transfer of one per-segment `QueryBatch` per non-empty segment.
        Same double-buffering contract as the monolithic
        `SuffixArrayIndex.stage_encoded` — the transfers ride under the
        in-flight kernel of the previous batch."""
        return (len(enc), [(seg, seg.index.stage_encoded(shifted))
                           for seg, shifted in self._fan_encoded(enc)])

    def ranges_staged(self, work):
        """Execute staged per-segment work items and merge. Returns
        ``(lo, hi)`` where ``lo`` is all-zero and ``hi`` the merged count
        per pattern — the *virtual* merged range [0, count): per-segment
        SA ranks don't compose into global ranks, so only the width
        survives the merge (documented in docs/api.md). Delegating to
        each segment's own `ranges_staged` keeps the fan-out uniform
        across dense and sparse segments — both report exact widths."""
        k, works = work
        counts = np.zeros(k, np.int64)
        for seg, w in works:
            lo, hi = seg.index.ranges_staged(w)
            counts += hi - lo
        return np.zeros(k, np.int64), counts

    # -------------------------------------------------------------- ingest
    def add_docs(self, docs, *, compact: bool = True) -> list[int]:
        """Ingest `docs` as ONE new segment; returns their global doc ids.

        Exactly one segment build per call (asserted via
        `repro.api.build.builder_cache_stats` traffic in
        `tests/api/test_segments.py`); with ``compact=True`` (default)
        size-tiered compaction then runs and may additionally merge —
        amortised, that keeps total builder traffic
        O(ingest · log_fanin n) while bounding query fan-out. Pass
        ``compact=False`` to defer merging (e.g. batch-ingest loops that
        compact once at the end). An empty `docs` is a no-op."""
        docs = list(docs)
        if not docs:
            return []
        ids = np.arange(self._next_doc_id, self._next_doc_id + len(docs),
                        dtype=np.int64)
        self._next_doc_id += len(docs)
        self._new_segment(docs, ids)
        if compact:
            self.compact()
        return ids.tolist()

    def delete_doc(self, doc_id: int, *, compact: bool = True) -> None:
        """Remove one document, rebuilding ONLY its owning segment (zero
        builds when the segment becomes empty — it is simply dropped)."""
        seg, local = self._find_doc(doc_id)
        payloads = seg.payloads()
        keep = [p for j, p in enumerate(payloads) if j != local]
        keep_ids = np.delete(seg.doc_ids, local)
        self._drop_segment(seg)
        if keep:
            self._new_segment(keep, keep_ids)
        if compact:
            self.compact()

    def compact(self) -> int:
        """Size-tiered compaction: whenever `compact_fanin` segments share
        a size tier (sizes within one power of `compact_fanin`), merge
        them into one. Repeats until no tier overflows — merged segments
        promote to higher tiers, so fan-out is bounded by
        O(fanin · log_fanin n). Returns the number of merges performed."""
        merges = 0
        while True:
            tiers: dict[int, list[Segment]] = {}
            for seg in self._segments:
                tiers.setdefault(_tier_of(seg.n, self.compact_fanin),
                                 []).append(seg)
            full = sorted(t for t, ss in tiers.items()
                          if len(ss) >= self.compact_fanin)
            if not full:
                return merges
            victims = tiers[full[0]]
            payloads: list[np.ndarray] = []
            ids: list[np.ndarray] = []
            for seg in victims:
                payloads.extend(seg.payloads())
                ids.append(seg.doc_ids)
                self._drop_segment(seg)
            self._new_segment(payloads, np.concatenate(ids))
            merges += 1

    def __repr__(self) -> str:
        return (f"SegmentedIndex(segments={self.n_segments}, "
                f"docs={self.n_docs}, n={self.n}, "
                f"fanin={self.compact_fanin})")
