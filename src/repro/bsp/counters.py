"""BSP cost accounting (Valiant's W, H, S — paper §4).

The shard_map implementation is instrumented at every collective call site:
one superstep per barrier, with analytic per-superstep h (max words in + max
words out per processor) and w (local work estimate). This reproduces the
paper's cost analysis measurably (EXPERIMENTS C4/C5). The same accounting
doubles as a pure cost model: `repro.bsp.suffix_array.estimate_costs`
replays the driver's superstep schedule for arbitrary (n, p) without
executing anything, and `tests/core/test_bsp.py` asserts that a measured
run and the model agree superstep-for-superstep (SM1 = 11, SM2 = 9 per
round, plus one base gather: S = 20·rounds + 1).
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class BSPCounters:
    supersteps: int = 0
    comm_words: int = 0          # H = Σ_s h_s
    work: int = 0                # W = Σ_s w_s
    log: list = field(default_factory=list)
    enabled: bool = True

    def superstep(self, label: str, *, h: int = 0, w: int = 0) -> None:
        if not self.enabled:
            return
        self.supersteps += 1
        self.comm_words += int(h)
        self.work += int(w)
        self.log.append({"label": label, "h": int(h), "w": int(w)})

    def local(self, label: str, *, w: int) -> None:
        """Local-only computation phase (no barrier, merged into next step)."""
        if not self.enabled:
            return
        self.work += int(w)
        if self.log:
            self.log[-1]["w_post"] = self.log[-1].get("w_post", 0) + int(w)

    @property
    def rounds(self) -> int:
        """Completed distributed SM1/SM2 rounds (recursion levels that ran
        on the mesh, excluding the sequential base)."""
        return sum(1 for e in self.log if e["label"] == "SM1/halo")

    def summary(self) -> dict:
        return {"S": self.supersteps, "H": self.comm_words, "W": self.work,
                "rounds": self.rounds}


NULL_COUNTERS = BSPCounters(enabled=False)
