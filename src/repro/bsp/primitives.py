"""Shard-local vectorised primitives used by the BSP suffix-array pipeline.

Everything here runs *inside* shard_map on fixed-shape int32 arrays with
validity masks (BSP processors hold equal-size blocks; ragged reality is
expressed with masks, never dynamic shapes).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

INT32_MAX = jnp.iinfo(jnp.int32).max


def compact_valid(rows: jnp.ndarray, valid: jnp.ndarray):
    """Stable-move valid rows to the front. rows [m, W], valid bool[m]."""
    m = rows.shape[0]
    order = jnp.argsort(~valid, stable=True)
    return rows[order], valid[order], order


def within_group_index(group: jnp.ndarray, valid: jnp.ndarray):
    """For each element, its index among *valid* elements with the same
    `group` value (order = original position). Invalid elements get 0.

    Vectorised via sort + run-start cummax. Returns int32[m].
    """
    m = group.shape[0]
    idx = jnp.arange(m, dtype=jnp.int32)
    big = jnp.where(valid, group.astype(jnp.int32), INT32_MAX)
    order = jnp.argsort(big, stable=True)            # valid groups first
    g_sorted = big[order]
    pos = jnp.arange(m, dtype=jnp.int32)
    boundary = jnp.ones(m, dtype=bool)
    if m > 1:
        boundary = boundary.at[1:].set(g_sorted[1:] != g_sorted[:-1])
    run_start = jax.lax.cummax(jnp.where(boundary, pos, 0))
    within_sorted = pos - run_start
    out = jnp.zeros(m, dtype=jnp.int32).at[order].set(within_sorted)
    return jnp.where(valid, out, 0)


def counts_per_bucket(dest: jnp.ndarray, valid: jnp.ndarray, p: int):
    """Histogram of dest (∈[0,p)) over valid rows → int32[p].

    One-hot matmul formulation (MXU-friendly; see kernels/radix_hist)."""
    oh = (dest[:, None] == jnp.arange(p, dtype=dest.dtype)[None, :]) & valid[:, None]
    return jnp.sum(oh.astype(jnp.int32), axis=0)


def lex_lt_rows(a: jnp.ndarray, b: jnp.ndarray):
    """Row-wise lexicographic a < b for int rows [N, W]; ties → False."""
    neq = a != b
    any_neq = jnp.any(neq, axis=-1)
    first = jnp.argmax(neq, axis=-1)
    a_star = jnp.take_along_axis(a, first[:, None], axis=-1)[:, 0]
    b_star = jnp.take_along_axis(b, first[:, None], axis=-1)[:, 0]
    return jnp.where(any_neq, a_star < b_star, False)


def searchsorted_rows(splitters: jnp.ndarray, rows: jnp.ndarray, lt_fn=None):
    """dest[i] = #{s : splitter_s < row_i} for row-valued splitters.

    splitters [q, W] must be sorted by the same order. Vectorised binary
    search, ⌈log2 q⌉ iterations. `lt_fn(a_rows, b_rows)` defaults to
    lexicographic on int columns. Returns int32[m] in [0, q].
    """
    if lt_fn is None:
        lt_fn = lex_lt_rows
    q = splitters.shape[0]
    m = rows.shape[0]
    lo = jnp.zeros(m, dtype=jnp.int32)
    hi = jnp.full(m, q, dtype=jnp.int32)
    steps = max(1, int(math.ceil(math.log2(max(q, 2)))) + 1)
    for _ in range(steps):
        mid = (lo + hi) // 2
        mid_c = jnp.clip(mid, 0, q - 1)
        s = splitters[mid_c]
        # splitter[mid] < row  → answer is right of mid
        go_right = lt_fn(s, rows) & (mid < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, jnp.maximum(mid, lo))
    return lo


def local_sort_rows(rows: jnp.ndarray, valid: jnp.ndarray, num_keys: int):
    """Sort rows (int32[m, W]) lexicographically by first num_keys cols,
    invalid rows last; stable by trailing columns left intact via index key.
    Returns (rows_sorted, valid_sorted)."""
    m = rows.shape[0]
    pad_flag = (~valid).astype(jnp.int32)
    operands = (pad_flag,) + tuple(rows[:, c] for c in range(num_keys)) + (
        jnp.arange(m, dtype=jnp.int32),)
    out = jax.lax.sort(operands, num_keys=num_keys + 2)
    perm = out[-1]
    return rows[perm], valid[perm]
