"""Deterministic ragged row exchange over dense all_to_all (two hops).

XLA:CPU cannot lower `ragged-all-to-all` (and real-TPU deployments may prefer
static shapes anyway), so we emulate the paper's "send each element to its
bucket's processor" h-relation with two dense all_to_all hops and
*per-destination round-robin* intermediate placement:

  hop 1: row r — the i-th valid row of this shard destined to shard d — is
         sent to intermediate shard q = i mod p. Per-(src,q) traffic is
         ≤ Σ_d ⌈n_{s,d}/p⌉ ≤ m/p + p rows: cap1 = ⌈m/p⌉ + p.
  hop 2: intermediate q forwards to d; per-(q,d) traffic is
         Σ_s ⌈n_{s,d}/p⌉ ≤ total_d/p + p ≤ cap_out/p + p rows.

Both caps are *deterministic* (adversarial-input safe), so total per-shard
communication is O(m + p²) words per exchange — the paper's O(n/p) given the
slackness n ≥ p³ (§5, Algorithm 2). Exactly 2 supersteps: the overflow flag
is computed *locally* (no extra collective), so the superstep count per
exchange really is 2 and `BSPCounters` accounting matches execution.

Overflow contract
-----------------
`exchange` returns a shard-local `overflowed` flag covering every way a cap
can be exceeded (hop-1 slots, hop-2 slots, cap_out arrivals). The flag is a
**bug detector, not a runtime condition**: every call site's cap is sound
by construction, so a set flag means the caller's bound is wrong. All call
sites gather the flag across shards (out_specs P(axis)) and raise
RuntimeError — see `repro.bsp.suffix_array._check_overflow` and
`repro.bsp.psort.run_psort`. The audit of the four call sites:

  psort bucket exchange   cap_out = 2m + 2p + 4  (regular-sampling bound:
                          p(p+1) samples ⇒ every bucket < 2·m_tot/p + slack)
  psort rebalance         cap_out = m            (shard d receives exactly
                          the rows with gpos ∈ [d·m, (d+1)·m))
  SM1 rank routing        cap_out = m_loc        (block-major index j is a
                          bijection onto [0, p·m_loc))
  SM2 rank un-routing     cap_out = m_loc        (each shard owns exactly
                          m_loc sample positions)

`impl="ragged"` plugs in jax.lax.ragged_all_to_all on backends that support
it (TPU); semantics and caps are identical.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .primitives import within_group_index

INT32_MAX = jnp.iinfo(jnp.int32).max


def hop_caps(m: int, p: int, cap_out: int) -> tuple[int, int]:
    cap1 = -(-m // p) + p
    cap2 = -(-cap_out // p) + p
    return cap1, cap2


def exchange(
    rows: jnp.ndarray,       # int32[m, W] (local)
    dest: jnp.ndarray,       # int32[m] ∈ [0, p)
    valid: jnp.ndarray,      # bool[m]
    *,
    p: int,
    cap_out: int,
    axis: str,
):
    """Route valid rows to their dest shards.

    Returns (out_rows int32[cap_out, W], out_valid bool[cap_out],
    overflowed bool[]) — rows arrive grouped by source shard then round-robin
    order; callers re-sort locally. `overflowed` is this shard's local OR
    that any capacity was exceeded; callers MUST return it through
    out_specs P(axis) and raise on `any()` (see the module docstring —
    a set flag is a caller bug, never a recoverable condition).
    """
    m, W = rows.shape
    cap1, cap2 = hop_caps(m, p, cap_out)

    # ---- hop 1: per-destination round robin ----
    i_d = within_group_index(dest, valid)
    inter = jnp.where(valid, i_d % p, p)                 # p → dropped
    slot1 = within_group_index(inter, valid)
    over1 = jnp.any(valid & (slot1 >= cap1))
    buf1 = jnp.full((p, cap1, W + 1), -1, dtype=jnp.int32)
    payload1 = jnp.concatenate([dest[:, None].astype(jnp.int32), rows], axis=1)
    buf1 = buf1.at[inter, slot1].set(payload1, mode="drop")
    recv1 = jax.lax.all_to_all(buf1, axis, split_axis=0, concat_axis=0,
                               tiled=False)
    flat1 = recv1.reshape(p * cap1, W + 1)
    dest2 = flat1[:, 0]
    valid2 = dest2 >= 0

    # ---- hop 2: forward to true destination ----
    slot2 = within_group_index(dest2, valid2)
    over2 = jnp.any(valid2 & (slot2 >= cap2))
    d2 = jnp.where(valid2, dest2, p)
    buf2 = jnp.full((p, cap2, W + 1), -1, dtype=jnp.int32)
    buf2 = buf2.at[d2, slot2].set(flat1, mode="drop")
    recv2 = jax.lax.all_to_all(buf2, axis, split_axis=0, concat_axis=0,
                               tiled=False)
    flat2 = recv2.reshape(p * cap2, W + 1)
    got = flat2[:, 0] >= 0

    # compact to cap_out
    order = jnp.argsort(~got, stable=True)
    out = flat2[order][:cap_out, 1:]
    out_valid = got[order][:cap_out]
    over3 = jnp.sum(got.astype(jnp.int32)) > cap_out
    overflowed = over1 | over2 | over3
    return out, out_valid, overflowed
