"""Algorithm 3 — BSP parallel suffix array construction by accelerated
sampling, on a 1-D shard_map mesh.

Round structure (per recursion level i, modulus v = v_i, cover D = D_i):

  SM1  (11 supersteps): char halo → sample super-character windows →
       Algorithm-2 psort (key mode) → global dense rank (+ all-distinct
       flag) → route ranks to block-major X' layout.
  rec  : recurse on X' with v' = min(⌈v^{5/4}⌉, ⌈v²/|D|⌉−1, |X'|); base case
       (|X'| ≤ threshold ≈ n/p) gathers X' and solves with the single-device
       DC-v (the paper's "send to processor 0").
  SM2  (9 supersteps): route sample ranks back to position owners → rank/char
       halos → build self-contained Lemma-1 payloads → Algorithm-2 psort
       (the fused Steps 2–4, DESIGN §3.3) → SA.

The shard-local sorts inside both psorts are pluggable (`sort_impl`,
resolved by `repro.bsp.psort.resolve_bsp_sort_impl`): the default "radix"
path packs the SM1 super-character windows AND the SM2 Lemma-1 payload
characters into 30-bit int32 key lanes and key-sorts them with ONE variadic
lax.sort per call (Lemma-1 comparisons only run on equal-window runs, via a
cond-gated bitonic pass); "lax" is the same two-phase sort on unpacked
columns; "bitonic" is the legacy full comparator network, kept as the
regression row of `benchmarks/bsp_throughput.py`.

All shapes are data-independent functions of (n, p, schedule): the index
domain is padded to n_pv = p·v·⌈n/(p·v)⌉ so every shard holds n_loc = n_pv/p
characters (a multiple of v) and exactly m_loc = |D|·n_loc/v sample windows.
Sentinel-pad suffixes sort first and are trimmed at the end.

Superstep accounting: the counts logged by `BSPCounters` (SM1 = 11, SM2 = 9
per round — `_round_cost`) match the collectives the code executes barrier
for barrier: SM1 = halo ppermute + 6 psort collectives + boundary ppermute
+ rank-offset all_gather + 2 routing all_to_alls; SM2 = 2 un-routing
all_to_alls + halo ppermute + 6 psort collectives. Diagnostic flags
(overflow, all-distinct) are computed shard-locally and gathered through
the stage outputs, so they add no barriers. `estimate_costs` replays the
same schedule analytically for arbitrary (n, p).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.compat import shard_map
from ..core.difference_cover import cover_tables
from ..core.dcv_jax import suffix_array_jax
from ..core.seq_ref import accelerated_next_v
from .counters import BSPCounters, NULL_COUNTERS
from .exchange import exchange
from .psort import (make_local_sort_bitonic, make_local_sort_keyed,
                    make_pad_rows, make_payload_lt, pack_key_columns,
                    packed_width, psort_shard_body, quantize_sigma,
                    resolve_bsp_sort_impl)

INT32_MAX = np.int32(np.iinfo(np.int32).max)


# --------------------------------------------------------------------------
# round geometry
# --------------------------------------------------------------------------
def round_geometry(n: int, p: int, v: int):
    n_pv = p * v * math.ceil(n / (p * v))
    n_loc = n_pv // p
    tabs = cover_tables(v)
    dsize = len(tabs.D)
    m_loc = dsize * n_loc // v          # samples per shard == X' elems/shard
    m_tot = m_loc * p
    return n_pv, n_loc, m_loc, m_tot, tabs


# --------------------------------------------------------------------------
# SM1: sample sort + X' construction
# --------------------------------------------------------------------------
def pack_window_columns(win: jnp.ndarray, sigma: int):
    """Radix key packing for SM1 windows (§Perf SA-iteration A): characters
    are shifted +1 so the -1 sentinel packs as 0, then packed into 30-bit
    int32 lanes by `repro.bsp.psort.pack_key_columns` (order-preserving,
    injective). Cuts the sort/exchange width from v to ⌈v·bits/30⌉ lanes."""
    return pack_key_columns(win, -1, sigma)


def _sm1_body(xloc, *, p, v, n_loc, m_loc, tabs, axis, sigma=None):
    dsize = len(tabs.D)
    me = jax.lax.axis_index(axis)

    # --- char halo: first v chars of next shard (last shard: sentinels) ---
    halo = jax.lax.ppermute(xloc[:v], axis, [(s, s - 1) for s in range(1, p)])
    halo = jnp.where(me == p - 1, jnp.full((v,), -1, jnp.int32), halo)
    xp = jnp.concatenate([xloc, halo])                      # [n_loc + v]

    # --- sample windows (block-local positions ≡ k (mod v), k ∈ D) ---
    D = jnp.asarray(tabs.D, jnp.int32)
    off = (D[:, None] + jnp.arange(n_loc // v, dtype=jnp.int32)[None, :] * v
           ).reshape(-1)                                    # [m_loc] local pos
    gpos = me.astype(jnp.int32) * n_loc + off
    win = xp[off[:, None] + jnp.arange(v, dtype=jnp.int32)[None, :]]
    if sigma is not None:
        win = pack_window_columns(win, sigma)
    w = win.shape[1]                       # packed key width ≤ v
    rows = jnp.concatenate([
        jnp.zeros((m_loc, 1), jnp.int32), win, gpos[:, None]], axis=1)

    # --- Algorithm 2 (key mode) ---
    rows, over = psort_shard_body(rows, p=p, axis=axis)

    # --- global dense rank of windows + distinct flag ---
    keys = rows[:, 1:1 + w]
    prev_last = jax.lax.ppermute(keys[-1:], axis,
                                 [(s, s + 1) for s in range(p - 1)])
    first_b = jnp.where(me == 0, True, jnp.any(keys[0] != prev_last[0]))
    b = jnp.ones(m_loc, dtype=jnp.int32)
    b = b.at[0].set(first_b.astype(jnp.int32))
    if m_loc > 1:
        b = b.at[1:].set(jnp.any(keys[1:] != keys[:-1], axis=1).astype(jnp.int32))
    loc_sum = jnp.sum(b)
    sums = jax.lax.all_gather(loc_sum[None], axis).reshape(p)
    offset = (jnp.cumsum(sums) - sums)[me]
    rank = offset + jnp.cumsum(b) - 1                       # dense global rank
    # shard-local "every window here started a run"; the driver ANDs the
    # per-shard flags — no pmin barrier needed.
    distinct = jnp.min(b) == 1

    # --- route (j, rank) to X' owners; j = block-major sample index ---
    d_idx = np.full(v, -1, np.int32)
    for a_i, dd in enumerate(tabs.D):
        d_idx[dd] = a_i
    d_idx = jnp.asarray(d_idx)
    g = rows[:, 1 + w]                                      # gpos
    j = d_idx[g % v] * ((n_loc // v) * p) + g // v
    rows2 = jnp.concatenate([
        jnp.zeros((m_loc, 1), jnp.int32), rank[:, None].astype(jnp.int32),
        j[:, None]], axis=1)
    dest = jnp.clip(j // m_loc, 0, p - 1)
    got, got_valid, over2 = exchange(
        rows2, dest, jnp.ones(m_loc, bool), p=p, cap_out=m_loc, axis=axis)
    xprime = jnp.zeros(m_loc, jnp.int32).at[
        jnp.where(got_valid, got[:, 2] % m_loc, m_loc)
    ].set(got[:, 1], mode="drop")
    return xprime, distinct[None], (over | over2)[None]


# --------------------------------------------------------------------------
# SM2: rank scatter + fused Lemma-1 payload sort
# --------------------------------------------------------------------------
def _sm2_body(xloc, sa_rank_loc, *, p, v, n_loc, m_loc, tabs, axis,
              impl="bitonic", sigma=None):
    dsize = len(tabs.D)
    me = jax.lax.axis_index(axis)
    D_np = np.asarray(tabs.D, np.int32)
    per_block = (n_loc // v) * p                            # block length in X'

    # --- route sample ranks back to position owners ---
    jloc = me.astype(jnp.int32) * m_loc + jnp.arange(m_loc, dtype=jnp.int32)
    blk = jloc // per_block                                  # index into D
    pos = jnp.asarray(D_np)[jnp.clip(blk, 0, dsize - 1)] + (jloc % per_block) * v
    rows = jnp.concatenate([
        jnp.zeros((m_loc, 1), jnp.int32),
        sa_rank_loc[:, None].astype(jnp.int32), pos[:, None]], axis=1)
    dest = jnp.clip(pos // n_loc, 0, p - 1)
    got, got_valid, over = exchange(
        rows, dest, jnp.ones(m_loc, bool), p=p, cap_out=m_loc, axis=axis)

    rank_loc = jnp.full(n_loc + v, -1, jnp.int32).at[
        jnp.where(got_valid, got[:, 2] % n_loc, n_loc + v)
    ].set(got[:, 1], mode="drop")

    # --- halos: rank (v) and chars (v) from next shard ---
    fwd = jnp.concatenate([rank_loc[:v], xloc[:v]])
    halo = jax.lax.ppermute(fwd, axis, [(s, s - 1) for s in range(1, p)])
    halo = jnp.where(me == p - 1, jnp.full((2 * v,), -1, jnp.int32), halo)
    rank_loc = rank_loc.at[n_loc:].set(halo[:v])
    xp = jnp.concatenate([xloc, halo[v:]])                   # [n_loc + v]

    # --- Lemma-1 payloads for ALL local suffixes ---
    offs = jnp.arange(n_loc, dtype=jnp.int32)
    gidx = me.astype(jnp.int32) * n_loc + offs
    chars = xp[offs[:, None] + jnp.arange(v, dtype=jnp.int32)[None, :]]
    klass = gidx % v
    shifts = jnp.asarray(tabs.shifts, jnp.int32)             # [v, |D|]
    rvals = rank_loc[jnp.clip(offs[:, None] + shifts[klass], 0, n_loc + v - 1)]

    lam_i1 = jnp.asarray(tabs.lam_idx1, jnp.int32)
    lam_i2 = jnp.asarray(tabs.lam_idx2, jnp.int32)
    if impl == "bitonic":
        # legacy: the Lemma-1 comparator at every compare-exchange of the
        # local bitonic network, raw characters as the head.
        payload = jnp.concatenate([
            jnp.zeros((n_loc, 1), jnp.int32), chars, rvals,
            klass[:, None], gidx[:, None]], axis=1)
        lt = make_payload_lt(v, v, dsize, lam_i1, lam_i2)
        out, over2 = psort_shard_body(
            payload, p=p, axis=axis, lt_fn=lt,
            local_sort=make_local_sort_bitonic(lt))
        sa = out[:, 2 + v + dsize]                           # gidx column
    else:
        # keyed: pack ("radix") or keep raw ("lax") the character head,
        # key-sort it, and resolve equal-window runs with the cond-gated
        # Lemma-1 pass — see repro.bsp.psort.make_local_sort_keyed.
        keys = pack_key_columns(chars, -1, sigma) if sigma is not None else chars
        nk = keys.shape[1]
        payload = jnp.concatenate([
            jnp.zeros((n_loc, 1), jnp.int32), keys, rvals,
            klass[:, None], gidx[:, None]], axis=1)
        lt = make_payload_lt(nk, v, dsize, lam_i1, lam_i2)
        out, over2 = psort_shard_body(
            payload, p=p, axis=axis, lt_fn=lt,
            local_sort=make_local_sort_keyed(nk, v, dsize, lam_i1, lam_i2))
        sa = out[:, 2 + nk + dsize]                          # gidx column
    return sa, (over | over2)[None]


# --------------------------------------------------------------------------
# jitted stage wrappers
# --------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("p", "v", "n_loc", "m_loc", "vkey", "axis",
                              "mesh_holder", "sigma"))
def _sm1(xg, *, p, v, n_loc, m_loc, vkey, axis, mesh_holder, sigma=None):
    mesh = mesh_holder.mesh
    tabs = cover_tables(v)
    body = functools.partial(_sm1_body, p=p, v=v, n_loc=n_loc, m_loc=m_loc,
                             tabs=tabs, axis=axis, sigma=sigma)
    return shard_map(
        body, mesh=mesh, in_specs=(P(axis),),
        out_specs=(P(axis), P(axis), P(axis)))(xg)


@functools.partial(
    jax.jit, static_argnames=("p", "v", "n_loc", "m_loc", "vkey", "axis",
                              "mesh_holder", "impl", "sigma"))
def _sm2(xg, sa_rank, *, p, v, n_loc, m_loc, vkey, axis, mesh_holder,
         impl="bitonic", sigma=None):
    mesh = mesh_holder.mesh
    tabs = cover_tables(v)
    body = functools.partial(_sm2_body, p=p, v=v, n_loc=n_loc, m_loc=m_loc,
                             tabs=tabs, axis=axis, impl=impl, sigma=sigma)
    return shard_map(
        body, mesh=mesh, in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis)))(xg, sa_rank)


class _MeshHolder:
    """Hashable wrapper so a Mesh can be a static jit arg."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh

    def __hash__(self):
        return hash(tuple(d.id for d in self.mesh.devices.flat)
                    + tuple(self.mesh.shape.items()))

    def __eq__(self, other):
        return isinstance(other, _MeshHolder) and hash(self) == hash(other)


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------
def _round_cost(label, n_loc, m_loc, p, v, dsize, W, counters):
    """Analytic per-superstep BSP costs for one SM stage (C4/C5)."""
    lb = int(math.ceil(math.log2(max(m_loc * 4, 2))))
    psort = [
        ("psort/sample_gather", p * (p + 1) * W, m_loc * W * lb),
        ("psort/a2a_hop1", m_loc * W, m_loc * W),
        ("psort/a2a_hop2", 2 * m_loc * W, m_loc * W),
        ("psort/count_gather", p, 2 * m_loc * W * lb),
        ("psort/rebal_hop1", 2 * m_loc * W, m_loc * W),
        ("psort/rebal_hop2", m_loc * W, m_loc * W * lb),
    ]
    if label == "SM1":
        steps = ([("halo", v, n_loc)] + psort
                 + [("rank/boundary", W, m_loc * W), ("rank/scan", p, m_loc),
                    ("route/a2a_hop1", 3 * m_loc, m_loc),
                    ("route/a2a_hop2", 3 * m_loc, m_loc)])
    else:
        steps = ([("unroute/a2a_hop1", 3 * m_loc, m_loc),
                  ("unroute/a2a_hop2", 3 * m_loc, m_loc),
                  ("halo", 2 * v, n_loc)] + psort)
    for name, h, w in steps:
        counters.superstep(f"{label}/{name}", h=h, w=w)


def _check_overflow(over, stage: str) -> None:
    """Turn a gathered per-shard overflow flag into a hard error."""
    if bool(np.asarray(over).any()):
        raise RuntimeError(
            f"BSP exchange capacity overflow in {stage}: the deterministic "
            f"two-hop caps were exceeded — a bug in the caller's cap_out "
            f"bound (see repro.bsp.exchange), never an input-data error")


def _sm_widths(v: int, sigma: int, impl: str, pack_keys: bool):
    """(SM1 sigma-or-None, SM1 key lanes, SM2 sigma-or-None, SM2 key lanes).

    "radix" packs both stages; "lax" packs neither; "bitonic" keeps the
    legacy behaviour (SM1 packing per `pack_keys`, SM2 raw characters)."""
    sm1_sigma = sigma if (impl == "radix"
                          or (impl == "bitonic" and pack_keys)) else None
    w1 = packed_width(v, -1, sigma) if sm1_sigma is not None else v
    sm2_sigma = sigma if impl == "radix" else None
    nk2 = packed_width(v, -1, sigma) if sm2_sigma is not None else v
    return sm1_sigma, w1, sm2_sigma, nk2


def suffix_array_bsp(
    x,
    mesh: Mesh,
    axis: str = "bsp",
    v: int = 3,
    schedule=accelerated_next_v,
    base_threshold: int | None = None,
    counters: BSPCounters = NULL_COUNTERS,
    pack_keys: bool = True,
    sort_impl: str = "auto",
    _n0: int | None = None,
) -> np.ndarray:
    """Distributed suffix array of x over a 1-D mesh. Returns np.int32[n].

    `sort_impl` selects the shard-local sort family inside both Algorithm-2
    psorts ("auto" → packed-key "radix"; see `repro.bsp.psort`)."""
    x = np.asarray(x)
    n = int(len(x))
    p = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    impl = resolve_bsp_sort_impl(sort_impl, pack_keys)
    if p == 1:
        # degenerate mesh: Algorithm 2's splitter machinery needs p ≥ 2;
        # a 1-processor BSP run IS the single-device algorithm.
        counters.superstep("base/gather", h=n, w=n * 4)
        return suffix_array_jax(
            x, v=max(v, 3), schedule=schedule,
            base_threshold=base_threshold or 256).astype(np.int32)
    n0 = _n0 or n
    if base_threshold is None:
        base_threshold = max(1024, n0 // p)
    holder = _MeshHolder(mesh)
    shard = NamedSharding(mesh, P(axis))

    def rec(x_np: np.ndarray, v: int) -> np.ndarray:
        n = len(x_np)
        if n <= max(base_threshold, 2 * p * v, 8):
            # paper: |X'| ≤ n/p → ship to one processor, solve sequentially.
            counters.superstep("base/gather", h=n, w=n * 4)
            return suffix_array_jax(x_np, v=3)
        v = int(min(max(v, 3), n))
        n_pv, n_loc, m_loc, m_tot, tabs = round_geometry(n, p, v)
        dsize = len(tabs.D)
        xp_np = np.full(n_pv, -1, dtype=np.int32)
        xp_np[:n] = x_np
        xg = jax.device_put(jnp.asarray(xp_np), shard)

        # quantized so the data-dependent max collapses onto O(log σ)
        # distinct static-arg values (same packed bit width, no retrace)
        sigma = quantize_sigma(int(x_np.max()) + 1)
        sm1_sigma, w1, sm2_sigma, nk2 = _sm_widths(v, sigma, impl, pack_keys)
        xprime, distinct, over = _sm1(
            xg, p=p, v=v, n_loc=n_loc, m_loc=m_loc, vkey=v, axis=axis,
            mesh_holder=holder, sigma=sm1_sigma)
        _round_cost("SM1", n_loc, m_loc, p, v, dsize, w1 + 2, counters)
        _check_overflow(over, "SM1")

        # saca-lint: allow[SCHED001] host-uniform by construction: `distinct`
        # is a fully-replicated stage output (per-shard flags gathered via
        # out_specs) and the single host driver ANDs it — every rank follows
        # the same branch, so the recursion depth is globally consistent.
        if bool(np.asarray(distinct).all()):
            sa_rank = xprime                                  # ranks are final
        else:
            v_next = schedule(v, dsize, m_tot)
            sa_sub = rec(np.asarray(xprime).reshape(-1), v_next)
            inv = np.empty(m_tot, dtype=np.int32)
            inv[sa_sub] = np.arange(m_tot, dtype=np.int32)
            sa_rank = jax.device_put(jnp.asarray(inv), shard)

        sa, over = _sm2(xg, sa_rank, p=p, v=v, n_loc=n_loc, m_loc=m_loc,
                        vkey=v, axis=axis, mesh_holder=holder, impl=impl,
                        sigma=sm2_sigma)
        _round_cost("SM2", n_loc, m_loc, p, v, dsize, 3 + nk2 + dsize,
                    counters)
        _check_overflow(over, "SM2")
        sa = np.asarray(sa).reshape(-1)
        return sa[sa < n]                                     # trim pads

    # top-level all-distinct shortcut (recursion base of Algorithm 3)
    if n <= max(base_threshold, 2 * p * 3, 8):
        counters.superstep("base/gather", h=n, w=n * 4)
        return suffix_array_jax(x, v=3).astype(np.int32)
    return rec(x.astype(np.int32), v).astype(np.int32)


# --------------------------------------------------------------------------
# analytic cost model (C4/C5 — "model only" mode)
# --------------------------------------------------------------------------
def estimate_costs(
    n: int,
    p: int,
    *,
    v: int = 3,
    schedule=accelerated_next_v,
    base_threshold: int | None = None,
    sort_impl: str = "auto",
    pack_keys: bool = True,
    sigma: int = 256,
) -> BSPCounters:
    """Replay `suffix_array_bsp`'s superstep schedule without executing it.

    Returns a `BSPCounters` holding the supersteps/communication/work a run
    would log on an input that never triggers the all-distinct recursion
    short-circuit (the worst case — e.g. an all-equal text, for which the
    replay is *exact*: same labels, same S). `sigma` is the level-0
    alphabet bound; deeper levels use the dense-rank bound m_tot, so H/W
    are estimates while S and the label sequence are structural.

    The replay instantiates each level's difference-cover tables
    (`round_geometry`), so call it with realistic (n, p); for asymptotic
    round counting at astronomic sizes use the capped model in
    `benchmarks/supersteps.py`.
    """
    ct = BSPCounters()
    impl = resolve_bsp_sort_impl(sort_impl, pack_keys)
    n = int(n)
    if p == 1:
        ct.superstep("base/gather", h=n, w=n * 4)
        return ct
    if base_threshold is None:
        base_threshold = max(1024, n // p)
    if n <= max(base_threshold, 2 * p * 3, 8):
        ct.superstep("base/gather", h=n, w=n * 4)
        return ct

    def rec(nn: int, vv: int, sig: int) -> None:
        if nn <= max(base_threshold, 2 * p * vv, 8):
            ct.superstep("base/gather", h=nn, w=nn * 4)
            return
        vv = int(min(max(vv, 3), nn))
        n_pv, n_loc, m_loc, m_tot, tabs = round_geometry(nn, p, vv)
        dsize = len(tabs.D)
        _, w1, _, nk2 = _sm_widths(vv, quantize_sigma(sig), impl, pack_keys)
        _round_cost("SM1", n_loc, m_loc, p, vv, dsize, w1 + 2, ct)
        rec(m_tot, schedule(vv, dsize, m_tot), m_tot)
        _round_cost("SM2", n_loc, m_loc, p, vv, dsize, 3 + nk2 + dsize, ct)

    rec(n, max(v, 3), sigma)
    return ct
