"""Algorithm 3 — BSP parallel suffix array construction by accelerated
sampling, on a 1-D shard_map mesh.

Round structure (per recursion level i, modulus v = v_i, cover D = D_i):

  SM1  (11 supersteps): char halo → sample super-character windows →
       Algorithm-2 psort (key mode) → global dense rank (+ all-distinct
       flag) → route ranks to block-major X' layout.
  rec  : recurse on X' with v' = min(⌈v^{5/4}⌉, ⌈v²/|D|⌉−1, |X'|); base case
       (|X'| ≤ threshold ≈ n/p) gathers X' and solves with the single-device
       DC-v (the paper's "send to processor 0").
  SM2  (9 supersteps): route sample ranks back to position owners → rank/char
       halos → build self-contained Lemma-1 payloads → Algorithm-2 psort in
       comparator mode (the fused Steps 2–4, DESIGN §3.3) → SA.

All shapes are data-independent functions of (n, p, schedule): the index
domain is padded to n_pv = p·v·⌈n/(p·v)⌉ so every shard holds n_loc = n_pv/p
characters (a multiple of v) and exactly m_loc = |D|·n_loc/v sample windows.
Sentinel-pad suffixes sort first and are trimmed at the end.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.bitonic import lex_lt_int
from ..core.compat import shard_map
from ..core.difference_cover import cover_tables
from ..core.dcv_jax import suffix_array_jax
from ..core.seq_ref import accelerated_next_v
from .counters import BSPCounters, NULL_COUNTERS
from .exchange import exchange
from .psort import (lex_lt_full, local_sort_lex, make_local_sort_bitonic,
                    make_pad_rows, psort_shard_body)

INT32_MAX = np.int32(np.iinfo(np.int32).max)


# --------------------------------------------------------------------------
# payload comparator (Lemma 1)
# --------------------------------------------------------------------------
def make_payload_lt(v: int, dsize: int, lam_i1, lam_i2):
    """Strict total order on payload rows
    [valid | chars(v) | ranks(|D|) | klass | gidx]."""
    cr = 1 + v
    ck = 1 + v + dsize
    cg = 2 + v + dsize

    def lt(a, b):
        ka = jnp.clip(a[:, ck], 0, v - 1)
        kb = jnp.clip(b[:, ck], 0, v - 1)
        lt_head, eq_head = lex_lt_int(a[:, : 1 + v], b[:, : 1 + v])
        ia = lam_i1[ka, kb]
        ib = lam_i2[ka, kb]
        ra = jnp.take_along_axis(a[:, cr:cr + dsize], ia[:, None], axis=1)[:, 0]
        rb = jnp.take_along_axis(b[:, cr:cr + dsize], ib[:, None], axis=1)[:, 0]
        return jnp.where(
            eq_head & (ra != rb), ra < rb,
            jnp.where(eq_head, a[:, cg] < b[:, cg], lt_head))

    return lt


# --------------------------------------------------------------------------
# round geometry
# --------------------------------------------------------------------------
def round_geometry(n: int, p: int, v: int):
    n_pv = p * v * math.ceil(n / (p * v))
    n_loc = n_pv // p
    tabs = cover_tables(v)
    dsize = len(tabs.D)
    m_loc = dsize * n_loc // v          # samples per shard == X' elems/shard
    m_tot = m_loc * p
    return n_pv, n_loc, m_loc, m_tot, tabs


# --------------------------------------------------------------------------
# SM1: sample sort + X' construction
# --------------------------------------------------------------------------
def pack_window_columns(win: jnp.ndarray, sigma: int):
    """Radix key packing (§Perf SA-iteration A): pack several characters of
    a known alphabet bound σ into each int32 sort column, big-endian, order-
    preserving (fixed-width fields ⇒ lexicographic order is unchanged).
    Characters are shifted +1 so the -1 sentinel packs as 0. Cuts the sort/
    exchange width from v to ⌈v·bits/30⌉ columns."""
    v = win.shape[1]
    bits = max(1, int(math.ceil(math.log2(max(sigma + 2, 2)))))
    per = max(1, 30 // bits)
    if per < 2:
        return win
    shifted = (win + 1).astype(jnp.int32)                  # [m, v] ∈ [0, σ+1]
    ncol = -(-v // per)
    pad = ncol * per - v
    if pad:
        shifted = jnp.concatenate(
            [shifted, jnp.zeros((win.shape[0], pad), jnp.int32)], axis=1)
    shifted = shifted.reshape(win.shape[0], ncol, per)
    weights = jnp.asarray([1 << (bits * (per - 1 - j)) for j in range(per)],
                          jnp.int32)
    return jnp.sum(shifted * weights[None, None, :], axis=-1)


def _sm1_body(xloc, *, p, v, n_loc, m_loc, tabs, axis, sigma=None):
    dsize = len(tabs.D)
    me = jax.lax.axis_index(axis)

    # --- char halo: first v chars of next shard (last shard: sentinels) ---
    halo = jax.lax.ppermute(xloc[:v], axis, [(s, s - 1) for s in range(1, p)])
    halo = jnp.where(me == p - 1, jnp.full((v,), -1, jnp.int32), halo)
    xp = jnp.concatenate([xloc, halo])                      # [n_loc + v]

    # --- sample windows (block-local positions ≡ k (mod v), k ∈ D) ---
    D = jnp.asarray(tabs.D, jnp.int32)
    off = (D[:, None] + jnp.arange(n_loc // v, dtype=jnp.int32)[None, :] * v
           ).reshape(-1)                                    # [m_loc] local pos
    gpos = me.astype(jnp.int32) * n_loc + off
    win = xp[off[:, None] + jnp.arange(v, dtype=jnp.int32)[None, :]]
    if sigma is not None:
        win = pack_window_columns(win, sigma)
    w = win.shape[1]                       # packed key width ≤ v
    rows = jnp.concatenate([
        jnp.zeros((m_loc, 1), jnp.int32), win, gpos[:, None]], axis=1)

    # --- Algorithm 2 (key mode) ---
    rows, over = psort_shard_body(rows, p=p, axis=axis)

    # --- global dense rank of windows + distinct flag ---
    keys = rows[:, 1:1 + w]
    prev_last = jax.lax.ppermute(keys[-1:], axis,
                                 [(s, s + 1) for s in range(p - 1)])
    first_b = jnp.where(me == 0, True, jnp.any(keys[0] != prev_last[0]))
    b = jnp.ones(m_loc, dtype=jnp.int32)
    b = b.at[0].set(first_b.astype(jnp.int32))
    if m_loc > 1:
        b = b.at[1:].set(jnp.any(keys[1:] != keys[:-1], axis=1).astype(jnp.int32))
    loc_sum = jnp.sum(b)
    sums = jax.lax.all_gather(loc_sum[None], axis).reshape(p)
    offset = (jnp.cumsum(sums) - sums)[me]
    rank = offset + jnp.cumsum(b) - 1                       # dense global rank
    distinct = jax.lax.pmin(
        jnp.min(b), axis) == 1                              # all boundaries

    # --- route (j, rank) to X' owners; j = block-major sample index ---
    d_idx = np.full(v, -1, np.int32)
    for a_i, dd in enumerate(tabs.D):
        d_idx[dd] = a_i
    d_idx = jnp.asarray(d_idx)
    g = rows[:, 1 + w]                                      # gpos
    j = d_idx[g % v] * ((n_loc // v) * p) + g // v
    rows2 = jnp.concatenate([
        jnp.zeros((m_loc, 1), jnp.int32), rank[:, None].astype(jnp.int32),
        j[:, None]], axis=1)
    dest = jnp.clip(j // m_loc, 0, p - 1)
    got, got_valid, over2 = exchange(
        rows2, dest, jnp.ones(m_loc, bool), p=p, cap_out=m_loc, axis=axis)
    xprime = jnp.zeros(m_loc, jnp.int32).at[
        jnp.where(got_valid, got[:, 2] % m_loc, m_loc)
    ].set(got[:, 1], mode="drop")
    return xprime, distinct[None], (over | over2)[None]


# --------------------------------------------------------------------------
# SM2: rank scatter + fused Lemma-1 payload sort
# --------------------------------------------------------------------------
def _sm2_body(xloc, sa_rank_loc, *, p, v, n_loc, m_loc, tabs, axis):
    dsize = len(tabs.D)
    me = jax.lax.axis_index(axis)
    D_np = np.asarray(tabs.D, np.int32)
    per_block = (n_loc // v) * p                            # block length in X'

    # --- route sample ranks back to position owners ---
    jloc = me.astype(jnp.int32) * m_loc + jnp.arange(m_loc, dtype=jnp.int32)
    blk = jloc // per_block                                  # index into D
    pos = jnp.asarray(D_np)[jnp.clip(blk, 0, dsize - 1)] + (jloc % per_block) * v
    rows = jnp.concatenate([
        jnp.zeros((m_loc, 1), jnp.int32),
        sa_rank_loc[:, None].astype(jnp.int32), pos[:, None]], axis=1)
    dest = jnp.clip(pos // n_loc, 0, p - 1)
    got, got_valid, over = exchange(
        rows, dest, jnp.ones(m_loc, bool), p=p, cap_out=m_loc, axis=axis)

    rank_loc = jnp.full(n_loc + v, -1, jnp.int32).at[
        jnp.where(got_valid, got[:, 2] % n_loc, n_loc + v)
    ].set(got[:, 1], mode="drop")

    # --- halos: rank (v) and chars (v) from next shard ---
    fwd = jnp.concatenate([rank_loc[:v], xloc[:v]])
    halo = jax.lax.ppermute(fwd, axis, [(s, s - 1) for s in range(1, p)])
    halo = jnp.where(me == p - 1, jnp.full((2 * v,), -1, jnp.int32), halo)
    rank_loc = rank_loc.at[n_loc:].set(halo[:v])
    xp = jnp.concatenate([xloc, halo[v:]])                   # [n_loc + v]

    # --- Lemma-1 payloads for ALL local suffixes ---
    offs = jnp.arange(n_loc, dtype=jnp.int32)
    gidx = me.astype(jnp.int32) * n_loc + offs
    chars = xp[offs[:, None] + jnp.arange(v, dtype=jnp.int32)[None, :]]
    klass = gidx % v
    shifts = jnp.asarray(tabs.shifts, jnp.int32)             # [v, |D|]
    rvals = rank_loc[jnp.clip(offs[:, None] + shifts[klass], 0, n_loc + v - 1)]
    payload = jnp.concatenate([
        jnp.zeros((n_loc, 1), jnp.int32), chars, rvals,
        klass[:, None], gidx[:, None]], axis=1)

    lam_i1 = jnp.asarray(tabs.lam_idx1, jnp.int32)
    lam_i2 = jnp.asarray(tabs.lam_idx2, jnp.int32)
    lt = make_payload_lt(v, dsize, lam_i1, lam_i2)
    out, over2 = psort_shard_body(
        payload, p=p, axis=axis, lt_fn=lt,
        local_sort=make_local_sort_bitonic(lt))
    sa = out[:, 2 + v + dsize]                               # gidx column
    return sa, (over | over2)[None]


# --------------------------------------------------------------------------
# jitted stage wrappers
# --------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("p", "v", "n_loc", "m_loc", "vkey", "axis",
                              "mesh_holder", "sigma"))
def _sm1(xg, *, p, v, n_loc, m_loc, vkey, axis, mesh_holder, sigma=None):
    mesh = mesh_holder.mesh
    tabs = cover_tables(v)
    body = functools.partial(_sm1_body, p=p, v=v, n_loc=n_loc, m_loc=m_loc,
                             tabs=tabs, axis=axis, sigma=sigma)
    return shard_map(
        body, mesh=mesh, in_specs=(P(axis),),
        out_specs=(P(axis), P(axis), P(axis)))(xg)


@functools.partial(
    jax.jit, static_argnames=("p", "v", "n_loc", "m_loc", "vkey", "axis",
                              "mesh_holder"))
def _sm2(xg, sa_rank, *, p, v, n_loc, m_loc, vkey, axis, mesh_holder):
    mesh = mesh_holder.mesh
    tabs = cover_tables(v)
    body = functools.partial(_sm2_body, p=p, v=v, n_loc=n_loc, m_loc=m_loc,
                             tabs=tabs, axis=axis)
    return shard_map(
        body, mesh=mesh, in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis)))(xg, sa_rank)


class _MeshHolder:
    """Hashable wrapper so a Mesh can be a static jit arg."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh

    def __hash__(self):
        return hash(tuple(d.id for d in self.mesh.devices.flat)
                    + tuple(self.mesh.shape.items()))

    def __eq__(self, other):
        return isinstance(other, _MeshHolder) and hash(self) == hash(other)


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------
def _round_cost(label, n_loc, m_loc, p, v, dsize, W, counters):
    """Analytic per-superstep BSP costs for one SM stage (C4/C5)."""
    lb = int(math.ceil(math.log2(max(m_loc * 4, 2))))
    psort = [
        ("psort/sample_gather", p * (p + 1) * W, m_loc * W * lb),
        ("psort/a2a_hop1", m_loc * W, m_loc * W),
        ("psort/a2a_hop2", 2 * m_loc * W, m_loc * W),
        ("psort/count_gather", p, 2 * m_loc * W * lb),
        ("psort/rebal_hop1", 2 * m_loc * W, m_loc * W),
        ("psort/rebal_hop2", m_loc * W, m_loc * W * lb),
    ]
    if label == "SM1":
        steps = ([("halo", v, n_loc)] + psort
                 + [("rank/boundary", W, m_loc * W), ("rank/scan", p, m_loc),
                    ("route/a2a_hop1", 3 * m_loc, m_loc),
                    ("route/a2a_hop2", 3 * m_loc, m_loc)])
    else:
        steps = ([("unroute/a2a_hop1", 3 * m_loc, m_loc),
                  ("unroute/a2a_hop2", 3 * m_loc, m_loc),
                  ("halo", 2 * v, n_loc)] + psort)
    for name, h, w in steps:
        counters.superstep(f"{label}/{name}", h=h, w=w)


def suffix_array_bsp(
    x,
    mesh: Mesh,
    axis: str = "bsp",
    v: int = 3,
    schedule=accelerated_next_v,
    base_threshold: int | None = None,
    counters: BSPCounters = NULL_COUNTERS,
    pack_keys: bool = True,
    _n0: int | None = None,
) -> np.ndarray:
    """Distributed suffix array of x over a 1-D mesh. Returns np.int32[n]."""
    x = np.asarray(x)
    n = int(len(x))
    p = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    if p == 1:
        # degenerate mesh: Algorithm 2's splitter machinery needs p ≥ 2;
        # a 1-processor BSP run IS the single-device algorithm.
        counters.superstep("base/gather", h=n, w=n * 4)
        return suffix_array_jax(
            x, v=max(v, 3), schedule=schedule,
            base_threshold=base_threshold or 256).astype(np.int32)
    n0 = _n0 or n
    if base_threshold is None:
        base_threshold = max(1024, n0 // p)
    holder = _MeshHolder(mesh)
    shard = NamedSharding(mesh, P(axis))

    def rec(x_np: np.ndarray, v: int) -> np.ndarray:
        n = len(x_np)
        if n <= max(base_threshold, 2 * p * v, 8):
            # paper: |X'| ≤ n/p → ship to one processor, solve sequentially.
            counters.superstep("base/gather", h=n, w=n * 4)
            return suffix_array_jax(x_np, v=3)
        v = int(min(max(v, 3), n))
        n_pv, n_loc, m_loc, m_tot, tabs = round_geometry(n, p, v)
        dsize = len(tabs.D)
        xp_np = np.full(n_pv, -1, dtype=np.int32)
        xp_np[:n] = x_np
        xg = jax.device_put(jnp.asarray(xp_np), shard)

        sigma = int(x_np.max()) + 1 if pack_keys else None
        xprime, distinct, over = _sm1(
            xg, p=p, v=v, n_loc=n_loc, m_loc=m_loc, vkey=v, axis=axis,
            mesh_holder=holder, sigma=sigma)
        if sigma is not None:            # packed key width (§Perf SA-iter A)
            bits = max(1, math.ceil(math.log2(max(sigma + 2, 2))))
            per = max(1, 30 // bits)
            w_keys = -(-v // per) if per >= 2 else v
        else:
            w_keys = v
        _round_cost("SM1", n_loc, m_loc, p, v, dsize, w_keys + 2, counters)
        if bool(np.asarray(over).any()):
            raise RuntimeError("BSP exchange capacity overflow (bug)")

        if bool(np.asarray(distinct).all()):
            sa_rank = xprime                                  # ranks are final
        else:
            v_next = schedule(v, dsize, m_tot)
            sa_sub = rec(np.asarray(xprime).reshape(-1), v_next)
            inv = np.empty(m_tot, dtype=np.int32)
            inv[sa_sub] = np.arange(m_tot, dtype=np.int32)
            sa_rank = jax.device_put(jnp.asarray(inv), shard)

        sa, over = _sm2(xg, sa_rank, p=p, v=v, n_loc=n_loc, m_loc=m_loc,
                        vkey=v, axis=axis, mesh_holder=holder)
        _round_cost("SM2", n_loc, m_loc, p, v, dsize, 3 + v + dsize, counters)
        if bool(np.asarray(over).any()):
            raise RuntimeError("BSP exchange capacity overflow (bug)")
        sa = np.asarray(sa).reshape(-1)
        return sa[sa < n]                                     # trim pads

    # top-level all-distinct shortcut (recursion base of Algorithm 3)
    if n <= max(base_threshold, 2 * p * 3, 8):
        counters.superstep("base/gather", h=n, w=n * 4)
        return suffix_array_jax(x, v=3).astype(np.int32)
    return rec(x.astype(np.int32), v).astype(np.int32)
