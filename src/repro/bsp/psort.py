"""Algorithm 2 — parallel sorting by regular sampling (Shi–Schaeffer /
Chan–Dehne), generic over key-based and comparator-based orders, with
pluggable **shard-local sorts** (`SAOptions.sort_impl`).

Row contract
------------
Rows are int32[m_local, W] with a fixed column layout:
  col 0      : valid flag (0 = valid, 1 = pad)  — pads sort last,
  col 1..W-2 : payload (keys first for key-mode),
  col W-1    : unique global index — strict total-order tiebreak.
`lt_fn(a, b) -> bool[N]` must be a strict total order consistent with that
contract; `local_sort(rows) -> rows` must sort by the same order.

Local-sort implementations
--------------------------
==========  ===============================================================
"radix"     packed keys: the key columns are packed into as few 30-bit
            int32 lanes as their value range allows (`pack_key_columns` —
            order-preserving and injective, so lexicographic order and
            row equality are unchanged), then ONE variadic `lax.sort`
            orders everything; a Lemma-1 comparator tail (when configured,
            `make_local_sort_keyed`) runs as a *cond-gated* bitonic pass
            that only fires when the key sort left equal-key runs.
"lax"       the same two-phase sort over the raw (unpacked) key columns.
"bitonic"   the legacy comparator network over full payload rows
            (`make_local_sort_bitonic`) — O(m log² m) compare-exchanges
            with the Lemma-1 comparator at every stage. Kept as the
            executable reference and the `benchmarks/bsp_throughput.py`
            regression row.
==========  ===============================================================

Supersteps per call: 6 (sample gather, 2×a2a bucket exchange, count gather,
2×a2a rebalance) — O(1) as in the paper. Communication per shard:
O(m_local + p²) words (regular-sampling bucket bound 2m/p + slack); the
packed-key layout shrinks every exchanged row from ~v to ⌈v·bits/30⌉ key
lanes, so the same h-relation moves proportionally fewer words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bitonic import bitonic_sort, lex_lt_int, next_pow2
from ..core.compat import shard_map
from .exchange import exchange
from .primitives import lex_lt_rows, searchsorted_rows

INT32_MAX = jnp.iinfo(jnp.int32).max

#: accepted BSP `sort_impl` values ("auto" resolves via
#: `resolve_bsp_sort_impl`; "pallas" is jax-backend-only and rejected).
BSP_SORT_IMPLS = ("auto", "radix", "lax", "bitonic")


def resolve_bsp_sort_impl(sort_impl: str, pack_keys: bool = True) -> str:
    """Concrete shard-local sort implementation for the BSP backend.

    ``"auto"`` resolves to the packed-key path (``"radix"``) unless key
    packing is disabled (`pack_keys=False`), in which case the unpacked
    multi-key sort (``"lax"``) is used. ``"pallas"`` (valid for the jax
    backend) has no BSP lowering — shard-local sorts run inside shard_map
    where the Mosaic kernels cannot be dispatched per shard — and is
    rejected with an explicit error rather than silently remapped.
    """
    if sort_impl == "auto":
        return "radix" if pack_keys else "lax"
    if sort_impl not in BSP_SORT_IMPLS:
        raise ValueError(
            f"sort_impl {sort_impl!r} is not supported by the bsp backend; "
            f"expected one of {BSP_SORT_IMPLS}")
    return sort_impl


# --------------------------------------------------------------------------
# key packing (§Perf SA-iteration A; Rajasekaran & Nicolae's radix-on-
# packed-keys trick applied to the BSP row layout)
# --------------------------------------------------------------------------
def pack_key_columns(cols: jnp.ndarray, lo: int, hi: int) -> jnp.ndarray:
    """Pack integer key columns with a known value range into 30-bit lanes.

    cols int[m, k] with every value in [lo, hi] → int32[m, ⌈k/per⌉] where
    `per = ⌊30 / bits⌋` fixed-width fields of `bits = bit_length(hi - lo)`
    are packed big-endian into each lane. Fixed-width fields make the
    packing *order-preserving* (lexicographic comparison of the packed
    lanes equals lexicographic comparison of the original columns) and
    *injective* (row equality is preserved exactly). Returns `cols`
    unchanged when a field does not fit at least twice into 30 bits —
    packing would not reduce the width. 30 bits (not 31) keeps every
    packed lane strictly below INT32_MAX, so pad rows still sort last.
    """
    m, k = cols.shape
    span = max(1, int(hi) - int(lo))
    bits = span.bit_length()
    per = max(1, 30 // bits)
    if per < 2:
        return cols
    shifted = (cols - lo).astype(jnp.int32)
    ncol = -(-k // per)
    pad = ncol * per - k
    if pad:
        shifted = jnp.concatenate(
            [shifted, jnp.zeros((m, pad), jnp.int32)], axis=1)
    shifted = shifted.reshape(m, ncol, per)
    weights = jnp.asarray([1 << (bits * (per - 1 - j)) for j in range(per)],
                          jnp.int32)
    return jnp.sum(shifted * weights[None, None, :], axis=-1)


def packed_width(k: int, lo: int, hi: int) -> int:
    """Number of int32 key lanes `pack_key_columns` produces for k columns."""
    span = max(1, int(hi) - int(lo))
    per = max(1, 30 // span.bit_length())
    return k if per < 2 else -(-k // per)


def quantize_sigma(sigma: int) -> int:
    """Round an alphabet bound up to the largest bound with the same packed
    field width (`bit_length(sigma + 1)` bits for values in [-1, sigma]).

    The packed key layout — and therefore every traced shape downstream —
    depends on sigma only through that bit width, but sigma itself is
    data-dependent (max(x) + 1 per recursion level) and is a *static* jit
    argument of the SM stages. Quantising collapses the open-ended family
    of observed maxima onto O(log σ) distinct static values, so nearby
    inputs (max 200 vs 201) reuse compiled programs instead of retracing.
    Always ≥ sigma, so the value range stays sound."""
    return (1 << (int(sigma) + 1).bit_length()) - 2


# --------------------------------------------------------------------------
# pad rows + orders
# --------------------------------------------------------------------------
def make_pad_rows(k: int, W: int, tag_base: int = 1 << 29):
    """Pad rows: valid=1, payload=MAX, unique huge tiebreak index."""
    pad = jnp.full((k, W), INT32_MAX, dtype=jnp.int32)
    pad = pad.at[:, 0].set(1)
    pad = pad.at[:, W - 1].set(tag_base + jnp.arange(k, dtype=jnp.int32))
    return pad


def lex_lt_full(a: jnp.ndarray, b: jnp.ndarray):
    """Default strict total order: lexicographic over ALL columns.

    Strict because col W-1 is unique."""
    return lex_lt_rows(a, b)


def local_sort_lex(rows: jnp.ndarray) -> jnp.ndarray:
    m, W = rows.shape
    operands = tuple(rows[:, c] for c in range(W))
    out = jax.lax.sort(operands + (jnp.arange(m, dtype=jnp.int32),),
                       num_keys=W)
    perm = out[-1]
    return rows[perm]


def make_local_sort_bitonic(lt_fn):
    def local_sort(rows: jnp.ndarray) -> jnp.ndarray:
        m, W = rows.shape
        n2 = next_pow2(m)
        if n2 != m:
            rows = jnp.concatenate([rows, make_pad_rows(n2 - m, W)], axis=0)
        out = bitonic_sort({"rows": rows},
                           lambda a, b: lt_fn(a["rows"], b["rows"]))
        return out["rows"][:m]
    return local_sort


# --------------------------------------------------------------------------
# Lemma-1 payload order over packed/unpacked keys
# --------------------------------------------------------------------------
def make_payload_lt(nk: int, v: int, dsize: int, lam_i1, lam_i2):
    """Strict total order on Lemma-1 payload rows
    [valid | keys(nk) | ranks(|D|) | klass | gidx].

    The head (valid flag + nk key lanes — packed or raw characters) is
    compared lexicographically; head-equal rows (identical v-character
    windows) are resolved by the paper's Lemma-1 rank lookup
    `rank[i + Λ[k_i][k_j]]` via the per-class index tables, then by the
    unique gidx column. `v` bounds the klass clip (pads carry INT32_MAX)."""
    cr = 1 + nk
    ck = 1 + nk + dsize
    cg = 2 + nk + dsize

    def lt(a, b):
        ka = jnp.clip(a[:, ck], 0, v - 1)
        kb = jnp.clip(b[:, ck], 0, v - 1)
        lt_head, eq_head = lex_lt_int(a[:, : 1 + nk], b[:, : 1 + nk])
        ia = lam_i1[ka, kb]
        ib = lam_i2[ka, kb]
        ra = jnp.take_along_axis(a[:, cr:cr + dsize], ia[:, None], axis=1)[:, 0]
        rb = jnp.take_along_axis(b[:, cr:cr + dsize], ib[:, None], axis=1)[:, 0]
        return jnp.where(
            eq_head & (ra != rb), ra < rb,
            jnp.where(eq_head, a[:, cg] < b[:, cg], lt_head))

    return lt


def make_local_sort_keyed(nk: int, v: int, dsize: int, lam_i1, lam_i2):
    """Two-phase shard-local sort by the `make_payload_lt` order.

    Phase 1 is ONE variadic `lax.sort` over (valid | keys | gidx) — the
    packed-key fast path that replaces the comparator-bitonic network for
    the bulk O(m log m) work. Phase 2 resolves *equal-key runs* (suffix
    pairs sharing their full v-character window — the only pairs Lemma 1
    is needed for) with a bitonic pass whose comparator is (run id,
    Λ-rank, slot); the pass is wrapped in `lax.cond` and skipped entirely
    when the key sort left no ties among valid rows, which is the common
    case for realistic alphabets. Pad rows never trigger the pass: their
    relative order is already fixed by the unique gidx sort key.
    """
    cr = 1 + nk
    ck = 1 + nk + dsize
    cg = 2 + nk + dsize

    def local_sort(rows: jnp.ndarray) -> jnp.ndarray:
        m, W = rows.shape
        operands = tuple(rows[:, c] for c in range(1 + nk)) + (
            rows[:, cg], jnp.arange(m, dtype=jnp.int32))
        perm = jax.lax.sort(operands, num_keys=2 + nk)[-1]
        rows = rows[perm]
        head = rows[:, : 1 + nk]
        boundary = jnp.ones(m, dtype=bool)
        if m > 1:
            boundary = boundary.at[1:].set(
                jnp.any(head[1:] != head[:-1], axis=1))
        seg = jnp.cumsum(boundary.astype(jnp.int32)) - 1   # run id, monotone

        def tie_break(rows):
            m2 = next_pow2(m)
            pad = m2 - m
            payload = {
                "seg": jnp.concatenate(
                    [seg, jnp.full((pad,), INT32_MAX, jnp.int32)]),
                "ranks": jnp.concatenate(
                    [rows[:, cr:ck],
                     jnp.zeros((pad, dsize), jnp.int32)], axis=0),
                "klass": jnp.concatenate(
                    [rows[:, ck], jnp.zeros((pad,), jnp.int32)]),
                "slot": jnp.arange(m2, dtype=jnp.int32),
            }

            def lt(a, b):
                seg_lt = a["seg"] < b["seg"]
                seg_eq = a["seg"] == b["seg"]
                ka = jnp.clip(a["klass"], 0, v - 1)
                kb = jnp.clip(b["klass"], 0, v - 1)
                ra = jnp.take_along_axis(
                    a["ranks"], lam_i1[ka, kb][:, None], axis=1)[:, 0]
                rb = jnp.take_along_axis(
                    b["ranks"], lam_i2[ka, kb][:, None], axis=1)[:, 0]
                rank_decides = seg_eq & (ra != rb)
                # slot order within a run == gidx order (gidx was a sort key)
                return jnp.where(
                    rank_decides, ra < rb,
                    jnp.where(seg_eq, a["slot"] < b["slot"], seg_lt))

            out = bitonic_sort(payload, lt)
            return rows[out["slot"][:m]]   # pad slots (seg=MAX) sort last

        has_real_tie = jnp.any((~boundary) & (rows[:, 0] == 0))
        return jax.lax.cond(has_real_tie, tie_break, lambda r: r, rows)

    return local_sort


# --------------------------------------------------------------------------
# Algorithm 2 body
# --------------------------------------------------------------------------
def psort_shard_body(
    rows: jnp.ndarray,           # int32[m_local, W]
    *,
    p: int,
    axis: str,
    lt_fn=None,
    local_sort=None,
):
    """Body to be run inside shard_map. Returns globally sorted, block-
    balanced rows int32[m_local, W] (pads last globally), plus this shard's
    local overflow flag (callers MUST gather it across shards and raise —
    see `repro.bsp.exchange`)."""
    if lt_fn is None:
        lt_fn = lex_lt_full
    if local_sort is None:
        local_sort = local_sort_lex
    m, W = rows.shape

    # --- 1. local sort ---
    rows = local_sort(rows)
    nvalid = jnp.sum((rows[:, 0] == 0).astype(jnp.int32))

    # --- 2. p+1 equally spaced primary samples (incl. min/max) ---
    t = jnp.arange(p + 1, dtype=jnp.int32)
    samp_idx = jnp.where(
        nvalid > 0,
        (t.astype(jnp.int64) * jnp.maximum(nvalid - 1, 0) // p).astype(jnp.int32),
        0)
    primary = rows[samp_idx]                                   # [p+1, W]
    primary = jnp.where((nvalid > 0), primary, make_pad_rows(p + 1, W))

    # --- 3. gather all p(p+1) samples everywhere (designated-processor step
    #        replicated: same h, fewer supersteps — DESIGN §3) ---
    all_samples = jax.lax.all_gather(primary, axis).reshape(p * (p + 1), W)
    all_samples = local_sort(all_samples)
    ns = jnp.sum((all_samples[:, 0] == 0).astype(jnp.int32))

    # --- 4. p-1 secondary splitters → p buckets ---
    tt = jnp.arange(1, p, dtype=jnp.int32)
    sec_idx = jnp.where(
        ns > 0,
        (tt.astype(jnp.int64) * jnp.maximum(ns - 1, 0) // p).astype(jnp.int32),
        0)
    splitters = all_samples[sec_idx]                           # [p-1, W]

    valid = rows[:, 0] == 0
    dest = searchsorted_rows(splitters, rows, lt_fn=lt_fn)     # [m] ∈ [0,p)
    dest = jnp.clip(dest, 0, p - 1)

    # --- 5. bucket exchange (2 supersteps) + local sort ---
    cap_out = 2 * m + 2 * p + 4
    got, got_valid, over1 = exchange(rows, dest, valid, p=p, cap_out=cap_out,
                                     axis=axis)
    got = jnp.where(got_valid[:, None], got, make_pad_rows(cap_out, W))
    got = local_sort(got)

    # --- 6. rebalance to exactly m rows per shard, preserving global order ---
    cnt = jnp.sum(got_valid.astype(jnp.int32))
    counts = jax.lax.all_gather(cnt[None], axis).reshape(p)
    offset = jnp.cumsum(counts) - counts
    my_off = offset[jax.lax.axis_index(axis)]
    gpos = my_off + jnp.arange(cap_out, dtype=jnp.int32)
    v2 = got[:, 0] == 0
    dest2 = jnp.clip(gpos // m, 0, p - 1)
    # carry gpos so receivers can restore order with a cheap key sort
    carried = jnp.concatenate([gpos[:, None].astype(jnp.int32), got], axis=1)
    out, out_valid, over2 = exchange(carried, dest2, v2, p=p, cap_out=m,
                                     axis=axis)
    perm = jnp.argsort(jnp.where(out_valid, out[:, 0], INT32_MAX), stable=True)
    out = out[perm][:, 1:]
    out_valid = out_valid[perm]
    out = jnp.where(out_valid[:, None], out, make_pad_rows(m, W))
    return out, (over1 | over2)


def run_psort(mesh, axis: str, rows_global, *, lt_fn=None, local_sort=None,
              check: bool = True):
    """Convenience wrapper: jit(shard_map(psort_shard_body)) over a 1-D mesh.

    rows_global: int32[p*m, W] sharded (or shardable) on dim 0. Returns
    (rows_sorted, over bool[p]); raises RuntimeError when any shard's
    exchange overflowed (pass ``check=False`` to inspect the flags instead).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    p = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))

    @functools.partial(jax.jit, out_shardings=(
        NamedSharding(mesh, P(axis)), NamedSharding(mesh, P(axis))))
    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P(axis),),
        out_specs=(P(axis), P(axis)))
    def fn(rows):
        out, over = psort_shard_body(rows, p=p, axis=axis, lt_fn=lt_fn,
                                     local_sort=local_sort)
        return out, over[None]

    out, over = fn(rows_global)
    if check and bool(np.asarray(over).any()):
        raise RuntimeError(
            "psort exchange capacity overflow — the deterministic two-hop "
            "caps were exceeded (bug in the cap_out bound, not bad input)")
    return out, over
