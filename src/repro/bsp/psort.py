"""Algorithm 2 — parallel sorting by regular sampling (Shi–Schaeffer /
Chan–Dehne), generic over key-based and comparator-based orders.

Row contract
------------
Rows are int32[m_local, W] with a fixed column layout:
  col 0      : valid flag (0 = valid, 1 = pad)  — pads sort last,
  col 1..W-2 : payload (keys first for key-mode),
  col W-1    : unique global index — strict total-order tiebreak.
`lt_fn(a, b) -> bool[N]` must be a strict total order consistent with that
contract; `local_sort(rows) -> rows` must sort by the same order. The
key-based fast path uses variadic lax.sort; the comparator path (the paper's
Lemma-1 suffix order) uses the bitonic network from repro.core.bitonic.

Supersteps per call: 6 (sample gather, 2×a2a bucket exchange, count gather,
2×a2a rebalance) — O(1) as in the paper. Communication per shard:
O(m_local + p²) words (regular-sampling bucket bound 2m/p + slack).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bitonic import bitonic_sort, next_pow2
from ..core.compat import shard_map
from .exchange import exchange
from .primitives import lex_lt_rows, searchsorted_rows

INT32_MAX = jnp.iinfo(jnp.int32).max


def make_pad_rows(k: int, W: int, tag_base: int = 1 << 29):
    """Pad rows: valid=1, payload=MAX, unique huge tiebreak index."""
    pad = jnp.full((k, W), INT32_MAX, dtype=jnp.int32)
    pad = pad.at[:, 0].set(1)
    pad = pad.at[:, W - 1].set(tag_base + jnp.arange(k, dtype=jnp.int32))
    return pad


def lex_lt_full(a: jnp.ndarray, b: jnp.ndarray):
    """Default strict total order: lexicographic over ALL columns.

    Strict because col W-1 is unique."""
    return lex_lt_rows(a, b)


def local_sort_lex(rows: jnp.ndarray) -> jnp.ndarray:
    m, W = rows.shape
    operands = tuple(rows[:, c] for c in range(W))
    out = jax.lax.sort(operands + (jnp.arange(m, dtype=jnp.int32),),
                       num_keys=W)
    perm = out[-1]
    return rows[perm]


def make_local_sort_bitonic(lt_fn):
    def local_sort(rows: jnp.ndarray) -> jnp.ndarray:
        m, W = rows.shape
        n2 = next_pow2(m)
        if n2 != m:
            rows = jnp.concatenate([rows, make_pad_rows(n2 - m, W)], axis=0)
        out = bitonic_sort({"rows": rows},
                           lambda a, b: lt_fn(a["rows"], b["rows"]))
        return out["rows"][:m]
    return local_sort


def psort_shard_body(
    rows: jnp.ndarray,           # int32[m_local, W]
    *,
    p: int,
    axis: str,
    lt_fn=None,
    local_sort=None,
):
    """Body to be run inside shard_map. Returns globally sorted, block-
    balanced rows int32[m_local, W] (pads last globally)."""
    if lt_fn is None:
        lt_fn = lex_lt_full
    if local_sort is None:
        local_sort = local_sort_lex
    m, W = rows.shape

    # --- 1. local sort ---
    rows = local_sort(rows)
    nvalid = jnp.sum((rows[:, 0] == 0).astype(jnp.int32))

    # --- 2. p+1 equally spaced primary samples (incl. min/max) ---
    t = jnp.arange(p + 1, dtype=jnp.int32)
    samp_idx = jnp.where(
        nvalid > 0,
        (t.astype(jnp.int64) * jnp.maximum(nvalid - 1, 0) // p).astype(jnp.int32),
        0)
    primary = rows[samp_idx]                                   # [p+1, W]
    primary = jnp.where((nvalid > 0), primary, make_pad_rows(p + 1, W))

    # --- 3. gather all p(p+1) samples everywhere (designated-processor step
    #        replicated: same h, fewer supersteps — DESIGN §3) ---
    all_samples = jax.lax.all_gather(primary, axis).reshape(p * (p + 1), W)
    all_samples = local_sort(all_samples)
    ns = jnp.sum((all_samples[:, 0] == 0).astype(jnp.int32))

    # --- 4. p-1 secondary splitters → p buckets ---
    tt = jnp.arange(1, p, dtype=jnp.int32)
    sec_idx = jnp.where(
        ns > 0,
        (tt.astype(jnp.int64) * jnp.maximum(ns - 1, 0) // p).astype(jnp.int32),
        0)
    splitters = all_samples[sec_idx]                           # [p-1, W]

    valid = rows[:, 0] == 0
    dest = searchsorted_rows(splitters, rows, lt_fn=lt_fn)     # [m] ∈ [0,p)
    dest = jnp.clip(dest, 0, p - 1)

    # --- 5. bucket exchange (2 supersteps) + local sort ---
    cap_out = 2 * m + 2 * p + 4
    got, got_valid, over1 = exchange(rows, dest, valid, p=p, cap_out=cap_out,
                                     axis=axis)
    got = jnp.where(got_valid[:, None], got, make_pad_rows(cap_out, W))
    got = local_sort(got)

    # --- 6. rebalance to exactly m rows per shard, preserving global order ---
    cnt = jnp.sum(got_valid.astype(jnp.int32))
    counts = jax.lax.all_gather(cnt[None], axis).reshape(p)
    offset = jnp.cumsum(counts) - counts
    my_off = offset[jax.lax.axis_index(axis)]
    gpos = my_off + jnp.arange(cap_out, dtype=jnp.int32)
    v2 = got[:, 0] == 0
    dest2 = jnp.clip(gpos // m, 0, p - 1)
    # carry gpos so receivers can restore order with a cheap key sort
    carried = jnp.concatenate([gpos[:, None].astype(jnp.int32), got], axis=1)
    out, out_valid, over2 = exchange(carried, dest2, v2, p=p, cap_out=m,
                                     axis=axis)
    perm = jnp.argsort(jnp.where(out_valid, out[:, 0], INT32_MAX), stable=True)
    out = out[perm][:, 1:]
    out_valid = out_valid[perm]
    out = jnp.where(out_valid[:, None], out, make_pad_rows(m, W))
    return out, (over1 | over2)


def run_psort(mesh, axis: str, rows_global, *, lt_fn=None, local_sort=None):
    """Convenience wrapper: jit(shard_map(psort_shard_body)) over a 1-D mesh.

    rows_global: int32[p*m, W] sharded (or shardable) on dim 0.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    p = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))

    @functools.partial(jax.jit, out_shardings=(
        NamedSharding(mesh, P(axis)), NamedSharding(mesh, P())))
    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P(axis),),
        out_specs=(P(axis), P()))
    def fn(rows):
        out, over = psort_shard_body(rows, p=p, axis=axis, lt_fn=lt_fn,
                                     local_sort=local_sort)
        return out, over[None]

    return fn(rows_global)
