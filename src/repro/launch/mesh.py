"""Production meshes. Functions, not module constants — importing this module
never touches jax device state (the dry-run sets XLA_FLAGS first)."""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis is
    pure extra data parallelism (lowest ICI traffic across the DCN/pod
    boundary, DESIGN §6)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_sa_mesh(p: int | None = None, axis: str = "bsp"):
    """1-D mesh for the BSP suffix-array pipeline (the paper's p)."""
    devs = jax.devices()
    p = p or len(devs)
    return jax.sharding.Mesh(np.array(devs[:p]).reshape(p), (axis,))


def mesh_num_devices(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
