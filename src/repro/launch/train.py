"""Training launcher over the SA-backed data plane.

    python -m repro.launch.train --arch minicpm-2b --smoke --steps 50
    python -m repro.launch.train --arch gemma3-1b --smoke --steps 200 \\
        --ckpt-dir /tmp/ckpt --resume
    python -m repro.launch.train --arch minicpm-2b --smoke --steps 20 \\
        --dedup --shard-docs 8 --eval-gate --plant-contamination 40 \\
        --probe-every 10

--smoke runs the reduced same-family config on CPU; without it the full
config is used (real cluster). Checkpoints every --ckpt-every steps with an
async writer; --resume continues from the latest committed step with
deterministic data skip-ahead (fault-tolerance path).

Data goes through `repro.data.pipeline.TrainingDataPlane`: the synthetic
corpus arrives as document shards (--shard-docs per shard), each ingested
into the streaming dedup index (--dedup); --eval-gate builds a held-out
eval set and rejects/masks training windows that overlap it
(--plant-contamination splices eval text into the training shards so the
gate has real work); --probe-every decodes samples from the live model and
logs longest-verbatim-copy metrics against the training index into the
step report. `main` returns a metrics dict::

    {"loss": float, "gate": {...}, "probe": {...}, "dedup": {...}}
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..configs import get_config
from ..ckpt.checkpoint import (latest_step, restore_checkpoint,
                               save_checkpoint, wait_for_async)
from ..data.pipeline import (GATE_POLICIES, PipelineConfig,
                             TrainingDataPlane, synthetic_corpus,
                             synthetic_doc_shards)
from ..models.lm import lm_init
from ..train.optim import OptConfig
from ..train.train_step import (TrainConfig, make_train_state,
                                make_train_step)


def plant_contamination(shards, eval_docs, *, n_blocks: int,
                        block_len: int, seed: int = 123) -> int:
    """Splice ``n_blocks`` stretches of eval text into the training shards
    (in place) so the contamination gate has guaranteed positives. Blocks
    cycle through distinct eval offsets so dedup can't collapse them.
    Returns the number of chars planted."""
    rng = np.random.default_rng(seed)
    flat = np.concatenate([np.asarray(d).ravel() for d in eval_docs])
    docs = [d for s in shards for d in s if len(d) >= block_len]
    planted = 0
    for k in range(n_blocks):
        src = (k * block_len) % max(len(flat) - block_len, 1)
        doc = docs[int(rng.integers(0, len(docs)))]
        dst = int(rng.integers(0, len(doc) - block_len + 1))
        doc[dst:dst + block_len] = flat[src:src + block_len]
        planted += block_len
    return planted


def build_plane(args, vocab: int) -> TrainingDataPlane:
    """Wire the data plane from CLI flags: shards, eval set, gate, probe."""
    pcfg = PipelineConfig(
        seq_len=args.seq_len, global_batch=args.batch, dedup=args.dedup,
        dedup_min_len=args.dedup_min_len, vocab=vocab,
        gate_min_len=args.gate_min_len, gate_policy=args.gate_policy,
        build_index=True if args.probe_every else None)
    shards = synthetic_doc_shards(
        args.corpus_chars, vocab, shard_docs=args.shard_docs,
        doc_len=args.doc_len,
        dup_fraction=0.2 if args.dedup else 0.0)
    eval_docs = None
    if args.eval_gate:
        eval_docs = [synthetic_corpus(4096, vocab, seed=777 + j)
                     for j in range(4)]
        if args.plant_contamination:
            planted = plant_contamination(
                shards, eval_docs, n_blocks=args.plant_contamination,
                block_len=2 * (args.seq_len + 1))
            print(f"gate: planted {planted} contaminated chars "
                  f"({args.plant_contamination} blocks)")
    return TrainingDataPlane(pcfg, eval_docs=eval_docs, shards=shards)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--corpus-chars", type=int, default=200_000)
    ap.add_argument("--log-every", type=int, default=10)
    # ---- data plane ----
    ap.add_argument("--dedup", action="store_true",
                    help="streaming suffix-array dedup over the shards")
    ap.add_argument("--dedup-min-len", type=int, default=48)
    ap.add_argument("--shard-docs", type=int, default=8,
                    help="documents per ingested shard")
    ap.add_argument("--doc-len", type=int, default=4096)
    ap.add_argument("--eval-gate", action="store_true",
                    help="held-out eval set + train/eval contamination gate")
    ap.add_argument("--gate-min-len", type=int, default=48)
    ap.add_argument("--gate-policy", choices=GATE_POLICIES,
                    default="reject")
    ap.add_argument("--plant-contamination", type=int, default=0,
                    help="splice N blocks of eval text into the training "
                         "shards (gives the gate guaranteed positives)")
    ap.add_argument("--probe-every", type=int, default=0,
                    help="every N steps, decode samples and log "
                         "longest-verbatim-copy vs the training index")
    ap.add_argument("--probe-samples", type=int, default=4)
    ap.add_argument("--probe-len", type=int, default=64)
    ap.add_argument("--probe-prompt", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    tcfg = TrainConfig(
        opt=OptConfig(name=cfg.optimizer, lr=args.lr),
        schedule=cfg.lr_schedule, warmup=max(args.steps // 20, 1),
        total_steps=args.steps, microbatches=args.microbatches)

    plane = build_plane(args, vocab=min(cfg.vocab_size, 256))
    if args.dedup:
        rep = plane.report
        print(f"dedup: removed {rep.dup_chars} duplicate chars "
              f"({100 * rep.dup_fraction:.1f}%) across {rep.shards} shards "
              f"({rep.builds} segment builds)")

    params, _ = lm_init(jax.random.PRNGKey(0), cfg)
    state = make_train_state(params, tcfg)
    start = 0
    if args.resume and args.ckpt_dir:
        st = latest_step(args.ckpt_dir)
        if st is not None:
            state, extras = restore_checkpoint(args.ckpt_dir, st, state)
            start = st
            print(f"resumed from step {st}")

    step_fn = jax.jit(make_train_step(cfg, tcfg))
    probe_metrics: dict = {}
    pending = None
    t0 = time.time()
    for i in range(start, args.steps):
        batch = plane.batch_at(i)
        if cfg.is_encdec:
            rng = np.random.default_rng(i)
            batch["enc_embeds"] = 0.02 * rng.standard_normal(
                (args.batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)
        if args.microbatches > 1:
            B = args.batch // args.microbatches
            batch = {k: v.reshape((args.microbatches, B) + v.shape[1:])
                     for k, v in batch.items()}
        state, m = step_fn(state, batch)
        if args.probe_every and (i + 1) % args.probe_every == 0:
            probe_metrics = run_probe(plane, state["params"], cfg, args,
                                      step=i)
        if (i + 1) % args.log_every == 0 or i == start:
            dt = (time.time() - t0) / max(i + 1 - start, 1)
            line = (f"step {i+1:5d} loss {float(m['loss']):.4f} "
                    f"lr {float(m['lr']):.2e} "
                    f"gnorm {float(m['grad_norm']):.2f}")
            if "masked_frac" in m:
                line += f" masked {100 * float(m['masked_frac']):.2f}%"
            if plane.gate is not None:
                gs = plane.gate.stats
                line += (f" gate[rej {gs['rejected_windows']}"
                         f"/msk {gs['masked_windows']}]")
            if probe_metrics:
                line += (f" copy[max {probe_metrics['longest_copy_max']}"
                         f"/mem {100 * probe_metrics['frac_memorized']:.0f}%]")
            print(line + f" ({dt:.2f}s/step)", flush=True)
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            wait_for_async(pending)
            pending = save_checkpoint(args.ckpt_dir, i + 1, state,
                                      extras={"loss": float(m["loss"])},
                                      async_write=True)
    wait_for_async(pending)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state)
    report = {"loss": float(m["loss"]),
              "gate": plane.gate_stats(),
              "probe": probe_metrics,
              "dedup": ({"dropped_chars": plane.report.dropped_chars,
                         "dup_fraction": plane.report.dup_fraction,
                         "shards": plane.report.shards,
                         "builds": plane.report.builds}
                        if args.dedup else {})}
    print("done: " + json.dumps(report))
    return report


def run_probe(plane: TrainingDataPlane, params, cfg, args, *,
              step: int) -> dict:
    """Decode --probe-samples continuations from corpus prompts and score
    them against the training index (memorization probe)."""
    if cfg.is_encdec or plane.index is None:
        return {}
    from .serve import prefill_then_decode
    corpus, P = plane.corpus, args.probe_prompt
    rng = np.random.default_rng(np.random.SeedSequence([plane.cfg.seed,
                                                        step, 7]))
    starts = rng.integers(0, max(len(corpus) - P, 1),
                          size=args.probe_samples)
    prompts = np.stack([corpus[s:s + P] for s in starts]).astype(np.int32)
    toks = np.asarray(prefill_then_decode(params, cfg, prompts,
                                          args.probe_len))
    return plane.probe(list(toks), min_len=plane.cfg.probe_min_len)


if __name__ == "__main__":
    main()
