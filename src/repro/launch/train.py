"""Training launcher.

    python -m repro.launch.train --arch minicpm-2b --smoke --steps 50
    python -m repro.launch.train --arch gemma3-1b --smoke --steps 200 \\
        --ckpt-dir /tmp/ckpt --resume

--smoke runs the reduced same-family config on CPU; without it the full
config is used (real cluster). Checkpoints every --ckpt-every steps with an
async writer; --resume continues from the latest committed step with
deterministic data skip-ahead (fault-tolerance path).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..configs import get_config
from ..ckpt.checkpoint import (latest_step, restore_checkpoint,
                               save_checkpoint, wait_for_async)
from ..data.pipeline import PipelineConfig, TokenPipeline, synthetic_corpus
from ..models.lm import lm_init
from ..train.optim import OptConfig
from ..train.train_step import (TrainConfig, make_train_state,
                                make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--dedup", action="store_true",
                    help="suffix-array dedup stage in the data pipeline")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--corpus-chars", type=int, default=200_000)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    tcfg = TrainConfig(
        opt=OptConfig(name=cfg.optimizer, lr=args.lr),
        schedule=cfg.lr_schedule, warmup=max(args.steps // 20, 1),
        total_steps=args.steps, microbatches=args.microbatches)

    pipe = TokenPipeline(
        synthetic_corpus(args.corpus_chars, vocab=min(cfg.vocab_size, 256),
                         dup_fraction=0.2 if args.dedup else 0.0),
        PipelineConfig(seq_len=args.seq_len, global_batch=args.batch,
                       dedup=args.dedup))
    if pipe.dedup_report:
        print(f"dedup: removed {pipe.dedup_report.dup_chars} duplicate chars "
              f"({100 * pipe.dedup_report.dup_fraction:.1f}%)")

    params, _ = lm_init(jax.random.PRNGKey(0), cfg)
    state = make_train_state(params, tcfg)
    start = 0
    if args.resume and args.ckpt_dir:
        st = latest_step(args.ckpt_dir)
        if st is not None:
            state, extras = restore_checkpoint(args.ckpt_dir, st, state)
            start = st
            print(f"resumed from step {st}")

    step_fn = jax.jit(make_train_step(cfg, tcfg))
    pending = None
    t0 = time.time()
    for i in range(start, args.steps):
        batch = pipe.batch_at(i)
        if cfg.is_encdec:
            rng = np.random.default_rng(i)
            batch["enc_embeds"] = 0.02 * rng.standard_normal(
                (args.batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)
        if args.microbatches > 1:
            B = args.batch // args.microbatches
            batch = {k: v.reshape((args.microbatches, B) + v.shape[1:])
                     for k, v in batch.items()}
        state, m = step_fn(state, batch)
        if (i + 1) % args.log_every == 0 or i == start:
            dt = (time.time() - t0) / max(i + 1 - start, 1)
            print(f"step {i+1:5d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e} gnorm {float(m['grad_norm']):.2f}"
                  f" ({dt:.2f}s/step)", flush=True)
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            wait_for_async(pending)
            pending = save_checkpoint(args.ckpt_dir, i + 1, state,
                                      extras={"loss": float(m["loss"])},
                                      async_write=True)
    wait_for_async(pending)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state)
    print(f"done: final loss {float(m['loss']):.4f}")
    return float(m["loss"])


if __name__ == "__main__":
    main()
