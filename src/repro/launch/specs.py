"""ShapeDtypeStruct input specs + sharding assignment for every
(architecture × input shape) dry-run cell — weak-type-correct, shardable,
zero device allocation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import SHAPES, ModelConfig, ShapeConfig
from ..models.layers import COMPUTE_DTYPE
from ..models.lm import init_decode_states, lm_init
from ..models.sharding import ShardingRules, logical_to_sharding
from ..train.optim import OptConfig
from ..train.train_step import TrainConfig, make_train_state


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(tuple(shape), dtype, sharding=sharding)


# --------------------------------------------------------------------------
# params + optimizer state (abstract)
# --------------------------------------------------------------------------
def abstract_params(cfg: ModelConfig):
    params, axes = lm_init(jax.random.PRNGKey(0), cfg, abstract=True)
    return params, axes


def params_shardings(axes, mesh: Mesh, rules: ShardingRules, params_abs=None):
    return logical_to_sharding(axes, mesh, rules, tree_abs=params_abs)


def opt_state_shardings(params_shard, params_abs, opt_name: str, mesh: Mesh):
    """Structural sharding for optimizer state given param shardings."""
    rep = NamedSharding(mesh, P())

    if opt_name in ("adamw", "sgdm"):
        out = {"m": params_shard, "step": rep}
        if opt_name == "adamw":
            out["v"] = params_shard
        return out

    # adafactor: vr drops the last dim, vc drops the second-last
    def fac(sh: NamedSharding, p_abs):
        spec = tuple(sh.spec) + (None,) * (len(p_abs.shape) - len(tuple(sh.spec)))
        if len(p_abs.shape) >= 2:
            return {"vr": NamedSharding(mesh, P(*spec[:-1])),
                    "vc": NamedSharding(mesh, P(*(spec[:-2] + spec[-1:])))}
        return {"v": NamedSharding(mesh, P(*spec))}

    f = jax.tree_util.tree_map(fac, params_shard, params_abs)
    return {"f": f, "step": rep}


def abstract_train_state(cfg: ModelConfig, tcfg: TrainConfig):
    params, axes = abstract_params(cfg)
    state = jax.eval_shape(lambda p: make_train_state(p, tcfg), params)
    return state, axes


def train_state_shardings(cfg, tcfg, state_abs, axes, mesh, rules):
    p_shard = params_shardings(axes, mesh, rules, state_abs["params"])
    out = {"params": p_shard,
           "opt": opt_state_shardings(p_shard, state_abs["params"],
                                      tcfg.opt.name, mesh)}
    if "ef_error" in state_abs:
        out["ef_error"] = p_shard
    return out


# --------------------------------------------------------------------------
# batch / decode-state specs
# --------------------------------------------------------------------------
def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    dp = dp_axes(mesh)
    B, S = shape.global_batch, shape.seq_len
    toks = sds((B, S + 1), jnp.int32, NamedSharding(mesh, P(dp, None)))
    batch = {"tokens": toks}
    if cfg.is_encdec:
        batch["enc_embeds"] = sds((B, cfg.enc_seq, cfg.d_model), jnp.float32,
                                  NamedSharding(mesh, P(dp, None, None)))
    return batch


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    dp = dp_axes(mesh)
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((B, S), jnp.int32,
                           NamedSharding(mesh, P(dp, None)))}
    if cfg.is_encdec:
        batch["enc_embeds"] = sds((B, cfg.enc_seq, cfg.d_model), jnp.float32,
                                  NamedSharding(mesh, P(dp, None, None)))
    return batch


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Abstract decode states with shape-dependent sharding:
    batch over dp when B > 1, cache-seq over `data` when B == 1 (the
    long_500k sequence-parallel layout, DESIGN §6)."""
    dp = dp_axes(mesh)
    B, S = shape.global_batch, shape.seq_len
    states = jax.eval_shape(
        lambda: init_decode_states(cfg, B, cache_len=S))
    seq_shard = B == 1

    def shard_of(leaf):
        shp = leaf.shape
        # stacked leaves: [L?, B, ...] — detect the batch dim position
        spec = [None] * len(shp)
        bdim = 1 if (len(shp) >= 2 and shp[1] == B) else 0
        if shp[bdim] != B:
            return NamedSharding(mesh, P())
        if not seq_shard and B % max(
                int(np.prod([mesh.shape[a] for a in dp])), 1) == 0 and dp:
            spec[bdim] = dp
        # KV caches: [..., B, C, Hk, hd] — shard heads over model; when the
        # head count does not divide the axis (GQA kv ≤ 16), fall back to
        # sharding the cache length C over model (§Perf iteration 2: minicpm
        # decode_32k had 98 GB/device of unsharded KV cache).
        if len(shp) - bdim == 4:                       # B, C, H, hd
            if shp[bdim + 2] % mesh.shape["model"] == 0:
                spec[bdim + 2] = "model"
            elif shp[bdim + 1] % mesh.shape["model"] == 0:
                spec[bdim + 1] = "model"
            if seq_shard and "data" in mesh.axis_names and \
                    spec[bdim + 1] is None and \
                    shp[bdim + 1] % mesh.shape["data"] == 0:
                spec[bdim + 1] = "data"
        elif len(shp) - bdim == 3:                     # rwkv S: B, H, hd, hd?
            if shp[bdim + 1] % mesh.shape["model"] == 0:
                spec[bdim + 1] = "model"
        return NamedSharding(mesh, P(*spec))

    shardings = jax.tree_util.tree_map(shard_of, states)
    with_sh = jax.tree_util.tree_map(
        lambda l, sh: sds(l.shape, l.dtype, sh), states, shardings)
    return with_sh, shardings


def decode_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    dp = dp_axes(mesh)
    B = shape.global_batch
    bspec = P(dp, None) if B > 1 else P(None, None)
    out = {"token": sds((B, 1), jnp.int32, NamedSharding(mesh, bspec)),
           "cur_pos": sds((), jnp.int32, NamedSharding(mesh, P()))}
    if cfg.is_encdec:
        out["enc_out"] = sds((B, cfg.enc_seq, cfg.d_model), COMPUTE_DTYPE,
                             NamedSharding(mesh, P(dp if B > 1 else None,
                                                   None, None)))
    return out


def default_train_config(cfg: ModelConfig) -> TrainConfig:
    # remat="full" recomputes blocks in backward: activation footprint drops
    # from O(L·B·S·d·intermediates) to O(L·B·S·d) (§Perf iteration 6).
    return TrainConfig(
        opt=OptConfig(name=cfg.optimizer, lr=3e-4),
        schedule=cfg.lr_schedule,
        warmup=2000, total_steps=100_000,
        microbatches=1, remat="none")   # remat lives INSIDE the model
                                           # (per-layer, cfg.remat)


# which cells run (DESIGN §5 applicability table)
LONG_OK = {"gemma2-27b", "gemma3-27b", "gemma3-1b", "recurrentgemma-2b",
           "rwkv6-1.6b"}


def cell_runs(cfg: ModelConfig, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return cfg.name in LONG_OK
    return True
