import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines — jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, record memory_analysis / cost_analysis / collective
bytes for §Dry-run and §Roofline.

Usage:
    python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, model_archs
from ..models.config import SHAPES
from ..models.layers import logits_from_embedding
from ..models.lm import decode_step, forward_hidden, encode
from ..models.sharding import ShardingRules
from ..train.train_step import make_train_step
from .mesh import make_production_mesh, mesh_num_devices
from .specs import (abstract_train_state, cell_runs, decode_batch_specs,
                    decode_state_specs, default_train_config, dp_axes,
                    prefill_specs, train_batch_specs, train_state_shardings)

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\(?\s*([a-z0-9]+)\[([\d,]*)\]")
_OPS_RE = re.compile(r"\(([^)]*)\)")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand sizes of every collective op in the optimised HLO."""
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, dt, dims = m.groups()
            if dt in DTYPE_BYTES:
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                sizes[name] = n * DTYPE_BYTES[dt]
    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for c in COLLECTIVES:
            token = f" {c}(" if not stripped.startswith(c) else f"{c}("
            if f"= {c}(" in stripped or f" {c}(" in stripped:
                if f"{c}(" not in stripped:
                    continue
                counts[c] += 1
                ops_m = _OPS_RE.search(stripped[stripped.index(f"{c}("):])
                total = 0
                if ops_m:
                    for op in ops_m.group(1).split(","):
                        op = op.strip().lstrip("%")
                        total += sizes.get(op, 0)
                if total == 0:
                    m = _DEF_RE.match(line)
                    if m and m.group(2) in DTYPE_BYTES:
                        n = 1
                        for d in m.group(3).split(","):
                            if d:
                                n *= int(d)
                        total = n * DTYPE_BYTES[m.group(2)]
                out[c] += total
                break
    out["_counts"] = counts
    return out


def model_params_breakdown(cfg):
    """(n_total, n_active, n_embed) from the actual abstract param tree.
    MoE expert params are counted at top_k/E for n_active."""
    from .specs import abstract_params
    params, _ = abstract_params(cfg)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    total = active = emb = 0
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        keys = [getattr(p, "key", str(p)) for p in path]
        total += n
        if "embed" in keys:
            emb += n
            continue
        if "moe" in keys and "router" not in keys:
            active += n * cfg.top_k / max(cfg.n_experts, 1)
        else:
            active += n
    return total, active, emb


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (prefill/decode);
    N excludes the embedding table (the HLO/model ratio row captures
    attention-score and lm-head compute)."""
    _, n_active, _ = model_params_breakdown(cfg)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch   # one token per sequence


def build_cell(arch: str, shape_name: str, mesh, rules=None):
    """Returns (fn, example_args) ready for jit(...).lower(*args)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules = rules or ShardingRules()

    if shape.kind == "train":
        cfg = cfg.replace(remat="full")     # per-layer remat (§Perf iter. 6)
        tcfg = default_train_config(cfg)
        state_abs, axes = abstract_train_state(cfg, tcfg)
        st_sh = train_state_shardings(cfg, tcfg, state_abs, axes, mesh, rules)
        state_abs = jax.tree_util.tree_map(
            lambda l, sh: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=sh),
            state_abs, st_sh)
        batch = train_batch_specs(cfg, shape, mesh)
        step = make_train_step(cfg, tcfg, mesh=mesh)
        return step, (state_abs, batch)

    from .specs import abstract_params, params_shardings
    params_abs, axes = abstract_params(cfg)
    p_sh = params_shardings(axes, mesh, rules, params_abs)
    params_abs = jax.tree_util.tree_map(
        lambda l, sh: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=sh),
        params_abs, p_sh)

    if shape.kind == "prefill":
        batch = prefill_specs(cfg, shape, mesh)

        def prefill(params, batch):
            enc_out = None
            if cfg.is_encdec:
                enc_out = encode(params, cfg, batch["enc_embeds"], mesh=mesh)
            hidden, _, _ = forward_hidden(
                params, cfg, tokens=batch["tokens"], enc_out=enc_out,
                mesh=mesh)
            return logits_from_embedding(
                hidden[:, -1:], params["embed"], cap=cfg.logit_softcap)

        return prefill, (params_abs, batch)

    # decode
    states_abs, _ = decode_state_specs(cfg, shape, mesh)
    dbatch = decode_batch_specs(cfg, shape, mesh)

    def serve_step(params, states, token, cur_pos, *rest):
        enc_out = rest[0] if rest else None
        return decode_step(params, cfg, token, states, cur_pos,
                           enc_out=enc_out, mesh=mesh)

    args = [params_abs, states_abs, dbatch["token"], dbatch["cur_pos"]]
    if cfg.is_encdec:
        args.append(dbatch["enc_out"])
    return serve_step, tuple(args)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             rules=None, tag: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "chips": mesh_num_devices(mesh), "tag": tag}
    t0 = time.time()
    try:
        fn, args = build_cell(arch, shape_name, mesh, rules)
        with mesh:
            lowered = jax.jit(fn).lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, list):   # older jaxlib: one dict per device
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        rec["flops"] = float(cost.get("flops", -1)) if cost else -1.0
        rec["bytes"] = float(cost.get("bytes accessed", -1)) if cost else -1.0
        if mem is not None:
            for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    rec[k] = int(v)
        rec["collectives"] = parse_collective_bytes(hlo)
        # loop-corrected totals (cost_analysis counts while bodies once;
        # hlo_stats multiplies by known_trip_count — see hlo_stats.py)
        from .hlo_stats import hlo_stats
        st = hlo_stats(hlo)
        rec["flops_corrected"] = float(st["flops"])
        rec["bytes_corrected"] = float(st["bytes"])
        rec["collective_bytes_corrected"] = float(st["collective_bytes"])
        rec["collectives_corrected"] = {
            k: float(v) for k, v in st.items()
            if k not in ("flops", "bytes", "collective_bytes")}
        rec["hlo_lines"] = hlo.count("\n")
        rec["model_flops"] = model_flops_estimate(cfg, shape)
        rec["status"] = "ok"
    except Exception as e:  # record failures — they are bugs to fix
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{mesh_kind}{tag}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in model_archs()
                 for s in SHAPES if cell_runs(get_config(a), s)]
    else:
        cells = [(args.arch, args.shape)]

    for arch, shape_name in cells:
        for mk in meshes:
            fname = os.path.join(args.out,
                                 f"{arch}__{shape_name}__{mk}.json")
            if args.skip_existing and os.path.exists(fname):
                print(f"skip {arch} {shape_name} {mk}")
                continue
            rec = run_cell(arch, shape_name, mk, args.out)
            ok = rec["status"]
            print(f"{arch:22s} {shape_name:12s} {mk:6s} {ok:5s} "
                  f"compile={rec.get('compile_s', '-'):>7}s "
                  f"flops={rec.get('flops', -1):.3e} "
                  f"err={rec.get('error', '')[:90]}",
                  flush=True)


if __name__ == "__main__":
    main()
