"""Loop-corrected HLO statistics.

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE, so any model
using scan-over-layers / chunked attention under-reports FLOPs, bytes and
collective traffic by the loop trip counts. This parser walks the optimised
HLO text, builds the computation call graph, and aggregates per-computation:

  * dot FLOPs  (2 · prod(result) · contracted-dim product),
  * convolution FLOPs (2 · prod(result) · kernel spatial · in-features),
  * HBM traffic model: operand + result bytes of top-level (non-fused) ops,
  * collective operand bytes by kind,

then scales while bodies by `backend_config={"known_trip_count":{"n":N}}`
(fallback 1) and fusions/calls/conditionals by 1. Elementwise FLOPs inside
fusions are ignored (dot-dominated workloads; the gap is reported as the
MODEL_FLOPS/HLO ratio in §Roofline).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_CALLEE_LIST_RE = re.compile(
    r"(?:branch_computations|called_computations)=\{([^}]*)\}")
_CALLEE_ONE_RE = re.compile(
    r"(?:to_apply|body|condition|calls)=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\\"{:n ]+(\d+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_elems_bytes(shape_str: str):
    """Total (elems, bytes) over all array shapes in a type string."""
    elems = tot = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        tot += n * DTYPE_BYTES[dt]
    return elems, tot


@dataclass
class _Instr:
    name: str
    kind: str
    result_type: str
    rest: str
    callees: list = field(default_factory=list)
    trip: int = 1


@dataclass
class _Comp:
    name: str
    instrs: list = field(default_factory=list)
    params: dict = field(default_factory=dict)   # name -> type str


def parse_hlo_module(text: str) -> dict:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        head = s.split("(")[0]
        if s.startswith("%") and s.rstrip().endswith("{") and "=" not in head:
            name = s.split()[0].lstrip("%")
            # strip parameter list / signature
            name = name.split("(")[0].split(".{")[0]
            cur = _Comp(name=name)
            comps[name] = cur
            continue
        if s.startswith("ENTRY"):
            name = s.split()[1].lstrip("%").split("(")[0]
            cur = _Comp(name=name)
            comps[name] = cur
            comps["__entry__"] = cur
            continue
        if s == "}":
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        iname, rtype, kind, rest = m.groups()
        inst = _Instr(name=iname, kind=kind, result_type=rtype, rest=rest)
        if kind == "parameter":
            cur.params[iname] = rtype
        for group in _CALLEE_LIST_RE.findall(line):
            for c in group.split(","):
                c = c.strip().lstrip("%")
                if c:
                    inst.callees.append(c)
        for c in _CALLEE_ONE_RE.findall(line):
            inst.callees.append(c)
        tm = _TRIP_RE.search(line)
        if tm:
            inst.trip = int(tm.group(1))
        cur.instrs.append(inst)
    return comps


def _operands_bytes(inst: _Instr, type_of: dict) -> int:
    ops_str = inst.rest.split(")")[0]
    total = 0
    for op in ops_str.split(","):
        op = op.strip().lstrip("%")
        if op in type_of:
            _, b = _shape_elems_bytes(type_of[op])
            total += b
    return total


def _dot_flops(inst: _Instr, type_of: dict) -> float:
    res_elems, _ = _shape_elems_bytes(inst.result_type)
    # contracted size = lhs elems / (lhs share of result) — derive instead
    # from lhs shape and contracting dims
    ops_str = inst.rest.split(")")[0]
    lhs = ops_str.split(",")[0].strip().lstrip("%")
    lhs_type = type_of.get(lhs, "")
    mm = _SHAPE_RE.search(lhs_type)
    if not mm:
        return 0.0
    lhs_dims = [int(d) for d in mm.group(2).split(",") if d]
    cm = _CONTRACT_RE.search(inst.rest)
    k = 1
    if cm:
        for ci in cm.group(1).split(","):
            if ci:
                k *= lhs_dims[int(ci)] if int(ci) < len(lhs_dims) else 1
    return 2.0 * res_elems * k


def _conv_flops(inst: _Instr, type_of: dict) -> float:
    res_elems, _ = _shape_elems_bytes(inst.result_type)
    ops_str = inst.rest.split(")")[0]
    parts = [o.strip().lstrip("%") for o in ops_str.split(",")]
    if len(parts) < 2:
        return 0.0
    ker = type_of.get(parts[1], "")
    mm = _SHAPE_RE.search(ker)
    if not mm:
        return 0.0
    kdims = [int(d) for d in mm.group(2).split(",") if d]
    out_feat_elems = 1
    for d in kdims:
        out_feat_elems *= d
    # flops ≈ 2 · result · (kernel elems / out_features); approximate with
    # kernel elems directly divided by the largest dim (out features)
    of = max(kdims) if kdims else 1
    return 2.0 * res_elems * (out_feat_elems / max(of, 1))


def aggregate(comps: dict) -> dict:
    """Bottom-up totals with while-trip multiplication. Returns stats of the
    entry computation."""
    memo: dict[str, dict] = {}

    def comp_stats(name: str, depth=0) -> dict:
        if name in memo:
            return memo[name]
        z = {"flops": 0.0, "bytes": 0.0,
             **{c: 0.0 for c in COLLECTIVES}}
        comp = comps.get(name)
        if comp is None or depth > 60:
            return z
        memo[name] = z                      # cycle guard
        type_of = {}
        for inst in comp.instrs:
            type_of[inst.name] = inst.result_type
        for inst in comp.instrs:
            if inst.kind == "dot":
                z["flops"] += _dot_flops(inst, type_of)
                z["bytes"] += _operands_bytes(inst, type_of) + \
                    _shape_elems_bytes(inst.result_type)[1]
            elif inst.kind == "convolution":
                z["flops"] += _conv_flops(inst, type_of)
                z["bytes"] += _operands_bytes(inst, type_of) + \
                    _shape_elems_bytes(inst.result_type)[1]
            elif inst.kind in COLLECTIVES:
                ob = _operands_bytes(inst, type_of)
                if ob == 0:
                    ob = _shape_elems_bytes(inst.result_type)[1]
                z[inst.kind] += ob
            elif inst.kind == "fusion":
                # HBM traffic model: fusion reads operands, writes result
                z["bytes"] += _operands_bytes(inst, type_of) + \
                    _shape_elems_bytes(inst.result_type)[1]
            elif inst.kind in ("copy", "transpose", "reshape", "broadcast"):
                z["bytes"] += _shape_elems_bytes(inst.result_type)[1]
            # recurse into callees
            mult = inst.trip if inst.kind == "while" else 1
            for c in inst.callees:
                sub = comp_stats(c, depth + 1)
                for k in z:
                    # fused bodies run from registers/VMEM: their inner
                    # "bytes" are not HBM traffic (the fusion op's operand/
                    # result bytes were already charged above)
                    if inst.kind == "fusion" and k == "bytes":
                        continue
                    z[k] += mult * sub[k]
        memo[name] = z
        return z

    entry = comps.get("__entry__")
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, **{c: 0.0 for c in COLLECTIVES}}
    return comp_stats(entry.name)


def hlo_stats(hlo_text: str) -> dict:
    comps = parse_hlo_module(hlo_text)
    out = aggregate(comps)
    out["collective_bytes"] = sum(out[c] for c in COLLECTIVES)
    return out
