"""Batched serving loop: prefill a batch of prompts, then decode with
ring-buffer KV caches / recurrent states.

    python -m repro.launch.serve --arch rwkv6-1.6b --smoke --prompt-len 16 \\
        --gen 32 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models.layers import logits_from_embedding
from ..models.lm import (decode_step, encode, forward_hidden,
                         init_decode_states, lm_init)


def prefill_then_decode(params, cfg, prompts, gen: int, *, enc_out=None,
                        temperature: float = 0.0, seed: int = 0):
    """prompts int32[B, P] → tokens int32[B, P+gen]. Prefill runs stepwise
    through the decode path (correct for every layer family incl. ring
    buffers); production TPU serving would batch the prompt pass."""
    B, P = prompts.shape
    states = init_decode_states(cfg, B, cache_len=P + gen)
    step = jax.jit(lambda p, t, st, pos: decode_step(
        p, cfg, t, st, pos, enc_out=enc_out))
    key = jax.random.PRNGKey(seed)
    out = [prompts[:, i:i + 1] for i in range(P)]
    logits = None
    for t in range(P):
        logits, states = step(params, out[t], states, jnp.int32(t))
    for g in range(gen):
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits[:, 0] / temperature,
                                         axis=-1)[:, None]
        else:
            nxt = jnp.argmax(logits[:, 0], axis=-1)[:, None]
        out.append(nxt.astype(jnp.int32))
        logits, states = step(params, out[-1], states, jnp.int32(P + g))
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params, _ = lm_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    enc_out = None
    if cfg.is_encdec:
        enc = 0.02 * rng.standard_normal(
            (args.batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)
        enc_out = encode(params, cfg, jnp.asarray(enc))

    t0 = time.time()
    toks = prefill_then_decode(params, cfg, prompts, args.gen,
                               enc_out=enc_out,
                               temperature=args.temperature)
    dt = time.time() - t0
    n_new = args.batch * args.gen
    print(f"generated {n_new} tokens in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s batched)")
    print("sample:", np.asarray(toks[0])[:32].tolist())
    assert toks.shape == (args.batch, args.prompt_len + args.gen)
    return toks


if __name__ == "__main__":
    main()
