"""Batched serving loop: prefill a batch of prompts, then decode with
ring-buffer KV caches / recurrent states.

    python -m repro.launch.serve --arch rwkv6-1.6b --smoke --prompt-len 16 \\
        --gen 32 --batch 4

The paper's own workload is served here too: `--arch suffix-array` obtains
a `repro.api.SuffixArrayIndex` over a synthetic corpus — restored from a
persistent `repro.api.IndexStore` when `--store` points at a warm one,
built through the facade otherwise (BSP backend on a mesh when more than
one device is visible, vectorised JAX otherwise) — and serves substring
count queries through the asynchronous tier (`repro.serve.SAServer`):
open-loop seeded arrivals (`--arrival poisson|onoff|uniform` at
`--offered-qps`), request coalescing into pow2 kernel buckets, admission
control (`--overload-policy`), and per-request queue/service/total
latency percentiles with JIT warmup excluded.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        python -m repro.launch.serve --arch suffix-array --smoke --queries 64 \\
        --store /tmp/sa_store --query-batch 64 --offered-qps 2000
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models.layers import logits_from_embedding
from ..models.lm import (decode_step, encode, forward_hidden,
                         init_decode_states, lm_init)


def prefill_then_decode(params, cfg, prompts, gen: int, *, enc_out=None,
                        temperature: float = 0.0, seed: int = 0):
    """prompts int32[B, P] → tokens int32[B, P+gen]. Prefill runs stepwise
    through the decode path (correct for every layer family incl. ring
    buffers); production TPU serving would batch the prompt pass."""
    B, P = prompts.shape
    states = init_decode_states(cfg, B, cache_len=P + gen)
    step = jax.jit(lambda p, t, st, pos: decode_step(
        p, cfg, t, st, pos, enc_out=enc_out))
    key = jax.random.PRNGKey(seed)
    out = [prompts[:, i:i + 1] for i in range(P)]
    logits = None
    for t in range(P):
        logits, states = step(params, out[t], states, jnp.int32(t))
    for g in range(gen):
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits[:, 0] / temperature,
                                         axis=-1)[:, None]
        else:
            nxt = jnp.argmax(logits[:, 0], axis=-1)[:, None]
        out.append(nxt.astype(jnp.int32))
        logits, states = step(params, out[-1], states, jnp.int32(P + g))
    return jnp.concatenate(out, axis=1)


def serve_sa_queries(cfg, *, n_chars: int, n_docs: int, n_queries: int,
                     pattern_len: int = 16, seed: int = 0,
                     store_dir: str | None = None,
                     query_batch: int | None = None,
                     offered_qps: float | None = None,
                     arrival: str | None = None,
                     coalesce_max_wait_us: float | None = None,
                     queue_depth: int | None = None,
                     overload_policy: str | None = None,
                     segments: int | None = None,
                     ingest: int | None = None):
    """Serve substring queries through the asynchronous serving tier.

    The index is a persistent artifact: with a `store_dir` (flag or
    `cfg.store_dir`) the corpus is looked up in an
    `repro.api.IndexStore` first — a warm restart *restores* the index
    (builder-cache stats stay at zero builds) instead of rebuilding it.
    On a miss/stale entry the build goes through the facade's auto rule
    (a 1-D mesh over all devices when p > 1, else the vectorised
    single-device DC-v) and is persisted for the next process.

    With ``--segments K`` (or ``cfg.segments``) the corpus is served as a
    `repro.api.SegmentedIndex` of K segments (persisted through a
    `SegmentedIndexStore`), and ``--ingest M`` streams M extra documents
    through `add_docs` AFTER the initial build — each ingest builds one
    small segment, and with a store each sync writes only the segments
    that changed (traffic is printed from the store's own accounting).

    Traffic is open-loop: `repro.serve.make_arrivals` schedules
    ~`n_queries` seeded arrivals (process/rate from cfg or flags) and a
    `repro.serve.SAServer` coalesces them into pow2 kernel buckets under
    admission control. Kernel-shape compiles are paid in an explicit
    warmup pass first, so the reported percentiles describe steady
    state, never JIT time."""
    from ..api import (IndexStore, SegmentedIndex, SegmentedIndexStore,
                       SuffixArrayIndex, builder_cache_stats,
                       corpus_fingerprint, encode_docs)
    from ..bsp.counters import BSPCounters
    from ..serve import SAServer, make_arrivals, run_open_loop, summarize
    from .mesh import make_sa_mesh

    n_segments = int(segments if segments is not None
                     else getattr(cfg, "segments", 0))
    n_ingest = int(ingest if ingest is not None
                   else getattr(cfg, "ingest", 0))
    if n_ingest and not n_segments:
        raise ValueError("--ingest requires --segments > 0: the monolithic "
                         "index has no incremental ingest path")

    mesh = make_sa_mesh() if len(jax.devices()) > 1 else None
    counters = BSPCounters() if mesh is not None else None
    opts = cfg.to_options(mesh=mesh, counters=counters)
    rng = np.random.default_rng(seed)
    doc_len = max(n_chars // max(n_docs, 1), pattern_len + 1)
    docs = [rng.integers(0, 256, size=doc_len) for _ in range(n_docs)]

    store_dir = store_dir if store_dir is not None else cfg.store_dir
    store = entry = None
    t0 = time.time()
    if n_segments > 0:
        per = max(-(-n_docs // n_segments), 1)      # ceil(docs / segments)
        if store_dir:
            store = SegmentedIndexStore(store_dir)
            entry = f"corpus-n{n_chars}-d{n_docs}-s{seed}-seg{n_segments}"
            index, status = store.get_or_build(
                entry,
                lambda: SegmentedIndex.from_docs(docs, opts, sigma=256,
                                                 segment_docs=per),
                options=opts)
            print(f"segment store: {status} (root={store.root}, "
                  f"entry={entry}, {store.stats()})")
        else:
            status = "off"
            index = SegmentedIndex.from_docs(docs, opts, sigma=256,
                                             segment_docs=per)
    elif store_dir:
        store = IndexStore(store_dir)
        text, _, _ = encode_docs(docs)
        # one entry per corpus configuration, so alternating --smoke/full
        # (or batch/seed changes) coexist instead of going mutually stale
        entry = f"corpus-n{n_chars}-d{n_docs}-s{seed}"
        index, status = store.get_or_build(
            entry,
            lambda: SuffixArrayIndex.from_docs(docs, opts, sigma=256),
            options=opts, corpus_sha=corpus_fingerprint(text))
        age = store.manifest_age(entry)
        print(f"index store: {status} (root={store.root}, entry={entry}, "
              f"manifest_age={age:.1f}s, {store.stats()})")
    else:
        status = "off"
        index = SuffixArrayIndex.from_docs(docs, opts, sigma=256)
    build_s = time.time() - t0
    verb = "restored" if status == "hit" else "indexed"
    seg_note = (f", segments={index.n_segments}"
                if n_segments > 0 else "")
    print(f"{verb} {index.n} chars / {index.n_docs} docs in {build_s:.2f}s "
          f"(backend={opts.resolve_backend()}{seg_note}, "
          f"builder_cache={builder_cache_stats()})")

    if n_ingest:
        s0 = builder_cache_stats()
        t0 = time.time()
        for _ in range(n_ingest):
            index.add_docs([rng.integers(0, 256, size=doc_len)])
        s1 = builder_cache_stats()
        built = (s1["hits"] + s1["misses"]) - (s0["hits"] + s0["misses"])
        line = (f"ingested {n_ingest} docs in {time.time() - t0:.2f}s: "
                f"{built} segment builds (incl. compaction merges), "
                f"segments={index.n_segments}")
        if store is not None:
            traffic = store.save(entry, index)
            line += (f", synced {traffic['segments_written']} segments "
                     f"(-{traffic['segments_deleted']} dropped)")
        print(line)
    if counters is not None and counters.supersteps:
        from ..bsp.psort import resolve_bsp_sort_impl
        impl = resolve_bsp_sort_impl(opts.sort_impl, opts.pack_keys)
        print(f"bsp costs: S={counters.supersteps} supersteps over "
              f"{counters.rounds} distributed rounds, "
              f"H={counters.comm_words} words, W={counters.work} ops "
              f"(sort_impl={impl})")

    # half the queries are planted substrings (must hit), half random
    patterns, planted = [], set()
    for q in range(n_queries):
        if q % 2 == 0:
            d = rng.integers(0, n_docs)
            at = rng.integers(0, doc_len - pattern_len)
            patterns.append(docs[d][at:at + pattern_len])
            planted.add(q)
        else:
            patterns.append(rng.integers(0, 256, size=pattern_len))

    batch = int(query_batch if query_batch is not None else cfg.query_batch)
    qps = float(offered_qps if offered_qps is not None else cfg.offered_qps)
    proc = arrival if arrival is not None else cfg.arrival
    wait_us = float(coalesce_max_wait_us if coalesce_max_wait_us is not None
                    else cfg.coalesce_max_wait_us)
    depth = int(queue_depth if queue_depth is not None else cfg.queue_depth)
    policy = (overload_policy if overload_policy is not None
              else cfg.overload_policy)

    server = SAServer(index, max_batch=batch,
                      coalesce_max_wait_us=wait_us, queue_depth=depth,
                      overload_policy=policy,
                      gc_hygiene=cfg.gc_hygiene).start()
    t0 = time.time()
    shapes = server.warmup(pattern_lens=(pattern_len,))
    print(f"warmup: {shapes} kernel shapes compiled in "
          f"{time.time() - t0:.2f}s (excluded from percentiles)")

    # ~n_queries seeded open-loop arrivals at the offered rate
    arrivals = make_arrivals(proc, qps, n_queries / qps, seed=seed)
    t0 = time.time()
    responses = run_open_loop(server, patterns, arrivals)
    dt = time.time() - t0
    server.stop()
    slo = summarize(responses, dt)

    # planted patterns that were admitted must hit; spot-check counts
    # against the closed-loop batched engine (same index, same kernel)
    ok_hits = [r for i, r in enumerate(responses)
               if r.ok and (i % n_queries) in planted]
    assert all(r.count >= 1 for r in ok_hits), "planted patterns must hit"
    check = [(i, r) for i, r in enumerate(responses) if r.ok][:8]
    if check:
        want = index.count_batch([patterns[i % n_queries] for i, _ in check])
        assert [r.count for _, r in check] == list(want), "tier != engine"

    m = server.metrics.snapshot()
    lat = {k: (f"{v * 1e3:.0f}us" if v is not None else "absent")
           for k, v in [("p50", slo["p50_ms"]), ("p95", slo["p95_ms"]),
                        ("p99", slo["p99_ms"])]}
    print(f"served {slo['offered']} open-loop queries ({proc}@{qps:.0f} "
          f"offered qps) in {dt:.3f}s: ok={slo['ok']} "
          f"rejected={slo['rejected']} shed={slo['shed']} "
          f"goodput={slo['goodput_qps']:.0f} qps")
    print(f"latency p50={lat['p50']} p95={lat['p95']} p99={lat['p99']}; "
          f"coalesced batch mean={m['batch_size']['mean'] or 0:.1f} "
          f"occupancy={m['bucket_occupancy']['mean'] or 0:.2f} "
          f"(policy={policy}, queue_depth={depth}, "
          f"max_wait={wait_us:.0f}us)")
    return index


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--queries", type=int, default=64,
                    help="query count for --arch suffix-array")
    ap.add_argument("--store", default=None,
                    help="IndexStore root for --arch suffix-array (a warm "
                         "restart restores the index instead of rebuilding)")
    ap.add_argument("--query-batch", type=int, default=None,
                    help="max coalesced batch for --arch suffix-array "
                         "(default: cfg.query_batch)")
    ap.add_argument("--offered-qps", type=float, default=None,
                    help="open-loop offered load (default: cfg.offered_qps)")
    ap.add_argument("--arrival", default=None,
                    choices=["uniform", "poisson", "onoff"],
                    help="arrival process (default: cfg.arrival)")
    ap.add_argument("--coalesce-max-wait-us", type=float, default=None,
                    help="batch-window deadline in µs "
                         "(default: cfg.coalesce_max_wait_us)")
    ap.add_argument("--queue-depth", type=int, default=None,
                    help="admission bound on queued requests "
                         "(default: cfg.queue_depth)")
    ap.add_argument("--overload-policy", default=None,
                    choices=["none", "reject", "shed"],
                    help="behavior past queue_depth (default: "
                         "cfg.overload_policy)")
    ap.add_argument("--segments", type=int, default=None,
                    help="serve a SegmentedIndex with this many segments "
                         "for --arch suffix-array (default: cfg.segments; "
                         "0 = monolithic)")
    ap.add_argument("--ingest", type=int, default=None,
                    help="docs to stream through add_docs after the initial "
                         "build (requires --segments; default: cfg.ingest)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if getattr(cfg, "name", "") == "suffix-array":
        n_chars = 20_000 if args.smoke else cfg.n
        return serve_sa_queries(cfg, n_chars=n_chars, n_docs=args.batch,
                                n_queries=args.queries,
                                pattern_len=args.prompt_len,
                                store_dir=args.store,
                                query_batch=args.query_batch,
                                offered_qps=args.offered_qps,
                                arrival=args.arrival,
                                coalesce_max_wait_us=args.coalesce_max_wait_us,
                                queue_depth=args.queue_depth,
                                overload_policy=args.overload_policy,
                                segments=args.segments,
                                ingest=args.ingest)
    if args.smoke:
        cfg = cfg.smoke()
    params, _ = lm_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    enc_out = None
    if cfg.is_encdec:
        enc = 0.02 * rng.standard_normal(
            (args.batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)
        enc_out = encode(params, cfg, jnp.asarray(enc))

    t0 = time.time()
    toks = prefill_then_decode(params, cfg, prompts, args.gen,
                               enc_out=enc_out,
                               temperature=args.temperature)
    dt = time.time() - t0
    n_new = args.batch * args.gen
    print(f"generated {n_new} tokens in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s batched)")
    print("sample:", np.asarray(toks[0])[:32].tolist())
    assert toks.shape == (args.batch, args.prompt_len + args.gen)
    return toks


if __name__ == "__main__":
    main()
