"""CLI: ``python -m tools.saca_lint [--check|--strict|...] [paths...]``.

Exit codes: 0 clean, 1 findings (or strict-mode hygiene failures),
2 usage errors.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import DEFAULT_BASELINE, DEFAULT_PATHS, RULES, run, write_baseline
from .collectives import STAGES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.saca_lint",
        description="Static analysis for the BSP/JAX/serve layers "
                    "(SCHED/TRACE/THREAD rule families).")
    ap.add_argument("paths", nargs="*",
                    help="files or directories to lint (default: src/repro)")
    ap.add_argument("--check", action="store_true",
                    help="report non-baselined findings; exit 1 if any "
                         "(this is also the default action)")
    ap.add_argument("--strict", action="store_true",
                    help="nightly mode: additionally fail on stale pragmas, "
                         "any non-empty baseline, and list every "
                         "suppression for audit")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                    help="baseline file (default: tools/saca_lint/"
                         "baseline.txt)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the current active findings into the "
                         "baseline file and exit 0")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    ap.add_argument("--schedule", action="store_true",
                    help="print the statically extracted per-stage "
                         "collective schedules and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in sorted(RULES.values(), key=lambda r: r.rule_id):
            print(f"{r.rule_id}  {r.name}\n    {r.summary}")
        return 0

    report = run(args.paths or None, baseline_path=args.baseline)

    if args.schedule:
        for stage in STAGES:
            seq = report.extractor.stage_schedule(stage)
            if seq is None:
                print(f"{stage:9s} <stage module not in lint paths>")
            else:
                print(f"{stage:9s} [{len(seq):2d}] "
                      + " ".join(e.kind for e in seq))
        return 0

    if args.update_baseline:
        write_baseline(args.baseline, report.active)
        print(f"baseline: wrote {len(report.active)} finding(s) to "
              f"{args.baseline}")
        return 0

    failures = 0
    for f in report.active:
        print(f.render())
        failures += 1
    if args.strict:
        for f in report.suppressed:
            print(f.render())
        for p in report.stale_pragmas:
            print(f"{p.path}:{p.pragma_line}: LINT001 stale pragma "
                  f"allow[{','.join(p.rules)}] — no finding matches it")
            failures += 1
        for f in report.baselined:
            print(f.render())
            failures += 1
    else:
        for p in report.stale_pragmas:
            print(f"{p.path}:{p.pragma_line}: warning: stale pragma "
                  f"allow[{','.join(p.rules)}] (LINT001; fails --strict)")

    n_sup = len(report.suppressed)
    n_base = len(report.baselined)
    print(f"saca-lint: {failures} failure(s), {n_sup} suppressed, "
          f"{n_base} baselined, {len(report.modules)} module(s) analyzed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
