"""TRACE rules: JAX trace hygiene over the jitted hot paths.

Traced regions are discovered, not configured: any function decorated
with `jax.jit` (directly, via ``@functools.partial(jax.jit, ...)``, or
``jit(f)``), plus every callable handed to ``shard_map`` (shared with
the collective extractor). Inside a traced region:

* **TRACE001** — the function reads a module-level *mutable* global
  (dict/list/set literal or Counter/defaultdict/deque constructor).
  Closing over mutable state is a retrace/staleness hazard: the traced
  value is baked in at trace time and silently goes stale (the repo's
  own `TRACE_COUNTS` counters are the deliberate, suppressed instance).
* **TRACE002** — a host-sync call (`float()`/`int()`/`bool()`,
  `np.asarray`/`np.array`, `.item()`/`.tolist()`, `jax.device_get`) is
  applied to a traced value. Under jit this either fails at trace time
  or, worse, constant-folds a device value into the compiled artifact.
  The same check runs over `SAServer._device_loop`, where a per-item
  scalar sync stalls the double-buffered pipeline.
* **TRACE003** — a traced (non-static) parameter steers host control
  flow (`range()`, `if`/`while` tests, `.bit_length()`): it must be a
  Python scalar, so every distinct value triggers a retrace — the
  class of bug that burns the compiled-builder cache.

Dataflow is a single forward pass per function: traced-ness seeds at
the non-static parameters (for shard_map bodies: the positional
parameters — keyword-only ones are partial-bound config by repo
convention) and propagates through jnp/jax ops, indexing and
arithmetic; `.shape`/`.dtype`/`.ndim`/`len()` reads are static and
*clear* it.
"""
from __future__ import annotations

import ast

from .astutil import Module, SymbolTable, attr_chain, const_str_tuple, \
    iter_functions, symbols
from .framework import Finding, rule

TRACE001 = rule(
    "TRACE001", "jit-closes-over-mutable-global",
    "jit/shard_map-traced callable reads a module-level mutable global "
    "(value is baked in at trace time; mutation is a retrace/staleness "
    "hazard)")
TRACE002 = rule(
    "TRACE002", "host-sync-in-traced-region",
    "host-synchronising call (float/int/bool, np.asarray, .item(), "
    ".tolist(), jax.device_get) applied to a traced value inside a jitted "
    "region or the serve device loop")
TRACE003 = rule(
    "TRACE003", "traced-param-in-host-control",
    "non-static parameter of a jitted function steers host control flow "
    "(range/if/while/.bit_length) — should be a static arg; every new "
    "value retraces")

MUTABLE_CONSTRUCTORS = {"dict", "list", "set", "Counter", "defaultdict",
                        "deque", "OrderedDict"}
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "weak_type", "sharding"}
SYNC_BUILTINS = {"float", "int", "bool", "complex"}
SYNC_METHODS = {"item", "tolist", "__array__"}
TRACED_ROOTS = {"jnp", "jax", "lax"}


def _mutable_globals(mod: Module) -> dict[str, int]:
    """Module-level name -> def line for mutable-container globals."""
    out: dict[str, int] = {}
    for node in mod.tree.body:
        targets = []
        value = None
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        if value is None:
            continue
        mutable = isinstance(value, (ast.Dict, ast.List, ast.Set,
                                     ast.DictComp, ast.ListComp, ast.SetComp))
        if isinstance(value, ast.Call):
            chain = attr_chain(value.func) or []
            mutable = bool(chain) and chain[-1] in MUTABLE_CONSTRUCTORS
        if not mutable:
            continue
        for t in targets:
            if isinstance(t, ast.Name):
                out[t.id] = node.lineno
    return out


def _jit_regions(mod: Module):
    """Yield (qualname, node, static_argnames) for jit-decorated defs."""
    for qualname, node in iter_functions(mod):
        for dec in node.decorator_list:
            static = _jit_decorator_static(dec)
            if static is not None:
                yield qualname, node, set(static)
                break


def _jit_decorator_static(dec: ast.AST) -> tuple[str, ...] | None:
    """None if not a jit decorator; else its static_argnames."""
    chain = attr_chain(dec)
    if chain and chain[-1] == "jit":
        return ()
    if isinstance(dec, ast.Call):
        chain = attr_chain(dec.func) or []
        if chain and chain[-1] == "jit":
            for kw in dec.keywords:
                if kw.arg in ("static_argnames", "static_argnums"):
                    return const_str_tuple(kw.value)
            return ()
        if chain and chain[-1] == "partial" and dec.args:
            inner_chain = attr_chain(dec.args[0]) or []
            if inner_chain and inner_chain[-1] == "jit":
                for kw in dec.keywords:
                    if kw.arg in ("static_argnames", "static_argnums"):
                        return const_str_tuple(kw.value)
                return ()
    return None


class _Dataflow:
    """Forward traced-ness propagation + sync/control checks for one fn."""

    def __init__(self, mod: Module, sym: SymbolTable, qualname: str,
                 node: ast.FunctionDef, traced_params: set[str],
                 findings: list[Finding], check_trace003: bool):
        self.mod = mod
        self.qualname = qualname
        self.node = node
        self.findings = findings
        self.traced: set[str] = set(traced_params)
        self.params = traced_params
        self.check_trace003 = check_trace003
        self.np_aliases = {alias for alias, m in sym.mod_imports.items()
                           if m == "numpy"}
        self._flagged: set[tuple[str, int]] = set()

    # -- traced-ness of an expression -------------------------------------
    def is_traced(self, node: ast.AST | None) -> bool:
        if node is None or isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Name):
            return node.id in self.traced
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False
            return self.is_traced(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_traced(node.value)
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func) or []
            if chain and chain[0] in TRACED_ROOTS:
                # device op: result is traced unless it's a static query
                return chain[-1] not in ("static_argnames",)
            if chain and chain[0] in self.np_aliases:
                return False        # numpy result lives on host
            if isinstance(node.func, ast.Name):
                if node.func.id in {"len"} | SYNC_BUILTINS:
                    return False
                # unknown local callable: traced iff any arg is
                return any(self.is_traced(a) for a in node.args)
            if isinstance(node.func, ast.Attribute):
                if node.func.attr in SYNC_METHODS:
                    return False
                return self.is_traced(node.func.value) or \
                    any(self.is_traced(a) for a in node.args)
            return False
        if isinstance(node, (ast.BinOp, ast.BoolOp, ast.Compare,
                             ast.UnaryOp, ast.IfExp, ast.Tuple, ast.List)):
            return any(self.is_traced(c) for c in ast.iter_child_nodes(node)
                       if isinstance(c, ast.expr))
        return False

    # -- sync checks --------------------------------------------------------
    def _check_sync(self, tree: ast.AST) -> None:
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            site = None
            if isinstance(node.func, ast.Name) \
                    and node.func.id in SYNC_BUILTINS:
                if any(self.is_traced(a) for a in node.args):
                    site = f"{node.func.id}()"
            chain = attr_chain(node.func) or []
            if (len(chain) == 2 and chain[0] in self.np_aliases
                    and chain[1] in ("asarray", "array", "copy")):
                if any(self.is_traced(a) for a in node.args):
                    site = f"{chain[0]}.{chain[1]}()"
            if chain[-2:] == ["jax", "device_get"] or \
                    chain[-1:] == ["device_get"]:
                if any(self.is_traced(a) for a in node.args):
                    site = "jax.device_get()"
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in SYNC_METHODS \
                    and self.is_traced(node.func.value):
                site = f".{node.func.attr}()"
            if site and (site, node.lineno) not in self._flagged:
                self._flagged.add((site, node.lineno))
                self.findings.append(Finding(
                    TRACE002, self.mod.rel, node.lineno,
                    f"host sync {site} on a traced value inside "
                    f"`{self.qualname}`"))

    def _check_host_control(self) -> None:
        if not self.check_trace003:
            return

        def names_in(tree):
            return {n.id for n in ast.walk(tree) if isinstance(n, ast.Name)}

        for node in ast.walk(self.node):
            hot: set[str] = set()
            where = None
            if isinstance(node, (ast.If, ast.While)):
                hot = names_in(node.test) & self.params
                where = "an if/while test"
            elif isinstance(node, ast.Call):
                if isinstance(node.func, ast.Name) \
                        and node.func.id == "range":
                    hot = set().union(*(names_in(a) for a in node.args)) \
                        & self.params if node.args else set()
                    where = "range()"
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "bit_length" \
                        and isinstance(node.func.value, ast.Name):
                    hot = {node.func.value.id} & self.params
                    where = ".bit_length()"
            for name in sorted(hot):
                key = (f"003:{name}", node.lineno)
                if key in self._flagged:
                    continue
                self._flagged.add(key)
                self.findings.append(Finding(
                    TRACE003, self.mod.rel, node.lineno,
                    f"traced parameter `{name}` of `{self.qualname}` "
                    f"steers host control flow ({where}); make it a "
                    f"static arg or derive it from a .shape"))

    # -- statement pass -----------------------------------------------------
    def run(self) -> None:
        self._walk(self.node.body)
        self._check_host_control()

    def _walk(self, body: list[ast.stmt]) -> None:
        for st in body:
            self._stmt(st)

    def _assign_target(self, target: ast.AST, traced: bool) -> None:
        if isinstance(target, ast.Name):
            if traced:
                self.traced.add(target.id)
            else:
                self.traced.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._assign_target(e, traced)

    def _stmt(self, st: ast.stmt) -> None:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            return                      # nested defs analyzed separately
        if isinstance(st, ast.Assign):
            self._check_sync(st.value)
            t = self.is_traced(st.value)
            for target in st.targets:
                self._assign_target(target, t)
            return
        if isinstance(st, ast.AnnAssign) and st.value is not None:
            self._check_sync(st.value)
            self._assign_target(st.target, self.is_traced(st.value))
            return
        if isinstance(st, ast.AugAssign):
            self._check_sync(st.value)
            if self.is_traced(st.value):
                self._assign_target(st.target, True)
            return
        if isinstance(st, ast.For):
            self._check_sync(st.iter)
            self._assign_target(st.target, self.is_traced(st.iter))
            self._walk(st.body)
            self._walk(st.orelse)
            return
        if isinstance(st, (ast.If, ast.While)):
            self._check_sync(st.test)
            self._walk(st.body)
            self._walk(st.orelse)
            return
        if isinstance(st, ast.With):
            for item in st.items:
                self._check_sync(item.context_expr)
            self._walk(st.body)
            return
        if isinstance(st, ast.Try):
            self._walk(st.body)
            for h in st.handlers:
                self._walk(h.body)
            self._walk(st.orelse)
            self._walk(st.finalbody)
            return
        self._check_sync(st)


def _param_names(node: ast.FunctionDef) -> tuple[set[str], set[str]]:
    """(positional-or-keyword names, keyword-only names)."""
    pos = {a.arg for a in node.args.args + node.args.posonlyargs}
    kw = {a.arg for a in node.args.kwonlyargs}
    return pos, kw


def analyze(modules: dict[str, Module],
            shard_map_bodies: set[tuple[str, str]]) -> list[Finding]:
    findings: list[Finding] = []
    func_index = {name: dict(iter_functions(m))
                  for name, m in modules.items()}
    for name, mod in modules.items():
        sym = symbols(mod)
        mutables = _mutable_globals(mod)
        regions: list[tuple[str, ast.FunctionDef, set[str], bool]] = []
        for qualname, node, static in _jit_regions(mod):
            pos, kw = _param_names(node)
            traced = (pos | kw) - static - {"self"}
            regions.append((qualname, node, traced, True))
        for m, q in sorted(shard_map_bodies):
            if m == name and q in func_index[name]:
                node = func_index[name][q]
                pos, _kw = _param_names(node)
                # keyword-only params are partial-bound static config
                regions.append((q, node, pos - {"self"}, False))
        # the serve device loop is a host thread, but everything it pulls
        # off the staging queue is device-resident: per-item scalar syncs
        # stall the pipeline exactly like a sync under jit.
        for qualname, node in func_index[name].items():
            if qualname.endswith("._device_loop"):
                regions.append((qualname, node, set(), False))

        seen: set[int] = set()
        for qualname, node, traced, is_jit in regions:
            if id(node) in seen:
                continue
            seen.add(id(node))
            # TRACE001: reads of module-level mutable globals
            reported: set[str] = set()
            for sub in ast.walk(node):
                if isinstance(sub, ast.Name) and sub.id in mutables \
                        and sub.id not in reported:
                    reported.add(sub.id)
                    findings.append(Finding(
                        TRACE001, mod.rel, sub.lineno,
                        f"traced callable `{qualname}` reads module-level "
                        f"mutable global `{sub.id}` (defined line "
                        f"{mutables[sub.id]})"))
            flow = _Dataflow(mod, sym, qualname, node, traced, findings,
                             check_trace003=is_jit)
            flow.run()
    return findings
