"""SCHED rules: static collective-schedule extraction over the BSP layer.

The extractor reconstructs, per function, the *sequence of collective
kinds* issued along control-flow paths — interprocedurally, through the
repo's actual composition idioms:

* direct ``jax.lax.<collective>(...)`` calls,
* calls to functions defined in any analyzed module (``exchange`` from
  `repro.bsp.exchange`, ``psort_shard_body`` from `repro.bsp.psort`),
* ``functools.partial(f, ...)`` bindings and name aliases,
* the jitted-wrapper idiom ``shard_map(body, mesh=...)(args)``,
* ``lax.cond`` / ``lax.switch`` branch callables and the lax loop
  combinators (`fori_loop`, `while_loop`, `scan`).

On a real mesh every rank must issue the *same* collective sequence; a
host conditional whose branches diverge deadlocks unless its predicate
is provably replica-uniform. We treat a predicate as uniform only when
it is *structural* — built from plain names, constants, arithmetic,
comparisons, `len`/`max`/`min`/`math.*` and `.shape`-style attributes —
i.e. a function of static geometry, never of device data. Branches that
terminate in `raise` are error teardown and exempt.

Unknown callables (imported from un-analyzed modules, or passed in as
parameters like `psort_shard_body`'s ``lt_fn``/``local_sort``) are
assumed collective-free; that is the documented soundness boundary.

SCHED002 pins the extracted schedule against the repo's dynamic
accounting: the per-stage sequences must match the SM1=11 / SM2=9
contract of `repro.bsp.counters`, and the label stream that
`estimate_costs(n, p)` replays must map, label by label, onto the
statically extracted kinds. Model, counters and source cannot drift
apart without a lint failure.
"""
from __future__ import annotations

import ast
import dataclasses

from .astutil import Module, SymbolTable, attr_chain, iter_functions, symbols
from .framework import Finding, rule

SCHED001 = rule(
    "SCHED001", "divergent-collectives-host-branch",
    "host `if` whose branches issue different collective sequences from a "
    "predicate that is not provably replica-uniform (real-mesh deadlock)")
SCHED002 = rule(
    "SCHED002", "schedule-model-drift",
    "statically extracted collective schedule disagrees with the "
    "BSPCounters contract (SM1=11/SM2=9) or with estimate_costs' replay")
SCHED003 = rule(
    "SCHED003", "divergent-collectives-traced-branch",
    "`lax.cond`/`lax.switch` branches issue different collective sequences "
    "(predicate is traced, i.e. data-dependent by construction)")
SCHED004 = rule(
    "SCHED004", "collective-inside-loop",
    "collective issued inside a loop whose trip count is not part of the "
    "static schedule (superstep count becomes data/shape dependent)")

#: lax collective name -> canonical kind
COLLECTIVES = {
    "all_to_all": "all_to_all", "ragged_all_to_all": "all_to_all",
    "all_gather": "all_gather",
    "ppermute": "ppermute", "pshuffle": "ppermute",
    "psum": "psum", "psum_scatter": "psum",
    "pmax": "pmax", "pmin": "pmin", "pmean": "pmean",
}

RECURSION = "<recursion>"

#: BSP stage bodies whose schedules are contract-pinned (SCHED002).
STAGES = {
    "exchange": ("repro.bsp.exchange", "exchange"),
    "psort": ("repro.bsp.psort", "psort_shard_body"),
    "SM1": ("repro.bsp.suffix_array", "_sm1_body"),
    "SM2": ("repro.bsp.suffix_array", "_sm2_body"),
}

#: counter label (stage prefix stripped) -> collective kind, straight from
#: `_round_cost`. This is the bridge between dynamic accounting and the AST.
LABEL_KINDS = {
    "halo": "ppermute",
    "psort/sample_gather": "all_gather",
    "psort/a2a_hop1": "all_to_all",
    "psort/a2a_hop2": "all_to_all",
    "psort/count_gather": "all_gather",
    "psort/rebal_hop1": "all_to_all",
    "psort/rebal_hop2": "all_to_all",
    "rank/boundary": "ppermute",
    "rank/scan": "all_gather",
    "route/a2a_hop1": "all_to_all",
    "route/a2a_hop2": "all_to_all",
    "unroute/a2a_hop1": "all_to_all",
    "unroute/a2a_hop2": "all_to_all",
}

SM1_LABELS = ["halo", "psort/sample_gather", "psort/a2a_hop1",
              "psort/a2a_hop2", "psort/count_gather", "psort/rebal_hop1",
              "psort/rebal_hop2", "rank/boundary", "rank/scan",
              "route/a2a_hop1", "route/a2a_hop2"]
SM2_LABELS = ["unroute/a2a_hop1", "unroute/a2a_hop2", "halo",
              "psort/sample_gather", "psort/a2a_hop1", "psort/a2a_hop2",
              "psort/count_gather", "psort/rebal_hop1", "psort/rebal_hop2"]

#: The SCHED rules encode *BSP superstep* discipline: every rank must issue
#: one fixed collective sequence per round. The transformer stack
#: (models/, launch/, train/) runs its collectives under pjit/scan where
#: per-layer repetition and config-gated MoE dispatch are SPMD-uniform by
#: construction — a different (compiler-checked) regime, so it is out of
#: scope by module prefix rather than drowned in pragmas.
SCHED_EXEMPT_PREFIXES = ("repro.models", "repro.launch", "repro.train")


@dataclasses.dataclass(frozen=True)
class Event:
    kind: str
    path: str
    line: int


def kinds(seq: list[Event]) -> tuple[str, ...]:
    return tuple(e.kind for e in seq)


_STRUCTURAL_CALLS = {"len", "max", "min", "int", "abs", "bool", "float",
                     "round", "isinstance", "str"}
_STRUCTURAL_ATTRS = {"shape", "ndim", "size", "dtype", "axis_names"}


def is_structural(node: ast.AST) -> bool:
    """True if the predicate is a function of static geometry only."""
    if isinstance(node, (ast.Name, ast.Constant)):
        return True
    if isinstance(node, (ast.BoolOp, ast.BinOp, ast.UnaryOp, ast.Compare,
                         ast.IfExp, ast.Tuple, ast.Subscript)):
        return all(is_structural(c) for c in ast.iter_child_nodes(node)
                   if not isinstance(c, (ast.operator, ast.cmpop,
                                         ast.unaryop, ast.boolop,
                                         ast.expr_context)))
    if isinstance(node, ast.Attribute):
        chain = attr_chain(node)
        if chain and chain[0] == "math":
            return True
        return node.attr in _STRUCTURAL_ATTRS and is_structural(node.value)
    if isinstance(node, ast.Call):
        chain = attr_chain(node.func)
        ok = (isinstance(node.func, ast.Name)
              and node.func.id in _STRUCTURAL_CALLS) \
            or (chain is not None and chain[0] == "math")
        return ok and all(is_structural(a) for a in node.args)
    return False


def _terminates(body: list[ast.stmt]) -> bool:
    """Branch is error teardown / early exit via raise."""
    return any(isinstance(s, ast.Raise) for s in body)


class ScheduleExtractor:
    """Interprocedural collective-sequence summaries over a module set."""

    def __init__(self, modules: dict[str, Module]):
        self.modules = modules
        self.syms: dict[str, SymbolTable] = {
            name: symbols(m) for name, m in modules.items()}
        self.funcs: dict[str, dict[str, ast.FunctionDef]] = {
            name: dict(iter_functions(m)) for name, m in modules.items()}
        self._memo: dict[tuple[str, str], list[Event]] = {}
        self._busy: set[tuple[str, str]] = set()
        self.findings: list[Finding] = []
        #: (module, qualname) of every callable handed to shard_map —
        #: shared with the TRACE rules (these run under tracing).
        self.shard_map_bodies: set[tuple[str, str]] = set()

    def emit(self, modname: str, finding: Finding) -> None:
        if not modname.startswith(SCHED_EXEMPT_PREFIXES):
            self.findings.append(finding)

    # -- public ------------------------------------------------------------
    def summarize(self, modname: str, qualname: str) -> list[Event]:
        key = (modname, qualname)
        if key in self._memo:
            return self._memo[key]
        if key in self._busy:
            node = self.funcs[modname][qualname]
            return [Event(RECURSION, self.modules[modname].rel, node.lineno)]
        self._busy.add(key)
        try:
            node = self.funcs[modname][qualname]
            walker = _FuncWalker(self, self.modules[modname], qualname)
            events = walker.stmts(node.body)
        finally:
            self._busy.discard(key)
        self._memo[key] = events
        return events

    def run(self) -> list[Finding]:
        for modname, funcs in self.funcs.items():
            for qualname in funcs:
                self.summarize(modname, qualname)
        self._crosscheck()
        return self.findings

    def stage_schedule(self, stage: str) -> list[Event] | None:
        modname, qual = STAGES[stage]
        if modname in self.funcs and qual in self.funcs.get(modname, {}):
            return self.summarize(modname, qual)
        return None

    # -- SCHED002 ----------------------------------------------------------
    def _crosscheck(self) -> None:
        sm1 = self.stage_schedule("SM1")
        sm2 = self.stage_schedule("SM2")
        if sm1 is None or sm2 is None:
            return  # not analyzing the real bsp package
        mod = self.modules[STAGES["SM1"][0]]

        def drift(stage, msg):
            node = self.funcs[STAGES[stage][0]][STAGES[stage][1]]
            self.findings.append(Finding(
                SCHED002, self.modules[STAGES[stage][0]].rel, node.lineno,
                f"[{stage}] {msg}"))

        expected = {"SM1": [LABEL_KINDS[s] for s in SM1_LABELS],
                    "SM2": [LABEL_KINDS[s] for s in SM2_LABELS]}
        for stage, seq in (("SM1", sm1), ("SM2", sm2)):
            got = list(kinds(seq))
            if got != expected[stage]:
                drift(stage,
                      f"static schedule {got} != counter contract "
                      f"{expected[stage]}")
        if len(sm1) != 11 or len(sm2) != 9:
            drift("SM1", f"SM1/SM2 superstep counts {len(sm1)}/{len(sm2)} "
                         f"!= pinned 11/9 (repro.bsp.counters contract)")
        exch = self.stage_schedule("exchange")
        if exch is not None and list(kinds(exch)) != ["all_to_all"] * 2:
            drift("SM1", f"exchange schedule {list(kinds(exch))} != two "
                         f"all_to_all hops")
        ps = self.stage_schedule("psort")
        if ps is not None and list(kinds(ps)) != [
                "all_gather", "all_to_all", "all_to_all",
                "all_gather", "all_to_all", "all_to_all"]:
            drift("SM1", f"psort_shard_body schedule {list(kinds(ps))} != "
                         f"the 6-collective Algorithm-2 contract")
        self._replay_check(drift, expected)

    def _replay_check(self, drift, expected) -> None:
        """Run estimate_costs' analytic replay; its label stream must map,
        label for label, onto the statically extracted kinds."""
        real = self.modules.get("repro.bsp.suffix_array")
        if real is None or "src/repro/bsp" not in real.rel:
            return
        import sys
        from .astutil import REPO
        src = str(REPO / "src")
        if src not in sys.path:
            sys.path.insert(0, src)
        try:
            from repro.bsp.suffix_array import estimate_costs
        except Exception as e:  # import env without jax etc.
            drift("SM1", f"could not import estimate_costs for replay: {e}")
            return
        ct = estimate_costs(3000, 8, base_threshold=64)
        labels = [e["label"] for e in ct.log]
        i, rounds = 0, 0
        while i < len(labels):
            lab = labels[i]
            if lab.startswith("SM1/") or lab.startswith("SM2/"):
                stage = lab[:3]
                want = SM1_LABELS if stage == "SM1" else SM2_LABELS
                chunk = labels[i:i + len(want)]
                suffixes = [c.split("/", 1)[1] if "/" in c else c
                            for c in chunk]
                if suffixes != want:
                    drift(stage, f"estimate_costs label run {chunk} != "
                                 f"static schedule labels {want}")
                    return
                if [LABEL_KINDS[s] for s in suffixes] != expected[stage]:
                    drift(stage, "estimate_costs labels map to kinds that "
                                 "differ from the static schedule")
                    return
                rounds += stage == "SM1"
                i += len(want)
            elif lab == "base/gather":
                i += 1
            else:
                drift("SM1", f"unknown counter label {lab!r} in replay")
                return
        if ct.supersteps != 20 * ct.rounds + 1 or ct.rounds != rounds:
            drift("SM1", f"replay S={ct.supersteps} rounds={ct.rounds} "
                         f"violates S = 20*rounds + 1")


class _FuncWalker:
    """Walks one function body, producing its collective event sequence."""

    def __init__(self, ex: ScheduleExtractor, mod: Module, qualname: str):
        self.ex = ex
        self.mod = mod
        self.qualname = qualname
        self.bindings: dict[str, tuple] = {}

    # -- resolution --------------------------------------------------------
    def resolve(self, node: ast.AST):
        """Resolve a callable expression to ("fn", mod, qual) /
        ("lambda", node) / None."""
        if isinstance(node, ast.Lambda):
            return ("lambda", node)
        if isinstance(node, ast.Name):
            if node.id in self.bindings:
                return self.bindings[node.id]
            # lexical scopes: innermost enclosing qualname prefix first
            parts = self.qualname.split(".")
            for depth in range(len(parts), -1, -1):
                cand = ".".join(parts[:depth] + [node.id])
                if cand in self.ex.funcs[self.mod.name]:
                    return ("fn", self.mod.name, cand)
            sym = self.ex.syms[self.mod.name]
            if node.id in sym.from_imports:
                m, a = sym.from_imports[node.id]
                if m in self.ex.funcs and a in self.ex.funcs[m]:
                    return ("fn", m, a)
            return None
        if isinstance(node, ast.Attribute):
            chain = attr_chain(node)
            if chain and len(chain) == 2:
                sym = self.ex.syms[self.mod.name]
                m = sym.mod_imports.get(chain[0])
                if m in self.ex.funcs and chain[1] in self.ex.funcs[m]:
                    return ("fn", m, chain[1])
            return None
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func) or []
            term = chain[-1] if chain else None
            if term in ("partial", "jit", "shard_map") and node.args:
                t = self.resolve(node.args[0])
                if term == "shard_map" and t and t[0] == "fn":
                    self.ex.shard_map_bodies.add((t[1], t[2]))
                return t
        return None

    def summary_of(self, target, line: int) -> list[Event]:
        if target is None:
            return []
        if target[0] == "lambda":
            return self.expr(target[1].body)
        return self.ex.summarize(target[1], target[2])

    # -- expressions -------------------------------------------------------
    def expr(self, node: ast.AST | None) -> list[Event]:
        if node is None or isinstance(node, (ast.Constant, ast.Name)):
            return []
        if isinstance(node, ast.Call):
            return self.call(node)
        if isinstance(node, ast.Lambda):
            return []          # deferred until called
        ev: list[Event] = []
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                ev += self.expr(child)
            elif isinstance(child, ast.comprehension):
                ev += self.expr(child.iter)
        return ev

    def _is_lax(self, node: ast.Call, chain: list[str] | None) -> bool:
        if chain and len(chain) >= 2:
            if "lax" in chain[:-1]:
                return True
        if isinstance(node.func, ast.Name):
            sym = self.ex.syms[self.mod.name]
            src = sym.from_imports.get(node.func.id, ("", ""))[0]
            return src in ("jax.lax", "jax")
        return False

    def call(self, node: ast.Call) -> list[Event]:
        chain = attr_chain(node.func)
        term = chain[-1] if chain else (
            node.func.id if isinstance(node.func, ast.Name) else None)
        lax = self._is_lax(node, chain)

        # lax control combinators: handle before generic arg visiting so
        # branch/body callables are not double-counted.
        if lax and term == "cond" and len(node.args) >= 3:
            return self._cond(node)
        if lax and term == "switch" and len(node.args) >= 2:
            return self._switch(node)
        if lax and term in ("fori_loop", "while_loop", "scan", "map",
                            "associative_scan"):
            return self._loop_combinator(node, term)

        # events hidden in the callee expression itself: method chains like
        # `jax.lax.all_gather(...).reshape(p)` put the collective inside
        # node.func.value, and `shard_map(body, ...)(xg)` puts the traced
        # body inside an inner Call.
        ev: list[Event] = []
        inner_target = None
        if isinstance(node.func, ast.Attribute):
            ev += self.expr(node.func.value)
        elif isinstance(node.func, ast.Call):
            inner_target = self.resolve(node.func)
            if inner_target is not None:
                ev += self.expr_call_args(node.func)
            else:
                ev += self.expr(node.func)
        for a in node.args:
            ev += self.expr(a)
        for kw in node.keywords:
            ev += self.expr(kw.value)

        if lax and term in COLLECTIVES:
            ev.append(Event(COLLECTIVES[term], self.mod.rel, node.lineno))
            return ev
        if inner_target is not None:
            return ev + self.summary_of(inner_target, node.lineno)
        target = self.resolve(node.func)
        if target is not None:
            ev += self.summary_of(target, node.lineno)
        return ev

    def expr_call_args(self, call: ast.Call) -> list[Event]:
        ev: list[Event] = []
        for a in call.args[1:]:       # args[0] is the resolved callable
            ev += self.expr(a)
        for kw in call.keywords:
            ev += self.expr(kw.value)
        return ev

    def _cond(self, node: ast.Call) -> list[Event]:
        ev = self.expr(node.args[0])
        for op in node.args[3:]:
            ev += self.expr(op)
        bt = self.summary_of(self.resolve(node.args[1]), node.lineno)
        bf = self.summary_of(self.resolve(node.args[2]), node.lineno)
        if kinds(bt) != kinds(bf):
            self.ex.emit(self.mod.name, Finding(
                SCHED003, self.mod.rel, node.lineno,
                f"lax.cond branches issue divergent collective sequences: "
                f"{list(kinds(bt))} vs {list(kinds(bf))}"))
        return ev + (bt if len(bt) >= len(bf) else bf)

    def _switch(self, node: ast.Call) -> list[Event]:
        ev = self.expr(node.args[0])
        for op in node.args[2:]:
            ev += self.expr(op)
        branches = node.args[1]
        sums: list[list[Event]] = []
        if isinstance(branches, (ast.List, ast.Tuple)):
            for b in branches.elts:
                sums.append(self.summary_of(self.resolve(b), node.lineno))
        if sums and any(kinds(s) != kinds(sums[0]) for s in sums[1:]):
            self.ex.emit(self.mod.name, Finding(
                SCHED003, self.mod.rel, node.lineno,
                f"lax.switch branches issue divergent collective sequences: "
                f"{[list(kinds(s)) for s in sums]}"))
        longest = max(sums, key=len) if sums else []
        return ev + longest

    def _loop_combinator(self, node: ast.Call, term: str) -> list[Event]:
        body_idx = {"fori_loop": [2], "while_loop": [0, 1], "scan": [0],
                    "map": [0], "associative_scan": [0]}[term]
        ev: list[Event] = []
        for i, a in enumerate(node.args):
            if i not in body_idx:
                ev += self.expr(a)
        body: list[Event] = []
        for i in body_idx:
            if i < len(node.args):
                body += self.summary_of(self.resolve(node.args[i]),
                                        node.lineno)
        if body:
            self.ex.emit(self.mod.name, Finding(
                SCHED004, self.mod.rel, node.lineno,
                f"collective sequence {list(kinds(body))} inside "
                f"lax.{term} body: superstep count leaves the static "
                f"schedule"))
        return ev + body

    # -- statements --------------------------------------------------------
    def stmts(self, body: list[ast.stmt]) -> list[Event]:
        ev: list[Event] = []
        for idx, st in enumerate(body):
            # early-exit conditional: `if pred: ...; return` makes the rest
            # of the block the implicit else branch — same divergence class
            # as an explicit if/else (the `rec` short-circuit shape).
            if isinstance(st, ast.If) and not st.orelse and st.body \
                    and isinstance(st.body[-1], (ast.Return, ast.Raise)):
                ev += self.expr(st.test)
                branch = self.stmts(st.body)
                rest = self.stmts(body[idx + 1:])
                real = any(e.kind != RECURSION for e in branch + rest)
                if real and not _terminates(st.body) \
                        and kinds(branch) != kinds(rest) \
                        and not is_structural(st.test):
                    self.ex.emit(self.mod.name, Finding(
                        SCHED001, self.mod.rel, st.lineno,
                        f"early return under `if {ast.unparse(st.test)}` "
                        f"diverges from the fall-through collective "
                        f"sequence: {list(kinds(branch))} vs "
                        f"{list(kinds(rest))}, and the predicate is not "
                        f"provably replica-uniform"))
                return ev + (branch if len(branch) >= len(rest) else rest)
            ev += self.stmt(st)
        return ev

    def stmt(self, st: ast.stmt) -> list[Event]:
        if isinstance(st, ast.If):
            return self._if(st)
        if isinstance(st, (ast.For, ast.AsyncFor)):
            ev = self.expr(st.iter)
            body = self.stmts(st.body) + self.stmts(st.orelse)
            if any(e.kind != RECURSION for e in body):
                self.ex.emit(self.mod.name, Finding(
                    SCHED004, self.mod.rel, st.lineno,
                    f"collective sequence {list(kinds(body))} inside a host "
                    f"for-loop: superstep count leaves the static schedule"))
            return ev + body
        if isinstance(st, ast.While):
            ev = self.expr(st.test)
            body = self.stmts(st.body) + self.stmts(st.orelse)
            if any(e.kind != RECURSION for e in body):
                self.ex.emit(self.mod.name, Finding(
                    SCHED004, self.mod.rel, st.lineno,
                    f"collective sequence {list(kinds(body))} inside a host "
                    f"while-loop: superstep count leaves the static "
                    f"schedule"))
            return ev + body
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.bindings[st.name] = (
                "fn", self.mod.name, f"{self.qualname}.{st.name}")
            return []
        if isinstance(st, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = st.value
            ev = self.expr(value)
            targets = getattr(st, "targets", None) or \
                ([st.target] if getattr(st, "target", None) else [])
            if value is not None and len(targets) == 1 \
                    and isinstance(targets[0], ast.Name):
                t = self.resolve(value)
                if t is not None:
                    self.bindings[targets[0].id] = t
            return ev
        if isinstance(st, ast.Return):
            return self.expr(st.value)
        if isinstance(st, ast.Expr):
            return self.expr(st.value)
        if isinstance(st, ast.With):
            ev = []
            for item in st.items:
                ev += self.expr(item.context_expr)
            return ev + self.stmts(st.body)
        if isinstance(st, ast.Try):
            ev = self.stmts(st.body)
            for h in st.handlers:
                ev += self.stmts(h.body)
            return ev + self.stmts(st.orelse) + self.stmts(st.finalbody)
        if isinstance(st, (ast.Raise, ast.Assert)):
            ev = self.expr(getattr(st, "exc", None) or
                           getattr(st, "test", None))
            return ev
        return []

    def _if(self, st: ast.If) -> list[Event]:
        ev = self.expr(st.test)
        body = self.stmts(st.body)
        orelse = self.stmts(st.orelse)
        if _terminates(st.body):
            return ev + orelse
        if _terminates(st.orelse):
            return ev + body
        if kinds(body) != kinds(orelse):
            if not is_structural(st.test):
                self.ex.emit(self.mod.name, Finding(
                    SCHED001, self.mod.rel, st.lineno,
                    f"branches of `if {ast.unparse(st.test)}` issue "
                    f"divergent collective sequences "
                    f"{list(kinds(body))} vs {list(kinds(orelse))} and the "
                    f"predicate is not provably replica-uniform"))
            return ev + (body if len(body) >= len(orelse) else orelse)
        return ev + body


def analyze(modules: dict[str, Module]) -> tuple[list[Finding],
                                                 ScheduleExtractor]:
    ex = ScheduleExtractor(modules)
    findings = ex.run()
    return findings, ex
