"""Shared AST machinery for saca-lint: module loading, name resolution.

Every rule family works on the same picture of the code: a registry of
parsed modules (keyed by dotted module name, derived from the repo
layout), a per-module symbol table (imports + top-level defs), and a
function index that also covers *nested* functions (``rec`` inside
`suffix_array_bsp`, ``fn`` inside `run_psort`) via dotted qualnames.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]


@dataclasses.dataclass
class Module:
    path: Path                       # absolute
    name: str                        # dotted module name (best effort)
    tree: ast.Module
    source: str

    @property
    def rel(self) -> str:
        """Repo-relative posix path (finding attribution)."""
        try:
            return self.path.relative_to(REPO).as_posix()
        except ValueError:
            return self.path.as_posix()


def module_name_for(path: Path) -> str:
    """Dotted module name from the repo layout (src/ is the import root)."""
    path = path.resolve()
    for root in (REPO / "src", REPO):
        try:
            rel = path.relative_to(root)
        except ValueError:
            continue
        parts = list(rel.with_suffix("").parts)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)
    return path.stem


def load_modules(paths) -> dict[str, Module]:
    """Parse every .py file under `paths` (files or directories)."""
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    out: dict[str, Module] = {}
    for f in files:
        src = f.read_text()
        mod = Module(path=f.resolve(), name=module_name_for(f),
                     tree=ast.parse(src, filename=str(f)), source=src)
        out[mod.name] = mod
    return out


@dataclasses.dataclass
class SymbolTable:
    """Per-module import aliases and top-level function defs."""

    #: local name -> (source module dotted name, attr) for `from X import Y`
    from_imports: dict[str, tuple[str, str]]
    #: local alias -> module dotted name for `import X [as Y]`
    mod_imports: dict[str, str]
    #: top-level function/class defs by name
    defs: dict[str, ast.AST]


def symbols(mod: Module) -> SymbolTable:
    from_imports: dict[str, tuple[str, str]] = {}
    mod_imports: dict[str, str] = {}
    defs: dict[str, ast.AST] = {}
    pkg_parts = mod.name.split(".")[:-1]
    for node in mod.tree.body:
        if isinstance(node, ast.ImportFrom):
            if node.level:  # relative import -> absolute, repo layout
                base = pkg_parts[: len(pkg_parts) - node.level + 1]
                src = ".".join(base + ([node.module] if node.module else []))
            else:
                src = node.module or ""
            for a in node.names:
                from_imports[a.asname or a.name] = (src, a.name)
        elif isinstance(node, ast.Import):
            for a in node.names:
                mod_imports[a.asname or a.name] = a.name
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            defs[node.name] = node
    return SymbolTable(from_imports, mod_imports, defs)


def iter_functions(mod: Module):
    """Yield (qualname, FunctionDef) for every function, nested included.

    Methods get ``Class.method`` qualnames; closures ``outer.inner``.
    """
    def walk(body, prefix):
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{node.name}"
                yield q, node
                yield from walk(node.body, q + ".")
            elif isinstance(node, ast.ClassDef):
                yield from walk(node.body, f"{prefix}{node.name}.")
            elif isinstance(node, (ast.If, ast.Try, ast.With, ast.For,
                                   ast.While)):
                for field in ("body", "orelse", "handlers", "finalbody"):
                    sub = getattr(node, field, None) or []
                    for h in sub:
                        if isinstance(h, ast.excepthandler):
                            yield from walk(h.body, prefix)
                    if sub and not isinstance(sub[0], ast.excepthandler):
                        yield from walk(sub, prefix)

    yield from walk(mod.tree.body, "")


def attr_chain(node: ast.AST) -> list[str] | None:
    """`jax.lax.all_to_all` -> ["jax", "lax", "all_to_all"]; None if not a
    pure Name/Attribute chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


def is_self_attr(node: ast.AST, attr: str | None = None) -> str | None:
    """Return the attribute name if `node` is ``self.X`` (optionally == attr)."""
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        if attr is None or node.attr == attr:
            return node.attr
    return None


def const_str_tuple(node: ast.AST) -> tuple[str, ...]:
    """Constant str or tuple/list of constant strs -> tuple of strings."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
        return tuple(out)
    return ()
