"""saca-lint rule framework: findings, registry, pragmas, baseline.

Suppression contract
--------------------
A finding is suppressed by an inline pragma **with a justification**::

    TRACE_COUNTS["k"] += 1  # saca-lint: allow[TRACE001] trace-time counter

The pragma may sit on the flagged line or on a comment line directly
above it. A pragma without justification text does NOT suppress — the
finding stays active and gains a note; this is what makes every
suppression "individually justified" checkable by machine.

Baseline
--------
`tools/saca_lint/baseline.txt` holds one finding key per line
(`path:rule:line`). Findings in the baseline are reported as grandfathered
and do not fail `--check`; `--strict` (nightly) fails on any non-empty
baseline and on stale pragmas, so suppressions can't rot silently.
"""
from __future__ import annotations

import dataclasses
import re
from pathlib import Path

from .astutil import REPO, Module

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.txt"

PRAGMA_RE = re.compile(
    r"#\s*saca-lint:\s*allow\[([A-Z]+\d+(?:\s*,\s*[A-Z]+\d+)*)\]\s*(.*)")


@dataclasses.dataclass(frozen=True)
class RuleInfo:
    rule_id: str
    name: str
    summary: str


#: rule_id -> RuleInfo; populated by the rule modules at import time.
RULES: dict[str, RuleInfo] = {}


def rule(rule_id: str, name: str, summary: str) -> str:
    RULES[rule_id] = RuleInfo(rule_id, name, summary)
    return rule_id


LINT001 = rule(
    "LINT001", "stale-suppression",
    "a `saca-lint: allow[...]` pragma that no current finding matches — "
    "the violation it excused is gone; delete the pragma")


@dataclasses.dataclass
class Finding:
    rule_id: str
    path: str            # repo-relative posix path
    line: int
    message: str
    suppressed: bool = False
    justification: str = ""
    baselined: bool = False

    @property
    def key(self) -> str:
        return f"{self.path}:{self.rule_id}:{self.line}"

    def render(self) -> str:
        tag = ""
        if self.suppressed:
            tag = f"  [suppressed: {self.justification}]"
        elif self.baselined:
            tag = "  [baselined]"
        return f"{self.path}:{self.line}: {self.rule_id} {self.message}{tag}"


@dataclasses.dataclass(frozen=True)
class Pragma:
    path: str
    line: int            # line the pragma applies to (not where it sits)
    rules: tuple[str, ...]
    justification: str
    pragma_line: int     # where the comment physically is


def scan_pragmas(mod: Module) -> list[Pragma]:
    """Collect pragmas; a comment-only pragma line covers the next line."""
    out: list[Pragma] = []
    lines = mod.source.splitlines()
    for i, text in enumerate(lines, start=1):
        m = PRAGMA_RE.search(text)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(","))
        just = m.group(2).strip()
        target = i
        if text.lstrip().startswith("#"):
            # standalone comment: applies to the next source line (blank
            # and further comment lines skipped, so a pragma can sit atop
            # or inside an explanatory comment block)
            j = i
            while j < len(lines) and (not lines[j].strip()
                                      or lines[j].lstrip().startswith("#")):
                j += 1
            target = j + 1
        out.append(Pragma(path=mod.rel, line=target, rules=rules,
                          justification=just, pragma_line=i))
    return out


def apply_pragmas(findings: list[Finding], pragmas: list[Pragma]
                  ) -> tuple[list[Pragma], list[Finding]]:
    """Mark findings suppressed in place.

    Returns (stale_pragmas, unjustified) — pragmas that matched nothing,
    and findings whose pragma carried no justification text.
    """
    by_site: dict[tuple[str, int], list[Pragma]] = {}
    for p in pragmas:
        by_site.setdefault((p.path, p.line), []).append(p)
    used: set[Pragma] = set()
    unjustified: list[Finding] = []
    for f in findings:
        for p in by_site.get((f.path, f.line), []):
            if f.rule_id not in p.rules:
                continue
            used.add(p)
            if p.justification:
                f.suppressed = True
                f.justification = p.justification
            else:
                f.message += "  (pragma present but missing justification)"
                unjustified.append(f)
    stale = [p for p in pragmas if p not in used]
    return stale, unjustified


def load_baseline(path: Path) -> set[str]:
    if not path.exists():
        return set()
    keys = set()
    for line in path.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            keys.add(line)
    return keys


def write_baseline(path: Path, findings: list[Finding]) -> None:
    active = sorted(f.key for f in findings if not f.suppressed)
    header = ("# saca-lint baseline — grandfathered findings (path:rule:line).\n"
              "# Keep this file EMPTY: fix or pragma-suppress findings instead.\n")
    path.write_text(header + "".join(k + "\n" for k in active))


def rel_to_repo(path: Path) -> str:
    try:
        return path.resolve().relative_to(REPO).as_posix()
    except ValueError:
        return path.as_posix()
