"""THREAD rules: serve-tier thread-safety over lock-owning classes.

The model is deliberately shaped like `SAServer`: a class owns
`threading` lock attributes, spawns daemon threads with
``threading.Thread(target=self._method)``, and shares plain attributes
between those threads and its public (caller-thread) API.

Analysis per class:

* **Execution contexts.** Each thread entry method is its own context;
  methods reachable only from an entry inherit its context; everything
  else (public API, dunder hooks) runs in the caller context. An
  attribute is *shared* when its accesses span ≥ 2 contexts.
* **Lock inheritance.** A helper whose every call site sits inside
  ``with self.<lock>`` is lock-inherited (`_shed_locked` /
  `_oldest_age_us` in the serve tier); its accesses count as locked.
* **THREAD001** — a shared attribute is written/mutated outside the
  lock. `__init__` is exempt (no threads yet); attributes holding
  thread-safe types (`queue.Queue`, `threading.*`, `itertools.count`)
  are exempt; objects with their own internal lock (e.g. `ServeMetrics`)
  are accessed through methods, which read-only attribute access
  doesn't flag.
* **THREAD002** — condition discipline: ``cond.wait()`` with no
  enclosing retest loop anywhere in the method (a woken waiter must
  re-check its predicate), or ``notify``/``notify_all`` outside the
  lock (undefined behaviour per the stdlib contract).
* **THREAD003** — in a lock-owning class, a container-typed attribute
  (deque/dict/list/set) is structurally mutated (append/popleft/
  setitem/...) outside the lock — flagged regardless of context
  analysis, because container mutation is never atomic enough to
  reason away.
"""
from __future__ import annotations

import ast
import dataclasses

from .astutil import Module, attr_chain
from .framework import Finding, rule

THREAD001 = rule(
    "THREAD001", "unlocked-cross-thread-write",
    "attribute shared across thread contexts is written without holding "
    "the class lock")
THREAD002 = rule(
    "THREAD002", "condition-discipline",
    "cond.wait() without an enclosing retest loop, or notify/notify_all "
    "outside the lock")
THREAD003 = rule(
    "THREAD003", "unlocked-container-mutation",
    "container attribute of a lock-owning class mutated outside the lock")

LOCK_CONSTRUCTORS = {"Lock", "RLock", "Condition", "Semaphore",
                     "BoundedSemaphore"}
SAFE_CONSTRUCTORS = {"Queue", "SimpleQueue", "LifoQueue", "PriorityQueue",
                     "count", "Event", "local", "Barrier"}
CONTAINER_CONSTRUCTORS = {"deque", "dict", "list", "set", "OrderedDict",
                          "defaultdict", "Counter"}
MUTATORS = {"append", "appendleft", "extend", "extendleft", "insert",
            "pop", "popleft", "remove", "clear", "add", "discard",
            "update", "setdefault"}
EXEMPT_METHODS = {"__init__", "__post_init__"}


@dataclasses.dataclass
class Access:
    attr: str
    kind: str        # "read" | "write" | "mutate"
    line: int
    locked: bool
    method: str


@dataclasses.dataclass
class CondCall:
    lock_attr: str
    op: str          # "wait" | "notify" | "notify_all"
    line: int
    locked: bool
    in_loop: bool
    method: str


@dataclasses.dataclass
class MethodCall:
    callee: str
    locked: bool
    method: str


class _MethodScanner(ast.NodeVisitor):
    """One pass over a method body, tracking lock regions and loops."""

    def __init__(self, cls: "_ClassInfo", method: str):
        self.cls = cls
        self.method = method
        self.locked = False
        self.loop_depth = 0

    # -- lock regions ------------------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        entered = False
        for item in node.items:
            ctx = item.context_expr
            if isinstance(ctx, ast.Attribute) and \
                    isinstance(ctx.value, ast.Name) and \
                    ctx.value.id == "self" and ctx.attr in self.cls.locks:
                entered = True
            self.visit(ctx)
        was = self.locked
        self.locked = was or entered
        for st in node.body:
            self.visit(st)
        self.locked = was

    def visit_While(self, node: ast.While) -> None:
        self.visit(node.test)
        self.loop_depth += 1
        for st in node.body + node.orelse:
            self.visit(st)
        self.loop_depth -= 1

    def visit_For(self, node: ast.For) -> None:
        self.visit(node.iter)
        self.loop_depth += 1
        for st in node.body + node.orelse:
            self.visit(st)
        self.loop_depth -= 1

    def visit_FunctionDef(self, node) -> None:
        pass                           # nested defs: separate concern

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node) -> None:
        pass

    # -- accesses ----------------------------------------------------------
    def _self_attr(self, node: ast.AST) -> str | None:
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and node.value.id == "self":
            return node.attr
        return None

    def _record(self, attr: str, kind: str, line: int) -> None:
        self.cls.accesses.append(Access(attr, kind, line, self.locked,
                                        self.method))

    def _record_targets(self, target: ast.AST, line: int) -> None:
        attr = self._self_attr(target)
        if attr is not None:
            self._record(attr, "write", line)
            return
        if isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._record_targets(e, line)
        elif isinstance(target, ast.Subscript):
            attr = self._self_attr(target.value)
            if attr is not None:
                self._record(attr, "mutate", line)
            else:
                self.visit(target.value)
            self.visit(target.slice)
        elif isinstance(target, (ast.Attribute, ast.Starred)):
            self.visit(target)

    def visit_Assign(self, node: ast.Assign) -> None:
        self.visit(node.value)
        for t in node.targets:
            self._record_targets(t, node.lineno)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self.visit(node.value)
            self._record_targets(node.target, node.lineno)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.visit(node.value)
        attr = self._self_attr(node.target)
        if attr is not None:
            self._record(attr, "write", node.lineno)
        else:
            self._record_targets(node.target, node.lineno)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = self._self_attr(node)
        if attr is not None:
            self._record(attr, "read", node.lineno)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Attribute):
            owner = self._self_attr(func.value)
            if owner is not None:
                if owner in self.cls.locks and func.attr in (
                        "wait", "wait_for", "notify", "notify_all"):
                    self.cls.cond_calls.append(CondCall(
                        owner, func.attr, node.lineno, self.locked,
                        self.loop_depth > 0, self.method))
                elif func.attr in MUTATORS:
                    self._record(owner, "mutate", node.lineno)
                else:
                    self._record(owner, "read", node.lineno)
                for a in node.args:
                    self.visit(a)
                for kw in node.keywords:
                    self.visit(kw.value)
                return
            callee = self._self_attr(func)
            if callee is not None and callee in self.cls.methods:
                self.cls.calls.append(MethodCall(callee, self.locked,
                                                 self.method))
        # Thread(target=self._x) discovery
        chain = attr_chain(func) or []
        if chain and chain[-1] == "Thread":
            for kw in node.keywords:
                if kw.arg == "target":
                    t = self._self_attr(kw.value)
                    if t is not None:
                        self.cls.entries.add(t)
        self.generic_visit(node)


class _ClassInfo:
    def __init__(self, mod: Module, node: ast.ClassDef):
        self.mod = mod
        self.node = node
        self.methods: dict[str, ast.FunctionDef] = {
            n.name: n for n in node.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        self.locks: set[str] = set()
        self.cond_locks: set[str] = set()
        self.safe: set[str] = set()
        self.containers: set[str] = set()
        self.accesses: list[Access] = []
        self.cond_calls: list[CondCall] = []
        self.calls: list[MethodCall] = []
        self.entries: set[str] = set()
        self._classify_attrs()

    def _classify_attrs(self) -> None:
        init = self.methods.get("__init__")
        bodies = ([init] if init else []) + [None]
        for holder in bodies:
            stmts = holder.body if holder else self.node.body
            for st in ast.walk(ast.Module(body=stmts, type_ignores=[])):
                value = None
                target = None
                if isinstance(st, ast.Assign) and len(st.targets) == 1:
                    target, value = st.targets[0], st.value
                elif isinstance(st, ast.AnnAssign) and st.value is not None:
                    target, value = st.target, st.value
                if value is None:
                    continue
                attr = None
                if isinstance(target, ast.Attribute) and \
                        isinstance(target.value, ast.Name) and \
                        target.value.id == "self":
                    attr = target.attr
                elif holder is None and isinstance(target, ast.Name):
                    attr = target.id       # class-level attribute
                if attr is None:
                    continue
                ann = getattr(st, "annotation", None)
                names = []
                if isinstance(value, ast.Call):
                    names = attr_chain(value.func) or []
                ann_names = (attr_chain(ann) or []) if ann is not None else []
                term = names[-1] if names else None
                if term in LOCK_CONSTRUCTORS:
                    self.locks.add(attr)
                    if term == "Condition":
                        self.cond_locks.add(attr)
                elif term in SAFE_CONSTRUCTORS:
                    self.safe.add(attr)
                elif term in CONTAINER_CONSTRUCTORS \
                        or (ann_names and ann_names[-1] in
                            CONTAINER_CONSTRUCTORS) \
                        or isinstance(value, (ast.Dict, ast.List, ast.Set,
                                              ast.DictComp, ast.ListComp,
                                              ast.SetComp)):
                    self.containers.add(attr)

    def scan(self) -> None:
        for name, fn in self.methods.items():
            scanner = _MethodScanner(self, name)
            for st in fn.body:
                scanner.visit(st)

    # -- derived relations -------------------------------------------------
    def lock_inherited(self) -> set[str]:
        """Methods whose every call site is inside the lock (fixpoint)."""
        sites: dict[str, list[MethodCall]] = {}
        for c in self.calls:
            sites.setdefault(c.callee, []).append(c)
        inherited: set[str] = set()
        changed = True
        while changed:
            changed = False
            for m, calls in sites.items():
                if m in inherited:
                    continue
                if all(c.locked or c.method in inherited for c in calls):
                    inherited.add(m)
                    changed = True
        return inherited

    def contexts(self) -> dict[str, frozenset]:
        """method -> execution contexts ("caller" or entry-method names)."""
        callers: dict[str, set[str]] = {}
        for c in self.calls:
            callers.setdefault(c.callee, set()).add(c.method)
        ctx: dict[str, set] = {m: set() for m in self.methods}
        for e in self.entries:
            if e in ctx:
                ctx[e].add(e)
        for _ in range(len(self.methods) + 1):
            changed = False
            for m in self.methods:
                base = set(ctx[m])
                for caller in callers.get(m, ()):  # inherit callers' ctx
                    base |= ctx.get(caller, set())
                if m not in self.entries and m not in callers:
                    base.add("caller")
                # a method with internal callers may also be public API,
                # but treating it as internal-only keeps the rule focused
                # on provable cross-thread pairs.
                if base != ctx[m]:
                    ctx[m] = base
                    changed = True
            if not changed:
                break
        for m in ctx:
            if not ctx[m]:
                ctx[m] = {"caller"}
        return {m: frozenset(s) for m, s in ctx.items()}


def analyze(modules: dict[str, Module]) -> list[Finding]:
    findings: list[Finding] = []
    for mod in modules.values():
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                cls = _ClassInfo(mod, node)
                if not cls.locks:
                    continue           # lock-free classes are out of scope
                cls.scan()
                findings += _check_class(cls)
    return findings


def _check_class(cls: _ClassInfo) -> list[Finding]:
    out: list[Finding] = []
    inherited = cls.lock_inherited()
    ctx = cls.contexts()

    def eff_locked(a: Access) -> bool:
        return a.locked or a.method in inherited

    # THREAD001: shared attribute written outside the lock
    by_attr: dict[str, list[Access]] = {}
    for a in cls.accesses:
        if a.method in EXEMPT_METHODS:
            continue
        if a.attr in cls.locks or a.attr in cls.safe:
            continue
        by_attr.setdefault(a.attr, []).append(a)
    for attr, accs in sorted(by_attr.items()):
        ctxs = set()
        for a in accs:
            ctxs |= ctx[a.method]
        if len(ctxs) < 2:
            continue
        for a in accs:
            if a.kind == "mutate" and a.attr in cls.containers:
                continue               # THREAD003's domain
            if a.kind in ("write", "mutate") and not eff_locked(a):
                out.append(Finding(
                    THREAD001, cls.mod.rel, a.line,
                    f"`{cls.node.name}.{attr}` is shared across thread "
                    f"contexts {sorted(ctxs)} but written in "
                    f"`{a.method}` without holding the lock"))

    # THREAD002: condition discipline
    for c in cls.cond_calls:
        if c.op in ("wait",) and not c.in_loop:
            out.append(Finding(
                THREAD002, cls.mod.rel, c.line,
                f"`self.{c.lock_attr}.wait()` in `{c.method}` has no "
                f"enclosing retest loop — a woken waiter must re-check "
                f"its predicate"))
        if c.op in ("notify", "notify_all") and not (
                c.locked or c.method in inherited):
            out.append(Finding(
                THREAD002, cls.mod.rel, c.line,
                f"`self.{c.lock_attr}.{c.op}()` in `{c.method}` outside "
                f"`with self.{c.lock_attr}` — undefined per the stdlib "
                f"Condition contract"))

    # THREAD003: container mutation outside the lock
    for a in cls.accesses:
        if a.method in EXEMPT_METHODS or a.attr not in cls.containers:
            continue
        if a.kind == "mutate" and not eff_locked(a):
            out.append(Finding(
                THREAD003, cls.mod.rel, a.line,
                f"container `{cls.node.name}.{a.attr}` mutated in "
                f"`{a.method}` outside the lock"))
    return out
