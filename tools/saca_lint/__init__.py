"""saca-lint — static analysis for the BSP/JAX/serve layers.

Three rule families over `src/repro/`:

* **SCHED** (`collectives.py`) — static collective-schedule extraction
  over the BSP stages, divergence detection across host and traced
  branches, and a drift check pinning source ⇔ `BSPCounters` contract
  ⇔ `estimate_costs` replay together.
* **TRACE** (`tracing.py`) — JAX trace hygiene in jitted regions:
  mutable-global closure, host syncs on traced values, traced params
  steering host control flow.
* **THREAD** (`threading_rules.py`) — serve-tier thread safety:
  cross-thread writes outside the lock, condition discipline,
  container mutation outside the lock.

Usage: ``python -m tools.saca_lint --check`` (see `__main__.py`).
Suppressions: ``# saca-lint: allow[RULE] <justification>`` — the
justification text is mandatory. Baseline: `tools/saca_lint/baseline.txt`
(kept empty; `--strict` fails if it is not).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

from . import collectives, threading_rules, tracing  # register rules
from .astutil import REPO, Module, load_modules
from .framework import (DEFAULT_BASELINE, LINT001, RULES, Finding, Pragma,
                        apply_pragmas, load_baseline, scan_pragmas,
                        write_baseline)

DEFAULT_PATHS = (REPO / "src" / "repro",)


@dataclasses.dataclass
class Report:
    findings: list[Finding]
    stale_pragmas: list[Pragma]
    extractor: "collectives.ScheduleExtractor"
    modules: dict[str, Module]

    @property
    def active(self) -> list[Finding]:
        return [f for f in self.findings
                if not f.suppressed and not f.baselined]

    @property
    def suppressed(self) -> list[Finding]:
        return [f for f in self.findings if f.suppressed]

    @property
    def baselined(self) -> list[Finding]:
        return [f for f in self.findings if f.baselined]


def run(paths=None, baseline_path: Path | None = None) -> Report:
    """Lint `paths` (files or directories; default src/repro)."""
    paths = [Path(p) for p in (paths or DEFAULT_PATHS)]
    modules = load_modules(paths)
    sched, extractor = collectives.analyze(modules)
    findings = list(sched)
    findings += tracing.analyze(modules, extractor.shard_map_bodies)
    findings += threading_rules.analyze(modules)

    pragmas: list[Pragma] = []
    for mod in modules.values():
        pragmas += scan_pragmas(mod)
    stale, _unjustified = apply_pragmas(findings, pragmas)

    baseline = load_baseline(baseline_path or DEFAULT_BASELINE)
    for f in findings:
        if not f.suppressed and f.key in baseline:
            f.baselined = True
    findings.sort(key=lambda f: (f.path, f.line, f.rule_id))
    return Report(findings=findings, stale_pragmas=stale,
                  extractor=extractor, modules=modules)


__all__ = ["run", "Report", "Finding", "RULES", "LINT001",
           "DEFAULT_BASELINE", "DEFAULT_PATHS", "write_baseline"]
