"""Repo tooling (`tools.check_docs`, `tools.saca_lint`)."""
