#!/usr/bin/env python
"""Executable-docs checker: run every python code block in README.md and
docs/*.md, and fail on broken cross-references to repo modules.

Two passes over each markdown file:

1. **Code blocks.** Every fenced ```python block is executed in its own
   namespace (doctest-style: blocks must be self-contained, and they are
   written that way on purpose — CI guarantees the docs never rot).
   Fenced ```bash blocks are NOT executed, but any `python -m <module>`
   they mention must at least be importable.
2. **Cross-references.** Every `repro.*` dotted path in backtick code
   spans must resolve to an importable module / attribute, every
   `src/...`, `docs/...`, `tests/...`, `benchmarks/...`, `examples/...`
   path mentioned must exist on disk, and every relative markdown link
   must point at an existing file.

Usage:  PYTHONPATH=src python tools/check_docs.py [files...]
Exit status 0 = all good, 1 = at least one failure (listed on stderr).
"""
from __future__ import annotations

import importlib
import re
import sys
import traceback
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
# blocks and xrefs assume the repo layout: repo root (benchmarks/, tools/)
# and src/ (repro) importable regardless of the caller's cwd.
for _p in (str(REPO), str(REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

FENCE_RE = re.compile(r"^```(\w*)\s*$")
#: dotted repro paths inside `backticks` (optionally with a call/member tail)
XREF_RE = re.compile(r"`(repro(?:\.\w+)+)")
#: repo-relative file paths inside backticks
PATH_RE = re.compile(
    r"`((?:src|docs|tests|benchmarks|examples|tools)/[\w./\-]+)`")
#: relative markdown links [text](target) — skip URLs and anchors
LINK_RE = re.compile(r"\]\((?!https?://|#)([^)#]+)(?:#[^)]*)?\)")
#: `python -m <module>` invocations in bash blocks
PYMOD_RE = re.compile(r"python\s+-m\s+([\w.]+)")


def iter_blocks(text: str):
    """Yield (language, first_line_number, source) for each fenced block."""
    lang, buf, start = None, [], 0
    for i, line in enumerate(text.splitlines(), 1):
        m = FENCE_RE.match(line.strip())
        if m and lang is None:
            lang, buf, start = m.group(1) or "text", [], i + 1
        elif line.strip() == "```" and lang is not None:
            yield lang, start, "\n".join(buf)
            lang = None
        elif lang is not None:
            buf.append(line)


def resolve_xref(dotted: str) -> bool:
    """True iff `dotted` names an importable module or module attribute."""
    parts = dotted.split(".")
    for cut in range(len(parts), 0, -1):
        modname = ".".join(parts[:cut])
        try:
            obj = importlib.import_module(modname)
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


def check_file(path: Path) -> list[str]:
    errors = []
    text = path.read_text()
    rel = path.relative_to(REPO)

    for lang, line, src in iter_blocks(text):
        if lang == "python":
            ns = {"__name__": f"docblock:{rel}:{line}"}
            try:
                exec(compile(src, f"{rel}:{line}", "exec"), ns)  # noqa: S102
            except Exception:
                tb = traceback.format_exc(limit=2)
                errors.append(f"{rel}:{line}: python block failed:\n{tb}")
        elif lang in ("bash", "sh", "shell"):
            for mod in PYMOD_RE.findall(src):
                try:
                    found = importlib.util.find_spec(mod) is not None
                except (ImportError, ModuleNotFoundError):
                    found = False
                if not found and not resolve_xref(mod):
                    errors.append(f"{rel}:{line}: bash block references "
                                  f"unimportable module {mod!r}")

    # cross-references outside code blocks too (tables, prose)
    for dotted in sorted(set(XREF_RE.findall(text))):
        if not resolve_xref(dotted):
            errors.append(f"{rel}: broken module reference `{dotted}`")
    for p in sorted(set(PATH_RE.findall(text))):
        target = REPO / p
        if not target.exists() and not list(REPO.glob(p)):
            errors.append(f"{rel}: broken path reference `{p}`")
    for link in sorted(set(LINK_RE.findall(text))):
        if not (path.parent / link).exists():
            errors.append(f"{rel}: broken markdown link `{link}`")
    return errors


def check_rule_catalogue() -> list[str]:
    """Every shipped saca-lint rule ID must appear in the rule catalogue
    (docs/static_analysis.md) — a rule without documentation is a finding
    nobody can act on."""
    catalogue = REPO / "docs" / "static_analysis.md"
    if not catalogue.exists():
        return ["docs/static_analysis.md: missing (saca-lint rule catalogue)"]
    text = catalogue.read_text()
    from tools.saca_lint import RULES
    return [f"docs/static_analysis.md: shipped rule {rid} "
            f"({info.name}) is not documented in the catalogue"
            for rid, info in sorted(RULES.items()) if rid not in text]


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    files = ([Path(a) for a in args] if args else
             [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))])
    all_errors = []
    for f in files:
        errs = check_file(f)
        blocks = sum(1 for lang, _, _ in iter_blocks(f.read_text())
                     if lang == "python")
        status = "FAIL" if errs else "ok"
        print(f"[{status}] {f.relative_to(REPO)} ({blocks} python blocks)")
        all_errors += errs
    rule_errs = check_rule_catalogue()
    print(f"[{'FAIL' if rule_errs else 'ok'}] saca-lint rule catalogue")
    all_errors += rule_errs
    for e in all_errors:
        print(e, file=sys.stderr)
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main())
